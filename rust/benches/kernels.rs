//! Kernel-level benches: the rust-native hot-path ops vs their
//! Pallas-lowered HLO twins (the ablation DESIGN.md §8 calls for), plus
//! the all-reduce implementations at paper scale — and the
//! scalar-vs-dispatched comparison for every `tensor::simd` kernel,
//! written to `BENCH_kernels.json` (`just bench-kernels`).
//!
//! ```bash
//! cargo bench --bench kernels
//! ```

use elastic_gossip::benchkit::{bench, print_comparison};
use elastic_gossip::collective::AllReduceImpl;
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::manifest::json::{self, Json, JsonObj};
use elastic_gossip::optim::{LrSchedule, OptimKind, Optimizer};
use elastic_gossip::runtime::KernelEngine;
use elastic_gossip::tensor;
use elastic_gossip::tensor::simd;
use elastic_gossip::util::rng::Rng;

/// One scalar-vs-dispatched measurement for `BENCH_kernels.json`.
struct DispatchEntry {
    kernel: &'static str,
    n: usize,
    scalar_ns: f64,
    dispatched_ns: f64,
    bytes_touched: f64,
}

/// Bench every `tensor::simd` kernel twice — through the runtime
/// dispatcher (AVX2 / NEON when the host has them, scalar otherwise)
/// and through the public `*_scalar` reference — on identical buffers.
/// Under `EG_FORCE_SCALAR=1` both columns take the scalar path and the
/// speedup collapses to ~1.0x, which is itself the escape hatch's
/// correctness signal.
fn bench_dispatch(entries: &mut Vec<DispatchEntry>) {
    let n = 262_144usize;
    let mut rng = Rng::new(0x51D);
    let a: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    println!(
        "== tensor::simd kernels: dispatched ({}) vs scalar reference, n={n} ==",
        simd::active_name()
    );

    let mut push = |kernel: &'static str,
                    bytes_touched: f64,
                    s_disp: elastic_gossip::benchkit::Stats,
                    s_scal: elastic_gossip::benchkit::Stats,
                    entries: &mut Vec<DispatchEntry>| {
        print_comparison(kernel, &[s_scal.clone(), s_disp.clone()]);
        println!(
            "  dispatched bandwidth: {:.2} GB/s",
            bytes_touched / s_disp.median_s / 1e9
        );
        entries.push(DispatchEntry {
            kernel,
            n,
            scalar_ns: s_scal.median_s * 1e9,
            dispatched_ns: s_disp.median_s * 1e9,
            bytes_touched,
        });
    };

    {
        let mut d1 = a.clone();
        let mut d2 = a.clone();
        let s_disp = bench("sub_scaled_diff/dispatched", || {
            simd::sub_scaled_diff(&mut d1, &a, &b, 0.5);
            std::hint::black_box(&d1);
        });
        let s_scal = bench("sub_scaled_diff/scalar", || {
            simd::sub_scaled_diff_scalar(&mut d2, &a, &b, 0.5);
            std::hint::black_box(&d2);
        });
        push("sub_scaled_diff", (4 * n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        let mut d1 = a.clone();
        let mut d2 = a.clone();
        let s_disp = bench("average/dispatched", || {
            simd::average(&mut d1, &a, &b);
            std::hint::black_box(&d1);
        });
        let s_scal = bench("average/scalar", || {
            simd::average_scalar(&mut d2, &a, &b);
            std::hint::black_box(&d2);
        });
        push("average", (3 * n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        let mut d1 = a.clone();
        let mut d2 = a.clone();
        let s_disp = bench("add_assign/dispatched", || {
            simd::add_assign(&mut d1, &b);
            std::hint::black_box(&d1);
        });
        let s_scal = bench("add_assign/scalar", || {
            simd::add_assign_scalar(&mut d2, &b);
            std::hint::black_box(&d2);
        });
        push("add_assign", (3 * n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        let mut acc1 = vec![0.0f64; n];
        let mut acc2 = vec![0.0f64; n];
        let s_disp = bench("wacc_add/dispatched", || {
            simd::wacc_add(&mut acc1, &a, 0.25);
            std::hint::black_box(&acc1);
        });
        let s_scal = bench("wacc_add/scalar", || {
            simd::wacc_add_scalar(&mut acc2, &a, 0.25);
            std::hint::black_box(&acc2);
        });
        push("wacc_add", (n * 4 + 2 * n * 8) as f64, s_disp, s_scal, entries);

        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; n];
        let s_disp = bench("store_scaled/dispatched", || {
            simd::store_scaled(&mut d1, &acc1, 0.125);
            std::hint::black_box(&d1);
        });
        let s_scal = bench("store_scaled/scalar", || {
            simd::store_scaled_scalar(&mut d2, &acc2, 0.125);
            std::hint::black_box(&d2);
        });
        push("store_scaled", (n * 8 + n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        let s_disp = bench("minmax/dispatched", || {
            std::hint::black_box(simd::minmax(&a));
        });
        let s_scal = bench("minmax/scalar", || {
            std::hint::black_box(simd::minmax_scalar(&a));
        });
        push("minmax", (n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        let (lo, hi) = simd::minmax_scalar(&a);
        let inv = 255.0 / (hi - lo);
        let scale = (hi - lo) / 255.0;
        let mut c1 = vec![0u8; n];
        let mut c2 = vec![0u8; n];
        let s_disp = bench("quant_codes/dispatched", || {
            simd::quant_codes(&a, lo, inv, 255, &mut c1);
            std::hint::black_box(&c1);
        });
        let s_scal = bench("quant_codes/scalar", || {
            simd::quant_codes_scalar(&a, lo, inv, 255, &mut c2);
            std::hint::black_box(&c2);
        });
        push("quant_codes", (n * 4 + n) as f64, s_disp, s_scal, entries);

        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; n];
        let s_disp = bench("dequant_codes/dispatched", || {
            simd::dequant_codes(&c1, lo, scale, &mut d1);
            std::hint::black_box(&d1);
        });
        let s_scal = bench("dequant_codes/scalar", || {
            simd::dequant_codes_scalar(&c2, lo, scale, &mut d2);
            std::hint::black_box(&d2);
        });
        push("dequant_codes", (n + n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        // the identity-codec byte paths: bulk LE serialization both ways;
        // the "scalar" column is the byte-wise semantic reference
        let mut wire1: Vec<u8> = Vec::with_capacity(4 * n);
        let s_disp = bench("f32s_to_le_bytes/dispatched", || {
            simd::f32s_to_le_bytes(&a, &mut wire1);
            std::hint::black_box(&wire1);
        });
        let mut wire2: Vec<u8> = Vec::with_capacity(4 * n);
        let s_scal = bench("f32s_to_le_bytes/byte-wise", || {
            wire2.clear();
            for &x in &a {
                wire2.extend_from_slice(&x.to_le_bytes());
            }
            std::hint::black_box(&wire2);
        });
        push("f32s_to_le_bytes", (2 * n * 4) as f64, s_disp, s_scal, entries);

        let mut d1 = vec![0.0f32; n];
        let s_disp = bench("le_bytes_to_f32s/dispatched", || {
            simd::le_bytes_to_f32s(&wire1, &mut d1);
            std::hint::black_box(&d1);
        });
        let mut d2 = vec![0.0f32; n];
        let s_scal = bench("le_bytes_to_f32s/byte-wise", || {
            for (x, chunk) in d2.iter_mut().zip(wire2.chunks_exact(4)) {
                *x = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            std::hint::black_box(&d2);
        });
        push("le_bytes_to_f32s", (2 * n * 4) as f64, s_disp, s_scal, entries);
    }
    {
        // sub-byte codec at the paper MLP size: the end-to-end q4
        // encode (minmax + quant + nibble pack) and decode per message
        use elastic_gossip::comm::codec::Q4_DEFAULT_CHUNK;
        let paper_n = 2_913_290usize;
        let src: Vec<f32> = (0..paper_n).map(|_| rng.gauss_f32()).collect();
        let enc_len = tensor::q4_encoded_len(paper_n, Q4_DEFAULT_CHUNK);
        let mut wire: Vec<u8> = Vec::with_capacity(enc_len);
        let s_enc = bench("quantize_q4/paper-MLP", || {
            tensor::quantize_q4_into(&src, Q4_DEFAULT_CHUNK, &mut wire);
            std::hint::black_box(&wire);
        });
        let mut back = vec![0.0f32; paper_n];
        let s_dec = bench("dequantize_q4/paper-MLP", || {
            tensor::dequantize_q4_into(&wire, Q4_DEFAULT_CHUNK, &mut back).unwrap();
            std::hint::black_box(&back);
        });
        print_comparison(
            &format!(
                "q4 codec at paper MLP size (n={paper_n}, {:.2}x compression)",
                (paper_n * 4) as f64 / enc_len as f64
            ),
            &[s_enc.clone(), s_dec.clone()],
        );
        entries.push(DispatchEntry {
            kernel: "quantize_q4",
            n: paper_n,
            scalar_ns: f64::NAN,
            dispatched_ns: s_enc.median_s * 1e9,
            bytes_touched: (paper_n * 4 + enc_len) as f64,
        });
        entries.push(DispatchEntry {
            kernel: "dequantize_q4",
            n: paper_n,
            scalar_ns: f64::NAN,
            dispatched_ns: s_dec.median_s * 1e9,
            bytes_touched: (enc_len + paper_n * 4) as f64,
        });
    }
}

fn write_kernels_json(entries: &[DispatchEntry]) {
    let mut root = JsonObj::new();
    root.insert("bench", Json::Str("kernel_dispatch".into()));
    root.insert("dispatch", Json::Str(simd::active_name().into()));
    root.insert(
        "note",
        Json::Str(
            "median ns per call: runtime-dispatched tensor::simd kernels vs \
             their scalar references on identical buffers (bit-identical \
             outputs by construction). dispatch records the level the host \
             selected; under EG_FORCE_SCALAR=1 it reads 'scalar' and \
             speedup_x ~= 1. q4 rows are whole-codec paper-MLP timings \
             with no scalar column."
                .into(),
        ),
    );
    let mut arr = Vec::new();
    for e in entries {
        let mut o = JsonObj::new();
        o.insert("kernel", Json::Str(e.kernel.into()));
        o.insert("n", Json::Num(e.n as f64));
        o.insert("dispatched_ns", Json::Num(e.dispatched_ns));
        if e.scalar_ns.is_finite() {
            o.insert("scalar_ns", Json::Num(e.scalar_ns));
            o.insert("speedup_x", Json::Num(e.scalar_ns / e.dispatched_ns));
        }
        o.insert(
            "gb_per_s",
            Json::Num(e.bytes_touched / (e.dispatched_ns / 1e9) / 1e9),
        );
        arr.push(Json::Obj(o));
    }
    root.insert("entries", Json::Arr(arr));
    let path = "BENCH_kernels.json";
    match std::fs::write(path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut dispatch_entries = Vec::new();
    bench_dispatch(&mut dispatch_entries);
    write_kernels_json(&dispatch_entries);

    let mut rng = Rng::new(7);
    let n = 65_536usize;
    let a: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();

    // ---- elastic pair update: rust native vs HLO (Pallas interpret) ----
    let mut stats = Vec::new();
    {
        let mut x = a.clone();
        let mut y = b.clone();
        stats.push(bench("gossip_pair/rust-native n=65536", || {
            tensor::elastic_pair_update(&mut x, &mut y, 0.5);
            std::hint::black_box(&x);
        }));
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let ke = KernelEngine::load(&dir, "gossip_pair_n65536").unwrap();
        stats.push(bench("gossip_pair/hlo-pallas  n=65536", || {
            let out = ke.gossip_pair(&a, &b, 0.5).unwrap();
            std::hint::black_box(out);
        }));
    }
    print_comparison("elastic pair update (Eq. 3.7/3.8)", &stats);
    let bytes_touched = (4 * n * 4) as f64; // 2 reads + 2 writes
    println!(
        "  native bandwidth: {:.2} GB/s",
        bytes_touched / stats[0].median_s / 1e9
    );

    // ---- fused multi-peer elastic update vs per-peer full sweeps ----
    // the comm-round hot path: worker i applies |K| peer terms; the seed
    // implementation swept the whole buffer once per peer, the fused
    // kernel walks it once in cache-sized chunks (bit-identical result)
    for peers in [2usize, 4, 8] {
        let snaps: Vec<Vec<f32>> = (0..peers)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = snaps.iter().map(|s| s.as_slice()).collect();
        let mut stats = Vec::new();
        {
            let mut dst = a.clone();
            stats.push(bench(&format!("multi_pull/per-peer sweeps K={peers}"), || {
                for s in &refs {
                    for ((t, &si), &sk) in dst.iter_mut().zip(&a).zip(*s) {
                        *t -= 0.5 * (si - sk);
                    }
                }
                std::hint::black_box(&dst);
            }));
        }
        {
            let mut dst = a.clone();
            stats.push(bench(&format!("multi_pull/fused         K={peers}"), || {
                tensor::elastic_multi_pull(&mut dst, &a, &refs, 0.5);
                std::hint::black_box(&dst);
            }));
        }
        print_comparison(
            &format!("fused multi-peer elastic update, K={peers} n=65536"),
            &stats,
        );
    }

    // ---- fused NAG: rust native vs HLO ----
    let mut stats = Vec::new();
    {
        let mut opt = Optimizer::new(OptimKind::Nag { momentum: 0.99 }, LrSchedule::Const(0.001), n);
        let mut theta = a.clone();
        stats.push(bench("nag_update/rust-native  n=65536", || {
            opt.update_velocity(&g);
            opt.apply(&mut theta, &g);
            std::hint::black_box(&theta);
        }));
    }
    if dir.join("manifest.json").exists() {
        let ke = KernelEngine::load(&dir, "nag_n65536").unwrap();
        let v = b.clone();
        stats.push(bench("nag_update/hlo-pallas   n=65536", || {
            let out = ke.nag(&a, &v, &g, 0.001, 0.99).unwrap();
            std::hint::black_box(out);
        }));
    }
    print_comparison("fused NAG update (Alg. 5 lines 3+9)", &stats);

    // ---- all-reduce implementations at paper flat size ----
    let paper_n = 2_913_290usize;
    let w = 4usize;
    let mut stats = Vec::new();
    for imp in [AllReduceImpl::Ring, AllReduceImpl::Tree, AllReduceImpl::Central] {
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..paper_n).map(|_| rng.gauss_f32()).collect())
            .collect();
        stats.push(bench(&format!("allreduce/{imp:?} w=4 n=2.9M"), || {
            let mut fabric = Fabric::new(w, LinkModel::default());
            imp.all_reduce_mean(&mut bufs, &mut fabric);
            std::hint::black_box(&bufs);
        }));
    }
    print_comparison("all-reduce mean at paper MLP size", &stats);

    // ---- mean-of-replicas (aggregate model) ----
    let bufs: Vec<Vec<f32>> = (0..8).map(|_| (0..paper_n).map(|_| rng.gauss_f32()).collect()).collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; paper_n];
    let s = bench("average_params w=8 n=2.9M", || {
        tensor::mean_of(&refs, &mut out);
        std::hint::black_box(&out);
    });
    s.print();
}
