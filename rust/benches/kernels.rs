//! Kernel-level benches: the rust-native hot-path ops vs their
//! Pallas-lowered HLO twins (the ablation DESIGN.md §8 calls for), plus
//! the all-reduce implementations at paper scale.
//!
//! ```bash
//! cargo bench --bench kernels
//! ```

use elastic_gossip::benchkit::{bench, print_comparison};
use elastic_gossip::collective::AllReduceImpl;
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::optim::{LrSchedule, OptimKind, Optimizer};
use elastic_gossip::runtime::KernelEngine;
use elastic_gossip::tensor;
use elastic_gossip::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let n = 65_536usize;
    let a: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();

    // ---- elastic pair update: rust native vs HLO (Pallas interpret) ----
    let mut stats = Vec::new();
    {
        let mut x = a.clone();
        let mut y = b.clone();
        stats.push(bench("gossip_pair/rust-native n=65536", || {
            tensor::elastic_pair_update(&mut x, &mut y, 0.5);
            std::hint::black_box(&x);
        }));
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let ke = KernelEngine::load(&dir, "gossip_pair_n65536").unwrap();
        stats.push(bench("gossip_pair/hlo-pallas  n=65536", || {
            let out = ke.gossip_pair(&a, &b, 0.5).unwrap();
            std::hint::black_box(out);
        }));
    }
    print_comparison("elastic pair update (Eq. 3.7/3.8)", &stats);
    let bytes_touched = (4 * n * 4) as f64; // 2 reads + 2 writes
    println!(
        "  native bandwidth: {:.2} GB/s",
        bytes_touched / stats[0].median_s / 1e9
    );

    // ---- fused multi-peer elastic update vs per-peer full sweeps ----
    // the comm-round hot path: worker i applies |K| peer terms; the seed
    // implementation swept the whole buffer once per peer, the fused
    // kernel walks it once in cache-sized chunks (bit-identical result)
    for peers in [2usize, 4, 8] {
        let snaps: Vec<Vec<f32>> = (0..peers)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = snaps.iter().map(|s| s.as_slice()).collect();
        let mut stats = Vec::new();
        {
            let mut dst = a.clone();
            stats.push(bench(&format!("multi_pull/per-peer sweeps K={peers}"), || {
                for s in &refs {
                    for ((t, &si), &sk) in dst.iter_mut().zip(&a).zip(*s) {
                        *t -= 0.5 * (si - sk);
                    }
                }
                std::hint::black_box(&dst);
            }));
        }
        {
            let mut dst = a.clone();
            stats.push(bench(&format!("multi_pull/fused         K={peers}"), || {
                tensor::elastic_multi_pull(&mut dst, &a, &refs, 0.5);
                std::hint::black_box(&dst);
            }));
        }
        print_comparison(
            &format!("fused multi-peer elastic update, K={peers} n=65536"),
            &stats,
        );
    }

    // ---- fused NAG: rust native vs HLO ----
    let mut stats = Vec::new();
    {
        let mut opt = Optimizer::new(OptimKind::Nag { momentum: 0.99 }, LrSchedule::Const(0.001), n);
        let mut theta = a.clone();
        stats.push(bench("nag_update/rust-native  n=65536", || {
            opt.update_velocity(&g);
            opt.apply(&mut theta, &g);
            std::hint::black_box(&theta);
        }));
    }
    if dir.join("manifest.json").exists() {
        let ke = KernelEngine::load(&dir, "nag_n65536").unwrap();
        let v = b.clone();
        stats.push(bench("nag_update/hlo-pallas   n=65536", || {
            let out = ke.nag(&a, &v, &g, 0.001, 0.99).unwrap();
            std::hint::black_box(out);
        }));
    }
    print_comparison("fused NAG update (Alg. 5 lines 3+9)", &stats);

    // ---- all-reduce implementations at paper flat size ----
    let paper_n = 2_913_290usize;
    let w = 4usize;
    let mut stats = Vec::new();
    for imp in [AllReduceImpl::Ring, AllReduceImpl::Tree, AllReduceImpl::Central] {
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..paper_n).map(|_| rng.gauss_f32()).collect())
            .collect();
        stats.push(bench(&format!("allreduce/{imp:?} w=4 n=2.9M"), || {
            let mut fabric = Fabric::new(w, LinkModel::default());
            imp.all_reduce_mean(&mut bufs, &mut fabric);
            std::hint::black_box(&bufs);
        }));
    }
    print_comparison("all-reduce mean at paper MLP size", &stats);

    // ---- mean-of-replicas (aggregate model) ----
    let bufs: Vec<Vec<f32>> = (0..8).map(|_| (0..paper_n).map(|_| rng.gauss_f32()).collect()).collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; paper_n];
    let s = bench("average_params w=8 n=2.9M", || {
        tensor::mean_of(&refs, &mut out);
        std::hint::black_box(&out);
    });
    s.print();
}
