//! Communication-cost bench: regenerates the paper's headline traffic
//! argument ("All-reduce ... entails a substantially higher communication
//! cost", abstract) as measured bytes + simulated link time, per method,
//! at the paper's MLP size — plus the ring-vs-central scaling curve from
//! §2.1.1 across cluster sizes, plus the **round-throughput** comparison
//! of the scratch-arena comm round against the seed (clone-everything,
//! one-sweep-per-peer) implementation.  The round-throughput numbers are
//! also written to `BENCH_comm.json` so later PRs can regress against
//! the trajectory.
//!
//! A second mode, **bench-wire**, measures the wire-codec subsystem
//! (`comm::codec`): encoded bytes per parameter message at the paper MLP
//! size, encode/decode throughput, and end-to-end bytes-on-wire of an
//! async training run per codec — written to `BENCH_wire.json` next to
//! `BENCH_comm.json`.
//!
//! A third mode, **bench-churn**, measures the elastic-membership
//! subsystem: async-runtime throughput with and without the standard
//! crash/rejoin schedule plus the dropped-traffic ledger — written to
//! `BENCH_churn.json`.
//!
//! A fourth mode, **bench-fd**, measures the gossip-native failure
//! detector: detection latency, suspicion / false-suspicion counts and
//! probe traffic across a link-loss sweep with the membership oracle
//! disabled — written to `BENCH_fd.json`.
//!
//! ```bash
//! cargo bench --bench comm_cost            # comm-round mode
//! cargo bench --bench comm_cost -- wire    # wire-codec mode (just bench-wire)
//! cargo bench --bench comm_cost -- churn   # membership mode (just bench-churn)
//! cargo bench --bench comm_cost -- fd      # failure-detector mode (just bench-fd)
//! ```

use elastic_gossip::algos::{gossip_picks, k_sets, CommCtx, ScratchArena};
use elastic_gossip::benchkit::{bench_heavy, fmt_time};
use elastic_gossip::collective::AllReduceImpl;
use elastic_gossip::comm::codec::{Codec, CodecKind};
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::config::CommSchedule;
use elastic_gossip::coordinator::{run_experiment, synthetic_cfg};
use elastic_gossip::manifest::json::{self, Json, JsonObj};
use elastic_gossip::prelude::*;
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncSimCfg};

/// The seed implementation of the elastic-gossip round, kept verbatim as
/// the "before" baseline: full-cluster snapshot clones + one full
/// parameter sweep per peer.
#[allow(clippy::too_many_arguments)]
fn naive_elastic_round(
    params: &mut [Vec<f32>],
    snapshot: &mut Vec<Vec<f32>>,
    comm: &[bool],
    alpha: f32,
    fabric: &mut Fabric,
    rng: &mut Rng,
) {
    let picks = gossip_picks(comm, &Topology::Full, rng);
    if picks.iter().all(Option::is_none) {
        return;
    }
    let ks = k_sets(&picks);
    snapshot.resize(params.len(), Vec::new());
    for (s, p) in snapshot.iter_mut().zip(params.iter()) {
        s.clear();
        s.extend_from_slice(p);
    }
    let n = params[0].len();
    for (i, p) in picks.iter().enumerate() {
        if let Some(k) = *p {
            fabric.send_params(i, k, n);
            fabric.send_params(k, i, n);
        }
    }
    for (i, kset) in ks.iter().enumerate() {
        if kset.is_empty() {
            continue;
        }
        let theta_i = &mut params[i];
        for &k in kset {
            let snap_i = &snapshot[i];
            let snap_k = &snapshot[k];
            for ((t, &si), &sk) in theta_i.iter_mut().zip(snap_i).zip(snap_k) {
                *t -= alpha * (si - sk);
            }
        }
    }
    fabric.end_round();
}

/// One measured configuration of the round-throughput comparison.
struct RoundEntry {
    method: &'static str,
    imp: &'static str,
    workers: usize,
    mask: &'static str,
    ns_per_round: f64,
    bytes_per_round: f64,
}

fn measure_rounds(flat: usize, entries: &mut Vec<RoundEntry>) {
    println!("\n== comm-round throughput: scratch arena vs seed implementation ==");
    println!("   (flat = {flat} f32 — the paper MLP; 'p25' = every 4th worker fires)\n");
    println!(
        "{:<12} {:>3} {:<5} {:>14} {:>14} {:>9}",
        "method", "W", "mask", "naive/round", "arena/round", "speedup"
    );
    for &w in &[4usize, 8, 16] {
        for (mask_name, mask) in [
            ("p25", (0..w).map(|i| i % 4 == 0).collect::<Vec<bool>>()),
            ("full", vec![true; w]),
        ] {
            // --- naive (seed) baseline ---
            // (scoped so its ~2 full-cluster buffers are freed before the
            // arena variant allocates its own)
            let (s_naive, naive_bytes) = {
                let mut params: Vec<Vec<f32>> =
                    (0..w).map(|i| vec![i as f32 * 1e-3; flat]).collect();
                let mut snapshot: Vec<Vec<f32>> = Vec::new();
                let mut fabric = Fabric::new(w + 1, LinkModel::default());
                let mut rng = Rng::new(42);
                let s = bench_heavy("naive", 7, || {
                    naive_elastic_round(
                        &mut params,
                        &mut snapshot,
                        &mask,
                        0.5,
                        &mut fabric,
                        &mut rng,
                    );
                    std::hint::black_box(&params);
                });
                (s, fabric.report().bytes_per_round())
            };

            // --- scratch-arena implementation ---
            let (s_arena, arena_bytes) = {
                let mut params: Vec<Vec<f32>> =
                    (0..w).map(|i| vec![i as f32 * 1e-3; flat]).collect();
                let mut grads: Vec<Vec<f32>> = vec![Vec::new(); w];
                let mut fabric = Fabric::new(w + 1, LinkModel::default());
                let mut arena = ScratchArena::new();
                arena.ensure(w, flat);
                let mut strategy =
                    elastic_gossip::algos::gossip::ElasticGossipStrategy::new(0.5);
                let mut rng = Rng::new(42);
                let s = bench_heavy("arena", 7, || {
                    let mut ctx = CommCtx {
                        params: &mut params,
                        grads: &mut grads,
                        fabric: &mut fabric,
                        topology: &Topology::Full,
                        step: 0,
                        communicating: &mask,
                        arena: &mut arena,
                    };
                    strategy.comm_round(&mut ctx, &mut rng).unwrap();
                    fabric.end_round();
                    std::hint::black_box(&params);
                });
                (s, fabric.report().bytes_per_round())
            };

            let speedup = s_naive.median_s / s_arena.median_s;
            println!(
                "{:<12} {:>3} {:<5} {:>14} {:>14} {:>8.2}x",
                "eg",
                w,
                mask_name,
                fmt_time(s_naive.median_s),
                fmt_time(s_arena.median_s),
                speedup
            );
            entries.push(RoundEntry {
                method: "elastic-gossip",
                imp: "naive",
                workers: w,
                mask: mask_name,
                ns_per_round: s_naive.median_s * 1e9,
                bytes_per_round: naive_bytes,
            });
            entries.push(RoundEntry {
                method: "elastic-gossip",
                imp: "arena",
                workers: w,
                mask: mask_name,
                ns_per_round: s_arena.median_s * 1e9,
                bytes_per_round: arena_bytes,
            });
        }
    }
}

fn write_bench_json(flat: usize, entries: &[RoundEntry]) {
    let mut root = JsonObj::new();
    root.insert("bench", Json::Str("comm_round".into()));
    root.insert("flat", Json::Num(flat as f64));
    root.insert(
        "note",
        Json::Str(
            "median ns per elastic-gossip comm round; 'naive' = seed impl \
             (full-cluster clone + per-peer sweeps), 'arena' = scratch-arena \
             fused round. mask p25 = 25% of workers fire (paper regime)."
                .into(),
        ),
    );
    let mut arr = Vec::new();
    for e in entries {
        let mut o = JsonObj::new();
        o.insert("method", Json::Str(e.method.into()));
        o.insert("impl", Json::Str(e.imp.into()));
        o.insert("workers", Json::Num(e.workers as f64));
        o.insert("mask", Json::Str(e.mask.into()));
        o.insert("ns_per_round", Json::Num(e.ns_per_round));
        o.insert("bytes_per_round", Json::Num(e.bytes_per_round));
        arr.push(Json::Obj(o));
    }
    root.insert("entries", Json::Arr(arr));
    let path = "BENCH_comm.json";
    match std::fs::write(path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// bench-wire: the codec subsystem at the paper MLP size — bytes on the
/// wire per message, encode/decode throughput, and a small end-to-end
/// async run per codec.  Writes `BENCH_wire.json`.
fn bench_wire(flat: usize) {
    println!("== wire codecs at the paper MLP size ({flat} f32, {:.1} MB raw) ==\n", flat as f64 * 4.0 / 1e6);
    println!(
        "{:<12} {:>14} {:>10} {:>14} {:>14} {:>12}",
        "codec", "wire bytes", "vs raw", "encode", "decode", "enc GB/s"
    );
    let raw = 4 * flat;
    let mut rng = Rng::new(0xC0DEC);
    let src: Vec<f32> = (0..flat).map(|_| rng.gauss_f32()).collect();
    let mut entries: Vec<Json> = Vec::new();
    for kind in [
        CodecKind::Identity,
        CodecKind::parse("q8").unwrap(),
        CodecKind::parse("topk:0.01").unwrap(),
    ] {
        let mut codec = kind.build();
        let mut wire: Vec<u8> = Vec::new();
        let mut back = vec![0.0f32; flat];
        // warm-up sizes every buffer (and seeds topk's residual state)
        codec.encode_into(0, &src, &mut wire);
        codec.decode_into(&wire, &mut back).unwrap();
        let s_enc = bench_heavy("encode", 5, || {
            codec.encode_into(0, &src, &mut wire);
            std::hint::black_box(&wire);
        });
        let s_dec = bench_heavy("decode", 5, || {
            codec.decode_into(&wire, &mut back).unwrap();
            std::hint::black_box(&back);
        });
        let bytes = wire.len();
        let reduction = raw as f64 / bytes as f64;
        let gbps = raw as f64 / s_enc.median_s / 1e9;
        println!(
            "{:<12} {:>14} {:>9.2}x {:>14} {:>14} {:>12.2}",
            kind.label(),
            bytes,
            reduction,
            fmt_time(s_enc.median_s),
            fmt_time(s_dec.median_s),
            gbps
        );
        let mut o = JsonObj::new();
        o.insert("codec", Json::Str(kind.label()));
        o.insert("flat", Json::Num(flat as f64));
        o.insert("raw_bytes", Json::Num(raw as f64));
        o.insert("wire_bytes_per_msg", Json::Num(bytes as f64));
        o.insert("reduction_x", Json::Num(reduction));
        o.insert("encode_ns", Json::Num(s_enc.median_s * 1e9));
        o.insert("decode_ns", Json::Num(s_dec.median_s * 1e9));
        entries.push(Json::Obj(o));
    }

    // end to end: the same straggler study `repro async-train` runs, per
    // codec — run-level raw vs encoded traffic under real message flow
    println!("\n== end-to-end async run (elastic gossip, 8 workers, straggler x4) ==\n");
    println!(
        "{:<12} {:>14} {:>14} {:>9} {:>10} {:>10}",
        "codec", "raw bytes", "wire bytes", "vs raw", "rank0", "stale-avg"
    );
    let mut runs: Vec<Json> = Vec::new();
    for kind in [
        CodecKind::Identity,
        CodecKind::parse("q8").unwrap(),
        CodecKind::parse("topk:0.01").unwrap(),
    ] {
        let (mut cfg, spec) = study_setup(Method::ElasticGossip { alpha: 0.5 }, 8, 0.125, 3, 7);
        cfg.codec = kind;
        let sim = AsyncSimCfg::straggler(8, 0.05, 0.1, 4.0);
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let m = &asy.report.metrics;
        let reduction = if m.wire_bytes > 0 { m.comm_bytes as f64 / m.wire_bytes as f64 } else { 1.0 };
        println!(
            "{:<12} {:>14} {:>14} {:>8.2}x {:>10.4} {:>10.2}",
            kind.label(),
            m.comm_bytes,
            m.wire_bytes,
            reduction,
            asy.report.rank0_accuracy,
            asy.staleness.mean()
        );
        let mut o = JsonObj::new();
        o.insert("codec", Json::Str(kind.label()));
        o.insert("comm_bytes", Json::Num(m.comm_bytes as f64));
        o.insert("wire_bytes", Json::Num(m.wire_bytes as f64));
        o.insert("reduction_x", Json::Num(reduction));
        o.insert("rank0_acc", Json::Num(asy.report.rank0_accuracy as f64));
        o.insert("staleness_mean", Json::Num(asy.staleness.mean()));
        runs.push(Json::Obj(o));
    }

    let mut root = JsonObj::new();
    root.insert("bench", Json::Str("wire_codecs".into()));
    root.insert("flat", Json::Num(flat as f64));
    root.insert(
        "note",
        Json::Str(
            "wire-codec subsystem: per-message encoded size + throughput at the \
             paper MLP size, and run-level raw vs encoded traffic of the async \
             straggler study. q8 = per-chunk affine int8 (8-bit codes; ~0.05% \
             header overhead => ~3.99x of the 4x ceiling), topk:0.01 = top-1% \
             sparsification with error feedback (~50x)."
                .into(),
        ),
    );
    root.insert("messages", Json::Arr(entries));
    root.insert("runs", Json::Arr(runs));
    let path = "BENCH_wire.json";
    match std::fs::write(path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// bench-churn: throughput + dropped-traffic ledger of the async runtime
/// under the standard crash schedule (`just bench-churn`).  Writes
/// `BENCH_churn.json` — wall-clock steps/s with and without churn, plus
/// the dropped/rolled-back message accounting per gossip method.
fn bench_churn() {
    use elastic_gossip::membership::ChurnSpec;
    let w = 8usize;
    let churn = ChurnSpec::parse(elastic_gossip::membership::STANDARD_CHURN).unwrap();
    println!(
        "== elastic membership under the standard crash schedule ({w} workers, `{}`) ==\n",
        churn.label()
    );
    println!(
        "{:<12} {:>12} {:>12} {:>9} {:>12} {:>9} {:>8}",
        "method", "steps/s", "no-churn/s", "dropped", "dropped-kB", "rollback", "alive"
    );
    let mut runs: Vec<Json> = Vec::new();
    for method in [
        Method::ElasticGossip { alpha: 0.5 },
        Method::GossipingSgdPull,
        Method::GossipingSgdPush,
        Method::GoSgd,
    ] {
        let (base_cfg, spec) = study_setup(method.clone(), w, 0.125, 6, 7);
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
        // fixed-roster reference throughput
        let t0 = std::time::Instant::now();
        let plain = run_async(&base_cfg, &spec, &sim).unwrap();
        let plain_s = t0.elapsed().as_secs_f64();
        // churn run
        let mut cfg = base_cfg.clone();
        cfg.churn = churn.clone();
        let t1 = std::time::Instant::now();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let churn_s = t1.elapsed().as_secs_f64();
        let m = &asy.report.metrics;
        let steps_churn = m.total_steps as f64 / churn_s.max(1e-9);
        let steps_plain = plain.report.metrics.total_steps as f64 / plain_s.max(1e-9);
        println!(
            "{:<12} {:>12.0} {:>12.0} {:>9} {:>12.2} {:>9} {:>8}",
            method.short_label(),
            steps_churn,
            steps_plain,
            m.dropped_messages,
            m.dropped_bytes as f64 / 1e3,
            asy.membership.rolled_back_msgs,
            asy.membership.final_alive.len(),
        );
        let mut o = JsonObj::new();
        o.insert("method", Json::Str(method.short_label()));
        o.insert("steps_per_s_churn", Json::Num(steps_churn));
        o.insert("steps_per_s_fixed", Json::Num(steps_plain));
        o.insert("dropped_messages", Json::Num(m.dropped_messages as f64));
        o.insert("dropped_bytes", Json::Num(m.dropped_bytes as f64));
        o.insert("rolled_back_msgs", Json::Num(asy.membership.rolled_back_msgs as f64));
        o.insert("final_alive", Json::Num(asy.membership.final_alive.len() as f64));
        if let Some(mass) = asy.push_sum_mass {
            o.insert("push_sum_mass", Json::Num(mass));
        }
        runs.push(Json::Obj(o));
    }
    let mut root = JsonObj::new();
    root.insert("bench", Json::Str("churn".into()));
    root.insert("schedule", Json::Str(churn.label().into()));
    root.insert(
        "note",
        Json::Str(
            "async runtime throughput and dropped-traffic ledger under the \
             standard crash/rejoin schedule (2 of 8 nodes crash mid-run, 1 \
             rejoins from its epoch checkpoint), straggler x3"
                .into(),
        ),
    );
    root.insert("runs", Json::Arr(runs));
    let path = "BENCH_churn.json";
    match std::fs::write(path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// bench-fd: the SWIM-style failure-detection plane across a link-loss
/// sweep (`just bench-fd`).  The membership oracle is off — every node
/// runs ping / ping-req probes and learns deaths from rumors — while the
/// fault plane drops a seeded fraction of all non-bootstrap messages.
/// Writes `BENCH_fd.json`: detection latency, suspicion / false-suspicion
/// counts, probe traffic, and wall-clock throughput per loss rate.
fn bench_fd() {
    use elastic_gossip::membership::{ChurnSpec, FaultSpec, FdSpec};
    let w = 8usize;
    let churn = ChurnSpec::parse("crash@30%:5,crash@45%:6").unwrap();
    let fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
    println!(
        "== gossip-native failure detection ({w} workers, `{}`, fd `{}`) ==\n",
        churn.label(),
        fd.label()
    );
    println!(
        "{:<8} {:>12} {:>8} {:>8} {:>7} {:>7} {:>9} {:>9} {:>9} {:>8}",
        "drop%", "steps/s", "probes", "acks", "susp", "false", "confirms", "det-mean", "det-max", "alive"
    );
    let method = Method::ElasticGossip { alpha: 0.5 };
    let mut runs: Vec<Json> = Vec::new();
    for drop in [0.0f64, 0.02, 0.05, 0.10] {
        let (mut cfg, spec) = study_setup(method.clone(), w, 0.125, 6, 7);
        cfg.churn = churn.clone();
        cfg.fd = fd.clone();
        cfg.faults = FaultSpec::parse(&format!("drop:{drop},jitter:0.3,seed:11")).unwrap();
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
        let t0 = std::time::Instant::now();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let wall_s = t0.elapsed().as_secs_f64();
        let m = &asy.report.metrics;
        let fdr = asy.membership.fd.as_ref().expect("fd-enabled run attaches FdReport");
        let steps_s = m.total_steps as f64 / wall_s.max(1e-9);
        println!(
            "{:<8} {:>12.0} {:>8} {:>8} {:>7} {:>7} {:>9} {:>8.2}s {:>8.2}s {:>8}",
            format!("{:.0}", drop * 100.0),
            steps_s,
            fdr.probes,
            fdr.acks,
            fdr.suspicions,
            fdr.false_suspicions,
            fdr.confirms,
            fdr.detection.mean(),
            fdr.detection.max(),
            asy.membership.final_alive.len(),
        );
        let mut o = JsonObj::new();
        o.insert("drop_p", Json::Num(drop));
        o.insert("steps_per_s", Json::Num(steps_s));
        o.insert("probes", Json::Num(fdr.probes as f64));
        o.insert("acks", Json::Num(fdr.acks as f64));
        o.insert("indirect_probes", Json::Num(fdr.indirect_probes as f64));
        o.insert("suspicions", Json::Num(fdr.suspicions as f64));
        o.insert("false_suspicions", Json::Num(fdr.false_suspicions as f64));
        o.insert("refutations", Json::Num(fdr.refutations as f64));
        o.insert("confirms", Json::Num(fdr.confirms as f64));
        o.insert("false_confirms", Json::Num(fdr.false_confirms as f64));
        o.insert("detection_mean_s", Json::Num(fdr.detection.mean()));
        o.insert("detection_max_s", Json::Num(fdr.detection.max()));
        o.insert("detections", Json::Num(fdr.detection.count() as f64));
        o.insert("final_alive", Json::Num(asy.membership.final_alive.len() as f64));
        runs.push(Json::Obj(o));
    }
    let mut root = JsonObj::new();
    root.insert("bench", Json::Str("failure_detection".into()));
    root.insert("schedule", Json::Str(churn.label().into()));
    root.insert("fd", Json::Str(fd.label().into()));
    root.insert(
        "note",
        Json::Str(
            "SWIM-style detector with the membership oracle off: elastic \
             gossip, 8 workers, 2 seeded crashes, straggler x3, link-loss \
             sweep. detection latency = crash time to first confirmed-dead \
             across all observers; false suspicions are live nodes suspected \
             (refuted via incarnation bumps, never confirmed at zero loss)."
                .into(),
        ),
    );
    root.insert("runs", Json::Arr(runs));
    let path = "BENCH_fd.json";
    match std::fs::write(path, json::write(&Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let flat = 2_913_290usize; // paper MLP
    let steps = 400u64; // one paper epoch

    if std::env::args().any(|a| a == "wire" || a == "--wire") {
        bench_wire(flat);
        return;
    }
    if std::env::args().any(|a| a == "churn" || a == "--churn") {
        bench_churn();
        return;
    }
    if std::env::args().any(|a| a == "fd" || a == "--fd") {
        bench_fd();
        return;
    }

    println!("== traffic per paper-epoch (400 steps), flat size 2.9M f32 ==\n");
    println!(
        "{:<30} {:>12} {:>16} {:>14} {:>12}",
        "method", "total MB", "MB/worker/step", "sim-link-s", "vs AR"
    );
    let mut ar_mb = None;
    for (label, method, sched) in [
        (
            "AR ring (every step)",
            Method::AllReduce { imp: AllReduceImpl::Ring },
            CommSchedule::EveryStep,
        ),
        (
            "AR central (every step)",
            Method::AllReduce { imp: AllReduceImpl::Central },
            CommSchedule::EveryStep,
        ),
        ("EG p=0.125", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.125)),
        ("EG p=0.03125", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.03125)),
        ("EG p=0.001953", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.001953125)),
        ("GS pull p=0.03125", Method::GossipingSgdPull, CommSchedule::Probability(0.03125)),
        ("GoSGD p=0.03125", Method::GoSgd, CommSchedule::Probability(0.03125)),
        ("EASGD tau=32", Method::Easgd { alpha: 0.125 }, CommSchedule::Period(32)),
    ] {
        let mut cfg = synthetic_cfg(method, 4, flat);
        cfg.schedule = sched;
        cfg.n_train = steps as usize * cfg.effective_batch;
        let r = run_experiment(&cfg).unwrap();
        let mb = r.metrics.comm_bytes as f64 / 1e6;
        let ratio = match ar_mb {
            None => {
                ar_mb = Some(mb);
                1.0
            }
            Some(b) => mb / b,
        };
        println!(
            "{:<30} {:>12.1} {:>16.4} {:>14.3} {:>12.5}",
            label,
            mb,
            mb / (4.0 * steps as f64),
            r.metrics.simulated_comm_s,
            ratio
        );
    }

    println!("\n== ring vs central all-reduce: per-worker bytes vs cluster size (§2.1.1) ==\n");
    println!("{:>5} {:>16} {:>16} {:>18}", "W", "ring MB/worker", "central root MB", "central leaf MB");
    let n = 262_144usize;
    for w in [2usize, 4, 8, 16, 32] {
        let mut bufs: Vec<Vec<f32>> = vec![vec![1.0; n]; w];
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Ring.all_reduce_mean(&mut bufs, &mut fabric);
        let ring_per = fabric.report().per_worker_sent[&0] as f64 / 1e6;

        let mut bufs: Vec<Vec<f32>> = vec![vec![1.0; n]; w];
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Central.all_reduce_mean(&mut bufs, &mut fabric);
        let root = fabric.report().per_worker_sent[&0] as f64 / 1e6;
        let leaf = fabric.report().per_worker_sent[&1] as f64 / 1e6;
        println!("{w:>5} {ring_per:>16.3} {root:>16.3} {leaf:>18.3}");
    }
    println!(
        "\nexpected shape: ring per-worker traffic saturates at 2*n*4 bytes\n\
         (cluster-size independent, §2.4); the central root grows linearly in W."
    );

    let mut entries = Vec::new();
    measure_rounds(flat, &mut entries);
    write_bench_json(flat, &entries);
}
