//! Communication-cost bench: regenerates the paper's headline traffic
//! argument ("All-reduce ... entails a substantially higher communication
//! cost", abstract) as measured bytes + simulated link time, per method,
//! at the paper's MLP size — plus the ring-vs-central scaling curve from
//! §2.1.1 across cluster sizes.
//!
//! ```bash
//! cargo bench --bench comm_cost
//! ```

use elastic_gossip::collective::AllReduceImpl;
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::config::CommSchedule;
use elastic_gossip::coordinator::{run_experiment, synthetic_cfg};
use elastic_gossip::prelude::*;

fn main() {
    let flat = 2_913_290usize; // paper MLP
    let steps = 400u64; // one paper epoch

    println!("== traffic per paper-epoch (400 steps), flat size 2.9M f32 ==\n");
    println!(
        "{:<30} {:>12} {:>16} {:>14} {:>12}",
        "method", "total MB", "MB/worker/step", "sim-link-s", "vs AR"
    );
    let mut ar_mb = None;
    for (label, method, sched) in [
        (
            "AR ring (every step)",
            Method::AllReduce { imp: AllReduceImpl::Ring },
            CommSchedule::EveryStep,
        ),
        (
            "AR central (every step)",
            Method::AllReduce { imp: AllReduceImpl::Central },
            CommSchedule::EveryStep,
        ),
        ("EG p=0.125", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.125)),
        ("EG p=0.03125", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.03125)),
        ("EG p=0.001953", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.001953125)),
        ("GS pull p=0.03125", Method::GossipingSgdPull, CommSchedule::Probability(0.03125)),
        ("GoSGD p=0.03125", Method::GoSgd, CommSchedule::Probability(0.03125)),
        ("EASGD tau=32", Method::Easgd { alpha: 0.125 }, CommSchedule::Period(32)),
    ] {
        let mut cfg = synthetic_cfg(method, 4, flat);
        cfg.schedule = sched;
        cfg.n_train = steps as usize * cfg.effective_batch;
        let r = run_experiment(&cfg).unwrap();
        let mb = r.metrics.comm_bytes as f64 / 1e6;
        let ratio = match ar_mb {
            None => {
                ar_mb = Some(mb);
                1.0
            }
            Some(b) => mb / b,
        };
        println!(
            "{:<30} {:>12.1} {:>16.4} {:>14.3} {:>12.5}",
            label,
            mb,
            mb / (4.0 * steps as f64),
            r.metrics.simulated_comm_s,
            ratio
        );
    }

    println!("\n== ring vs central all-reduce: per-worker bytes vs cluster size (§2.1.1) ==\n");
    println!("{:>5} {:>16} {:>16} {:>18}", "W", "ring MB/worker", "central root MB", "central leaf MB");
    let n = 262_144usize;
    for w in [2usize, 4, 8, 16, 32] {
        let mut bufs: Vec<Vec<f32>> = vec![vec![1.0; n]; w];
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Ring.all_reduce_mean(&mut bufs, &mut fabric);
        let ring_per = fabric.report().per_worker_sent[&0] as f64 / 1e6;

        let mut bufs: Vec<Vec<f32>> = vec![vec![1.0; n]; w];
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Central.all_reduce_mean(&mut bufs, &mut fabric);
        let root = fabric.report().per_worker_sent[&0] as f64 / 1e6;
        let leaf = fabric.report().per_worker_sent[&1] as f64 / 1e6;
        println!("{w:>5} {ring_per:>16.3} {root:>16.3} {leaf:>18.3}");
    }
    println!(
        "\nexpected shape: ring per-worker traffic saturates at 2*n*4 bytes\n\
         (cluster-size independent, §2.4); the central root grows linearly in W."
    );
}
