//! Per-table end-to-end benches: training-step throughput for the
//! configuration family behind each paper table, through the full
//! HLO/PJRT stack.  Accuracy regeneration lives in the `repro table`
//! harness; this measures the *system* cost of each method.
//!
//! ```bash
//! cargo bench --bench tables
//! cargo bench --bench tables -- --paper   # mlp_paper instead of mlp_small
//! ```

use elastic_gossip::benchkit::{bench_heavy, print_comparison, Stats};
use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment;
use elastic_gossip::prelude::*;

fn cfg_for(method: Method, schedule: CommSchedule, model: &str, steps: usize) -> ExperimentConfig {
    let workers = 4;
    let eff = if model == "mlp_paper" { 128 } else { 32 };
    ExperimentConfig {
        label: format!("bench-{}", method.short_label()),
        method,
        workers,
        schedule,
        engine: EngineKind::Hlo { model: model.into() },
        dataset: if model == "mlp_paper" {
            DatasetKind::SyntheticMnist
        } else {
            DatasetKind::SyntheticVectors { dim: 64 }
        },
        n_train: steps * eff,
        n_val: 64,
        n_test: 64,
        effective_batch: eff,
        epochs: 1,
        seed: 0,
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let model = if paper { "mlp_paper" } else { "mlp_small" };
    let steps = if paper { 10 } else { 60 };
    println!("model = {model}, 4 workers, {steps} steps per sample\n");

    // Table 4.1 family: NC / AR / EG / GS  — per-step cost of each method
    let rows: Vec<(&str, Method, CommSchedule)> = vec![
        ("NC-4   (no comm)", Method::NoComm, CommSchedule::EveryStep),
        (
            "AR-4   (ring, every step)",
            Method::AllReduce { imp: elastic_gossip::collective::AllReduceImpl::Ring },
            CommSchedule::EveryStep,
        ),
        (
            "EG-4   p=0.125",
            Method::ElasticGossip { alpha: 0.5 },
            CommSchedule::Probability(0.125),
        ),
        (
            "GS-4   p=0.125",
            Method::GossipingSgdPull,
            CommSchedule::Probability(0.125),
        ),
        ("GoSGD  p=0.125", Method::GoSgd, CommSchedule::Probability(0.125)),
        ("EASGD  tau=10", Method::Easgd { alpha: 0.125 }, CommSchedule::Period(10)),
    ];

    let mut stats: Vec<Stats> = Vec::new();
    for (name, method, sched) in rows {
        let cfg = cfg_for(method, sched, model, steps);
        let total = cfg.total_steps();
        let s = bench_heavy(&format!("table4.1/{name}"), 3, || {
            let r = run_experiment(&cfg).unwrap();
            assert_eq!(r.metrics.total_steps, total);
        });
        println!(
            "{:<44} {:>9.1} steps/s",
            s.name,
            total as f64 / s.median_s
        );
        stats.push(s);
    }
    print_comparison(
        "Table 4.1 configuration family — wall time for the same step budget",
        &stats,
    );
    println!(
        "\nexpected shape: AR pays the collective every step; gossip methods sit\n\
         within a few percent of NC — the paper's communication-cost headline."
    );

    // Table 4.2 family: alpha sweep has identical system cost (same comm
    // schedule) — verify that claim instead of blindly sweeping.
    let mut alpha_stats = Vec::new();
    for alpha in [0.05f32, 0.5, 0.95] {
        let cfg = cfg_for(
            Method::ElasticGossip { alpha },
            CommSchedule::Probability(0.125),
            model,
            steps,
        );
        alpha_stats.push(bench_heavy(&format!("table4.2/alpha={alpha}"), 3, || {
            run_experiment(&cfg).unwrap();
        }));
    }
    print_comparison("Table 4.2 family — alpha does not change system cost", &alpha_stats);
}
