//! Multi-process wire tests: spawn real `repro net-train` worker
//! processes over loopback UDP, SIGKILL one mid-run, restart it with
//! `--rejoin`, and check the PR-5/PR-6 recovery story end to end on a
//! real transport:
//!
//! * the restarted rank restores its epoch-boundary checkpoint and
//!   re-adopts exact parameters from a live donor (donor bootstrap),
//! * the survivors' wall-clock failure detectors first confirm the dead
//!   rank and then refute the confirmation when frames with a fresh
//!   (higher) incarnation arrive.
//!
//! Network-gated like `transport_conformance.rs`: a sandbox that forbids
//! binding loopback sockets gets a visible `skipped: no network` note.

use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::time::{Duration, Instant};

use elastic_gossip::comm::transport::probe_loopback;
use elastic_gossip::manifest::json::{self, Json};

const EXE: &str = env!("CARGO_BIN_EXE_repro");

fn network_or_skip(test: &str) -> bool {
    if probe_loopback() {
        true
    } else {
        eprintln!(
            "[net_process::{test}] skipped: no network — this sandbox forbids \
             binding loopback UDP sockets; the test passes vacuously"
        );
        false
    }
}

struct Dirs {
    rendezvous: PathBuf,
    out: PathBuf,
}

fn fresh_dirs(tag: &str) -> Dirs {
    let base = std::env::temp_dir().join(format!("eg_net_{tag}_{}", std::process::id()));
    let d = Dirs { rendezvous: base.join("rendezvous"), out: base.join("out") };
    for p in [&d.rendezvous, &d.out] {
        let _ = std::fs::remove_dir_all(p);
        std::fs::create_dir_all(p).unwrap();
    }
    d
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    d: &Dirs,
    rank: usize,
    workers: usize,
    epochs: usize,
    pace_ms: u64,
    linger_ms: u64,
    rejoin: bool,
) -> Child {
    let mut c = Command::new(EXE);
    c.args([
        "net-train",
        "--net-worker",
        &rank.to_string(),
        "--workers",
        &workers.to_string(),
        "--method",
        "elastic-gossip:0.5",
        "--epochs",
        &epochs.to_string(),
        "--prob",
        "0.25",
        "--seed",
        "11",
        "--codec",
        "identity",
        "--pace-ms",
        &pace_ms.to_string(),
        "--straggler",
        "1.0",
        "--rendezvous",
        d.rendezvous.to_str().unwrap(),
        "--out",
        d.out.to_str().unwrap(),
        "--linger-ms",
        &linger_ms.to_string(),
    ]);
    if rejoin {
        c.arg("--rejoin");
    }
    c.spawn().expect("spawning worker")
}

fn wait_ok(mut child: Child, who: &str, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => {
                assert!(status.success(), "{who} exited with {status}");
                return;
            }
            None => {
                if Instant::now() > deadline {
                    let _ = child.kill();
                    panic!("{who} did not finish within {timeout:?}");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn rank_json(d: &Dirs, rank: usize) -> Json {
    let p = d.out.join(format!("rank_{rank}.json"));
    let s = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {p:?}: {e}"));
    json::parse(&s).unwrap_or_else(|e| panic!("parsing {p:?}: {e}"))
}

fn fd_events(v: &Json) -> Vec<String> {
    v.as_obj()
        .and_then(|o| o.get("fd_events"))
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|e| e.as_str().map(str::to_string)).collect())
        .unwrap_or_default()
}

fn num(v: &Json, key: &str) -> f64 {
    v.as_obj()
        .and_then(|o| o.get(key))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("summary missing numeric {key:?}"))
}

fn wait_for_checkpoint(dir: &Path, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while !dir.join("async_checkpoint.json").exists() {
        assert!(
            Instant::now() < deadline,
            "no checkpoint appeared at {dir:?} within {timeout:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The happy path: a 3-rank fleet runs to completion; every rank reports
/// a summary with traffic, zero malformed frames, and (aggregate
/// reproducibility) all ranks trained the full step count.
#[test]
fn fleet_runs_and_reports() {
    if !network_or_skip("fleet_runs_and_reports") {
        return;
    }
    let d = fresh_dirs("fleet");
    let (w, epochs, pace) = (3usize, 2usize, 5u64);
    let children: Vec<Child> =
        (0..w).map(|r| spawn_worker(&d, r, w, epochs, pace, 300, false)).collect();
    for (r, c) in children.into_iter().enumerate() {
        wait_ok(c, &format!("rank {r}"), Duration::from_secs(60));
    }
    let total_steps = (epochs * 32) as f64; // study_setup: 32 steps/epoch
    for r in 0..w {
        let v = rank_json(&d, r);
        assert_eq!(num(&v, "rank"), r as f64);
        assert_eq!(num(&v, "steps"), total_steps, "rank {r} step count");
        assert_eq!(num(&v, "incarnation"), 1.0);
        let sent = v
            .as_obj()
            .and_then(|o| o.get("transport"))
            .and_then(Json::as_obj)
            .map(|t| {
                (
                    t.get("frames_sent").and_then(Json::as_f64).unwrap_or(0.0),
                    t.get("malformed_frames").and_then(Json::as_f64).unwrap_or(-1.0),
                )
            })
            .expect("transport block");
        assert!(sent.0 > 0.0, "rank {r} sent no frames");
        assert_eq!(sent.1, 0.0, "rank {r} saw malformed frames");
    }
}

/// The recovery path: SIGKILL rank 2 after its first checkpoint, restart
/// it with `--rejoin`, and verify checkpoint restore + donor bootstrap +
/// the survivors' confirm-then-refute fd sequence.
#[test]
fn kill_restart_rejoins_via_donor_bootstrap() {
    if !network_or_skip("kill_restart_rejoins_via_donor_bootstrap") {
        return;
    }
    let d = fresh_dirs("rejoin");
    let (w, epochs, pace) = (3usize, 6usize, 25u64);
    // survivors linger long enough to observe the refutation and to keep
    // serving acks while the rejoined rank finishes its remaining epochs
    let survivor_linger = 6_000u64;
    let victim = 2usize;

    let mut children: Vec<(usize, Child)> = (0..w)
        .map(|r| (r, spawn_worker(&d, r, w, epochs, pace, survivor_linger, false)))
        .collect();

    // wait for the victim's first epoch-boundary checkpoint, then let it
    // run a little past it so the restore visibly rolls progress back
    let ckdir = d.rendezvous.join(format!("ckpt_rank{victim}"));
    wait_for_checkpoint(&ckdir, Duration::from_secs(30));
    std::thread::sleep(Duration::from_millis(200));

    let (_, mut victim_child) = children.remove(
        children.iter().position(|(r, _)| *r == victim).unwrap(),
    );
    victim_child.kill().expect("SIGKILL victim"); // SIGKILL on unix
    let _ = victim_child.wait();

    // dead time must exceed the survivors' confirm window
    // (max(600ms, 8*pace) * 2 = 1.2s at pace 25ms)
    std::thread::sleep(Duration::from_millis(1_700));

    let restarted = spawn_worker(&d, victim, w, epochs, pace, 300, true);

    for (r, c) in children {
        wait_ok(c, &format!("survivor rank {r}"), Duration::from_secs(120));
    }
    wait_ok(restarted, "restarted victim", Duration::from_secs(120));

    // --- the rejoined rank: fresh incarnation, restored step, donor ----
    let v = rank_json(&d, victim);
    assert_eq!(num(&v, "incarnation"), 2.0, "restart must bump the incarnation");
    let restored = num(&v, "restored_step");
    assert!(
        restored > 0.0 && restored % 32.0 == 0.0,
        "restored_step {restored} is not an epoch boundary"
    );
    assert_eq!(
        num(&v, "steps"),
        (epochs * 32) as f64 - restored,
        "rejoined rank must run exactly the steps after its checkpoint"
    );
    let donor = v
        .as_obj()
        .and_then(|o| o.get("bootstrap_donor"))
        .expect("bootstrap_donor key");
    assert_eq!(
        donor.as_f64(),
        Some(((victim + 1) % w) as f64),
        "rejoin must adopt from the designated donor"
    );

    // --- the survivors: confirm, then refute with the higher inc -------
    let mut confirmed = 0;
    let mut refuted = 0;
    for r in (0..w).filter(|r| *r != victim) {
        let events = fd_events(&rank_json(&d, r));
        let confirm_at = events.iter().position(|e| e == &format!("confirm node={victim} inc=1"));
        let refute_at = events.iter().position(|e| e == &format!("refute node={victim} inc=2"));
        if confirm_at.is_some() {
            confirmed += 1;
        }
        if refute_at.is_some() {
            refuted += 1;
        }
        if let (Some(c), Some(rf)) = (confirm_at, refute_at) {
            assert!(c < rf, "rank {r}: refutation recorded before the confirmation");
        }
        // a donor served at least one bootstrap across the fleet; checked
        // below in aggregate
        let _ = r;
    }
    assert!(
        confirmed >= 1,
        "no survivor confirmed the killed rank (events: {:?})",
        (0..w).filter(|r| *r != victim).map(|r| fd_events(&rank_json(&d, r))).collect::<Vec<_>>()
    );
    assert!(
        refuted >= 1,
        "no survivor refuted with the fresh incarnation (events: {:?})",
        (0..w).filter(|r| *r != victim).map(|r| fd_events(&rank_json(&d, r))).collect::<Vec<_>>()
    );
    let served: f64 = (0..w)
        .filter(|r| *r != victim)
        .map(|r| num(&rank_json(&d, r), "served_bootstraps"))
        .sum();
    assert!(served >= 1.0, "no survivor served the rejoin bootstrap");
}
