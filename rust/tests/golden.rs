//! Golden-trajectory regression suite.
//!
//! Small deterministic end-to-end runs — every gossip method on the
//! synthetic task, in both execution regimes, plus the lossy wire codecs
//! and crash/rejoin churn schedules — are reduced to exact observables (a digest of the final parameters,
//! the f32 *bit patterns* of the loss curve and final accuracies, and
//! the byte ledgers) and compared against blessed fixtures under
//! `tests/fixtures/golden/`.  Any trajectory change — an optimizer
//! reorder, an rng-stream perturbation, a kernel "optimization" that is
//! not bit-identical, a codec format change — fails this suite loudly.
//!
//! * Intentional change?  Re-bless with `just regen-golden` (sets
//!   `REGEN_GOLDEN=1`) and commit the updated fixtures with the PR that
//!   changed the trajectory, so the diff *shows* the behavior change.
//! * Fixtures absent (fresh clone before the first bless)?  The suite
//!   skips with a visible note; CI bootstraps the fixtures on main and
//!   commits them (same pattern as `BENCH_comm.json`).
//!
//! Fixtures are bit-exact observations of runs on the committed rust
//! implementation; they are expected to be stable across machines for a
//! given target (the suite runs on CI's linux x86_64 across
//! stable/beta, debug/release).

use std::path::{Path, PathBuf};

use elastic_gossip::comm::codec::CodecKind;
use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::Coordinator;
use elastic_gossip::manifest::json::{self, Json, JsonObj};
use elastic_gossip::membership::ChurnSpec;
use elastic_gossip::optim::{LrSchedule, OptimKind};
use elastic_gossip::prelude::*;
use elastic_gossip::runtime_async::{run_async, AsyncSimCfg};
use elastic_gossip::runtime::SyntheticSpec;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

fn regen() -> bool {
    std::env::var("REGEN_GOLDEN").map(|v| v == "1").unwrap_or(false)
}

/// The frozen golden experiment.  Deliberately *not* shared with
/// `tiny_cfg` or `study_setup`: those may evolve with the harness, while
/// this one defines the fixtures — any behavioral drift must surface as
/// a digest mismatch, not be absorbed by a config change.
fn golden_cfg(method: Method, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        label: format!("golden-{}", method.short_label()),
        method,
        workers,
        schedule: CommSchedule::Probability(0.5),
        optimizer: OptimKind::Nag { momentum: 0.9 },
        lr: LrSchedule::Const(0.05),
        engine: EngineKind::Synthetic { dim: 12 },
        dataset: DatasetKind::SyntheticVectors { dim: 6 },
        n_train: 128,
        n_val: 64,
        n_test: 64,
        effective_batch: 8 * workers,
        epochs: 3,
        seed: 2024,
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

/// FNV-1a over the little-endian bytes of every parameter — one digest
/// pins the entire final state bit-for-bit.  Shared with the runtime's
/// bootstrap-adoption digests (`util::fnv_digest_nested`), so the
/// fixture format and the in-run membership digests can never drift
/// apart.
fn digest_params(params: &[Vec<f32>]) -> u64 {
    elastic_gossip::util::fnv_digest_nested(params)
}

/// One golden observation: everything we pin about a run.
#[derive(Debug, PartialEq)]
struct Golden {
    params_digest: u64,
    train_loss_bits: Vec<u32>,
    rank0_bits: u32,
    aggregate_bits: u32,
    comm_bytes: u64,
    wire_bytes: u64,
}

impl Golden {
    fn from_run(final_params: &[Vec<f32>], report: &RunReport) -> Golden {
        Golden {
            params_digest: digest_params(final_params),
            train_loss_bits: report
                .metrics
                .curve
                .points
                .iter()
                .map(|p| p.train_loss.to_bits())
                .collect(),
            rank0_bits: report.rank0_accuracy.to_bits(),
            aggregate_bits: report.aggregate_accuracy.to_bits(),
            comm_bytes: report.metrics.comm_bytes,
            wire_bytes: report.metrics.wire_bytes,
        }
    }

    fn to_json(&self, label: &str) -> Json {
        let mut o = JsonObj::new();
        o.insert("label", Json::Str(label.into()));
        o.insert("params_digest", Json::Str(format!("{:016x}", self.params_digest)));
        o.insert(
            "train_loss_bits",
            Json::Arr(self.train_loss_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        o.insert("rank0_bits", Json::Num(self.rank0_bits as f64));
        o.insert("aggregate_bits", Json::Num(self.aggregate_bits as f64));
        o.insert("comm_bytes", Json::Num(self.comm_bytes as f64));
        o.insert("wire_bytes", Json::Num(self.wire_bytes as f64));
        Json::Obj(o)
    }

    fn from_json(j: &Json) -> Option<Golden> {
        Some(Golden {
            params_digest: u64::from_str_radix(j.path(&["params_digest"]).as_str()?, 16).ok()?,
            train_loss_bits: j
                .path(&["train_loss_bits"])
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|x| x as u32))
                .collect::<Option<Vec<u32>>>()?,
            rank0_bits: j.path(&["rank0_bits"]).as_f64()? as u32,
            aggregate_bits: j.path(&["aggregate_bits"]).as_f64()? as u32,
            comm_bytes: j.path(&["comm_bytes"]).as_f64()? as u64,
            wire_bytes: j.path(&["wire_bytes"]).as_f64()? as u64,
        })
    }
}

/// Run the sequential coordinator, capturing the final per-worker
/// parameters through the step observer.
fn run_sequential(cfg: &ExperimentConfig) -> (RunReport, Vec<Vec<f32>>) {
    let spec = SyntheticSpec::for_cfg(cfg).unwrap();
    let last = cfg.total_steps() - 1;
    let mut final_params: Vec<Vec<f32>> = Vec::new();
    let report = {
        let mut c = Coordinator::new(cfg, &spec);
        c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
            if step == last {
                final_params = p.to_vec();
            }
        }));
        c.run().unwrap()
    };
    (report, final_params)
}

/// One golden case: the observation plus enough context to rerun it
/// with the flight recorder on when it mismatches.
struct Case {
    label: String,
    golden: Golden,
    cfg: ExperimentConfig,
    is_async: bool,
}

/// Diagnostic rerun of a mismatched case with tracing on: repeat the
/// run with a `dump:` spec so the failure message can point at a
/// Perfetto-loadable timeline of the diverging trajectory.
fn flight_dump(case: &Case) -> Option<PathBuf> {
    let dir = std::env::temp_dir().join("elastic_gossip_golden_flight");
    std::fs::create_dir_all(&dir).ok()?;
    let path = dir.join(format!("{}.json", case.label));
    let mut cfg = case.cfg.clone();
    cfg.trace =
        elastic_gossip::trace::TraceSpec::parse(&format!("on,dump:{}", path.display())).ok()?;
    let spec = SyntheticSpec::for_cfg(&cfg).ok()?;
    let ok = if case.is_async {
        run_async(&cfg, &spec, &AsyncSimCfg::lockstep(cfg.workers)).is_ok()
    } else {
        Coordinator::new(&cfg, &spec).run().is_ok()
    };
    if ok && path.exists() {
        Some(path)
    } else {
        None
    }
}

/// Produce every golden observation, labeled.  Sync and async-lockstep
/// runs are recorded separately (and cross-asserted to be identical for
/// the identity codec), plus lossy-codec async runs that pin the codec
/// numerics themselves.
fn observe_all() -> Vec<Case> {
    let mut out = Vec::new();
    for method in [
        Method::ElasticGossip { alpha: 0.5 },
        Method::GossipingSgdPull,
        Method::GossipingSgdPush,
        Method::GoSgd,
    ] {
        let cfg = golden_cfg(method.clone(), 4);
        let spec = SyntheticSpec::for_cfg(&cfg).unwrap();
        let (seq_report, seq_params) = run_sequential(&cfg);
        out.push(Case {
            label: format!("sync_{}", method.short_label()),
            golden: Golden::from_run(&seq_params, &seq_report),
            cfg: cfg.clone(),
            is_async: false,
        });
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(4)).unwrap();
        let g = Golden::from_run(&asy.final_params, &asy.report);
        // regime equivalence, independent of any fixture: the async
        // lockstep digest must equal the sequential one bit-for-bit
        assert_eq!(
            g.params_digest,
            digest_params(&seq_params),
            "{method:?}: async lockstep diverged from the sequential coordinator"
        );
        out.push(Case {
            label: format!("async_{}", method.short_label()),
            golden: g,
            cfg,
            is_async: true,
        });
    }
    // lossy codecs: pin the codec numerics end to end (elastic gossip,
    // lockstep so the only difference vs the identity run is the codec)
    for codec in [CodecKind::Q8 { chunk: 4096 }, CodecKind::TopK { frac: 0.25 }] {
        let mut cfg = golden_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.codec = codec;
        let spec = SyntheticSpec::for_cfg(&cfg).unwrap();
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(4)).unwrap();
        let name = codec.label().replace(':', "_").replace('.', "_");
        out.push(Case {
            label: format!("async_EG_{name}"),
            golden: Golden::from_run(&asy.final_params, &asy.report),
            cfg,
            is_async: true,
        });
    }
    // membership churn: pin the elastic-membership machinery end to end
    // (crash + rejoin under lockstep — deterministic event application,
    // drop/rollback rules, checkpoint restore and join bootstrap all
    // feed the digest; `just regen-golden` re-blesses these with the
    // rest of the suite)
    for method in [Method::ElasticGossip { alpha: 0.5 }, Method::GoSgd] {
        let mut cfg = golden_cfg(method.clone(), 4);
        cfg.churn = ChurnSpec::parse("crash@35%:1,rejoin@75%:1").unwrap();
        let spec = SyntheticSpec::for_cfg(&cfg).unwrap();
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(
            asy.membership.final_alive,
            vec![0, 1, 2, 3],
            "{method:?}: churn golden expects the rejoiner back"
        );
        if let Some(mass) = asy.push_sum_mass {
            assert!((mass - 1.0).abs() < 1e-9, "churn golden leaked mass: {mass}");
        }
        out.push(Case {
            label: format!("async_{}_churn", method.short_label()),
            golden: Golden::from_run(&asy.final_params, &asy.report),
            cfg,
            is_async: true,
        });
    }
    out
}

#[test]
fn golden_trajectories_match_blessed_fixtures() {
    let dir = fixture_dir();
    let observed = observe_all();
    if regen() {
        std::fs::create_dir_all(&dir).unwrap();
        for case in &observed {
            let path = dir.join(format!("{}.json", case.label));
            std::fs::write(&path, json::write(&case.golden.to_json(&case.label))).unwrap();
            println!("blessed {}", path.display());
        }
        return;
    }
    if !dir.exists() {
        eprintln!(
            "skipped: no golden fixtures at {} — bless them with `just regen-golden` \
             (CI bootstraps and commits them on main)",
            dir.display()
        );
        return;
    }
    let mut mismatches = Vec::new();
    for case in &observed {
        let (label, g) = (&case.label, &case.golden);
        let path = dir.join(format!("{label}.json"));
        let text = std::fs::read_to_string(&path).unwrap_or_else(|_| {
            panic!(
                "no golden fixture for {label:?} ({}). A new golden case must be \
                 blessed: run `just regen-golden` and commit the fixture.",
                path.display()
            )
        });
        let blessed = Golden::from_json(&json::parse(&text).unwrap_or_else(|e| {
            panic!("golden fixture {} is not valid JSON: {e}", path.display())
        }))
        .unwrap_or_else(|| panic!("golden fixture {} is malformed", path.display()));
        if &blessed != g {
            // rerun the diverging case with the flight recorder on, so
            // the failure names a timeline of what the run actually did
            let flight = match flight_dump(case) {
                Some(p) => format!("flight recording: {}", p.display()),
                None => "flight recording unavailable".into(),
            };
            mismatches.push(format!(
                "{label}: blessed {blessed:?}\n         observed {g:?}\n         {flight}"
            ));
        }
    }
    assert!(
        mismatches.is_empty(),
        "golden trajectories changed ({} of {}):\n{}\n\n\
         If this change is intentional, re-bless with `just regen-golden` and \
         commit the updated fixtures in the same PR.",
        mismatches.len(),
        observed.len(),
        mismatches.join("\n")
    );
}

#[test]
fn golden_observables_are_reproducible_in_process() {
    // the fixtures are only meaningful if two observations in the same
    // process agree bit-for-bit — run the cheapest case twice
    let cfg = golden_cfg(Method::GossipingSgdPush, 4);
    let (ra, pa) = run_sequential(&cfg);
    let (rb, pb) = run_sequential(&cfg);
    assert_eq!(Golden::from_run(&pa, &ra), Golden::from_run(&pb, &rb));
}

#[test]
fn golden_json_roundtrip() {
    let g = Golden {
        params_digest: 0xdeadbeef_12345678,
        train_loss_bits: vec![1, 2, 0xffffffff],
        rank0_bits: 7,
        aggregate_bits: 9,
        comm_bytes: 123456,
        wire_bytes: 999,
    };
    let back = Golden::from_json(&json::parse(&json::write(&g.to_json("x"))).unwrap()).unwrap();
    assert_eq!(g, back);
}
