//! Integration tests over the real PJRT path: artifact loading, fixture
//! cross-validation against jax, kernel parity, and a short end-to-end
//! training run on the compiled MLP.
//!
//! These tests are **fixture-gated**: they require `make artifacts` to
//! have run (a JAX toolchain box; the repo ships only the manifest
//! layout).  On a bare rust toolchain the whole file skips cleanly —
//! every test prints a visible `skipped: no artifacts` note and passes —
//! so `cargo test -q` stays green with zero external dependencies.

use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment;
use elastic_gossip::manifest::json;
use elastic_gossip::manifest::Manifest;
use elastic_gossip::prelude::*;
use elastic_gossip::runtime::{BatchX, GradEngine, HloEngine, KernelEngine};

/// The artifact directory, or `None` with a visible per-test skip note
/// when the JAX artifacts were never built on this box.
fn artifacts_or_skip(test: &str) -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "[integration_hlo::{test}] skipped: no artifacts — build them with \
             `make artifacts` (requires the python/JAX layer); the test passes \
             vacuously on a bare toolchain box"
        );
        None
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_or_skip("manifest_loads_and_is_consistent") else { return };
    let m = Manifest::load(&dir).unwrap();
    for model in ["mlp_small", "mlp_paper", "cnn_tiny", "lm_small"] {
        let meta = m.model(model).unwrap();
        assert!(meta.flat_size > 0);
        assert!(!m.train_batches(model).is_empty(), "{model}");
        m.eval_artifact(model).unwrap();
        // init file exists and has the right size
        let init = meta.init_file.as_ref().unwrap();
        let len = std::fs::metadata(init).unwrap().len() as usize;
        assert_eq!(len, meta.flat_size * 4, "{model} init size");
    }
}

/// Cross-language agreement: replay the jax-computed fixture through the
/// PJRT path and compare loss + gradient statistics.
#[test]
fn hlo_engine_matches_jax_fixtures() {
    let Some(dir) = artifacts_or_skip("hlo_engine_matches_jax_fixtures") else { return };
    let fixtures = json::parse(&std::fs::read_to_string(dir.join("fixtures.json")).unwrap()).unwrap();
    let fx = fixtures.path(&["mlp_small_train"]);
    let batch = fx.path(&["batch"]).as_usize().unwrap();
    let x: Vec<f32> = fx.path(&["x"]).as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let y: Vec<i32> = fx.path(&["y"]).as_arr().unwrap().iter().map(|v| v.as_i64().unwrap() as i32).collect();
    let seed = fx.path(&["seed"]).as_i64().unwrap() as i32;
    let want_loss = fx.path(&["loss"]).as_f64().unwrap() as f32;
    let want_g0_sum = fx.path(&["g0_sum"]).as_f64().unwrap() as f32;
    let want_g0_abs = fx.path(&["g0_abs_sum"]).as_f64().unwrap() as f32;

    let mut engine = HloEngine::load(&dir, "mlp_small", batch).unwrap();
    let params = engine.initial_params().unwrap();
    let mut grads = vec![0.0f32; engine.flat_size()];
    let loss = engine
        .loss_and_grad(&params, BatchX::F32(&x), &y, seed, &mut grads)
        .unwrap();
    assert!(
        (loss - want_loss).abs() < 1e-4 * (1.0 + want_loss.abs()),
        "loss {loss} vs jax {want_loss}"
    );
    let meta = Manifest::load(&dir).unwrap();
    let w0 = &meta.model("mlp_small").unwrap().params[0];
    let g0 = &grads[w0.offset..w0.offset + w0.size];
    let g0_sum: f32 = g0.iter().sum();
    let g0_abs: f32 = g0.iter().map(|x| x.abs()).sum();
    assert!((g0_sum - want_g0_sum).abs() < 2e-3 * (1.0 + want_g0_abs), "g0 sum {g0_sum} vs {want_g0_sum}");
    assert!((g0_abs - want_g0_abs).abs() < 2e-3 * (1.0 + want_g0_abs), "g0 |sum| {g0_abs} vs {want_g0_abs}");
}

/// The Pallas-lowered gossip kernel artifact agrees with both the jax
/// fixture and the rust-native implementation.
#[test]
fn gossip_kernel_parity_hlo_vs_rust_vs_jax() {
    let Some(dir) = artifacts_or_skip("gossip_kernel_parity_hlo_vs_rust_vs_jax") else { return };
    let fixtures = json::parse(&std::fs::read_to_string(dir.join("fixtures.json")).unwrap()).unwrap();
    let fx = fixtures.path(&["gossip_pair"]);
    let n = fx.path(&["n"]).as_usize().unwrap();
    let alpha = fx.path(&["alpha"]).as_f64().unwrap() as f32;

    let ke = KernelEngine::load(&dir, &format!("gossip_pair_n{n}")).unwrap();
    // regenerate deterministic inputs matching the fixture heads
    let head_ti: Vec<f32> = fx.path(&["ti_head"]).as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let head_tk: Vec<f32> = fx.path(&["tk_head"]).as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();

    // build full vectors: heads from fixture, tail deterministic
    let mut ti = vec![0.0f32; n];
    let mut tk = vec![0.0f32; n];
    let mut rng = Rng::new(99);
    for i in 0..n {
        ti[i] = if i < head_ti.len() { head_ti[i] } else { rng.gauss_f32() };
        tk[i] = if i < head_tk.len() { head_tk[i] } else { rng.gauss_f32() };
    }
    let (hi, hk) = ke.gossip_pair(&ti, &tk, alpha).unwrap();

    // rust-native path
    let mut ri = ti.clone();
    let mut rk = tk.clone();
    elastic_gossip::tensor::elastic_pair_update(&mut ri, &mut rk, alpha);
    for i in 0..n {
        assert!((hi[i] - ri[i]).abs() < 1e-5, "[{i}] hlo {} vs rust {}", hi[i], ri[i]);
        assert!((hk[i] - rk[i]).abs() < 1e-5, "[{i}] hlo {} vs rust {}", hk[i], rk[i]);
    }

    // jax fixture heads
    let want_gi: Vec<f32> = fx.path(&["gi_head"]).as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    for (i, w) in want_gi.iter().enumerate() {
        assert!((hi[i] - w).abs() < 1e-5, "[{i}] hlo {} vs jax {}", hi[i], w);
    }
}

/// The fused NAG kernel artifact matches the rust optimizer.
#[test]
fn nag_kernel_parity_hlo_vs_rust() {
    let Some(dir) = artifacts_or_skip("nag_kernel_parity_hlo_vs_rust") else { return };
    let ke = KernelEngine::load(&dir, "nag_n65536").unwrap();
    let n = ke.n;
    let mut rng = Rng::new(5);
    let theta: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
    let (eta, mu) = (0.001f32, 0.99f32);
    let (ht, hv) = ke.nag(&theta, &v, &g, eta, mu).unwrap();
    // rust path
    use elastic_gossip::optim::{LrSchedule, OptimKind, Optimizer};
    let mut opt = Optimizer::new(OptimKind::Nag { momentum: mu }, LrSchedule::Const(eta), n);
    let mut rt = theta.clone();
    // seed the optimizer's velocity with v by replaying: v' = mu*v - eta*g
    // (Optimizer starts at v=0, so compute expected manually)
    let mut expect_v = vec![0.0f32; n];
    let mut expect_t = theta.clone();
    for i in 0..n {
        expect_v[i] = mu * v[i] - eta * g[i];
        expect_t[i] = theta[i] - eta * g[i] + mu * expect_v[i];
    }
    for i in 0..n {
        assert!((hv[i] - expect_v[i]).abs() < 1e-5, "v[{i}]");
        assert!((ht[i] - expect_t[i]).abs() < 1e-5, "t[{i}]");
    }
    let _ = (&mut opt, &mut rt);
}

/// Short end-to-end HLO training run: loss must fall, accuracy must beat
/// chance, and the whole thing must be deterministic.
#[test]
fn hlo_training_converges_and_is_deterministic() {
    let Some(dir) = artifacts_or_skip("hlo_training_converges_and_is_deterministic") else { return };
    let cfg = ExperimentConfig {
        label: "it-hlo".into(),
        method: Method::ElasticGossip { alpha: 0.5 },
        workers: 4,
        schedule: CommSchedule::Probability(0.25),
        engine: EngineKind::Hlo { model: "mlp_small".into() },
        dataset: DatasetKind::SyntheticVectors { dim: 64 },
        n_train: 1024,
        n_val: 128,
        n_test: 128,
        effective_batch: 32,
        epochs: 3,
        seed: 3,
        eval_every: 1,
        artifact_dir: dir.clone(),
        ..ExperimentConfig::default()
    };
    let a = run_experiment(&cfg).unwrap();
    let first = a.metrics.curve.points.first().unwrap().train_loss;
    let last = a.metrics.curve.points.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
    assert!(a.rank0_accuracy > 0.15, "acc {}", a.rank0_accuracy); // chance = 0.1
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.rank0_accuracy, b.rank0_accuracy, "nondeterministic run");
    assert_eq!(a.metrics.comm_bytes, b.metrics.comm_bytes);
}

/// All-reduce on the real MLP keeps replicas bit-identical (the §2.1.1
/// equivalence, checked on the compiled model rather than the toy).
#[test]
fn hlo_allreduce_replicas_stay_identical() {
    let Some(dir) = artifacts_or_skip("hlo_allreduce_replicas_stay_identical") else { return };
    let cfg = ExperimentConfig {
        label: "it-ar".into(),
        method: Method::AllReduce { imp: elastic_gossip::collective::AllReduceImpl::Ring },
        workers: 4,
        schedule: CommSchedule::EveryStep,
        engine: EngineKind::Hlo { model: "mlp_small".into() },
        dataset: DatasetKind::SyntheticVectors { dim: 64 },
        n_train: 512,
        n_val: 64,
        n_test: 64,
        effective_batch: 32,
        epochs: 1,
        seed: 1,
        eval_every: 1,
        artifact_dir: dir,
        ..ExperimentConfig::default()
    };
    let r = run_experiment(&cfg).unwrap();
    // if replicas stayed identical, every worker reports the same val acc
    let p = r.metrics.curve.points.last().unwrap();
    let (lo, hi) = p.acc_range();
    assert!((hi - lo).abs() < 1e-6, "worker accs diverged: {:?}", p.worker_acc);
    // and aggregate == rank0 (mean of identical replicas)
    assert!((r.aggregate_accuracy - r.rank0_accuracy).abs() < 1e-6);
}

/// LM path: one gradient step through the transformer artifact.
#[test]
fn lm_engine_one_step() {
    let Some(dir) = artifacts_or_skip("lm_engine_one_step") else { return };
    let mut engine = HloEngine::load(&dir, "lm_small", 8).unwrap();
    assert_eq!(engine.task_kind(), TaskKind::LanguageModel);
    let params = engine.initial_params().unwrap();
    let ds = elastic_gossip::data::synthetic_corpus(8, 64, 9);
    let mut x = Vec::new();
    let mut y = Vec::new();
    elastic_gossip::data::gather_i32(&ds, &(0..8).collect::<Vec<_>>(), &mut x, &mut y);
    let mut grads = vec![0.0f32; engine.flat_size()];
    let loss = engine
        .loss_and_grad(&params, BatchX::I32(&x), &y, 0, &mut grads)
        .unwrap();
    // untrained byte LM: loss ~ ln(256) = 5.54
    assert!(loss > 3.0 && loss < 8.0, "loss {loss}");
    assert!(grads.iter().any(|&g| g != 0.0));
    assert!(grads.iter().all(|g| g.is_finite()));
}

/// CNN path: one gradient step + eval through the TinyResNet artifact
/// (the §4.2 CIFAR substitution).
#[test]
fn cnn_engine_one_step_and_eval() {
    let Some(dir) = artifacts_or_skip("cnn_engine_one_step_and_eval") else { return };
    let mut engine = HloEngine::load(&dir, "cnn_tiny", 16).unwrap();
    let params = engine.initial_params().unwrap();
    let ds = elastic_gossip::data::synthetic_cifar(engine.eval_batch().max(16), 4);
    let idx: Vec<usize> = (0..16).collect();
    let (mut x, mut y) = (Vec::new(), Vec::new());
    elastic_gossip::data::gather_f32(&ds, &idx, &mut x, &mut y);
    let mut grads = vec![0.0f32; engine.flat_size()];
    let loss = engine
        .loss_and_grad(&params, BatchX::F32(&x), &y, 0, &mut grads)
        .unwrap();
    assert!(loss > 1.0 && loss < 10.0, "loss {loss}"); // ~ln(10) untrained
    assert!(grads.iter().any(|&g| g != 0.0));
    assert!(grads.iter().all(|g| g.is_finite()));

    // masked eval over a full batch
    let b = engine.eval_batch();
    let idx: Vec<usize> = (0..b).collect();
    elastic_gossip::data::gather_f32(&ds, &idx, &mut x, &mut y);
    let (sl, nc) = engine
        .eval_batch_masked(&params, BatchX::F32(&x), &y, &vec![1.0; b])
        .unwrap();
    assert!(sl > 0.0);
    assert!((0.0..=b as f32).contains(&nc));
}

/// Stacked (vmapped-over-workers) dispatch computes the same losses and
/// gradients as per-worker dispatch — the EG_STACKED ablation is exact.
#[test]
fn stacked_dispatch_matches_looped() {
    let Some(dir) = artifacts_or_skip("stacked_dispatch_matches_looped") else { return };
    use elastic_gossip::runtime::BatchXOwned;
    let w = 4usize;
    let mut stacked = HloEngine::load_for_workers(&dir, "mlp_small", 8, w).unwrap();
    let mut looped = HloEngine::load(&dir, "mlp_small", 8).unwrap();
    let params: Vec<Vec<f32>> = (0..w)
        .map(|i| {
            let mut p = stacked.initial_params().unwrap();
            p.iter_mut().for_each(|x| *x += i as f32 * 0.01);
            p
        })
        .collect();
    let xs: Vec<BatchXOwned> = (0..w)
        .map(|k| BatchXOwned::F32((0..8 * 64).map(|i| ((i * (k + 2)) % 83) as f32 * 0.02).collect()))
        .collect();
    let ys: Vec<Vec<i32>> = (0..w).map(|k| (0..8).map(|i| ((i + k) % 10) as i32).collect()).collect();
    let seeds: Vec<i32> = vec![5, 6, 7, 8];
    let mut g_stacked = vec![vec![0.0f32; stacked.flat_size()]; w];
    let mut g_looped = vec![vec![0.0f32; looped.flat_size()]; w];
    let l_stacked = stacked
        .loss_and_grad_all(&params, &xs, &ys, &seeds, &mut g_stacked)
        .unwrap();
    let mut l_looped = Vec::new();
    for i in 0..w {
        l_looped.push(
            looped
                .loss_and_grad(&params[i], xs[i].as_ref(), &ys[i], seeds[i], &mut g_looped[i])
                .unwrap(),
        );
    }
    for i in 0..w {
        assert!(
            (l_stacked[i] - l_looped[i]).abs() < 1e-5,
            "loss[{i}] {} vs {}",
            l_stacked[i],
            l_looped[i]
        );
        for (a, b) in g_stacked[i].iter().zip(&g_looped[i]) {
            assert!((a - b).abs() < 1e-4, "grad mismatch worker {i}");
        }
    }
}
