//! Sim-vs-wire conformance: the loopback-UDP transport at zero induced
//! loss must be **digest-identical** to the pure in-process run.
//!
//! The wire plane splices real 127.0.0.1 sockets into the virtual-clock
//! delivery path — the simulator still makes every decision (schedules,
//! picks, delivery times), but the payload a strategy applies is whatever
//! actually crossed the wire.  Since the frame codec is lossless and UDP
//! over loopback at this scale drops nothing, the trajectories must match
//! bit for bit: same per-node final parameters, same digests, same
//! traffic ledger.  Every gossip method × every wire codec is pinned.
//!
//! These tests are **network-gated**: a sandbox that forbids binding
//! loopback sockets gets a visible `skipped: no network` note and a
//! vacuous pass, so `cargo test -q` stays green everywhere.

use elastic_gossip::comm::transport::{probe_loopback, TransportKind};
use elastic_gossip::membership::digest_params;
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncRunReport, AsyncSimCfg};

/// Loopback probe, or a visible per-test skip note.
fn network_or_skip(test: &str) -> bool {
    if probe_loopback() {
        true
    } else {
        eprintln!(
            "[transport_conformance::{test}] skipped: no network — this sandbox \
             forbids binding loopback UDP sockets; the test passes vacuously"
        );
        false
    }
}

fn run_with(
    method: &str,
    codec: &str,
    transport: TransportKind,
    sim: &AsyncSimCfg,
) -> AsyncRunReport {
    let m = elastic_gossip::algos::Method::parse(method).unwrap();
    let (mut cfg, spec) = study_setup(m, sim.speeds.len(), 0.25, 2, 11);
    cfg.codec = elastic_gossip::comm::codec::CodecKind::parse(codec).unwrap();
    cfg.transport = transport;
    run_async(&cfg, &spec, sim).unwrap()
}

/// Compare the full observable surface of two runs.
fn assert_conformant(a: &AsyncRunReport, b: &AsyncRunReport, what: &str) {
    assert_eq!(
        a.final_params.len(),
        b.final_params.len(),
        "{what}: node count diverged"
    );
    for (i, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(
            digest_params(pa),
            digest_params(pb),
            "{what}: node {i} final-parameter digest diverged"
        );
        assert_eq!(pa, pb, "{what}: node {i} final parameters diverged");
    }
    let (ma, mb) = (&a.report.metrics, &b.report.metrics);
    assert_eq!(ma.comm_bytes, mb.comm_bytes, "{what}: comm_bytes");
    assert_eq!(ma.wire_bytes, mb.wire_bytes, "{what}: wire_bytes");
    assert_eq!(ma.comm_messages, mb.comm_messages, "{what}: comm_messages");
    assert_eq!(
        elastic_gossip::manifest::json::write(&a.staleness.to_json()),
        elastic_gossip::manifest::json::write(&b.staleness.to_json()),
        "{what}: staleness histogram"
    );
    // the wire run decoded only well-formed frames
    assert_eq!(mb.malformed_frames, 0, "{what}: wire run saw malformed frames");
}

/// Every async method × every dense wire codec, zero-latency lockstep:
/// the wire run must be bit-identical to the in-process run.
#[test]
fn loopback_udp_matches_inproc_all_methods_and_codecs() {
    if !network_or_skip("loopback_udp_matches_inproc_all_methods_and_codecs") {
        return;
    }
    for method in ["elastic-gossip:0.5", "gossip-pull", "gossip-push", "gosgd"] {
        for codec in ["identity", "q8:64", "q4:64"] {
            let sim = AsyncSimCfg::lockstep(3);
            let inproc = run_with(method, codec, TransportKind::InProc, &sim);
            let wire = run_with(method, codec, TransportKind::LoopbackUdp, &sim);
            assert_conformant(&inproc, &wire, &format!("{method}/{codec}"));
        }
    }
}

/// A straggler-latency schedule reorders deliveries heavily; the
/// redemption layer (seq-keyed pending map) must still hand every
/// delivery its exact frame.
#[test]
fn loopback_udp_matches_inproc_under_straggler_reorder() {
    if !network_or_skip("loopback_udp_matches_inproc_under_straggler_reorder") {
        return;
    }
    let sim = AsyncSimCfg::straggler(4, 0.05, 0.1, 3.0);
    let inproc = run_with("elastic-gossip:0.5", "q8:64", TransportKind::InProc, &sim);
    let wire = run_with("elastic-gossip:0.5", "q8:64", TransportKind::LoopbackUdp, &sim);
    assert_conformant(&inproc, &wire, "straggler/elastic/q8");
}

/// The `udp` transport is the multi-process wire — the in-process
/// runtime must reject it loudly rather than half-support it.
#[test]
fn inprocess_runtime_rejects_udp_transport() {
    let m = elastic_gossip::algos::Method::parse("elastic-gossip:0.5").unwrap();
    let (mut cfg, spec) = study_setup(m, 2, 0.25, 1, 3);
    cfg.transport = TransportKind::Udp;
    let err = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(2))
        .unwrap_err()
        .to_string();
    assert!(err.contains("net-train"), "unhelpful error: {err}");
}
