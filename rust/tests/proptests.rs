//! Property-based tests over the coordinator's invariants, using the
//! crate's `proptest_mini` harness (DESIGN.md §7).
//!
//! These are the invariants the thesis's arguments rest on:
//! * elastic symmetry — a gossip round conserves the global parameter sum
//! * push-sum mass conservation (GoSGD)
//! * matchmaker set-K correctness (Algorithm 4 line 6)
//! * ring/tree all-reduce ≡ naive mean
//! * partitioner completeness/disjointness
//! * All-reduce SGD ≡ single-worker large-batch SGD (§2.1.1)

use elastic_gossip::algos::{gossip_picks, k_sets, CommCtx, Method, ScratchArena, Strategy};
use elastic_gossip::algos::central::AllReduceStrategy;
use elastic_gossip::algos::gossip::{ElasticGossipStrategy, GoSgdStrategy, PullGossipStrategy};
use elastic_gossip::collective::AllReduceImpl;
use elastic_gossip::comm::codec::{Codec, CodecKind};
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::config::{CommSchedule, ExperimentConfig};
use elastic_gossip::coordinator::{synthetic_cfg, Coordinator};
use elastic_gossip::data::{synthetic_vectors, Partition};
use elastic_gossip::membership::{ChurnSpec, FaultSpec, FdSpec};
use elastic_gossip::proptest_mini::{forall, prop_assert, prop_close, Gen, PropResult};
use elastic_gossip::runtime::{BatchX, GradEngine, SyntheticEngine, SyntheticSpec};
use elastic_gossip::runtime_async::{run_async, AsyncSimCfg};
use elastic_gossip::tensor;
use elastic_gossip::topology::Topology;
use elastic_gossip::util::rng::Rng;

fn random_params(g: &mut Gen, w: usize, n: usize) -> Vec<Vec<f32>> {
    (0..w).map(|_| g.vec_gauss(n)).collect()
}

fn run_round(strategy: &mut dyn Strategy, params: &mut Vec<Vec<f32>>, comm: &[bool], rng: &mut Rng) {
    run_round_on(strategy, params, comm, &Topology::Full, rng)
}

fn run_round_on(
    strategy: &mut dyn Strategy,
    params: &mut Vec<Vec<f32>>,
    comm: &[bool],
    topology: &Topology,
    rng: &mut Rng,
) {
    let w = params.len();
    let mut grads = vec![vec![0.0f32; params[0].len()]; w];
    let mut fabric = Fabric::new(w + 1, LinkModel::default());
    let mut arena = ScratchArena::new();
    let mut ctx = CommCtx {
        params,
        grads: &mut grads,
        fabric: &mut fabric,
        topology,
        step: 0,
        communicating: comm,
        arena: &mut arena,
    };
    strategy.comm_round(&mut ctx, rng).unwrap();
}

#[test]
fn prop_elastic_round_conserves_global_sum() {
    forall("elastic gossip conserves sum", 150, |g| {
        let w = g.usize_in(2, 10);
        let n = g.usize_in(1, 200);
        let alpha = g.f32_in(0.0, 1.0);
        let mut params = random_params(g, w, n);
        let before: f64 = params.iter().flatten().map(|&x| x as f64).sum();
        let comm = g.mask(w, 0.7);
        let mut s = ElasticGossipStrategy::new(alpha);
        let mut rng = Rng::new(g.rng().next_u64());
        run_round(&mut s, &mut params, &comm, &mut rng);
        let after: f64 = params.iter().flatten().map(|&x| x as f64).sum();
        prop_assert(
            (before - after).abs() < 1e-3 * (1.0 + before.abs()),
            format!("sum {before} -> {after} (w={w} n={n} alpha={alpha})"),
        )
    });
}

#[test]
fn prop_elastic_alpha_zero_is_identity() {
    forall("alpha=0 identity", 60, |g| {
        let w = g.usize_in(2, 8);
        let n = g.usize_in(1, 100);
        let mut params = random_params(g, w, n);
        let orig = params.clone();
        let comm = g.mask(w, 0.9);
        let mut s = ElasticGossipStrategy::new(0.0);
        let mut rng = Rng::new(g.rng().next_u64());
        run_round(&mut s, &mut params, &comm, &mut rng);
        for (a, b) in params.iter().zip(&orig) {
            prop_close(a, b, 0.0, "alpha=0 must not move params")?;
        }
        Ok(())
    });
}

#[test]
fn prop_gosgd_mass_conservation() {
    forall("gosgd mass conservation", 100, |g| {
        let w = g.usize_in(2, 12);
        let n = g.usize_in(1, 64);
        let mut params = random_params(g, w, n);
        let mut s = GoSgdStrategy::new(w);
        let mut rng = Rng::new(g.rng().next_u64());
        let rounds = g.usize_in(1, 20);
        for _ in 0..rounds {
            let comm = g.mask(w, 0.5);
            run_round(&mut s, &mut params, &comm, &mut rng);
            let mass: f64 = s.weights.iter().sum();
            prop_assert((mass - 1.0).abs() < 1e-9, format!("mass {mass}"))?;
            for &wi in &s.weights {
                prop_assert(wi > 0.0, format!("non-positive weight {wi}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gosgd_weighted_mean_invariant() {
    // push-sum: SUM_i w_i * theta_i is invariant under communication
    forall("gosgd weighted mean invariant", 80, |g| {
        let w = g.usize_in(2, 8);
        let n = g.usize_in(1, 32);
        let mut params = random_params(g, w, n);
        let mut s = GoSgdStrategy::new(w);
        let mut rng = Rng::new(g.rng().next_u64());
        let before: Vec<f64> = (0..n)
            .map(|j| params.iter().zip(&s.weights).map(|(p, &wi)| p[j] as f64 * wi).sum())
            .collect();
        for _ in 0..5 {
            let comm = g.mask(w, 0.6);
            run_round(&mut s, &mut params, &comm, &mut rng);
        }
        let after: Vec<f64> = (0..n)
            .map(|j| params.iter().zip(&s.weights).map(|(p, &wi)| p[j] as f64 * wi).sum())
            .collect();
        for (a, b) in before.iter().zip(&after) {
            prop_assert((a - b).abs() < 1e-3, format!("weighted mean drifted {a} -> {b}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_k_sets_match_algorithm_4() {
    forall("k-set semantics", 200, |g| {
        let w = g.usize_in(2, 16);
        let comm = g.mask(w, 0.5);
        let mut rng = Rng::new(g.rng().next_u64());
        let picks = gossip_picks(&comm, &Topology::Full, &mut rng);
        let ks = k_sets(&picks);
        // 1. a communicating worker has its pick in K; non-communicating
        //    workers only appear through reverse edges
        for i in 0..w {
            match picks[i] {
                Some(k) => {
                    prop_assert(ks[i].contains(&k), format!("own pick {k} missing from K[{i}]"))?;
                    prop_assert(k != i, "self-pick".to_string())?;
                    prop_assert(comm[i], format!("{i} picked but not communicating"))?;
                }
                None => prop_assert(!comm[i] || w < 2, format!("{i} communicating but no pick"))?,
            }
        }
        // 2. edge symmetry: j in K[i] exactly as many times as edges (i,j)
        let mut edge_count = std::collections::BTreeMap::new();
        for (i, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                *edge_count.entry((i.min(k), i.max(k))).or_insert(0u32) += 1;
            }
        }
        for ((a, b), cnt) in edge_count {
            let in_a = ks[a].iter().filter(|&&x| x == b).count() as u32;
            let in_b = ks[b].iter().filter(|&&x| x == a).count() as u32;
            prop_assert(in_a == cnt && in_b == cnt, format!("edge ({a},{b}) counts {in_a}/{in_b} != {cnt}"))?;
        }
        // 3. total K mass = 2 * number of picks
        let total: usize = ks.iter().map(Vec::len).sum();
        let picked = picks.iter().flatten().count();
        prop_assert(total == 2 * picked, format!("K mass {total} != 2*{picked}"))
    });
}

#[test]
fn prop_all_allreduce_impls_agree() {
    forall("allreduce impls agree", 80, |g| {
        let w = g.usize_in(2, 9);
        let n = g.usize_in(1, 300);
        let bufs: Vec<Vec<f32>> = (0..w).map(|_| g.vec_gauss(n)).collect();
        // naive mean
        let mut expect = vec![0.0f64; n];
        for b in &bufs {
            for (e, &x) in expect.iter_mut().zip(b) {
                *e += x as f64;
            }
        }
        let expect: Vec<f32> = expect.iter().map(|&x| (x / w as f64) as f32).collect();
        for imp in [AllReduceImpl::Central, AllReduceImpl::Tree, AllReduceImpl::Ring] {
            let mut work = bufs.clone();
            let mut fabric = Fabric::new(w, LinkModel::default());
            imp.all_reduce_mean(&mut work, &mut fabric);
            for (i, b) in work.iter().enumerate() {
                prop_close(b, &expect, 1e-4, &format!("{imp:?} worker {i}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partitioner_complete_and_disjoint() {
    forall("partitioner complete+disjoint", 80, |g| {
        let n = g.usize_in(1, 500);
        let w = g.usize_in(1, 9);
        let ds = synthetic_vectors(n, 4, 10, g.rng().next_u64());
        let beta = g.f64_in(0.05, 10.0);
        let part = if g.bool() {
            Partition::Iid
        } else {
            Partition::DirichletSkew { beta }
        };
        let mut rng = Rng::new(g.rng().next_u64());
        let shards = part.assign(&ds, w, &mut rng);
        prop_assert(shards.len() == w, "shard count".to_string())?;
        let mut all: Vec<usize> = shards.concat();
        all.sort();
        let expect: Vec<usize> = (0..n).collect();
        prop_assert(all == expect, format!("{part:?}: not a partition of 0..{n}"))
    });
}

#[test]
fn prop_allreduce_sgd_equals_large_batch_sgd() {
    // §2.1.1: All-reduce SGD == single-worker SGD with |W|x batch when the
    // gradient is linear in theta (exact for the synthetic engine).
    forall("AR == large-batch SGD", 60, |g| {
        let w = g.usize_in(2, 6);
        let n = g.usize_in(1, 24);
        let b = 4usize;
        let lr = g.f32_in(0.001, 0.2);
        let mut dist = SyntheticEngine::new(n, 5, b, 8, 7);
        let mut single = SyntheticEngine::new(n, 5, b * w, 8, 7);
        let mut theta_dist: Vec<Vec<f32>> = vec![g.vec_gauss(n); w];
        let mut theta_single = theta_dist[0].clone();
        let mut rng = Rng::new(g.rng().next_u64());
        for _ in 0..5 {
            // one batch per worker; the single worker sees the union
            let ys: Vec<Vec<i32>> = (0..w)
                .map(|_| (0..b).map(|_| rng.below(5) as i32).collect())
                .collect();
            let mut grads: Vec<Vec<f32>> = vec![vec![0.0; n]; w];
            for i in 0..w {
                dist.loss_and_grad(&theta_dist[i], BatchX::F32(&[]), &ys[i], 0, &mut grads[i])
                    .unwrap();
            }
            // all-reduce on grads
            let mut fabric = Fabric::new(w, LinkModel::default());
            let mut s = AllReduceStrategy::new(AllReduceImpl::Ring);
            {
                let comm = vec![true; w];
                let mut arena = ScratchArena::new();
                let mut ctx = CommCtx {
                    params: &mut theta_dist,
                    grads: &mut grads,
                    fabric: &mut fabric,
                    topology: &Topology::Full,
                    step: 0,
                    communicating: &comm,
                    arena: &mut arena,
                };
                s.comm_round(&mut ctx, &mut rng).unwrap();
            }
            for i in 0..w {
                for (t, &gr) in theta_dist[i].iter_mut().zip(&grads[i]) {
                    *t -= lr * gr;
                }
            }
            // single large batch
            let yall: Vec<i32> = ys.concat();
            let mut gs = vec![0.0f32; n];
            single
                .loss_and_grad(&theta_single, BatchX::F32(&[]), &yall, 0, &mut gs)
                .unwrap();
            for (t, &gr) in theta_single.iter_mut().zip(&gs) {
                *t -= lr * gr;
            }
        }
        for i in 0..w {
            prop_close(&theta_dist[i], &theta_single, 1e-4, &format!("worker {i} vs single"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_pull_gossip_moves_toward_peer() {
    forall("pull gossip halves distance", 80, |g| {
        let n = g.usize_in(1, 64);
        let mut params = vec![g.vec_gauss(n), g.vec_gauss(n)];
        let before: Vec<f32> = params[0]
            .iter()
            .zip(&params[1])
            .map(|(a, b)| (a - b).abs())
            .collect();
        let comm = vec![true, false];
        let mut rng = Rng::new(g.rng().next_u64());
        let mut s = PullGossipStrategy;
        run_round(&mut s, &mut params, &comm, &mut rng);
        for (j, d0) in before.iter().enumerate() {
            let d1 = (params[0][j] - params[1][j]).abs();
            prop_assert(d1 <= d0 * 0.5 + 1e-6, format!("[{j}] {d0} -> {d1}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_fused_multi_pull_matches_naive_bit_for_bit() {
    // the fused multi-peer kernel must reproduce the per-peer reference
    // loop exactly — not approximately: same f32 op sequence per element
    forall("fused elastic_multi_pull == naive", 150, |g| {
        let n = g.usize_in(1, 2000);
        let peers = g.usize_in(0, 12);
        let alpha = g.f32_in(0.0, 1.0);
        let snap_self = g.vec_gauss(n);
        let snaps: Vec<Vec<f32>> = (0..peers).map(|_| g.vec_gauss(n)).collect();
        let refs: Vec<&[f32]> = snaps.iter().map(|s| s.as_slice()).collect();
        let dst0 = g.vec_gauss(n);

        let mut fused = dst0.clone();
        tensor::elastic_multi_pull(&mut fused, &snap_self, &refs, alpha);

        let mut naive = dst0;
        for s in &snaps {
            for ((t, &si), &sk) in naive.iter_mut().zip(&snap_self).zip(s) {
                *t -= alpha * (si - sk);
            }
        }
        for (i, (a, b)) in fused.iter().zip(&naive).enumerate() {
            prop_assert(
                a.to_bits() == b.to_bits(),
                format!("[{i}] fused {a} != naive {b} (n={n} peers={peers})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_refactored_round_conserves_sum_any_topology() {
    // elastic symmetry survives the scratch-arena rewrite at every
    // topology and participation pattern, including sparse masks where
    // only a few slots get snapshotted
    forall("arena elastic round conserves sum on all topologies", 120, |g| {
        let w = g.usize_in(2, 12);
        let n = g.usize_in(1, 150);
        let alpha = g.f32_in(0.0, 1.0);
        let topo = match g.usize_in(0, 2) {
            0 => Topology::Full,
            1 => Topology::Ring,
            _ => Topology::RandomRegular { degree: 2, seed: g.rng().next_u64() },
        };
        let p_comm = g.f64_in(0.0, 1.0);
        let mut params = random_params(g, w, n);
        let before: f64 = params.iter().flatten().map(|&x| x as f64).sum();
        let comm = g.mask(w, p_comm);
        let mut s = ElasticGossipStrategy::new(alpha);
        let mut rng = Rng::new(g.rng().next_u64());
        for _ in 0..3 {
            run_round_on(&mut s, &mut params, &comm, &topo, &mut rng);
        }
        let after: f64 = params.iter().flatten().map(|&x| x as f64).sum();
        prop_assert(
            (before - after).abs() < 1e-3 * (1.0 + before.abs()),
            format!("sum {before} -> {after} (w={w} n={n} alpha={alpha} {topo:?})"),
        )
    });
}

/// Build a small synthetic-engine experiment + its factory for the
/// async↔sync equivalence properties.
fn async_equiv_cfg(g: &mut Gen, method: Method, w: usize) -> (ExperimentConfig, SyntheticSpec) {
    let mut cfg = synthetic_cfg(method, w, 16);
    cfg.seed = g.rng().next_u64();
    cfg.schedule = CommSchedule::Probability(g.f64_in(0.1, 0.9));
    cfg.epochs = 2;
    let spec = SyntheticSpec::for_cfg(&cfg).unwrap();
    (cfg, spec)
}

#[test]
fn prop_async_lockstep_equals_sequential_coordinator() {
    // the tentpole's equivalence claim as a property: for every pairwise
    // gossip method, worker count, seed and communication probability,
    // the event-driven runtime under zero latency + lockstep speeds
    // reproduces the sequential coordinator's parameter trajectory
    // bit-for-bit, and every exchange lands with zero staleness
    forall("async lockstep == sequential", 24, |g| {
        let w = g.usize_in(2, 6);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (cfg, spec) = async_equiv_cfg(g, method.clone(), w);

        // sequential reference: capture the final per-worker parameters
        let last = cfg.total_steps() - 1;
        let mut seq_params: Vec<Vec<f32>> = Vec::new();
        let seq = {
            let mut c = Coordinator::new(&cfg, &spec);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    seq_params = p.to_vec();
                }
            }));
            c.run().unwrap()
        };

        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        for (i, (a, s)) in asy.final_params.iter().zip(&seq_params).enumerate() {
            for (j, (x, y)) in a.iter().zip(s).enumerate() {
                prop_assert(
                    x.to_bits() == y.to_bits(),
                    format!("{method:?} w={w}: param[{i}][{j}] async {x} != seq {y}"),
                )?;
            }
        }
        prop_assert(
            asy.report.rank0_accuracy == seq.rank0_accuracy,
            format!("{method:?}: rank0 accuracy diverged"),
        )?;
        let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        let la: Vec<f32> = asy.report.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        prop_assert(ls == la, format!("{method:?}: loss curves diverged"))?;
        prop_assert(
            asy.staleness.max() == 0,
            format!("{method:?}: lockstep exchange was stale"),
        )
    });
}

#[test]
fn prop_async_straggler_is_deterministic_and_conserves_gosgd_mass() {
    // the asynchrony the thesis wants is *controlled*: a fixed seed must
    // reproduce the identical staleness histogram and parameters, and
    // GoSGD's push-sum mass survives arbitrary speed skew + link latency
    forall("async straggler determinism", 10, |g| {
        let w = g.usize_in(2, 5);
        let (mut cfg, spec) = async_equiv_cfg(g, Method::GoSgd, w);
        cfg.epochs = 1;
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.3), g.f64_in(1.0, 5.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.05), bandwidth_bps: 1e8 };
        sim.speed_seed = g.rng().next_u64();
        let a = run_async(&cfg, &spec, &sim).unwrap();
        let b = run_async(&cfg, &spec, &sim).unwrap();
        prop_assert(a.final_params == b.final_params, "nondeterministic async params".into())?;
        prop_assert(a.staleness == b.staleness, "nondeterministic staleness histogram".into())?;
        let mass = a.push_sum_mass.unwrap();
        prop_assert(
            (mass - 1.0).abs() < 1e-9,
            format!("push-sum mass drifted under async: {mass}"),
        )
    });
}

// ---------------------------------------------------------------------------
// wire-codec conformance (comm::codec)
// ---------------------------------------------------------------------------

#[test]
fn prop_identity_codec_roundtrip_is_bit_exact() {
    // the invariant the async equivalence suite rests on: with the
    // identity codec in the path, nothing about a payload can change
    forall("identity codec roundtrip", 120, |g| {
        let n = g.usize_in(1, 3000);
        let mut src = g.vec_gauss(n);
        if n > 2 && g.bool() {
            src[0] = f32::NAN;
            src[1] = -0.0;
        }
        let mut codec = CodecKind::Identity.build();
        let mut wire = Vec::new();
        codec.encode_into(g.usize_in(0, 7), &src, &mut wire);
        prop_assert(wire.len() == 4 * n, format!("wire {} != {}", wire.len(), 4 * n))?;
        let mut back = vec![0.0f32; n];
        codec.decode_into(&wire, &mut back).unwrap();
        for (i, (a, b)) in src.iter().zip(&back).enumerate() {
            prop_assert(
                a.to_bits() == b.to_bits(),
                format!("[{i}] {a} != {b} after identity roundtrip"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_q8_roundtrip_error_within_chunk_bound() {
    forall("q8 roundtrip bound", 120, |g| {
        let n = g.usize_in(1, 4000);
        let chunk = g.usize_in(1, 700);
        let scale_amp = g.f32_in(0.01, 50.0);
        let src: Vec<f32> = g.vec_gauss(n).iter().map(|&x| x * scale_amp).collect();
        let mut codec = CodecKind::Q8 { chunk }.build();
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        prop_assert(
            wire.len() == codec.encoded_len(n),
            format!("wire {} != encoded_len {}", wire.len(), codec.encoded_len(n)),
        )?;
        let mut back = vec![0.0f32; n];
        codec.decode_into(&wire, &mut back).unwrap();
        for (c, (s, b)) in src.chunks(chunk).zip(back.chunks(chunk)).enumerate() {
            let lo = s.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let step = (hi - lo) / 255.0;
            let bound = step * 0.51 + 1e-6 * (lo.abs() + hi.abs() + 1.0);
            for (i, (&x, &y)) in s.iter().zip(b).enumerate() {
                prop_assert(
                    (x - y).abs() <= bound,
                    format!(
                        "chunk {c} [{i}]: |{x} - {y}| > bound {bound} (n={n} chunk={chunk})"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q4_roundtrip_error_within_chunk_bound() {
    // sub-byte sibling of the q8 property: 4-bit codes quantize to a
    // 16-level grid per chunk, so the half-step bound uses /15
    forall("q4 roundtrip bound", 120, |g| {
        let n = g.usize_in(1, 4000);
        let chunk = g.usize_in(1, 700);
        let scale_amp = g.f32_in(0.01, 50.0);
        let src: Vec<f32> = g.vec_gauss(n).iter().map(|&x| x * scale_amp).collect();
        let mut codec = CodecKind::Q4 { chunk }.build();
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        prop_assert(
            wire.len() == codec.encoded_len(n),
            format!("wire {} != encoded_len {}", wire.len(), codec.encoded_len(n)),
        )?;
        let mut back = vec![0.0f32; n];
        codec.decode_into(&wire, &mut back).unwrap();
        for (c, (s, b)) in src.chunks(chunk).zip(back.chunks(chunk)).enumerate() {
            let lo = s.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let step = (hi - lo) / 15.0;
            let bound = step * 0.51 + 1e-6 * (lo.abs() + hi.abs() + 1.0);
            for (i, (&x, &y)) in s.iter().zip(b).enumerate() {
                prop_assert(
                    (x - y).abs() <= bound,
                    format!(
                        "chunk {c} [{i}]: |{x} - {y}| > bound {bound} (n={n} chunk={chunk})"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_error_feedback_drains_and_overlays() {
    forall("topk error feedback", 80, |g| {
        let n = g.usize_in(1, 600);
        let frac = g.f64_in(0.02, 0.5);
        let src = g.vec_gauss(n);
        let mut codec = CodecKind::TopK { frac }.build();
        let k = ((frac * n as f64).round() as usize).clamp(1, n);
        prop_assert(
            codec.encoded_len(n) == 8 + 8 * k,
            format!("encoded_len {} != {}", codec.encoded_len(n), 8 + 8 * k),
        )?;
        let mut recv = vec![0.0f32; n];
        let mut wire = Vec::new();
        // each send overlays at most k coordinates ...
        codec.encode_into(0, &src, &mut wire);
        let before = recv.clone();
        codec.decode_into(&wire, &mut recv).unwrap();
        let changed = recv.iter().zip(&before).filter(|(a, b)| a != b).count();
        prop_assert(changed <= k, format!("overlay touched {changed} > k = {k}"))?;
        // ... and the carried residual drains the full vector within
        // ceil(n/k) sends of a fixed source
        for _ in 0..n.div_ceil(k) {
            codec.encode_into(0, &src, &mut wire);
            codec.decode_into(&wire, &mut recv).unwrap();
        }
        for (i, (a, b)) in src.iter().zip(&recv).enumerate() {
            prop_assert(
                a.to_bits() == b.to_bits(),
                format!("[{i}] never transmitted (n={n} k={k})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_async_lockstep_with_identity_codec_in_path_stays_bit_identical() {
    // the satellite claim, stated directly: threading the codec layer
    // through send/delivery must not perturb the lockstep equivalence
    forall("identity codec lockstep equivalence", 8, |g| {
        let w = g.usize_in(2, 5);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        cfg.codec = CodecKind::Identity;
        let last = cfg.total_steps() - 1;
        let mut seq_params: Vec<Vec<f32>> = Vec::new();
        {
            let mut c = Coordinator::new(&cfg, &spec);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    seq_params = p.to_vec();
                }
            }));
            c.run().unwrap();
        }
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        prop_assert(
            asy.final_params == seq_params,
            format!("{method:?} w={w}: identity-codec lockstep diverged"),
        )?;
        prop_assert(
            asy.report.metrics.wire_bytes == asy.report.metrics.comm_bytes,
            format!(
                "identity codec must not change wire accounting: {} vs {}",
                asy.report.metrics.wire_bytes, asy.report.metrics.comm_bytes
            ),
        )
    });
}

#[test]
fn prop_topk_error_feedback_conserves_gosgd_mass_in_flight() {
    // lossy params, exact weights: push-sum mass survives top-k
    // sparsification and arbitrary in-flight latency
    forall("topk gosgd mass conservation", 6, |g| {
        let w = g.usize_in(2, 5);
        let (mut cfg, spec) = async_equiv_cfg(g, Method::GoSgd, w);
        cfg.epochs = 1;
        // frac capped at 0.35: at the test's flat size (16) a GoSgdShare
        // is 72 raw bytes and the topk stream is 16 + 8k — k <= 6 keeps
        // the strict wire < raw assertion below satisfiable
        cfg.codec = CodecKind::TopK { frac: g.f64_in(0.05, 0.35) };
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.3), g.f64_in(1.0, 4.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.05), bandwidth_bps: 1e7 };
        sim.speed_seed = g.rng().next_u64();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let mass = asy.push_sum_mass.unwrap();
        prop_assert(
            (mass - 1.0).abs() < 1e-9,
            format!("push-sum mass drifted under topk codec: {mass}"),
        )?;
        prop_assert(
            asy.report.metrics.comm_bytes == 0
                || asy.report.metrics.wire_bytes < asy.report.metrics.comm_bytes,
            "topk must shrink bytes-on-wire".to_string(),
        )
    });
}

// ---------------------------------------------------------------------------
// elastic membership (crate::membership)
// ---------------------------------------------------------------------------

/// Build a random-but-valid churn spec: distinct crash victims among
/// 1..w (node 0 survives), a subset rejoining later, optionally a fresh
/// join of a brand-new node id.
fn random_churn_spec(g: &mut Gen, w: usize) -> ChurnSpec {
    let mut victims: Vec<usize> = (1..w).collect();
    let mut rng = Rng::new(g.rng().next_u64());
    rng.shuffle(&mut victims);
    let crashes = g.usize_in(1, (w - 1).min(3));
    victims.truncate(crashes);
    let mut parts: Vec<String> = Vec::new();
    for &v in &victims {
        let kind = if g.bool() { "crash" } else { "leave" };
        parts.push(format!("{kind}@{}%:{v}", g.usize_in(18, 52)));
    }
    let rejoins = g.usize_in(0, victims.len());
    for &v in victims.iter().take(rejoins) {
        parts.push(format!("rejoin@{}%:{v}", g.usize_in(62, 88)));
    }
    if g.bool() {
        parts.push(format!("join@{}%:{w}", g.usize_in(35, 60)));
    }
    ChurnSpec::parse(&parts.join(",")).unwrap()
}

#[test]
fn prop_async_lockstep_with_empty_churn_schedule_is_bit_identical() {
    // the no-churn equivalence satellite, stated directly: an explicitly
    // set empty `churn:` schedule must leave the membership-aware
    // runtime bit-identical to the sequential coordinator
    forall("empty churn schedule lockstep equivalence", 8, |g| {
        let w = g.usize_in(2, 5);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        cfg.churn = ChurnSpec::parse("churn:none").unwrap();
        let last = cfg.total_steps() - 1;
        let mut seq_params: Vec<Vec<f32>> = Vec::new();
        {
            let sync_cfg = ExperimentConfig { churn: ChurnSpec::none(), ..cfg.clone() };
            let mut c = Coordinator::new(&sync_cfg, &spec);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    seq_params = p.to_vec();
                }
            }));
            c.run().unwrap();
        }
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        prop_assert(
            asy.final_params == seq_params,
            format!("{method:?} w={w}: empty churn schedule perturbed the trajectory"),
        )?;
        prop_assert(
            asy.membership.applied.is_empty() && asy.report.metrics.dropped_messages == 0,
            "empty schedule must apply no events and drop nothing".into(),
        )
    });
}

#[test]
fn prop_gosgd_mass_is_one_under_random_churn() {
    // THE hard invariant: push-sum mass == 1 at termination through
    // arbitrary crash/leave/join/rejoin interleavings, lossy codecs and
    // in-flight messages at every departure instant
    forall("gosgd mass under random churn", 12, |g| {
        let w = g.usize_in(3, 7);
        let (mut cfg, spec) = async_equiv_cfg(g, Method::GoSgd, w);
        cfg.epochs = 2;
        cfg.churn = if g.bool() {
            random_churn_spec(g, w)
        } else {
            ChurnSpec::parse(&format!(
                "rand:{}:{}:{}",
                g.usize_in(1, w - 1),
                g.usize_in(0, 2),
                g.rng().next_u64()
            ))
            .unwrap()
        };
        if g.bool() {
            cfg.codec = CodecKind::TopK { frac: g.f64_in(0.1, 0.4) };
        }
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.3), g.f64_in(1.0, 4.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.05), bandwidth_bps: 1e7 };
        sim.speed_seed = g.rng().next_u64();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let mass = asy.push_sum_mass.unwrap();
        prop_assert(
            (mass - 1.0).abs() < 1e-9,
            format!(
                "push-sum mass {mass} after churn `{}` (events {:?})",
                cfg.churn.label(),
                asy.membership.applied
            ),
        )
    });
}

#[test]
fn prop_churn_replay_is_deterministic() {
    // same seed + same `churn:` spec => identical applied-event trace,
    // identical final parameters, identical dropped ledger
    forall("churn replay determinism", 8, |g| {
        let w = g.usize_in(3, 6);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method, w);
        cfg.epochs = 2;
        cfg.churn = random_churn_spec(g, w);
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.2), g.f64_in(1.0, 3.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.03), bandwidth_bps: 1e8 };
        sim.speed_seed = g.rng().next_u64();
        let a = run_async(&cfg, &spec, &sim).unwrap();
        let b = run_async(&cfg, &spec, &sim).unwrap();
        prop_assert(a.membership == b.membership, "membership trace diverged".into())?;
        prop_assert(a.final_params == b.final_params, "final params diverged".into())?;
        prop_assert(
            a.report.metrics.dropped_messages == b.report.metrics.dropped_messages
                && a.report.metrics.dropped_bytes == b.report.metrics.dropped_bytes,
            "dropped ledger diverged".into(),
        )
    });
}

#[test]
fn prop_join_bootstrap_adopts_donor_state_exactly() {
    // a joiner's parameters equal its bootstrap donor's at pull time —
    // for fresh joins and crash-recovery rejoins alike (the reply is
    // codec-exempt, so this holds under lossy codecs too)
    forall("join bootstrap exactness", 10, |g| {
        let w = g.usize_in(3, 6);
        let (mut cfg, spec) = async_equiv_cfg(g, Method::GossipingSgdPush, w);
        cfg.epochs = 2;
        let mut parts = vec![format!("join@{}%:{w}", g.usize_in(30, 55))];
        if g.bool() {
            let v = g.usize_in(1, w - 1);
            parts.insert(0, format!("crash@{}%:{v}", g.usize_in(15, 40)));
            parts.push(format!("rejoin@{}%:{v}", g.usize_in(60, 85)));
        }
        if g.bool() {
            cfg.codec = CodecKind::Q8 { chunk: 64 };
        }
        cfg.churn = ChurnSpec::parse(&parts.join(",")).unwrap();
        let mut sim = AsyncSimCfg::straggler(w, 0.03, g.f64_in(0.0, 0.2), g.f64_in(1.0, 3.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.02), bandwidth_bps: 1e8 };
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        prop_assert(!asy.membership.bootstraps.is_empty(), "no bootstrap recorded".into())?;
        for b in &asy.membership.bootstraps {
            prop_assert(
                b.donor_digest == b.adopted_digest,
                format!(
                    "joiner {} adopted different state than donor {} served",
                    b.joiner, b.donor
                ),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// failure detection + link faults (crate::membership fd/fault planes)
// ---------------------------------------------------------------------------

#[test]
fn prop_async_lockstep_with_empty_fault_and_fd_specs_is_bit_identical() {
    // the byte-identical-when-disabled satellite, stated directly: an
    // explicitly set empty `faults:` plan and a `fd:off` detector must
    // leave the runtime bit-identical to the sequential coordinator
    forall("empty faults/fd lockstep equivalence", 8, |g| {
        let w = g.usize_in(2, 5);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        cfg.faults = FaultSpec::parse("faults:none").unwrap();
        cfg.fd = FdSpec::parse("fd:off").unwrap();
        let last = cfg.total_steps() - 1;
        let mut seq_params: Vec<Vec<f32>> = Vec::new();
        {
            let mut c = Coordinator::new(&cfg, &spec);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    seq_params = p.to_vec();
                }
            }));
            c.run().unwrap();
        }
        let asy = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        prop_assert(
            asy.final_params == seq_params,
            format!("{method:?} w={w}: empty faults/fd specs perturbed the trajectory"),
        )?;
        prop_assert(
            asy.membership.fd.is_none() && asy.report.metrics.dropped_messages == 0,
            "disabled detector must attach no report and drop nothing".into(),
        )
    });
}

#[test]
fn prop_fd_with_perfect_links_never_confirms_a_death() {
    // detector safety: timeouts far above the RTT and nothing actually
    // failing => the plane probes continuously but never even suspects
    forall("fd safety under generous timeouts", 8, |g| {
        let w = g.usize_in(3, 6);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method, w);
        cfg.fd = FdSpec::parse(&format!(
            "{:.3}:1.0:2.0:{}",
            g.f64_in(0.02, 0.1),
            g.usize_in(0, 3)
        ))
        .unwrap();
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.2), g.f64_in(1.0, 3.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.02), bandwidth_bps: 1e8 };
        sim.speed_seed = g.rng().next_u64();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let fd = asy.membership.fd.as_ref().unwrap();
        prop_assert(fd.probes > 0 && fd.acks > 0, "plane must probe and be acked".into())?;
        prop_assert(
            fd.suspicions == 0 && fd.confirms == 0 && fd.false_confirms == 0,
            format!(
                "false positives on perfect links: suspicions {} confirms {} false {}",
                fd.suspicions, fd.confirms, fd.false_confirms
            ),
        )
    });
}

#[test]
fn prop_gosgd_mass_is_exactly_one_through_suspect_refute_cycles() {
    // probe deadlines far below the link RTT: every probe escalates and
    // suspicion fires, sometimes all the way to a (false) confirmation —
    // then the victim's higher-incarnation heartbeat refutes it.  None
    // of that may touch training state: push-sum mass stays exactly 1
    // and the oracle roster is untouched.
    forall("gosgd mass through false suspicions", 8, |g| {
        let w = g.usize_in(3, 6);
        let (mut cfg, spec) = async_equiv_cfg(g, Method::GoSgd, w);
        cfg.fd = FdSpec::parse("0.05:0.005:0.08:2").unwrap();
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.2), g.f64_in(1.0, 2.5));
        sim.link = LinkModel { latency_s: g.f64_in(0.02, 0.05), bandwidth_bps: 1e7 };
        sim.speed_seed = g.rng().next_u64();
        let asy = run_async(&cfg, &spec, &sim).unwrap();
        let fd = asy.membership.fd.as_ref().unwrap();
        prop_assert(fd.suspicions > 0, "deadlines below the RTT must suspect".into())?;
        prop_assert(
            fd.false_suspicions == fd.suspicions && fd.confirms == fd.false_confirms,
            format!(
                "nothing actually died: suspicions {}/{} confirms {}/{}",
                fd.false_suspicions, fd.suspicions, fd.false_confirms, fd.confirms
            ),
        )?;
        let mass = asy.push_sum_mass.unwrap();
        prop_assert(
            (mass - 1.0).abs() < 1e-9,
            format!("push-sum mass drifted through false suspicions: {mass}"),
        )?;
        prop_assert(
            asy.membership.final_alive.len() == w,
            "oracle roster must be untouched".into(),
        )
    });
}

// ---------------------------------------------------------------------------
// sharded event queue + coalescing (runtime_async PR-7)
// ---------------------------------------------------------------------------

#[test]
fn prop_async_lockstep_sharded() {
    // the tentpole's bit-identity claim as a property: for every method,
    // codec, and (possibly empty) churn/fault/fd spec, a sharded queue
    // (shards > 1, gradient compute on per-shard threads) replays the
    // single-queue trajectory exactly — parameters, membership trace,
    // staleness histogram, event count and every byte ledger
    forall("sharded queue == single queue", 10, |g| {
        let w = g.usize_in(3, 7);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        cfg.codec = match g.usize_in(0, 2) {
            0 => CodecKind::Identity,
            1 => CodecKind::Q8 { chunk: 64 },
            _ => CodecKind::TopK { frac: g.f64_in(0.1, 0.4) },
        };
        if g.bool() {
            cfg.churn = random_churn_spec(g, w);
        }
        if g.bool() {
            cfg.faults = FaultSpec::parse(&format!(
                "drop:{:.3},jitter:{:.2},seed:{}",
                g.f64_in(0.0, 0.1),
                g.f64_in(0.0, 0.4),
                g.usize_in(1, 9999)
            ))
            .unwrap();
        }
        if g.bool() {
            cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
        }
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.3), g.f64_in(1.0, 4.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.05), bandwidth_bps: 1e8 };
        sim.speed_seed = g.rng().next_u64();
        let a = run_async(&cfg, &spec, &sim).unwrap();
        let mut sharded = cfg.clone();
        sharded.shards = g.usize_in(2, 5);
        let b = run_async(&sharded, &spec, &sim).unwrap();
        let tag = format!(
            "{method:?} w={w} shards={} codec={} churn=`{}`",
            sharded.shards,
            cfg.codec.label(),
            cfg.churn.label()
        );
        prop_assert(a.final_params == b.final_params, format!("{tag}: params diverged"))?;
        prop_assert(a.membership == b.membership, format!("{tag}: membership diverged"))?;
        prop_assert(a.staleness == b.staleness, format!("{tag}: staleness diverged"))?;
        prop_assert(a.events == b.events, format!("{tag}: event count diverged"))?;
        let (ma, mb) = (&a.report.metrics, &b.report.metrics);
        prop_assert(
            ma.comm_bytes == mb.comm_bytes
                && ma.wire_bytes == mb.wire_bytes
                && ma.dropped_messages == mb.dropped_messages
                && ma.dropped_bytes == mb.dropped_bytes,
            format!("{tag}: ledgers diverged"),
        )
    });
}

#[test]
fn prop_coalescing_is_bit_identical_under_zero_latency() {
    // S2's identity half: with zero-latency links a coalesced frame
    // arrives exactly when each member message would have, so packing
    // consecutive same-(src,dst) payloads must not move the trajectory
    // or any ledger — for every method, codec, and fault plane
    forall("coalesce lockstep identity", 8, |g| {
        let w = g.usize_in(2, 6);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        if g.bool() {
            cfg.codec = CodecKind::Q8 { chunk: 64 };
        }
        if g.bool() {
            cfg.faults = FaultSpec::parse(&format!(
                "drop:{:.3},seed:{}",
                g.f64_in(0.0, 0.1),
                g.usize_in(1, 9999)
            ))
            .unwrap();
        }
        let a = run_async(&cfg, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        let mut co = cfg.clone();
        co.coalesce = true;
        let b = run_async(&co, &spec, &AsyncSimCfg::lockstep(w)).unwrap();
        prop_assert(
            a.final_params == b.final_params,
            format!("{method:?} w={w}: lockstep coalescing diverged"),
        )?;
        let (ma, mb) = (&a.report.metrics, &b.report.metrics);
        prop_assert(
            ma.comm_bytes == mb.comm_bytes
                && ma.wire_bytes == mb.wire_bytes
                && ma.comm_messages == mb.comm_messages
                && ma.dropped_messages == mb.dropped_messages,
            format!("{method:?} w={w}: coalescing perturbed a ledger"),
        )
    });
}

#[test]
fn prop_tracing_is_inert_and_same_seed_traces_are_byte_identical() {
    // the flight recorder's two determinism claims as one property, for
    // every method x codec x (possibly empty) churn/fault/fd plane x
    // shard count: (a) turning tracing on must not perturb the
    // trajectory or any ledger — the recorder observes, never steers;
    // (b) two same-seed traced runs emit byte-identical Chrome trace
    // JSON (record identity derives from the virtual clock and the
    // queue's (class, seq) order, never wall time or allocation order),
    // and the emitted text validates against the trace-event schema
    forall("tracing inert + byte-identical", 8, |g| {
        use elastic_gossip::trace::{validate_chrome_trace, TraceSpec};
        let w = g.usize_in(3, 6);
        let method = match g.usize_in(0, 3) {
            0 => Method::ElasticGossip { alpha: g.f32_in(0.05, 0.95) },
            1 => Method::GossipingSgdPull,
            2 => Method::GossipingSgdPush,
            _ => Method::GoSgd,
        };
        let (mut cfg, spec) = async_equiv_cfg(g, method.clone(), w);
        cfg.codec = match g.usize_in(0, 2) {
            0 => CodecKind::Identity,
            1 => CodecKind::Q8 { chunk: 64 },
            _ => CodecKind::TopK { frac: g.f64_in(0.1, 0.4) },
        };
        if g.bool() {
            cfg.churn = random_churn_spec(g, w);
        }
        if g.bool() {
            cfg.faults = FaultSpec::parse(&format!(
                "drop:{:.3},jitter:{:.2},seed:{}",
                g.f64_in(0.0, 0.1),
                g.f64_in(0.0, 0.4),
                g.usize_in(1, 9999)
            ))
            .unwrap();
        }
        if g.bool() {
            cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
        }
        cfg.shards = g.usize_in(1, 3);
        let mut sim = AsyncSimCfg::straggler(w, 0.02, g.f64_in(0.0, 0.3), g.f64_in(1.0, 4.0));
        sim.link = LinkModel { latency_s: g.f64_in(0.0, 0.05), bandwidth_bps: 1e8 };
        sim.speed_seed = g.rng().next_u64();
        let off = run_async(&cfg, &spec, &sim).unwrap();
        let mut traced = cfg.clone();
        traced.trace =
            TraceSpec::parse(&format!("on,ring:{}", g.usize_in(64, 4096))).unwrap();
        let a = run_async(&traced, &spec, &sim).unwrap();
        let b = run_async(&traced, &spec, &sim).unwrap();
        let tag = format!(
            "{method:?} w={w} shards={} codec={} churn=`{}` ring={}",
            cfg.shards,
            cfg.codec.label(),
            cfg.churn.label(),
            traced.trace.ring
        );
        prop_assert(
            off.trace_json.is_none(),
            format!("{tag}: trace-off run attached trace JSON"),
        )?;
        prop_assert(
            off.final_params == a.final_params,
            format!("{tag}: tracing perturbed the trajectory"),
        )?;
        prop_assert(
            off.staleness == a.staleness && off.events == a.events,
            format!("{tag}: tracing perturbed staleness or event count"),
        )?;
        let (mo, ma) = (&off.report.metrics, &a.report.metrics);
        prop_assert(
            mo.comm_bytes == ma.comm_bytes
                && mo.wire_bytes == ma.wire_bytes
                && mo.dropped_messages == ma.dropped_messages
                && mo.dropped_bytes == ma.dropped_bytes,
            format!("{tag}: tracing perturbed a ledger"),
        )?;
        let ja = a.trace_json.as_deref().expect("traced run must attach trace JSON");
        let jb = b.trace_json.as_deref().expect("traced run must attach trace JSON");
        prop_assert(
            ja == jb,
            format!("{tag}: same-seed traced runs diverged byte-wise"),
        )?;
        let n = validate_chrome_trace(ja)
            .unwrap_or_else(|e| panic!("{tag}: invalid trace JSON: {e}"));
        prop_assert(n > 0, format!("{tag}: traced run recorded no events"))
    });
}

// ---------------------------------------------------------------------------
// SIMD kernel dispatch (tensor::simd) — dispatched == scalar, bit for bit
// ---------------------------------------------------------------------------
//
// These properties compare the runtime-dispatched entry points against
// their public `*_scalar` references on the SAME inputs, so they are
// meaningful on every host: under `EG_FORCE_SCALAR=1` (or on machines
// without AVX2/NEON) both sides take the scalar path and the property
// degenerates to a tautology; with a vector level active it is the
// bit-identity claim the goldens and lockstep suites rest on.

/// Length biased toward lane boundaries: empty, 1, lane−1/lane/lane+1
/// for both 4- and 8-wide registers, primes with ragged tails, plus a
/// uniform draw for everything in between.
fn simd_len(g: &mut Gen) -> usize {
    const EDGES: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 97, 257, 1009];
    if g.bool() {
        EDGES[g.usize_in(0, EDGES.len() - 1)]
    } else {
        g.usize_in(0, 3000)
    }
}

/// Gaussian data salted with the values folds must handle
/// deterministically: NaN, signed zero, subnormals.
fn salted_vec(g: &mut Gen, n: usize) -> Vec<f32> {
    let mut v = g.vec_gauss(n);
    for x in v.iter_mut() {
        match g.usize_in(0, 15) {
            0 => *x = f32::NAN,
            1 => *x = -0.0,
            2 => *x = f32::MIN_POSITIVE / 2.0, // subnormal
            3 => *x = 0.0,
            _ => {}
        }
    }
    v
}

fn bits_eq(a: &[f32], b: &[f32], what: &str) -> PropResult {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert(
            x.to_bits() == y.to_bits(),
            format!("{what} [{i}]: dispatched {x} != scalar {y} (n={})", a.len()),
        )?;
    }
    Ok(())
}

fn bits64_eq(a: &[f64], b: &[f64], what: &str) -> PropResult {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert(
            x.to_bits() == y.to_bits(),
            format!("{what} [{i}]: dispatched {x} != scalar {y} (n={})", a.len()),
        )?;
    }
    Ok(())
}

#[test]
fn prop_simd_elementwise_kernels_match_scalar_bitwise() {
    use elastic_gossip::tensor::simd;
    forall("simd elementwise == scalar", 150, |g| {
        let n = simd_len(g);
        let a = salted_vec(g, n);
        let b = salted_vec(g, n);
        let base = salted_vec(g, n);
        let alpha = g.f32_in(-1.0, 1.0);

        let mut d1 = base.clone();
        let mut d2 = base.clone();
        simd::sub_scaled_diff(&mut d1, &a, &b, alpha);
        simd::sub_scaled_diff_scalar(&mut d2, &a, &b, alpha);
        bits_eq(&d1, &d2, "sub_scaled_diff")?;

        let mut d1 = base.clone();
        let mut d2 = base.clone();
        simd::average(&mut d1, &a, &b);
        simd::average_scalar(&mut d2, &a, &b);
        bits_eq(&d1, &d2, "average")?;

        let mut d1 = base.clone();
        let mut d2 = base.clone();
        simd::average_in(&mut d1, &a);
        simd::average_in_scalar(&mut d2, &a);
        bits_eq(&d1, &d2, "average_in")?;

        let mut d1 = base.clone();
        let mut d2 = base.clone();
        simd::add_assign(&mut d1, &a);
        simd::add_assign_scalar(&mut d2, &a);
        bits_eq(&d1, &d2, "add_assign")?;

        let inv = g.f32_in(0.01, 2.0);
        let mut d1 = vec![0.0; n];
        let mut d2 = vec![0.0; n];
        simd::scale_into(&mut d1, &base, inv);
        simd::scale_into_scalar(&mut d2, &base, inv);
        bits_eq(&d1, &d2, "scale_into")
    });
}

#[test]
fn prop_simd_f64_accumulators_match_scalar_bitwise() {
    use elastic_gossip::tensor::simd;
    forall("simd f64 accumulators == scalar", 120, |g| {
        let n = simd_len(g);
        let x = salted_vec(g, n);
        let y = salted_vec(g, n);
        let w0 = g.f64_in(0.0, 2.0);
        let w1 = g.f64_in(0.0, 2.0);
        let mut a1 = vec![0.0f64; n];
        let mut a2 = vec![0.0f64; n];
        simd::wacc_set(&mut a1, &x, w0);
        simd::wacc_set_scalar(&mut a2, &x, w0);
        bits64_eq(&a1, &a2, "wacc_set")?;
        simd::wacc_add(&mut a1, &y, w1);
        simd::wacc_add_scalar(&mut a2, &y, w1);
        bits64_eq(&a1, &a2, "wacc_add")?;
        let inv = g.f64_in(0.1, 10.0);
        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; n];
        simd::store_scaled(&mut d1, &a1, inv);
        simd::store_scaled_scalar(&mut d2, &a2, inv);
        bits_eq(&d1, &d2, "store_scaled")
    });
}

#[test]
fn prop_simd_minmax_and_quant_match_scalar_bitwise() {
    use elastic_gossip::tensor::simd;
    forall("simd minmax/quant == scalar", 150, |g| {
        let n = simd_len(g);
        let v = salted_vec(g, n);

        let (l1, h1) = simd::minmax(&v);
        let (l2, h2) = simd::minmax_scalar(&v);
        prop_assert(
            l1.to_bits() == l2.to_bits() && h1.to_bits() == h2.to_bits(),
            format!("minmax ({l1},{h1}) != scalar ({l2},{h2}) n={n}"),
        )?;

        // quantize under the module's inv contract: (lo, inv) derived
        // from the input's own minmax, exactly as the q8/q4 codecs do
        let range = h2 - l2;
        let max_code = if g.bool() { 255i32 } else { 15 };
        let inv = if range > f32::MIN_POSITIVE { max_code as f32 / range } else { 0.0 };
        let mut c1 = vec![0u8; n];
        let mut c2 = vec![0u8; n];
        simd::quant_codes(&v, l2, inv, max_code, &mut c1);
        simd::quant_codes_scalar(&v, l2, inv, max_code, &mut c2);
        prop_assert(c1 == c2, format!("quant_codes diverged (n={n} max={max_code})"))?;

        let scale = if inv > 0.0 { range / max_code as f32 } else { 0.0 };
        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; n];
        simd::dequant_codes(&c1, l2, scale, &mut d1);
        simd::dequant_codes_scalar(&c2, l2, scale, &mut d2);
        bits_eq(&d1, &d2, "dequant_codes")
    });
}

#[test]
fn prop_simd_byte_paths_roundtrip_bit_exact() {
    use elastic_gossip::tensor::simd;
    forall("simd byte paths == byte-wise reference", 120, |g| {
        let n = simd_len(g);
        let v = salted_vec(g, n);
        let mut wire = Vec::new();
        simd::f32s_to_le_bytes(&v, &mut wire);
        let mut expect = Vec::with_capacity(4 * n);
        for &x in &v {
            expect.extend_from_slice(&x.to_le_bytes());
        }
        prop_assert(wire == expect, format!("LE serialization diverged (n={n})"))?;
        let mut back = vec![0.0f32; n];
        simd::le_bytes_to_f32s(&wire, &mut back);
        bits_eq(&back, &v, "le_bytes_to_f32s roundtrip")
    });
}

#[test]
fn prop_topology_constrains_picks() {
    forall("topology constrains picks", 80, |g| {
        let w = g.usize_in(3, 12);
        let topo = if g.bool() { Topology::Ring } else { Topology::Full };
        let comm = vec![true; w];
        let mut rng = Rng::new(g.rng().next_u64());
        let picks = gossip_picks(&comm, &topo, &mut rng);
        for (i, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                prop_assert(
                    topo.neighbors(i, w).contains(&k),
                    format!("{i} picked non-neighbor {k} under {topo:?}"),
                )?;
            }
        }
        Ok(())
    });
}
