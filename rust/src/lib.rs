//! # elastic-gossip
//!
//! A production-grade reproduction of **"Elastic Gossip: Distributing
//! Neural Network Training Using Gossip-like Protocols"** (Siddharth
//! Pramod, MS thesis, 2018).
//!
//! The library is the Layer-3 *coordinator* of a three-layer stack:
//!
//! * **Layer 1** — Pallas kernels (fused dense, elastic pair update,
//!   fused NAG), authored in `python/compile/kernels/` and lowered at
//!   build time.
//! * **Layer 2** — JAX models (the paper's MNIST MLP, a TinyResNet CIFAR
//!   substitute, a small transformer LM), lowered once to HLO text under
//!   `artifacts/` by `make artifacts`.
//! * **Layer 3** — this crate: distributed-training coordination.  It
//!   owns the worker topology, the gossip matchmaker (the set-**K**
//!   semantics of Algorithm 4), the NAG optimizer ordering of
//!   Algorithm 5, the communication fabric with byte/latency accounting,
//!   real ring/tree/central all-reduce implementations, and the
//!   experiment harness that regenerates every table and figure of the
//!   paper.  Two execution regimes share the same strategies: the
//!   barriered synchronous coordinator (`coordinator`, the thesis's
//!   reproducibility setting) and the event-driven asynchronous
//!   message-passing runtime (`runtime_async`, the controlled-asynchrony
//!   environment its future-work chapter calls for — the synchronous
//!   round is its zero-latency lockstep special case).  Python never
//!   runs on the training path: gradients come from the AOT artifacts
//!   through the PJRT C API (`runtime`).
//!
//! See `examples/` for runnable drivers and `DESIGN.md` for the full
//! system inventory.

pub mod algos;
pub mod benchkit;
pub mod cli;
pub mod collective;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod manifest;
pub mod membership;
pub mod metrics;
pub mod optim;
pub mod proptest_mini;
pub mod runtime;
pub mod runtime_async;
pub mod sim;
pub mod tensor;
pub mod topology;
pub mod trace;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::algos::{Method, Strategy};
    pub use crate::comm::codec::{Codec, CodecKind};
    pub use crate::config::{CommSchedule, EngineKind, ExperimentConfig};
    pub use crate::coordinator::{run_experiment, Coordinator, RunReport};
    pub use crate::data::{Dataset, Partition, TaskKind};
    pub use crate::membership::{ChurnKind, ChurnSpec, MembershipReport};
    pub use crate::metrics::{Curve, RunMetrics, StalenessHist};
    pub use crate::optim::{OptimKind, Optimizer};
    pub use crate::runtime::{EngineFactory, GradEngine};
    pub use crate::runtime_async::{run_async, AsyncRunReport, AsyncSimCfg};
    pub use crate::topology::Topology;
    pub use crate::trace::{Trace, TraceSpec};
    pub use crate::util::rng::Rng;
}
