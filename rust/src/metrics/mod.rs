//! Training metrics: loss/accuracy curves, per-worker spread, CSV/JSON
//! emitters for regenerating the paper's figures.
//!
//! Figures 4.1–4.4 plot, per epoch, the **mean and range across workers**
//! of validation accuracy (solid line + shaded region).  `Curve` stores
//! exactly those series; `to_csv` emits `epoch,mean,min,max` rows the
//! plotting side can consume directly.

use std::fmt::Write as _;

use crate::manifest::json::{Json, JsonObj};
use crate::util;

/// One evaluation snapshot (taken at an epoch boundary).
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub epoch: usize,
    pub step: u64,
    /// workers alive at this evaluation (== the cluster size on a fixed
    /// roster; under membership churn the survivor count — the
    /// per-epoch membership series of the churn studies)
    pub alive: usize,
    /// per-worker validation accuracy (alive workers only, ascending id)
    pub worker_acc: Vec<f32>,
    /// per-worker validation loss (mean per instance)
    pub worker_loss: Vec<f32>,
    /// mean training loss over the epoch, averaged across workers
    pub train_loss: f32,
    /// accuracy of the parameter-averaged ("aggregate") model
    pub aggregate_acc: f32,
    /// wall-clock seconds since run start
    pub wall_s: f64,
}

impl EvalPoint {
    pub fn acc_mean(&self) -> f32 {
        util::mean(&self.worker_acc)
    }
    pub fn acc_range(&self) -> (f32, f32) {
        util::min_max(&self.worker_acc)
    }
}

/// A named series of eval points (one training run).
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub label: String,
    pub points: Vec<EvalPoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Self {
        Curve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: EvalPoint) {
        self.points.push(p);
    }

    pub fn last(&self) -> Option<&EvalPoint> {
        self.points.last()
    }

    /// `epoch,train_loss,val_acc_mean,val_acc_min,val_acc_max,aggregate_acc`
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "epoch,step,train_loss,val_loss_mean,val_acc_mean,val_acc_min,val_acc_max,aggregate_acc,wall_s,alive\n",
        );
        for p in &self.points {
            let (lo, hi) = if p.worker_acc.is_empty() { (0.0, 0.0) } else { p.acc_range() };
            let _ = writeln!(
                out,
                "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3},{}",
                p.epoch,
                p.step,
                p.train_loss,
                util::mean(&p.worker_loss),
                p.acc_mean(),
                lo,
                hi,
                p.aggregate_acc,
                p.wall_s,
                p.alive,
            );
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("label", Json::Str(self.label.clone()));
        o.insert(
            "points",
            Json::Arr(
                self.points
                    .iter()
                    .map(|p| {
                        let mut po = JsonObj::new();
                        po.insert("epoch", Json::Num(p.epoch as f64));
                        po.insert("step", Json::Num(p.step as f64));
                        po.insert("alive", Json::Num(p.alive as f64));
                        po.insert("train_loss", Json::Num(p.train_loss as f64));
                        po.insert(
                            "worker_acc",
                            Json::Arr(p.worker_acc.iter().map(|&a| Json::Num(a as f64)).collect()),
                        );
                        po.insert("aggregate_acc", Json::Num(p.aggregate_acc as f64));
                        po.insert("wall_s", Json::Num(p.wall_s));
                        Json::Obj(po)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

/// Histogram of per-exchange staleness — the controlled-asynchrony
/// metric the thesis proposes measuring ("studying the effects of
/// asynchrony that is controlled in a simulated environment", Ch. 5).
///
/// One sample per applied gossip message: the receiver's local step at
/// application minus the sender's local step at send (absolute).  Under
/// the zero-latency lockstep schedule every exchange lands in the same
/// logical round and the histogram is identically zero; under stragglers
/// or slow links the distribution quantifies exactly how stale the
/// exchanged parameters were, in optimizer steps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StalenessHist {
    /// counts[d] = exchanges that were d steps behind; the last bucket
    /// absorbs everything >= STALENESS_BUCKETS - 1
    counts: Vec<u64>,
    sum: u64,
    n: u64,
    max: u64,
}

/// Bucket count for [`StalenessHist`] (last bucket saturates).
pub const STALENESS_BUCKETS: usize = 65;

impl Default for StalenessHist {
    fn default() -> Self {
        StalenessHist {
            counts: vec![0; STALENESS_BUCKETS],
            sum: 0,
            n: 0,
            max: 0,
        }
    }
}

impl StalenessHist {
    pub fn new() -> Self {
        StalenessHist::default()
    }

    /// Record one exchange that applied parameters `steps_behind` steps
    /// stale.
    pub fn record(&mut self, steps_behind: u64) {
        let b = (steps_behind as usize).min(STALENESS_BUCKETS - 1);
        self.counts[b] += 1;
        self.sum += steps_behind;
        self.n += 1;
        self.max = self.max.max(steps_behind);
    }

    /// Exchanges recorded.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean steps-behind per exchange (0 when no exchanges happened).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exchanges in bucket `d` (saturating index).
    pub fn bucket(&self, d: usize) -> u64 {
        self.counts[d.min(STALENESS_BUCKETS - 1)]
    }

    /// Fraction of exchanges that were stale at all (>= 1 step behind).
    pub fn stale_fraction(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.n - self.counts[0]) as f64 / self.n as f64
        }
    }

    /// Steps-behind at percentile `p` in `[0, 1]`: the smallest
    /// staleness `d` with at least `p` of all exchanges `<= d` steps
    /// behind.  The saturating last bucket reports the observed max
    /// (the bucket only bounds it from below).  0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        match crate::trace::percentile_bucket(&self.counts, p) {
            None => 0,
            Some(b) if b == STALENESS_BUCKETS - 1 => self.max,
            Some(b) => b as u64,
        }
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("count", Json::Num(self.n as f64));
        o.insert("mean", Json::Num(self.mean()));
        o.insert("p50", Json::Num(self.p50() as f64));
        o.insert("p95", Json::Num(self.p95() as f64));
        o.insert("p99", Json::Num(self.p99() as f64));
        o.insert("max", Json::Num(self.max as f64));
        o.insert("stale_fraction", Json::Num(self.stale_fraction()));
        // trim trailing empty buckets for compact output
        let hi = self
            .counts
            .iter()
            .rposition(|&c| c > 0)
            .map_or(0, |i| i + 1);
        o.insert(
            "buckets",
            Json::Arr(self.counts[..hi].iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(o)
    }
}

/// Full-run metrics: the curve plus final summary + traffic numbers.
///
/// The traffic fields are *views* over the fabric's unified
/// [`crate::trace::Registry`] counters, frozen at the end of the run by
/// [`RunMetrics::from_traffic`].  They are plain fields (not accessors)
/// so report JSON and goldens stay byte-identical across the registry
/// refactor.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub curve: Curve,
    pub rank0_test_acc: f32,
    pub aggregate_test_acc: f32,
    pub total_steps: u64,
    /// raw payload bytes handed to the fabric
    /// ([`crate::trace::Ctr::CommBytes`])
    pub comm_bytes: u64,
    /// bytes actually on the wire after payload encoding (== `comm_bytes`
    /// unless a wire codec shrank the payloads; see `comm::codec`) —
    /// [`crate::trace::Ctr::WireBytes`]
    pub wire_bytes: u64,
    pub comm_messages: u64,
    pub comm_rounds: u64,
    /// undeliverable messages under membership churn (0 on a fixed
    /// roster) — [`crate::trace::Ctr::DroppedMessages`]
    pub dropped_messages: u64,
    /// raw payload bytes of the dropped messages
    pub dropped_bytes: u64,
    /// datagrams that arrived but failed frame decoding (wire transports
    /// only; always 0 in process) —
    /// [`crate::trace::Ctr::MalformedFrames`]
    pub malformed_frames: u64,
    pub simulated_comm_s: f64,
    pub wall_train_s: f64,
    pub wall_eval_s: f64,
}

impl RunMetrics {
    /// Assemble run metrics from a finished curve and the fabric's
    /// traffic view — the single construction path shared by the
    /// sequential coordinator, the parallel coordinator, and the async
    /// runtime, so the registry → report field mapping lives in exactly
    /// one place.
    pub fn from_traffic(
        curve: Curve,
        accs: (f32, f32),
        total_steps: u64,
        traffic: &crate::comm::TrafficReport,
        wall_train_s: f64,
        wall_eval_s: f64,
    ) -> Self {
        RunMetrics {
            curve,
            rank0_test_acc: accs.0,
            aggregate_test_acc: accs.1,
            total_steps,
            comm_bytes: traffic.total_bytes,
            wire_bytes: traffic.wire_bytes,
            comm_messages: traffic.total_messages,
            comm_rounds: traffic.rounds,
            dropped_messages: traffic.dropped_messages,
            dropped_bytes: traffic.dropped_bytes,
            malformed_frames: traffic.malformed_frames,
            simulated_comm_s: traffic.simulated_comm_s,
            wall_train_s,
            wall_eval_s,
        }
    }

    pub fn summary_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("label", Json::Str(self.curve.label.clone()));
        o.insert("rank0_test_acc", Json::Num(self.rank0_test_acc as f64));
        o.insert("aggregate_test_acc", Json::Num(self.aggregate_test_acc as f64));
        o.insert("total_steps", Json::Num(self.total_steps as f64));
        o.insert("comm_bytes", Json::Num(self.comm_bytes as f64));
        o.insert("wire_bytes", Json::Num(self.wire_bytes as f64));
        o.insert("comm_messages", Json::Num(self.comm_messages as f64));
        o.insert("comm_rounds", Json::Num(self.comm_rounds as f64));
        o.insert("dropped_messages", Json::Num(self.dropped_messages as f64));
        o.insert("malformed_frames", Json::Num(self.malformed_frames as f64));
        o.insert("dropped_bytes", Json::Num(self.dropped_bytes as f64));
        o.insert("simulated_comm_s", Json::Num(self.simulated_comm_s));
        o.insert("wall_train_s", Json::Num(self.wall_train_s));
        o.insert("curve", self.curve.to_json());
        Json::Obj(o)
    }
}

/// Write a set of curves as one CSV per curve under `dir`.
pub fn write_curves_csv(dir: impl AsRef<std::path::Path>, curves: &[Curve]) -> anyhow::Result<Vec<std::path::PathBuf>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    for c in curves {
        let safe: String = c
            .label
            .chars()
            .map(|ch| if ch.is_alphanumeric() || ch == '-' || ch == '.' { ch } else { '_' })
            .collect();
        let path = dir.join(format!("{safe}.csv"));
        std::fs::write(&path, c.to_csv())?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(epoch: usize, accs: &[f32]) -> EvalPoint {
        EvalPoint {
            epoch,
            step: (epoch * 10) as u64,
            alive: accs.len(),
            worker_acc: accs.to_vec(),
            worker_loss: vec![0.5; accs.len()],
            train_loss: 1.0,
            aggregate_acc: 0.9,
            wall_s: 1.5,
        }
    }

    #[test]
    fn mean_and_range() {
        let p = point(1, &[0.8, 0.9, 1.0]);
        assert!((p.acc_mean() - 0.9).abs() < 1e-6);
        assert_eq!(p.acc_range(), (0.8, 1.0));
    }

    #[test]
    fn csv_format() {
        let mut c = Curve::new("EG-4-0.031");
        c.push(point(0, &[0.5, 0.7]));
        c.push(point(1, &[0.8, 0.9]));
        let csv = c.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("epoch,"));
        assert!(lines[1].starts_with("0,0,"));
        assert!(lines[2].contains("0.850000")); // mean of 0.8/0.9
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Curve::new("x");
        c.push(point(0, &[0.5]));
        let j = c.to_json();
        let s = crate::manifest::json::write(&j);
        let back = crate::manifest::json::parse(&s).unwrap();
        assert_eq!(back.path(&["label"]).as_str(), Some("x"));
        assert_eq!(back.path(&["points"]).as_arr().unwrap().len(), 1);
    }

    #[test]
    fn staleness_hist_moments_and_saturation() {
        let mut h = StalenessHist::new();
        assert_eq!(h.mean(), 0.0);
        for d in [0u64, 0, 2, 4, 1000] {
            h.record(d);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 201.2).abs() < 1e-9);
        assert_eq!(h.bucket(0), 2);
        assert_eq!(h.bucket(2), 1);
        // 1000 saturates into the last bucket
        assert_eq!(h.bucket(STALENESS_BUCKETS - 1), 1);
        assert!((h.stale_fraction() - 0.6).abs() < 1e-9);
        // equality for determinism tests
        let mut h2 = StalenessHist::new();
        for d in [0u64, 0, 2, 4, 1000] {
            h2.record(d);
        }
        assert_eq!(h, h2);
    }

    #[test]
    fn staleness_hist_json() {
        let mut h = StalenessHist::new();
        h.record(0);
        h.record(3);
        let s = crate::manifest::json::write(&h.to_json());
        let back = crate::manifest::json::parse(&s).unwrap();
        assert_eq!(back.path(&["count"]).as_f64(), Some(2.0));
        assert_eq!(back.path(&["buckets"]).as_arr().unwrap().len(), 4);
    }

    #[test]
    fn write_curves_to_dir() {
        let dir = std::env::temp_dir().join(format!("eg-metrics-{}", std::process::id()));
        let mut c = Curve::new("A/B weird label");
        c.push(point(0, &[1.0]));
        let paths = write_curves_csv(&dir, &[c]).unwrap();
        assert!(paths[0].exists());
        let content = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(content.contains("epoch,"));
    }
}
