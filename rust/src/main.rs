//! `repro` — leader entrypoint for the Elastic Gossip reproduction.
//!
//! All functionality lives in the `elastic_gossip` library; this binary
//! just parses the command line and dispatches (see `cli`).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match elastic_gossip::cli::main_with_args(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
