//! Optimizers over flat parameter buffers, with Algorithm-5 phase split.
//!
//! The paper's NAG incorporation (Appendix A.1.1) decomposes each
//! iteration into:
//!
//! 1. `g = grad(theta)`                       (line 2)
//! 2. `v = mu * v - eta * g`                  (line 3 — *before* comm)
//! 3. (communication round mutates `theta`)   (lines 4-8)
//! 4. `theta = theta - eta * g + mu * v`      (line 9 — uses the NEW v)
//!
//! The split matters: the communication-related component acts on the
//! pre-gradient parameters, so the optimizer exposes `update_velocity`
//! and `apply` separately and the coordinator interleaves the comm round
//! between them.  Plain SGD is the `mu = 0` degenerate case (velocity is
//! identically `-eta*g` and `apply` reduces to `theta -= eta*g` — we keep
//! a dedicated variant to skip the velocity buffer).

use crate::tensor;

/// Which optimizer update rule to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimKind {
    /// theta -= eta * g
    Sgd,
    /// Nesterov momentum per Algorithm 5
    Nag { momentum: f32 },
}

impl OptimKind {
    pub fn parse(s: &str) -> anyhow::Result<OptimKind> {
        if s == "sgd" {
            return Ok(OptimKind::Sgd);
        }
        if let Some(m) = s.strip_prefix("nag:") {
            return Ok(OptimKind::Nag { momentum: m.parse()? });
        }
        anyhow::bail!("unknown optimizer {s:?} (sgd | nag:MU)")
    }

    pub fn needs_velocity(&self) -> bool {
        matches!(self, OptimKind::Nag { .. })
    }
}

/// Learning-rate schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Const(f32),
    /// Multiply by `factor` after each epoch in `at_epochs` (the paper's
    /// CIFAR recipe: 0.01 halved after epochs 15, 30, 40).
    StepAnneal {
        base: f32,
        factor: f32,
        at_epochs: Vec<usize>,
    },
}

impl LrSchedule {
    pub fn lr_at(&self, epoch: usize) -> f32 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::StepAnneal { base, factor, at_epochs } => {
                let k = at_epochs.iter().filter(|&&e| epoch >= e).count();
                base * factor.powi(k as i32)
            }
        }
    }
}

/// Per-worker optimizer state.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub kind: OptimKind,
    pub schedule: LrSchedule,
    /// velocity buffer (empty for SGD)
    velocity: Vec<f32>,
    lr: f32,
}

impl Optimizer {
    pub fn new(kind: OptimKind, schedule: LrSchedule, flat_size: usize) -> Self {
        let velocity = if kind.needs_velocity() {
            vec![0.0; flat_size]
        } else {
            Vec::new()
        };
        let lr = schedule.lr_at(0);
        Optimizer { kind, schedule, velocity, lr }
    }

    /// Refresh the learning rate at an epoch boundary.
    pub fn start_epoch(&mut self, epoch: usize) {
        self.lr = self.schedule.lr_at(epoch);
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Phase 2 (Algorithm 5 line 3): `v = mu*v - eta*g`. No-op for SGD.
    pub fn update_velocity(&mut self, g: &[f32]) {
        if let OptimKind::Nag { momentum } = self.kind {
            debug_assert_eq!(self.velocity.len(), g.len());
            let (mu, eta) = (momentum, self.lr);
            for (v, &gi) in self.velocity.iter_mut().zip(g.iter()) {
                *v = mu * *v - eta * gi;
            }
        }
    }

    /// Phase 4 (line 9): `theta += -eta*g + mu*v` (NAG) or `theta -= eta*g`.
    pub fn apply(&self, theta: &mut [f32], g: &[f32]) {
        match self.kind {
            OptimKind::Sgd => tensor::axpy(theta, -self.lr, g),
            OptimKind::Nag { momentum } => {
                let (mu, eta) = (momentum, self.lr);
                for ((t, &gi), &vi) in theta.iter_mut().zip(g.iter()).zip(self.velocity.iter()) {
                    *t += -eta * gi + mu * vi;
                }
            }
        }
    }

    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore a checkpointed velocity buffer (crash-recovery rejoin of
    /// an async node).  No-op for SGD, which carries no velocity.
    pub fn restore_velocity(&mut self, v: &[f32]) {
        if self.kind.needs_velocity() {
            debug_assert_eq!(self.velocity.len(), v.len());
            self.velocity.clear();
            self.velocity.extend_from_slice(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let mut o = Optimizer::new(OptimKind::Sgd, LrSchedule::Const(0.1), 3);
        let mut t = vec![1.0f32, 2.0, 3.0];
        let g = vec![1.0f32, -1.0, 0.0];
        o.update_velocity(&g); // no-op
        o.apply(&mut t, &g);
        assert_eq!(t, vec![0.9, 2.1, 3.0]);
    }

    #[test]
    fn nag_matches_hand_rolled() {
        // one step from v=0: v' = -eta g; theta' = theta - eta g + mu v'
        let (eta, mu) = (0.1f32, 0.9f32);
        let mut o = Optimizer::new(OptimKind::Nag { momentum: mu }, LrSchedule::Const(eta), 2);
        let mut t = vec![1.0f32, -1.0];
        let g = vec![2.0f32, 4.0];
        o.update_velocity(&g);
        o.apply(&mut t, &g);
        let v1 = [-eta * 2.0, -eta * 4.0];
        assert!((t[0] - (1.0 - eta * 2.0 + mu * v1[0])).abs() < 1e-6);
        assert!((t[1] - (-1.0 - eta * 4.0 + mu * v1[1])).abs() < 1e-6);

        // second step accumulates momentum
        let g2 = vec![1.0f32, 0.0];
        o.update_velocity(&g2);
        let v2 = [mu * v1[0] - eta * 1.0, mu * v1[1]];
        assert!((o.velocity()[0] - v2[0]).abs() < 1e-6);
        assert!((o.velocity()[1] - v2[1]).abs() < 1e-6);
    }

    #[test]
    fn nag_zero_momentum_equals_sgd() {
        let mut a = Optimizer::new(OptimKind::Nag { momentum: 0.0 }, LrSchedule::Const(0.05), 4);
        let b = Optimizer::new(OptimKind::Sgd, LrSchedule::Const(0.05), 4);
        let g = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut ta = vec![0.0f32; 4];
        let mut tb = vec![0.0f32; 4];
        a.update_velocity(&g);
        a.apply(&mut ta, &g);
        b.apply(&mut tb, &g);
        assert_eq!(ta, tb);
    }

    #[test]
    fn step_anneal_schedule() {
        let s = LrSchedule::StepAnneal { base: 0.01, factor: 0.5, at_epochs: vec![15, 30, 40] };
        assert_eq!(s.lr_at(0), 0.01);
        assert_eq!(s.lr_at(14), 0.01);
        assert!((s.lr_at(15) - 0.005).abs() < 1e-9);
        assert!((s.lr_at(35) - 0.0025).abs() < 1e-9);
        assert!((s.lr_at(40) - 0.00125).abs() < 1e-9);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(OptimKind::parse("sgd").unwrap(), OptimKind::Sgd);
        assert_eq!(OptimKind::parse("nag:0.99").unwrap(), OptimKind::Nag { momentum: 0.99 });
        assert!(OptimKind::parse("adam").is_err());
    }
}
