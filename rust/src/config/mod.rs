//! Experiment configuration: a TOML-lite file format, typed config, and
//! presets for every experiment row in the paper.
//!
//! The vendored dependency set has no `toml`/`serde`, so the crate ships
//! a small parser for the subset we need: `key = value` pairs with
//! `[section]` headers, strings, numbers, booleans and flat arrays, plus
//! `#` comments.

pub mod toml_lite;

use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;

use crate::algos::Method;
use crate::comm::codec::CodecKind;
use crate::comm::transport::TransportKind;
use crate::data::Partition;
use crate::membership::{ChurnSpec, FaultSpec, FdSpec};
use crate::optim::{LrSchedule, OptimKind};
use crate::topology::Topology;
use crate::trace::TraceSpec;
use toml_lite::Value;

/// When workers engage in communication (§A.1.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CommSchedule {
    /// every step (tau = 1)
    EveryStep,
    /// fixed communication period: all workers communicate when
    /// `tau divides t` (Algorithms 2-4)
    Period(u64),
    /// Bernoulli communication probability per worker per step
    /// (Algorithm 5 / GoSGD style; expected period = 1/p)
    Probability(f64),
}

impl CommSchedule {
    pub fn parse(s: &str) -> Result<CommSchedule> {
        if s == "every" {
            return Ok(CommSchedule::EveryStep);
        }
        if let Some(t) = s.strip_prefix("period:") {
            return Ok(CommSchedule::Period(t.parse()?));
        }
        if let Some(p) = s.strip_prefix("prob:") {
            return Ok(CommSchedule::Probability(p.parse()?));
        }
        bail!("unknown schedule {s:?} (every | period:T | prob:P)")
    }

    /// Expected communication period (used in reports; §A.1.2's tau_eff).
    pub fn effective_period(&self) -> f64 {
        match self {
            CommSchedule::EveryStep => 1.0,
            CommSchedule::Period(t) => *t as f64,
            CommSchedule::Probability(p) => {
                if *p > 0.0 {
                    1.0 / p
                } else {
                    f64::INFINITY
                }
            }
        }
    }
}

/// Which gradient engine backs the workers.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineKind {
    /// AOT HLO artifacts via PJRT (the production path)
    Hlo { model: String },
    /// closed-form quadratic engine (tests / algorithm studies)
    Synthetic { dim: usize },
}

/// Which dataset feeds the workers.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetKind {
    SyntheticMnist,
    SyntheticCifar,
    SyntheticVectors { dim: usize },
    Corpus { seq: usize },
}

/// A fully-specified training experiment (one table row / curve).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub label: String,
    pub method: Method,
    pub workers: usize,
    pub schedule: CommSchedule,
    pub optimizer: OptimKind,
    pub lr: LrSchedule,
    pub engine: EngineKind,
    pub dataset: DatasetKind,
    /// instances in the training split (paper MNIST: 51200)
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    /// total batch across workers (paper: 128); per-worker = this / W
    pub effective_batch: usize,
    pub epochs: usize,
    pub seed: u64,
    pub partition: Partition,
    pub topology: Topology,
    /// evaluate every k epochs (1 = every epoch, like the figures)
    pub eval_every: usize,
    pub artifact_dir: PathBuf,
    /// wire codec for gossip payloads on the event-driven async fabric
    /// (`identity` | `q8[:<chunk>]` | `topk:<frac>`; the synchronous
    /// coordinator exchanges raw snapshots and rejects lossy codecs)
    pub codec: CodecKind,
    /// membership churn schedule for the event-driven async runtime
    /// (`churn:` grammar — `crash@T:N,rejoin@T:N,...` or
    /// `rand:<crashes>:<rejoins>:<seed>`; default empty = fixed roster;
    /// the barriered coordinator rejects non-empty schedules)
    pub churn: ChurnSpec,
    /// deterministic link-fault plan for the async fabric (`faults:`
    /// grammar — `drop:<p>,jitter:<f>,partition@<t0>-<t1>:<k>,seed:<s>`;
    /// default empty = perfect links)
    pub faults: FaultSpec,
    /// SWIM-style gossip-native failure detection (`fd:` grammar —
    /// `on` for defaults or `<period>:<probe_to>:<suspect_to>:<fanout>`;
    /// default off = oracle membership, byte-identical to PR-5 runs)
    pub fd: FdSpec,
    /// event-queue shards for the async runtime (`shards:<n>` config key,
    /// `--shards` CLI flag).  `1` (default) is the single-queue runtime;
    /// `n > 1` pins nodes to shards (node % n), runs gradient compute on
    /// n worker threads and merges per-shard heaps in (time, class, seq)
    /// order — the trajectory is bit-identical to `shards:1`
    pub shards: usize,
    /// coalesce consecutive same-(src,dst) async payloads into one wire
    /// frame (one latency + summed bytes instead of per-message pricing);
    /// default off = per-message framing, byte-identical to PR-6 runs
    pub coalesce: bool,
    /// message transport for the async runtime (`transport:` config key,
    /// `--transport` CLI flag).  `inproc` (default) keeps payloads in
    /// process; `loopback-udp` pushes every committed delivery through a
    /// real 127.0.0.1 UDP socket (digest-identical at zero loss — the
    /// sim-vs-wire conformance suite pins this); `udp` is the
    /// multi-process wire behind `repro net-train`
    pub transport: TransportKind,
    /// flight-recorder tracing (`trace:` config key, `--trace` CLI
    /// flag).  `off` (default) is the zero-overhead path; see
    /// [`crate::trace::TraceSpec::parse`] for the grammar
    pub trace: TraceSpec,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            label: "custom".into(),
            method: Method::ElasticGossip { alpha: 0.5 },
            workers: 4,
            schedule: CommSchedule::Probability(0.03125),
            optimizer: OptimKind::Nag { momentum: 0.99 },
            lr: LrSchedule::Const(0.001),
            engine: EngineKind::Hlo { model: "mlp_paper".into() },
            dataset: DatasetKind::SyntheticMnist,
            n_train: 51_200,
            n_val: 8_800,
            n_test: 10_000,
            effective_batch: 128,
            epochs: 100,
            seed: 0,
            partition: Partition::Iid,
            topology: Topology::Full,
            eval_every: 1,
            artifact_dir: PathBuf::from("artifacts"),
            codec: CodecKind::Identity,
            churn: ChurnSpec::none(),
            faults: FaultSpec::none(),
            fd: FdSpec::none(),
            shards: 1,
            coalesce: false,
            transport: TransportKind::InProc,
            trace: TraceSpec::off(),
        }
    }
}

impl ExperimentConfig {
    pub fn per_worker_batch(&self) -> usize {
        assert!(
            self.effective_batch % self.workers == 0,
            "effective batch {} not divisible by {} workers",
            self.effective_batch,
            self.workers
        );
        self.effective_batch / self.workers
    }

    /// Weight updates per epoch (paper: 51200/128 = 400).
    pub fn steps_per_epoch(&self) -> u64 {
        (self.n_train / self.effective_batch).max(1) as u64
    }

    pub fn total_steps(&self) -> u64 {
        self.steps_per_epoch() * self.epochs as u64
    }

    /// Scale the run down by `factor` (smaller dataset + fewer epochs)
    /// while keeping steps-per-epoch proportional. Used for quick runs;
    /// `--full` restores paper scale.
    pub fn scaled(mut self, data_factor: usize, epochs: usize) -> Self {
        self.n_train = (self.n_train / data_factor).max(self.effective_batch * 2);
        self.n_val = (self.n_val / data_factor).max(64);
        self.n_test = (self.n_test / data_factor).max(64);
        self.epochs = epochs;
        self
    }

    // -----------------------------------------------------------------
    // presets: every labeled experiment in the paper
    // -----------------------------------------------------------------

    /// Look up a paper experiment label, e.g. `AR-4`, `NC-4`,
    /// `EG-4-0.031`, `GS-8-0.002`, `EG-4-0.0312-0.25` (Table 4.2 α-sweep),
    /// `CIFAR-EG-4-0.125` (Table 4.3), `GS-4-TAU-32` (Table A.1).
    pub fn preset(label: &str) -> Result<ExperimentConfig> {
        for cfg in Self::all_presets() {
            if cfg.label == label {
                return Ok(cfg);
            }
        }
        bail!(
            "unknown preset {label:?}; available: {}",
            Self::all_presets()
                .iter()
                .map(|c| c.label.clone())
                .collect::<Vec<_>>()
                .join(", ")
        )
    }

    /// All paper experiments (Tables 4.1, 4.2, 4.3, A.1 + Fig 4.1 baseline).
    pub fn all_presets() -> Vec<ExperimentConfig> {
        let mut out = Vec::new();
        let base = ExperimentConfig::default();

        // Figure 4.1: single-worker baseline (4 seeds handled by harness)
        out.push(ExperimentConfig {
            label: "SGD-1".into(),
            method: Method::NoComm,
            workers: 1,
            schedule: CommSchedule::EveryStep,
            ..base.clone()
        });

        // Table 4.1 — the p values used in the paper
        let ps = [0.125f64, 0.03125, 0.0078125, 0.001953125];
        let p_label = |p: f64| -> String {
            // match the paper's label style: 0.125, 0.031, 0.008, 0.002
            if (p - 0.125).abs() < 1e-9 {
                "0.125".into()
            } else if (p - 0.03125).abs() < 1e-9 {
                "0.031".into()
            } else if (p - 0.0078125).abs() < 1e-9 {
                "0.008".into()
            } else if (p - 0.001953125).abs() < 1e-9 {
                "0.002".into()
            } else if (p - 0.00048828125).abs() < 1e-9 {
                "0.0005".into()
            } else {
                format!("{p}")
            }
        };

        out.push(ExperimentConfig {
            label: "AR-4".into(),
            method: Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            workers: 4,
            schedule: CommSchedule::EveryStep,
            ..base.clone()
        });
        out.push(ExperimentConfig {
            label: "NC-4".into(),
            method: Method::NoComm,
            workers: 4,
            schedule: CommSchedule::EveryStep,
            ..base.clone()
        });
        for &w in &[4usize, 8] {
            for &p in &ps {
                if w == 8 && (p - 0.125).abs() < 1e-9 {
                    continue; // paper's 8-worker rows start at 0.031
                }
                out.push(ExperimentConfig {
                    label: format!("EG-{w}-{}", p_label(p)),
                    method: Method::ElasticGossip { alpha: 0.5 },
                    workers: w,
                    schedule: CommSchedule::Probability(p),
                    ..base.clone()
                });
                out.push(ExperimentConfig {
                    label: format!("GS-{w}-{}", p_label(p)),
                    method: Method::GossipingSgdPull,
                    workers: w,
                    schedule: CommSchedule::Probability(p),
                    ..base.clone()
                });
            }
        }

        // Table 4.2 — moving-rate sweep
        for &(w, p) in &[(4usize, 0.03125f64), (4, 0.00048828125), (8, 0.00048828125)] {
            for &alpha in &[0.05f32, 0.25, 0.5, 0.75, 0.95] {
                if w == 8 && alpha > 0.5 {
                    continue; // paper's Table 4.2 stops at 0.50 for W=8
                }
                let pl = if (p - 0.03125).abs() < 1e-12 { "0.0312" } else { "0.0005" };
                out.push(ExperimentConfig {
                    label: format!("EG-{w}-{pl}-{alpha:.2}"),
                    method: Method::ElasticGossip { alpha },
                    workers: w,
                    schedule: CommSchedule::Probability(p),
                    ..base.clone()
                });
            }
        }

        // Table 4.3 — CIFAR-10 (TinyResNet substitution, annealed LR)
        let cifar_base = ExperimentConfig {
            engine: EngineKind::Hlo { model: "cnn_tiny".into() },
            dataset: DatasetKind::SyntheticCifar,
            n_train: 44_800,
            n_val: 5_200,
            n_test: 10_000,
            optimizer: OptimKind::Nag { momentum: 0.9 },
            lr: LrSchedule::StepAnneal { base: 0.01, factor: 0.5, at_epochs: vec![15, 30, 40] },
            epochs: 50,
            ..base.clone()
        };
        out.push(ExperimentConfig {
            label: "CIFAR-AR-4".into(),
            method: Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            workers: 4,
            schedule: CommSchedule::EveryStep,
            ..cifar_base.clone()
        });
        for &p in &ps {
            out.push(ExperimentConfig {
                label: format!("CIFAR-EG-4-{}", p_label(p)),
                method: Method::ElasticGossip { alpha: 0.5 },
                workers: 4,
                schedule: CommSchedule::Probability(p),
                ..cifar_base.clone()
            });
            out.push(ExperimentConfig {
                label: format!("CIFAR-GS-4-{}", p_label(p)),
                method: Method::GossipingSgdPull,
                workers: 4,
                schedule: CommSchedule::Probability(p),
                ..cifar_base.clone()
            });
        }

        // Table A.1 — communication period vs probability (Gossiping SGD, 4 workers)
        for &tau in &[8u64, 32, 128, 512] {
            out.push(ExperimentConfig {
                label: format!("GS-4-TAU-{tau}"),
                method: Method::GossipingSgdPull,
                workers: 4,
                schedule: CommSchedule::Period(tau),
                ..base.clone()
            });
        }
        out
    }

    // -----------------------------------------------------------------
    // file format
    // -----------------------------------------------------------------

    /// Parse from TOML-lite text; unspecified keys fall back to either a
    /// `preset` key named in the file or the library default.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = toml_lite::parse(text).map_err(|e| anyhow!("config: {e}"))?;
        let get = |k: &str| doc.get(k);
        let mut cfg = match get("preset").and_then(Value::as_str) {
            Some(p) => Self::preset(p)?,
            None => ExperimentConfig::default(),
        };
        if let Some(v) = get("label").and_then(Value::as_str) {
            cfg.label = v.to_string();
        }
        if let Some(v) = get("method").and_then(Value::as_str) {
            cfg.method = Method::parse(v)?;
        }
        if let Some(v) = get("workers").and_then(Value::as_int) {
            cfg.workers = v as usize;
        }
        if let Some(v) = get("schedule").and_then(Value::as_str) {
            cfg.schedule = CommSchedule::parse(v)?;
        }
        if let Some(v) = get("optimizer").and_then(Value::as_str) {
            cfg.optimizer = OptimKind::parse(v)?;
        }
        if let Some(v) = get("lr").and_then(Value::as_float) {
            cfg.lr = LrSchedule::Const(v as f32);
        }
        if let Some(v) = get("model").and_then(Value::as_str) {
            cfg.engine = EngineKind::Hlo { model: v.to_string() };
        }
        if let Some(v) = get("dataset").and_then(Value::as_str) {
            cfg.dataset = match v {
                "mnist" => DatasetKind::SyntheticMnist,
                "cifar" => DatasetKind::SyntheticCifar,
                "corpus" => DatasetKind::Corpus { seq: 64 },
                other => {
                    if let Some(d) = other.strip_prefix("vectors:") {
                        DatasetKind::SyntheticVectors { dim: d.parse()? }
                    } else {
                        bail!("unknown dataset {other:?}")
                    }
                }
            };
        }
        if let Some(v) = get("n_train").and_then(Value::as_int) {
            cfg.n_train = v as usize;
        }
        if let Some(v) = get("n_val").and_then(Value::as_int) {
            cfg.n_val = v as usize;
        }
        if let Some(v) = get("n_test").and_then(Value::as_int) {
            cfg.n_test = v as usize;
        }
        if let Some(v) = get("effective_batch").and_then(Value::as_int) {
            cfg.effective_batch = v as usize;
        }
        if let Some(v) = get("epochs").and_then(Value::as_int) {
            cfg.epochs = v as usize;
        }
        if let Some(v) = get("seed").and_then(Value::as_int) {
            cfg.seed = v as u64;
        }
        if let Some(v) = get("topology").and_then(Value::as_str) {
            cfg.topology = Topology::parse(v)?;
        }
        if let Some(v) = get("partition").and_then(Value::as_str) {
            cfg.partition = if v == "iid" {
                Partition::Iid
            } else if let Some(b) = v.strip_prefix("dirichlet:") {
                Partition::DirichletSkew { beta: b.parse()? }
            } else {
                bail!("unknown partition {v:?}")
            };
        }
        if let Some(v) = get("eval_every").and_then(Value::as_int) {
            cfg.eval_every = v as usize;
        }
        if let Some(v) = get("codec").and_then(Value::as_str) {
            cfg.codec = CodecKind::parse(v)?;
        }
        if let Some(v) = get("churn").and_then(Value::as_str) {
            cfg.churn = ChurnSpec::parse(v)?;
        }
        if let Some(v) = get("faults").and_then(Value::as_str) {
            cfg.faults = FaultSpec::parse(v)?;
        }
        if let Some(v) = get("fd").and_then(Value::as_str) {
            cfg.fd = FdSpec::parse(v)?;
        }
        if let Some(v) = get("shards").and_then(Value::as_int) {
            if v < 1 {
                bail!("shards must be >= 1, got {v}");
            }
            cfg.shards = v as usize;
        }
        if let Some(v) = get("coalesce").and_then(Value::as_bool) {
            cfg.coalesce = v;
        }
        if let Some(v) = get("transport").and_then(Value::as_str) {
            cfg.transport = TransportKind::parse(v)?;
        }
        if let Some(v) = get("trace").and_then(Value::as_str) {
            cfg.trace = TraceSpec::parse(v)?;
        }
        if let Some(v) = get("artifact_dir").and_then(Value::as_str) {
            cfg.artifact_dir = PathBuf::from(v);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_the_tables() {
        let all = ExperimentConfig::all_presets();
        let labels: Vec<&str> = all.iter().map(|c| c.label.as_str()).collect();
        // Table 4.1
        for l in ["AR-4", "NC-4", "EG-4-0.125", "GS-4-0.125", "EG-8-0.002", "GS-8-0.031"] {
            assert!(labels.contains(&l), "missing {l}");
        }
        // Table 4.2
        assert!(labels.contains(&"EG-4-0.0312-0.05"));
        assert!(labels.contains(&"EG-8-0.0005-0.50"));
        // Table 4.3
        assert!(labels.contains(&"CIFAR-AR-4"));
        assert!(labels.contains(&"CIFAR-GS-4-0.002"));
        // Table A.1
        assert!(labels.contains(&"GS-4-TAU-512"));
        // no duplicate labels
        let mut sorted = labels.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), labels.len());
    }

    #[test]
    fn preset_lookup() {
        let c = ExperimentConfig::preset("EG-4-0.031").unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.method, Method::ElasticGossip { alpha: 0.5 });
        assert_eq!(c.schedule, CommSchedule::Probability(0.03125));
        assert!(ExperimentConfig::preset("EG-9-nope").is_err());
    }

    #[test]
    fn paper_arithmetic() {
        let c = ExperimentConfig::preset("AR-4").unwrap();
        assert_eq!(c.per_worker_batch(), 32);
        assert_eq!(c.steps_per_epoch(), 400); // 51200 / 128
        assert_eq!(c.total_steps(), 40_000); // 100 epochs
        let c8 = ExperimentConfig::preset("EG-8-0.031").unwrap();
        assert_eq!(c8.per_worker_batch(), 16);
    }

    #[test]
    fn cifar_presets_anneal() {
        let c = ExperimentConfig::preset("CIFAR-EG-4-0.125").unwrap();
        assert_eq!(c.epochs, 50);
        assert_eq!(c.optimizer, OptimKind::Nag { momentum: 0.9 });
        assert!(matches!(c.lr, LrSchedule::StepAnneal { .. }));
        assert_eq!(c.steps_per_epoch(), 350); // 44800 / 128
    }

    #[test]
    fn from_toml_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            # quick elastic gossip run
            preset = "EG-4-0.031"
            epochs = 3
            n_train = 2560
            seed = 7
            topology = "ring"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.epochs, 3);
        assert_eq!(cfg.n_train, 2560);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.topology, Topology::Ring);
        // inherited from preset
        assert_eq!(cfg.method, Method::ElasticGossip { alpha: 0.5 });
    }

    #[test]
    fn from_toml_codec_key() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            codec = "topk:0.01"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.codec, CodecKind::TopK { frac: 0.01 });
        // default is the bit-exact identity codec
        assert_eq!(ExperimentConfig::default().codec, CodecKind::Identity);
        assert!(ExperimentConfig::from_toml("codec = \"zstd\"").is_err());
    }

    #[test]
    fn from_toml_churn_key() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            churn = "crash@35%:1,rejoin@75%:1"
            "#,
        )
        .unwrap();
        assert!(!cfg.churn.is_empty());
        assert_eq!(cfg.churn.label(), "crash@35%:1,rejoin@75%:1");
        // default is the empty (fixed-roster) schedule
        assert!(ExperimentConfig::default().churn.is_empty());
        assert!(ExperimentConfig::from_toml("churn = \"explode@1:1\"").is_err());
    }

    #[test]
    fn from_toml_faults_and_fd_keys() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            faults = "drop:0.05,partition@2-4:2,seed:7"
            fd = "0.25:0.3:1.0:2"
            "#,
        )
        .unwrap();
        assert!(!cfg.faults.is_empty());
        assert!(!cfg.fd.is_empty());
        assert_eq!(cfg.fd.fanout, 2);
        // defaults are the empty specs (perfect links, oracle membership)
        assert!(ExperimentConfig::default().faults.is_empty());
        assert!(ExperimentConfig::default().fd.is_empty());
        // parse diagnostics surface through the toml layer
        assert!(ExperimentConfig::from_toml("faults = \"drip:0.5\"").is_err());
        assert!(ExperimentConfig::from_toml("fd = \"0.25:oops:1:2\"").is_err());
    }

    #[test]
    fn from_toml_shards_and_coalesce_keys() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            shards = 4
            coalesce = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.shards, 4);
        assert!(cfg.coalesce);
        // defaults: single queue, per-message framing
        assert_eq!(ExperimentConfig::default().shards, 1);
        assert!(!ExperimentConfig::default().coalesce);
        assert!(ExperimentConfig::from_toml("shards = 0").is_err());
    }

    #[test]
    fn from_toml_transport_key() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            transport = "loopback-udp"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.transport, TransportKind::LoopbackUdp);
        assert_eq!(ExperimentConfig::default().transport, TransportKind::InProc);
        let err = ExperimentConfig::from_toml("transport = \"carrier-pigeon\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("carrier-pigeon") || err.contains("transport"), "{err}");
    }

    #[test]
    fn from_toml_trace_key() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            preset = "EG-4-0.031"
            trace = "on,ring:128,dump:/tmp/t.json"
            "#,
        )
        .unwrap();
        assert!(cfg.trace.on);
        assert_eq!(cfg.trace.ring, 128);
        assert!(ExperimentConfig::default().trace.is_off());
        assert!(ExperimentConfig::from_toml("trace = \"sometimes\"").is_err());
    }

    #[test]
    fn scaled_keeps_minimums() {
        let c = ExperimentConfig::preset("EG-4-0.031").unwrap().scaled(10, 5);
        assert_eq!(c.epochs, 5);
        assert_eq!(c.n_train, 5120);
        assert!(c.n_val >= 64);
    }

    #[test]
    fn schedule_parse_and_period() {
        assert_eq!(CommSchedule::parse("every").unwrap(), CommSchedule::EveryStep);
        assert_eq!(CommSchedule::parse("period:32").unwrap(), CommSchedule::Period(32));
        assert_eq!(CommSchedule::parse("prob:0.125").unwrap(), CommSchedule::Probability(0.125));
        assert_eq!(CommSchedule::Probability(0.125).effective_period(), 8.0);
    }
}
