//! TOML-lite: the subset of TOML the config system needs.
//!
//! Supported: `key = value` pairs, `[section]` headers (flattened to
//! `section.key`), `#` comments, strings (`"..."`), integers, floats,
//! booleans, and flat arrays.  Not supported (by design): nested tables,
//! multi-line strings, datetimes.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat document: `[section]` keys become `section.key`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", ln + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = parse_value(v.trim()).map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.map.insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas not inside strings (arrays are flat, no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_types() {
        let d = parse(
            r#"
            name = "elastic"   # trailing comment
            workers = 4
            lr = 0.001
            fast = true
            taus = [8, 32, 128]
            "#,
        )
        .unwrap();
        assert_eq!(d.get("name").unwrap().as_str(), Some("elastic"));
        assert_eq!(d.get("workers").unwrap().as_int(), Some(4));
        assert_eq!(d.get("lr").unwrap().as_float(), Some(0.001));
        assert_eq!(d.get("fast").unwrap().as_bool(), Some(true));
        assert_eq!(
            d.get("taus").unwrap(),
            &Value::Arr(vec![Value::Int(8), Value::Int(32), Value::Int(128)])
        );
    }

    #[test]
    fn sections_flatten() {
        let d = parse("[run]\nepochs = 3\n[data]\nn = 100\n").unwrap();
        assert_eq!(d.get("run.epochs").unwrap().as_int(), Some(3));
        assert_eq!(d.get("data.n").unwrap().as_int(), Some(100));
    }

    #[test]
    fn hash_inside_string_kept() {
        let d = parse(r##"tag = "a#b" # real comment"##).unwrap();
        assert_eq!(d.get("tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn int_promotes_to_float() {
        let d = parse("x = 3").unwrap();
        assert_eq!(d.get("x").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn errors() {
        assert!(parse("just a line").is_err());
        assert!(parse("[open").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn string_array() {
        let d = parse(r#"labels = ["a,b", "c"]"#).unwrap();
        assert_eq!(
            d.get("labels").unwrap(),
            &Value::Arr(vec![Value::Str("a,b".into()), Value::Str("c".into())])
        );
    }
}
