//! Persistent scratch arena for zero-allocation communication rounds.
//!
//! The seed implementation cloned every worker's full parameter buffer
//! into a fresh `Vec<Vec<f32>>` on every communication round — at the
//! paper's MLP size that is `W x 2.9M x 4` bytes of allocation + copy
//! per round before a single useful flop.  This module replaces those
//! clones with one arena that is
//!
//! * **persistent** — owned by the coordinator, threaded through
//!   [`CommCtx`](super::CommCtx) each round; every internal buffer keeps
//!   its capacity across rounds, so after warm-up the round performs no
//!   heap allocation at all on *every* topology (asserted by
//!   `arena_footprint_is_stable` and the strategy-level round-trip
//!   tests): Full/Ring sample peers in closed form, Torus2D and
//!   RandomRegular through the arena's [`TopologyCache`] CSR adjacency,
//!   built once per `(topology, n)`;
//! * **double-buffered** — a snapshot plane (per-worker pre-round
//!   parameter copies, plane A) plus an aux plane (two flat rows, used
//!   e.g. for EASGD's pre-round center and summed center delta, plane B),
//!   so a strategy can read consistent pre-round state while the live
//!   buffers move on;
//! * **participation-aware** — only workers that are an endpoint of at
//!   least one gossip edge are snapshotted ([`snapshot_participants`]
//!   consults the [`EdgePlan`]); at the paper's default communication
//!   probability p = 0.03125 most rounds touch a small fraction of the
//!   cluster, which is exactly the paper's traffic argument applied to
//!   memory bandwidth.
//!
//! [`EdgePlan`] is the round's matchmaking result in CSR form: the
//! per-worker interaction sets **K** of Algorithm 4 (own pick ∪ reverse
//! picks) and the reverse-only pusher lists, stored in flat reusable
//! arrays instead of a `Vec<Vec<usize>>` per round.  Building it consumes
//! the gossip rng exactly like the free function
//! [`gossip_picks`](super::gossip_picks), so seeds reproduce the same
//! edge sequence as the seed implementation.
//!
//! The arena is also the hand-off point for the threaded runtime: the
//! leader fills it during the plan phase (`Strategy::plan_round`), the
//! parked worker threads then read it concurrently (`&ScratchArena`)
//! while each applies its own slot's update — see
//! `coordinator::parallel`.
//!
//! [`snapshot_participants`]: ScratchArena::snapshot_participants

use std::cell::UnsafeCell;

use crate::topology::{Topology, TopologyCache};
use crate::util::rng::Rng;

/// One snapshot row (plane A) behind an `UnsafeCell` so the threaded
/// runtime's worker threads can *pre-snapshot* their own slot during the
/// compute phase (each worker writes only row `i == its slot`, the
/// leader reads nothing until the next barrier — same partitioned-access
/// discipline as `coordinator::parallel::SlotStore`).  Single-threaded
/// callers go through `&mut self` methods and never notice the cell.
struct SnapRow(UnsafeCell<Vec<f32>>);

// SAFETY: rows are only accessed concurrently by the threaded runtime,
// which partitions them by worker index between barriers (writers) or
// shares them read-only (readers) — see `coordinator::parallel`.
unsafe impl Sync for SnapRow {}

impl Default for SnapRow {
    fn default() -> Self {
        SnapRow(UnsafeCell::new(Vec::new()))
    }
}

impl std::fmt::Debug for SnapRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // don't read through the cell: a Debug dump must stay safe even
        // while worker threads own their rows
        f.debug_tuple("SnapRow").finish()
    }
}

/// Round matchmaking in CSR (flat offsets + items) form.
///
/// `k_set(i)` reproduces [`super::k_sets`]'s list for worker `i` in the
/// same order (own pick interleaved with reverse picks by picker index),
/// so per-element floating-point application order is unchanged from the
/// reference semantics.
#[derive(Debug, Default)]
pub struct EdgePlan {
    n: usize,
    edges: usize,
    picks: Vec<Option<usize>>,
    /// K-set CSR: worker i's interaction set is
    /// `k_items[k_off[i]..k_off[i + 1]]`
    k_off: Vec<usize>,
    k_items: Vec<usize>,
    /// reverse-edge-only CSR (push-gossip receivers): workers that picked i
    r_off: Vec<usize>,
    r_items: Vec<usize>,
    /// fill cursors, reused per build
    cursor: Vec<usize>,
}

impl EdgePlan {
    pub fn new() -> Self {
        EdgePlan::default()
    }

    /// Sample this round's edges (convenience wrapper that builds a
    /// throwaway [`TopologyCache`] — tests and one-shot callers; the hot
    /// loop goes through [`build_cached`](Self::build_cached) with the
    /// arena's persistent cache).
    pub fn build(&mut self, communicating: &[bool], topology: &Topology, rng: &mut Rng) {
        let mut cache = TopologyCache::new();
        cache.ensure(topology, communicating.len());
        self.build_cached(communicating, &cache, rng);
    }

    /// Sample this round's edges. Consumes `rng` identically to
    /// [`super::gossip_picks`] (one uniform draw per communicating
    /// worker, in worker order), then indexes the K-sets and pusher
    /// lists without allocating beyond the high-water mark.  Peer
    /// sampling goes through the cached adjacency, so no topology
    /// allocates on the sampling path.
    pub fn build_cached(&mut self, communicating: &[bool], cache: &TopologyCache, rng: &mut Rng) {
        let n = communicating.len();
        self.n = n;
        self.picks.clear();
        for (i, &c) in communicating.iter().enumerate() {
            self.picks.push(if c { cache.sample_peer(i, rng) } else { None });
        }

        // degree counting: K = own pick + reverse edges; R = reverse only
        self.k_off.clear();
        self.k_off.resize(n + 1, 0);
        self.r_off.clear();
        self.r_off.resize(n + 1, 0);
        self.edges = 0;
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.k_off[i + 1] += 1;
                self.k_off[k + 1] += 1;
                self.r_off[k + 1] += 1;
                self.edges += 1;
            }
        }
        for i in 0..n {
            self.k_off[i + 1] += self.k_off[i];
            self.r_off[i + 1] += self.r_off[i];
        }

        // fill in the same traversal order as `k_sets`: iterate pickers in
        // worker order, appending the own pick to i and the reverse edge
        // to k as encountered
        self.k_items.clear();
        self.k_items.resize(2 * self.edges, usize::MAX);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.k_off[..n]);
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.k_items[self.cursor[i]] = k;
                self.cursor[i] += 1;
                self.k_items[self.cursor[k]] = i;
                self.cursor[k] += 1;
            }
        }

        self.r_items.clear();
        self.r_items.resize(self.edges, usize::MAX);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.r_off[..n]);
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.r_items[self.cursor[k]] = i;
                self.cursor[k] += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of directed edges selected this round.
    pub fn edge_count(&self) -> usize {
        self.edges
    }

    pub fn any_edges(&self) -> bool {
        self.edges > 0
    }

    pub fn pick(&self, i: usize) -> Option<usize> {
        self.picks[i]
    }

    pub fn picks(&self) -> &[Option<usize>] {
        &self.picks
    }

    /// Algorithm 4 line 6: worker `i`'s interaction set **K**.
    pub fn k_set(&self, i: usize) -> &[usize] {
        &self.k_items[self.k_off[i]..self.k_off[i + 1]]
    }

    /// Workers that pushed to `i` this round (reverse edges only).
    pub fn pushers(&self, i: usize) -> &[usize] {
        &self.r_items[self.r_off[i]..self.r_off[i + 1]]
    }

    /// Worker `i` is an endpoint of at least one edge.
    pub fn participates(&self, i: usize) -> bool {
        self.k_off[i + 1] > self.k_off[i]
    }
}

/// The scratch arena. See the module docs for the design rationale.
#[derive(Debug, Default)]
pub struct ScratchArena {
    flat: usize,
    /// plane A: per-worker pre-round parameter snapshots
    snaps: Vec<SnapRow>,
    /// which slots hold a valid snapshot for the *current* round
    valid: Vec<bool>,
    /// rows whose contents were pre-snapshotted by worker threads since
    /// the last `begin_round` (leader-written via [`set_presnap`];
    /// consumed — validated and cleared — by `begin_round`).  Empty on
    /// the sequential path, which keeps it byte-identical.
    ///
    /// [`set_presnap`]: ScratchArena::set_presnap
    presnap_mask: Vec<bool>,
    /// plane B row 1 (e.g. EASGD pre-round center)
    aux: Vec<f32>,
    /// plane B row 2 (e.g. EASGD summed center delta)
    aux2: Vec<f32>,
    /// this round's communication mask (copied so sharded appliers can
    /// read it without holding the coordinator's schedule buffer)
    mask: Vec<bool>,
    /// cached adjacency for allocation-free peer sampling (built once per
    /// (topology, n); Full/Ring are closed-form and store nothing)
    topo_cache: TopologyCache,
    /// free-list of in-flight message parameter buffers (event-driven
    /// runtime): rent on send, return after boundary apply — capacity
    /// persists, so the async path stops allocating once the in-flight
    /// high-water mark has been seen
    msg_pool: Vec<Vec<f32>>,
    /// free-list of encoded wire buffers (`comm::codec`): rented when the
    /// outbox is flushed, returned once the payload is decoded at
    /// delivery — same discipline as `msg_pool`
    byte_pool: Vec<Vec<u8>>,
    /// this round's matchmaking
    pub plan: EdgePlan,
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena::default()
    }

    /// Size the arena for a `workers x flat` cluster. Idempotent.
    /// Snapshot *rows* are sized lazily on first use (a strategy that
    /// never snapshots — EASGD, All-reduce — pays nothing for the
    /// snapshot plane); every buffer keeps its capacity afterwards, so
    /// steady-state rounds never touch the allocator.
    pub fn ensure(&mut self, workers: usize, flat: usize) {
        if self.snaps.len() != workers || self.flat != flat {
            self.flat = flat;
            self.snaps.resize_with(workers, SnapRow::default);
            self.valid.resize(workers, false);
            self.aux.resize(flat, 0.0);
            self.aux2.resize(flat, 0.0);
            self.mask.resize(workers, false);
        }
    }

    /// Start a round: size the arena, invalidate stale snapshots (rows
    /// pre-snapshotted by worker threads since the last round stay
    /// valid — with no pre-snapshots, exactly the old all-invalid
    /// reset), and copy the communication mask.
    pub fn begin_round(&mut self, workers: usize, flat: usize, communicating: &[bool]) {
        self.ensure(workers, flat);
        for (i, v) in self.valid.iter_mut().enumerate() {
            *v = self.presnap_mask.get(i).copied().unwrap_or(false);
        }
        self.presnap_mask.clear();
        self.mask.copy_from_slice(communicating);
    }

    /// Declare which rows worker threads pre-snapshotted since the last
    /// round (threaded runtime's leader, just before `plan_round`): the
    /// next [`begin_round`](Self::begin_round) marks exactly these rows
    /// valid instead of invalidating them.  The contents were written by
    /// [`presnapshot_row`](Self::presnapshot_row); splitting the valid
    /// bit from the row write is what lets the workers write lock-free.
    pub fn set_presnap(&mut self, mask: &[bool]) {
        self.presnap_mask.clear();
        self.presnap_mask.extend_from_slice(mask);
    }

    /// Pre-snapshot row `i`'s *contents* from a worker thread (the valid
    /// bit travels separately through [`set_presnap`](Self::set_presnap)).
    ///
    /// # Safety
    ///
    /// The caller must have exclusive access to row `i` and there must be
    /// no concurrent reader of it — the threaded runtime guarantees this
    /// by having worker `i` call it only during the compute phase, in
    /// which snapshot rows have no other writers or readers.
    pub unsafe fn presnapshot_row(&self, i: usize, src: &[f32]) {
        let s = &mut *self.snaps[i].0.get();
        s.clear();
        s.extend_from_slice(src);
    }

    /// Build this round's [`EdgePlan`] from the mask stored by
    /// [`begin_round`](Self::begin_round), sampling peers through the
    /// arena's persistent adjacency cache (built on first use per
    /// `(topology, n)`; every later round is allocation-free for every
    /// topology, closing the ROADMAP's Torus2D/RandomRegular gap).
    pub fn plan_edges(&mut self, topology: &Topology, rng: &mut Rng) {
        self.topo_cache.ensure(topology, self.mask.len());
        self.plan.build_cached(&self.mask, &self.topo_cache, rng);
    }

    /// The persistent adjacency cache (event-driven runtime pre-draws its
    /// pick tables through the same cache so sync and async matchmaking
    /// consume the gossip stream identically).
    pub fn topo_cache_mut(&mut self) -> &mut TopologyCache {
        &mut self.topo_cache
    }

    /// Snapshot exactly the workers that participate in an edge this
    /// round (pre-round state, plane A).  Rows already valid — worker
    /// threads pre-snapshotted them during the compute phase — are
    /// skipped: their contents are the same pre-round bytes this copy
    /// would write.
    pub fn snapshot_participants(&mut self, params: &[Vec<f32>]) {
        for (i, p) in params.iter().enumerate() {
            if self.plan.participates(i) && !self.valid[i] {
                self.snapshot(i, p);
            }
        }
    }

    /// Snapshot a single worker (strategies with non-edge participation).
    /// The row is sized on first use; its capacity persists, so this
    /// allocates only until the worker's first-ever participation.
    pub fn snapshot(&mut self, i: usize, params: &[f32]) {
        let s = self.snaps[i].0.get_mut();
        s.clear();
        s.extend_from_slice(params);
        self.valid[i] = true;
    }

    /// Worker `i`'s pre-round snapshot. Panics in debug builds if `i` was
    /// not snapshotted this round.
    pub fn snap(&self, i: usize) -> &[f32] {
        debug_assert!(self.valid[i], "worker {i} was not snapshotted this round");
        // SAFETY: shared read — writers only exist in phases where the
        // threaded runtime hands out no shared arena references
        unsafe { &*self.snaps[i].0.get() }
    }

    pub fn has_snap(&self, i: usize) -> bool {
        self.valid[i]
    }

    /// The round's communication mask as copied by `begin_round`.
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }

    pub fn aux(&self) -> &[f32] {
        &self.aux
    }

    pub fn aux_mut(&mut self) -> &mut [f32] {
        &mut self.aux
    }

    pub fn aux2(&self) -> &[f32] {
        &self.aux2
    }

    pub fn aux2_mut(&mut self) -> &mut [f32] {
        &mut self.aux2
    }

    /// Fused multi-peer elastic update for slot `i` (the comm component
    /// of Algorithms 4/5):
    ///
    /// ```text
    /// dst <- dst - alpha * SUM_{k in K_i} (snap_i - snap_k)
    /// ```
    ///
    /// Applied through the shared
    /// [`crate::tensor::elastic_apply_grouped`] kernel (fixed-width peer
    /// groups, allocation-free), fed from the snapshot plane; per-element
    /// operation order equals the naive one-sweep-per-peer reference
    /// exactly, so the result is bit-identical to the seed implementation
    /// — and to the async boundary apply, which feeds the same kernel
    /// from message buffers.
    pub fn elastic_apply(&self, dst: &mut [f32], i: usize, alpha: f32) {
        let kset = self.plan.k_set(i);
        if kset.is_empty() {
            return;
        }
        crate::tensor::elastic_apply_grouped(dst, self.snap(i), kset.len(), |j| self.snap(kset[j]), alpha);
    }

    /// Push-gossip receiver update for slot `i`: mean over
    /// `{snap_i} ∪ {snap_j : j pushed to i}`, single fused pass with a
    /// stack accumulator (no heap) — the shared
    /// [`crate::tensor::push_mean_into`] kernel, fed from the snapshot
    /// plane (the async runtime feeds the same kernel from message
    /// buffers, which is what keeps the two regimes bit-identical).
    pub fn push_mean_apply(&self, dst: &mut [f32], i: usize) {
        let pushers = self.plan.pushers(i);
        if pushers.is_empty() {
            return;
        }
        crate::tensor::push_mean_into(dst, self.snap(i), pushers.len(), |j| self.snap(pushers[j]));
    }

    /// Pass every valid participating snapshot row through `codec`
    /// (encode then decode, in place): after this, *both* endpoints of
    /// every gossip edge read the **published** — quantized — snapshot,
    /// which is what a real wire would deliver and what keeps elastic
    /// sum conservation exact under lossy codecs.  The wire buffer is
    /// rented from the arena's byte pool, so warm rounds stay
    /// allocation-free.  Identity codecs should be skipped by the caller
    /// (the roundtrip is then a byte-identical no-op, just wasted work).
    pub fn codec_roundtrip_snapshots(&mut self, codec: &mut dyn crate::comm::codec::Codec) -> anyhow::Result<()> {
        let mut wire = self.rent_bytes();
        for i in 0..self.snaps.len() {
            if !(self.plan.participates(i) && self.valid[i]) {
                continue;
            }
            let row = self.snaps[i].0.get_mut();
            wire.clear();
            codec.encode_into(i, row, &mut wire);
            codec.decode_into(&wire, row)?;
        }
        self.return_bytes(wire);
        Ok(())
    }

    /// Rent a pooled buffer holding a copy of `src` (in-flight message
    /// payloads of the event-driven runtime).  Pops from the free-list —
    /// after the in-flight high-water mark has been seen, renting never
    /// allocates.
    pub fn rent_msg(&mut self, src: &[f32]) -> Vec<f32> {
        let mut buf = self.msg_pool.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(src);
        buf
    }

    /// Return a rented message buffer to the pool (capacity retained).
    pub fn return_msg(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        self.msg_pool.push(buf);
    }

    /// Buffers currently parked in the message pool.
    pub fn msg_pool_len(&self) -> usize {
        self.msg_pool.len()
    }

    /// Rent an empty byte buffer for an encoded wire payload
    /// (`comm::codec`).  Pops from the free-list — after the in-flight
    /// high-water mark has been seen, renting never allocates.
    pub fn rent_bytes(&mut self) -> Vec<u8> {
        let mut buf = self.byte_pool.pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return a rented wire buffer to the pool (capacity retained).
    pub fn return_bytes(&mut self, mut buf: Vec<u8>) {
        buf.clear();
        self.byte_pool.push(buf);
    }

    /// Buffers currently parked in the wire-byte pool.
    pub fn byte_pool_len(&self) -> usize {
        self.byte_pool.len()
    }

    /// Capacity fingerprint: hashes the (pointer, capacity) pair of every
    /// internal buffer. If two fingerprints taken across rounds are equal,
    /// no arena buffer was reallocated in between — the zero-allocation
    /// round-trip assertion.
    pub fn footprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |ptr: usize, cap: usize| {
            for v in [ptr as u64, cap as u64] {
                h ^= v;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for s in &self.snaps {
            // SAFETY: footprint is only taken in single-threaded phases
            let v = unsafe { &*s.0.get() };
            mix(v.as_ptr() as usize, v.capacity());
        }
        mix(self.snaps.as_ptr() as usize, self.snaps.capacity());
        mix(self.valid.as_ptr() as usize, self.valid.capacity());
        mix(self.presnap_mask.as_ptr() as usize, self.presnap_mask.capacity());
        mix(self.aux.as_ptr() as usize, self.aux.capacity());
        mix(self.aux2.as_ptr() as usize, self.aux2.capacity());
        mix(self.mask.as_ptr() as usize, self.mask.capacity());
        mix(self.plan.picks.as_ptr() as usize, self.plan.picks.capacity());
        mix(self.plan.k_off.as_ptr() as usize, self.plan.k_off.capacity());
        mix(self.plan.k_items.as_ptr() as usize, self.plan.k_items.capacity());
        mix(self.plan.r_off.as_ptr() as usize, self.plan.r_off.capacity());
        mix(self.plan.r_items.as_ptr() as usize, self.plan.r_items.capacity());
        mix(self.plan.cursor.as_ptr() as usize, self.plan.cursor.capacity());
        for (ptr, cap) in self.topo_cache.footprint_parts() {
            mix(ptr, cap);
        }
        // the message pool is a free-list: buffers permute between pool and
        // in-flight messages, so fold them order-independently (XOR) — the
        // *set* of buffers must be stable, not their stack order
        let mut pool_fold: u64 = self.msg_pool.len() as u64;
        for b in &self.msg_pool {
            let mut e: u64 = 0xcbf29ce484222325;
            for v in [b.as_ptr() as u64, b.capacity() as u64] {
                e ^= v;
                e = e.wrapping_mul(0x100000001b3);
            }
            pool_fold ^= e;
        }
        mix(pool_fold as usize, self.msg_pool.capacity());
        // wire-byte pool: same free-list discipline, same order-free fold
        let mut byte_fold: u64 = self.byte_pool.len() as u64;
        for b in &self.byte_pool {
            let mut e: u64 = 0xcbf29ce484222325;
            for v in [b.as_ptr() as u64, b.capacity() as u64] {
                e ^= v;
                e = e.wrapping_mul(0x100000001b3);
            }
            byte_fold ^= e;
        }
        mix(byte_fold as usize, self.byte_pool.capacity());
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{gossip_picks, k_sets};

    #[test]
    fn edge_plan_matches_reference_matchmaker() {
        // EdgePlan must consume the rng and index edges exactly like the
        // reference free functions, for every topology
        for topo in [
            Topology::Full,
            Topology::Ring,
            Topology::RandomRegular { degree: 2, seed: 7 },
        ] {
            for seed in 0..20u64 {
                let w = 3 + (seed as usize % 8);
                let mut rng_a = Rng::new(seed);
                let mut rng_b = Rng::new(seed);
                let mut mask_rng = Rng::new(seed ^ 0xABCD);
                let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.6)).collect();

                let picks = gossip_picks(&comm, &topo, &mut rng_a);
                let ks = k_sets(&picks);

                let mut plan = EdgePlan::new();
                plan.build(&comm, &topo, &mut rng_b);

                assert_eq!(plan.picks(), &picks[..], "{topo:?} seed {seed}");
                for i in 0..w {
                    assert_eq!(plan.k_set(i), &ks[i][..], "k_set[{i}] {topo:?} seed {seed}");
                    let ref_pushers: Vec<usize> = picks
                        .iter()
                        .enumerate()
                        .filter_map(|(j, p)| (*p == Some(i)).then_some(j))
                        .collect();
                    assert_eq!(plan.pushers(i), &ref_pushers[..], "pushers[{i}]");
                    assert_eq!(plan.participates(i), !ks[i].is_empty());
                }
                let picked = picks.iter().flatten().count();
                assert_eq!(plan.edge_count(), picked);
            }
        }
    }

    #[test]
    fn snapshot_only_participants() {
        let mut arena = ScratchArena::new();
        let params: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 3]).collect();
        // worker 0 picks worker 2; workers 1 and 3 silent
        let comm = vec![true, false, false, false];
        arena.begin_round(4, 3, &comm);
        // deterministic pick via Full topology on a seed known to pick 2
        let mut rng = Rng::new(0);
        loop {
            arena.plan_edges(&Topology::Full, &mut rng);
            if arena.plan.pick(0).is_some() {
                break;
            }
        }
        arena.snapshot_participants(&params);
        let k = arena.plan.pick(0).unwrap();
        assert!(arena.has_snap(0));
        assert!(arena.has_snap(k));
        for i in 0..4 {
            if i != 0 && i != k {
                assert!(!arena.has_snap(i), "worker {i} snapshotted needlessly");
            }
        }
        assert_eq!(arena.snap(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn presnapshotted_rows_survive_begin_round_and_skip_the_leader_copy() {
        let mut arena = ScratchArena::new();
        arena.ensure(2, 2);
        // worker thread wrote the row contents; leader declares the bit
        unsafe { arena.presnapshot_row(0, &[7.0, 8.0]) };
        arena.set_presnap(&[true, false]);
        arena.begin_round(2, 2, &[true, true]);
        assert!(arena.has_snap(0), "pre-snapshotted row lost its validity");
        assert!(!arena.has_snap(1));
        // snapshot_participants must not overwrite the pre-snapshotted row
        let params = vec![vec![1.0f32, 2.0], vec![3.0f32, 4.0]];
        arena.plan_edges(&Topology::Full, &mut Rng::new(0));
        arena.snapshot_participants(&params);
        assert_eq!(arena.snap(0), &[7.0, 8.0], "leader re-copied a valid row");
        // a round with no presnap declaration invalidates as before
        arena.begin_round(2, 2, &[false, false]);
        assert!(!arena.has_snap(0));
    }

    #[test]
    fn begin_round_invalidates_previous_snapshots() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 2, &[true, true]);
        arena.snapshot(0, &[1.0, 2.0]);
        assert!(arena.has_snap(0));
        arena.begin_round(2, 2, &[false, false]);
        assert!(!arena.has_snap(0));
    }

    #[test]
    fn arena_footprint_is_stable_after_warmup() {
        let mut arena = ScratchArena::new();
        let topo = Topology::Full;
        let w = 8;
        let n = 500;
        let params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
        let mut rng = Rng::new(3);
        // warm-up at full participation pins the high-water mark
        for _ in 0..3 {
            let comm = vec![true; w];
            arena.begin_round(w, n, &comm);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
        }
        let fp = arena.footprint();
        let mut mask_rng = Rng::new(11);
        for round in 0..60 {
            let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.4)).collect();
            arena.begin_round(w, n, &comm);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
            assert_eq!(arena.footprint(), fp, "arena reallocated at round {round}");
        }
    }

    #[test]
    fn presnapshot_path_is_allocation_stable_after_warmup() {
        // the sharded synchronous round (coordinator::parallel) writes
        // snapshot rows from worker threads via presnapshot_row; the
        // allocation fingerprint must reach the same steady state as the
        // leader-copied path
        let mut arena = ScratchArena::new();
        let topo = Topology::Full;
        let w = 8;
        let n = 500;
        let params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
        let mut rng = Rng::new(3);
        arena.ensure(w, n);
        for _ in 0..3 {
            for (i, p) in params.iter().enumerate() {
                unsafe { arena.presnapshot_row(i, p) };
            }
            arena.set_presnap(&vec![true; w]);
            arena.begin_round(w, n, &vec![true; w]);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
        }
        let fp = arena.footprint();
        let mut mask_rng = Rng::new(11);
        for round in 0..60 {
            let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.4)).collect();
            for (i, p) in params.iter().enumerate() {
                if comm[i] {
                    unsafe { arena.presnapshot_row(i, p) };
                }
            }
            arena.set_presnap(&comm);
            arena.begin_round(w, n, &comm);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
            assert_eq!(arena.footprint(), fp, "presnap path reallocated at round {round}");
        }
    }

    #[test]
    fn elastic_apply_empty_kset_is_noop() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 3, &[false, false]);
        arena.plan_edges(&Topology::Full, &mut Rng::new(0));
        let mut dst = vec![1.0f32, 2.0, 3.0];
        arena.elastic_apply(&mut dst, 0, 0.5);
        assert_eq!(dst, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn msg_pool_reuses_capacity() {
        let mut arena = ScratchArena::new();
        let src = vec![1.0f32; 500];
        // warm-up: two buffers in flight at once
        let a = arena.rent_msg(&src);
        let b = arena.rent_msg(&src);
        assert_eq!(a.len(), 500);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        arena.return_msg(a);
        arena.return_msg(b);
        assert_eq!(arena.msg_pool_len(), 2);
        // steady state: renting up to the high-water mark reuses the same
        // allocations (in some order) and never grows the pool
        for _ in 0..50 {
            let x = arena.rent_msg(&src);
            let y = arena.rent_msg(&src);
            assert!(
                (x.as_ptr() == pa || x.as_ptr() == pb) && (y.as_ptr() == pa || y.as_ptr() == pb),
                "pool handed out a fresh allocation"
            );
            arena.return_msg(x);
            arena.return_msg(y);
        }
        assert_eq!(arena.msg_pool_len(), 2);
    }

    #[test]
    fn byte_pool_reuses_capacity() {
        let mut arena = ScratchArena::new();
        let mut a = arena.rent_bytes();
        a.extend_from_slice(&[1u8; 900]);
        let mut b = arena.rent_bytes();
        b.extend_from_slice(&[2u8; 900]);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        arena.return_bytes(a);
        arena.return_bytes(b);
        assert_eq!(arena.byte_pool_len(), 2);
        for _ in 0..50 {
            let mut x = arena.rent_bytes();
            x.extend_from_slice(&[3u8; 900]);
            let mut y = arena.rent_bytes();
            y.extend_from_slice(&[4u8; 900]);
            assert!(
                (x.as_ptr() == pa || x.as_ptr() == pb) && (y.as_ptr() == pa || y.as_ptr() == pb),
                "byte pool handed out a fresh allocation"
            );
            arena.return_bytes(x);
            arena.return_bytes(y);
        }
        assert_eq!(arena.byte_pool_len(), 2);
    }

    #[test]
    fn plan_edges_is_allocation_free_on_csr_topologies_after_warmup() {
        // the ROADMAP gap this PR closes: RandomRegular used to rebuild the
        // whole adjacency per *sample*
        for topo in [
            Topology::RandomRegular { degree: 3, seed: 5 },
            Topology::Torus2D { width: 4 },
        ] {
            let w = 16;
            let n = 64;
            let params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
            let mut arena = ScratchArena::new();
            let mut rng = Rng::new(8);
            for _ in 0..3 {
                let comm = vec![true; w];
                arena.begin_round(w, n, &comm);
                arena.plan_edges(&topo, &mut rng);
                arena.snapshot_participants(&params);
            }
            let fp = arena.footprint();
            let mut mask_rng = Rng::new(31);
            for round in 0..40 {
                let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.5)).collect();
                arena.begin_round(w, n, &comm);
                arena.plan_edges(&topo, &mut rng);
                arena.snapshot_participants(&params);
                assert_eq!(arena.footprint(), fp, "{topo:?} reallocated at round {round}");
            }
        }
    }

    #[test]
    fn codec_roundtrip_publishes_quantized_snapshots_allocation_free() {
        use crate::comm::codec::{Codec, Q8Codec};
        let topo = Topology::Full;
        let w = 4;
        let n = 300;
        let params: Vec<Vec<f32>> = (0..w)
            .map(|i| (0..n).map(|j| ((i * n + j) as f32).sin()).collect())
            .collect();
        let mut arena = ScratchArena::new();
        let mut codec = Q8Codec { chunk: 64 };
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            arena.begin_round(w, n, &vec![true; w]);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
            arena.codec_roundtrip_snapshots(&mut codec).unwrap();
        }
        let fp = arena.footprint();
        for round in 0..30 {
            arena.begin_round(w, n, &vec![true; w]);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
            arena.codec_roundtrip_snapshots(&mut codec).unwrap();
            assert_eq!(arena.footprint(), fp, "codec roundtrip reallocated at round {round}");
            // published rows are the q8 images of the raw params: close
            // but (generically) not equal, and identical to a direct
            // encode/decode of the same row
            for i in 0..w {
                if !arena.has_snap(i) {
                    continue;
                }
                let mut wire = Vec::new();
                let mut want = params[i].clone();
                codec.encode_into(i, &params[i], &mut wire);
                codec.decode_into(&wire, &mut want).unwrap();
                assert_eq!(arena.snap(i), &want[..], "worker {i} round {round}");
            }
        }
    }

    #[test]
    fn push_mean_apply_averages() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 2, &[false, true]);
        // force worker 1 to push to 0 (W=2: the only possible peer)
        arena.plan_edges(&Topology::Full, &mut Rng::new(0));
        assert_eq!(arena.plan.pick(1), Some(0));
        let params = vec![vec![0.0f32, 2.0], vec![4.0f32, 6.0]];
        arena.snapshot_participants(&params);
        let mut dst = params[0].clone();
        arena.push_mean_apply(&mut dst, 0);
        assert_eq!(dst, vec![2.0, 4.0]);
    }
}
