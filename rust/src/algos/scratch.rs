//! Persistent scratch arena for zero-allocation communication rounds.
//!
//! The seed implementation cloned every worker's full parameter buffer
//! into a fresh `Vec<Vec<f32>>` on every communication round — at the
//! paper's MLP size that is `W x 2.9M x 4` bytes of allocation + copy
//! per round before a single useful flop.  This module replaces those
//! clones with one arena that is
//!
//! * **persistent** — owned by the coordinator, threaded through
//!   [`CommCtx`](super::CommCtx) each round; every internal buffer keeps
//!   its capacity across rounds, so after warm-up the round performs no
//!   heap allocation at all on the closed-form topologies (Full, Ring —
//!   asserted by `arena_footprint_is_stable` and the strategy-level
//!   round-trip tests; Torus2D/RandomRegular peer sampling still
//!   materializes neighbor lists, see `sample_peer_fast`);
//! * **double-buffered** — a snapshot plane (per-worker pre-round
//!   parameter copies, plane A) plus an aux plane (two flat rows, used
//!   e.g. for EASGD's pre-round center and summed center delta, plane B),
//!   so a strategy can read consistent pre-round state while the live
//!   buffers move on;
//! * **participation-aware** — only workers that are an endpoint of at
//!   least one gossip edge are snapshotted ([`snapshot_participants`]
//!   consults the [`EdgePlan`]); at the paper's default communication
//!   probability p = 0.03125 most rounds touch a small fraction of the
//!   cluster, which is exactly the paper's traffic argument applied to
//!   memory bandwidth.
//!
//! [`EdgePlan`] is the round's matchmaking result in CSR form: the
//! per-worker interaction sets **K** of Algorithm 4 (own pick ∪ reverse
//! picks) and the reverse-only pusher lists, stored in flat reusable
//! arrays instead of a `Vec<Vec<usize>>` per round.  Building it consumes
//! the gossip rng exactly like the free function
//! [`gossip_picks`](super::gossip_picks), so seeds reproduce the same
//! edge sequence as the seed implementation.
//!
//! The arena is also the hand-off point for the threaded runtime: the
//! leader fills it during the plan phase (`Strategy::plan_round`), the
//! parked worker threads then read it concurrently (`&ScratchArena`)
//! while each applies its own slot's update — see
//! `coordinator::parallel`.
//!
//! [`snapshot_participants`]: ScratchArena::snapshot_participants

use crate::topology::Topology;
use crate::util::rng::Rng;

/// Round matchmaking in CSR (flat offsets + items) form.
///
/// `k_set(i)` reproduces [`super::k_sets`]'s list for worker `i` in the
/// same order (own pick interleaved with reverse picks by picker index),
/// so per-element floating-point application order is unchanged from the
/// reference semantics.
#[derive(Debug, Default)]
pub struct EdgePlan {
    n: usize,
    edges: usize,
    picks: Vec<Option<usize>>,
    /// K-set CSR: worker i's interaction set is
    /// `k_items[k_off[i]..k_off[i + 1]]`
    k_off: Vec<usize>,
    k_items: Vec<usize>,
    /// reverse-edge-only CSR (push-gossip receivers): workers that picked i
    r_off: Vec<usize>,
    r_items: Vec<usize>,
    /// fill cursors, reused per build
    cursor: Vec<usize>,
}

impl EdgePlan {
    pub fn new() -> Self {
        EdgePlan::default()
    }

    /// Sample this round's edges. Consumes `rng` identically to
    /// [`super::gossip_picks`] (one uniform draw per communicating
    /// worker, in worker order), then indexes the K-sets and pusher
    /// lists without allocating beyond the high-water mark.
    pub fn build(&mut self, communicating: &[bool], topology: &Topology, rng: &mut Rng) {
        let n = communicating.len();
        self.n = n;
        self.picks.clear();
        for (i, &c) in communicating.iter().enumerate() {
            self.picks.push(if c { sample_peer_fast(topology, i, n, rng) } else { None });
        }

        // degree counting: K = own pick + reverse edges; R = reverse only
        self.k_off.clear();
        self.k_off.resize(n + 1, 0);
        self.r_off.clear();
        self.r_off.resize(n + 1, 0);
        self.edges = 0;
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.k_off[i + 1] += 1;
                self.k_off[k + 1] += 1;
                self.r_off[k + 1] += 1;
                self.edges += 1;
            }
        }
        for i in 0..n {
            self.k_off[i + 1] += self.k_off[i];
            self.r_off[i + 1] += self.r_off[i];
        }

        // fill in the same traversal order as `k_sets`: iterate pickers in
        // worker order, appending the own pick to i and the reverse edge
        // to k as encountered
        self.k_items.clear();
        self.k_items.resize(2 * self.edges, usize::MAX);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.k_off[..n]);
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.k_items[self.cursor[i]] = k;
                self.cursor[i] += 1;
                self.k_items[self.cursor[k]] = i;
                self.cursor[k] += 1;
            }
        }

        self.r_items.clear();
        self.r_items.resize(self.edges, usize::MAX);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.r_off[..n]);
        for (i, p) in self.picks.iter().enumerate() {
            if let Some(k) = *p {
                self.r_items[self.cursor[k]] = i;
                self.cursor[k] += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of directed edges selected this round.
    pub fn edge_count(&self) -> usize {
        self.edges
    }

    pub fn any_edges(&self) -> bool {
        self.edges > 0
    }

    pub fn pick(&self, i: usize) -> Option<usize> {
        self.picks[i]
    }

    pub fn picks(&self) -> &[Option<usize>] {
        &self.picks
    }

    /// Algorithm 4 line 6: worker `i`'s interaction set **K**.
    pub fn k_set(&self, i: usize) -> &[usize] {
        &self.k_items[self.k_off[i]..self.k_off[i + 1]]
    }

    /// Workers that pushed to `i` this round (reverse edges only).
    pub fn pushers(&self, i: usize) -> &[usize] {
        &self.r_items[self.r_off[i]..self.r_off[i + 1]]
    }

    /// Worker `i` is an endpoint of at least one edge.
    pub fn participates(&self, i: usize) -> bool {
        self.k_off[i + 1] > self.k_off[i]
    }
}

/// Allocation-free peer sampling for the closed-form topologies (Full,
/// Ring). Bit-identical (same rng consumption, same result) to
/// `Topology::sample_peer`, which materializes the sorted neighbor list
/// and draws `below(len)` — Torus2D/RandomRegular fall back to that
/// allocating path (an adjacency cache in the arena is a ROADMAP item).
fn sample_peer_fast(topology: &Topology, i: usize, n: usize, rng: &mut Rng) -> Option<usize> {
    match topology {
        Topology::Full => {
            if n <= 1 {
                None
            } else {
                // neighbors of i under Full, sorted, are 0..i ++ i+1..n:
                // index j maps to j (j < i) or j + 1 (j >= i)
                let j = rng.below(n - 1);
                Some(if j < i { j } else { j + 1 })
            }
        }
        Topology::Ring => {
            if n <= 1 {
                None
            } else if n == 2 {
                // single neighbor; `choose` still consumes one draw
                let _ = rng.below(1);
                Some(1 - i)
            } else {
                let a = (i + n - 1) % n;
                let b = (i + 1) % n;
                let (lo, hi) = (a.min(b), a.max(b));
                Some(if rng.below(2) == 0 { lo } else { hi })
            }
        }
        _ => topology.sample_peer(i, n, rng),
    }
}

/// The scratch arena. See the module docs for the design rationale.
#[derive(Debug, Default)]
pub struct ScratchArena {
    flat: usize,
    /// plane A: per-worker pre-round parameter snapshots
    snaps: Vec<Vec<f32>>,
    /// which slots hold a valid snapshot for the *current* round
    valid: Vec<bool>,
    /// plane B row 1 (e.g. EASGD pre-round center)
    aux: Vec<f32>,
    /// plane B row 2 (e.g. EASGD summed center delta)
    aux2: Vec<f32>,
    /// this round's communication mask (copied so sharded appliers can
    /// read it without holding the coordinator's schedule buffer)
    mask: Vec<bool>,
    /// this round's matchmaking
    pub plan: EdgePlan,
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena::default()
    }

    /// Size the arena for a `workers x flat` cluster. Idempotent.
    /// Snapshot *rows* are sized lazily on first use (a strategy that
    /// never snapshots — EASGD, All-reduce — pays nothing for the
    /// snapshot plane); every buffer keeps its capacity afterwards, so
    /// steady-state rounds never touch the allocator.
    pub fn ensure(&mut self, workers: usize, flat: usize) {
        if self.snaps.len() != workers || self.flat != flat {
            self.flat = flat;
            self.snaps.resize_with(workers, Vec::new);
            self.valid.resize(workers, false);
            self.aux.resize(flat, 0.0);
            self.aux2.resize(flat, 0.0);
            self.mask.resize(workers, false);
        }
    }

    /// Start a round: size the arena, invalidate stale snapshots, and
    /// copy the communication mask.
    pub fn begin_round(&mut self, workers: usize, flat: usize, communicating: &[bool]) {
        self.ensure(workers, flat);
        for v in self.valid.iter_mut() {
            *v = false;
        }
        self.mask.copy_from_slice(communicating);
    }

    /// Build this round's [`EdgePlan`] from the mask stored by
    /// [`begin_round`](Self::begin_round).
    pub fn plan_edges(&mut self, topology: &Topology, rng: &mut Rng) {
        self.plan.build(&self.mask, topology, rng);
    }

    /// Snapshot exactly the workers that participate in an edge this
    /// round (pre-round state, plane A).
    pub fn snapshot_participants(&mut self, params: &[Vec<f32>]) {
        for (i, p) in params.iter().enumerate() {
            if self.plan.participates(i) {
                self.snapshot(i, p);
            }
        }
    }

    /// Snapshot a single worker (strategies with non-edge participation).
    /// The row is sized on first use; its capacity persists, so this
    /// allocates only until the worker's first-ever participation.
    pub fn snapshot(&mut self, i: usize, params: &[f32]) {
        let s = &mut self.snaps[i];
        s.clear();
        s.extend_from_slice(params);
        self.valid[i] = true;
    }

    /// Worker `i`'s pre-round snapshot. Panics in debug builds if `i` was
    /// not snapshotted this round.
    pub fn snap(&self, i: usize) -> &[f32] {
        debug_assert!(self.valid[i], "worker {i} was not snapshotted this round");
        &self.snaps[i]
    }

    pub fn has_snap(&self, i: usize) -> bool {
        self.valid[i]
    }

    /// The round's communication mask as copied by `begin_round`.
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }

    pub fn aux(&self) -> &[f32] {
        &self.aux
    }

    pub fn aux_mut(&mut self) -> &mut [f32] {
        &mut self.aux
    }

    pub fn aux2(&self) -> &[f32] {
        &self.aux2
    }

    pub fn aux2_mut(&mut self) -> &mut [f32] {
        &mut self.aux2
    }

    /// Fused multi-peer elastic update for slot `i` (the comm component
    /// of Algorithms 4/5):
    ///
    /// ```text
    /// dst <- dst - alpha * SUM_{k in K_i} (snap_i - snap_k)
    /// ```
    ///
    /// Applied through [`crate::tensor::elastic_multi_pull`] in fixed-width
    /// peer groups so the call is allocation-free; per-element operation
    /// order equals the naive one-sweep-per-peer reference exactly, so the
    /// result is bit-identical to the seed implementation.
    pub fn elastic_apply(&self, dst: &mut [f32], i: usize, alpha: f32) {
        let kset = self.plan.k_set(i);
        if kset.is_empty() {
            return;
        }
        const GROUP: usize = 8;
        let snap_i = self.snap(i);
        let mut g = 0;
        while g < kset.len() {
            let take = (kset.len() - g).min(GROUP);
            let mut refs: [&[f32]; GROUP] = [&[]; GROUP];
            for (r, &k) in refs.iter_mut().zip(&kset[g..g + take]) {
                *r = self.snap(k);
            }
            crate::tensor::elastic_multi_pull(dst, snap_i, &refs[..take], alpha);
            g += take;
        }
    }

    /// Push-gossip receiver update for slot `i`: mean over
    /// `{snap_i} ∪ {snap_j : j pushed to i}`, single fused pass with a
    /// stack accumulator (no heap).
    pub fn push_mean_apply(&self, dst: &mut [f32], i: usize) {
        let pushers = self.plan.pushers(i);
        if pushers.is_empty() {
            return;
        }
        let inv = 1.0 / (pushers.len() + 1) as f32;
        const CHUNK: usize = 256;
        let snap_i = self.snap(i);
        let n = dst.len();
        let mut acc = [0.0f32; CHUNK];
        let mut s = 0;
        while s < n {
            let e = (s + CHUNK).min(n);
            let m = e - s;
            acc[..m].copy_from_slice(&snap_i[s..e]);
            for &j in pushers {
                let sj = &self.snap(j)[s..e];
                for (a, &x) in acc[..m].iter_mut().zip(sj) {
                    *a += x;
                }
            }
            for (d, &a) in dst[s..e].iter_mut().zip(&acc[..m]) {
                *d = a * inv;
            }
            s = e;
        }
    }

    /// Capacity fingerprint: hashes the (pointer, capacity) pair of every
    /// internal buffer. If two fingerprints taken across rounds are equal,
    /// no arena buffer was reallocated in between — the zero-allocation
    /// round-trip assertion.
    pub fn footprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |ptr: usize, cap: usize| {
            for v in [ptr as u64, cap as u64] {
                h ^= v;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for s in &self.snaps {
            mix(s.as_ptr() as usize, s.capacity());
        }
        mix(self.snaps.as_ptr() as usize, self.snaps.capacity());
        mix(self.valid.as_ptr() as usize, self.valid.capacity());
        mix(self.aux.as_ptr() as usize, self.aux.capacity());
        mix(self.aux2.as_ptr() as usize, self.aux2.capacity());
        mix(self.mask.as_ptr() as usize, self.mask.capacity());
        mix(self.plan.picks.as_ptr() as usize, self.plan.picks.capacity());
        mix(self.plan.k_off.as_ptr() as usize, self.plan.k_off.capacity());
        mix(self.plan.k_items.as_ptr() as usize, self.plan.k_items.capacity());
        mix(self.plan.r_off.as_ptr() as usize, self.plan.r_off.capacity());
        mix(self.plan.r_items.as_ptr() as usize, self.plan.r_items.capacity());
        mix(self.plan.cursor.as_ptr() as usize, self.plan.cursor.capacity());
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{gossip_picks, k_sets};

    #[test]
    fn edge_plan_matches_reference_matchmaker() {
        // EdgePlan must consume the rng and index edges exactly like the
        // reference free functions, for every topology
        for topo in [
            Topology::Full,
            Topology::Ring,
            Topology::RandomRegular { degree: 2, seed: 7 },
        ] {
            for seed in 0..20u64 {
                let w = 3 + (seed as usize % 8);
                let mut rng_a = Rng::new(seed);
                let mut rng_b = Rng::new(seed);
                let mut mask_rng = Rng::new(seed ^ 0xABCD);
                let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.6)).collect();

                let picks = gossip_picks(&comm, &topo, &mut rng_a);
                let ks = k_sets(&picks);

                let mut plan = EdgePlan::new();
                plan.build(&comm, &topo, &mut rng_b);

                assert_eq!(plan.picks(), &picks[..], "{topo:?} seed {seed}");
                for i in 0..w {
                    assert_eq!(plan.k_set(i), &ks[i][..], "k_set[{i}] {topo:?} seed {seed}");
                    let ref_pushers: Vec<usize> = picks
                        .iter()
                        .enumerate()
                        .filter_map(|(j, p)| (*p == Some(i)).then_some(j))
                        .collect();
                    assert_eq!(plan.pushers(i), &ref_pushers[..], "pushers[{i}]");
                    assert_eq!(plan.participates(i), !ks[i].is_empty());
                }
                let picked = picks.iter().flatten().count();
                assert_eq!(plan.edge_count(), picked);
            }
        }
    }

    #[test]
    fn snapshot_only_participants() {
        let mut arena = ScratchArena::new();
        let params: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 3]).collect();
        // worker 0 picks worker 2; workers 1 and 3 silent
        let comm = vec![true, false, false, false];
        arena.begin_round(4, 3, &comm);
        // deterministic pick via Full topology on a seed known to pick 2
        let mut rng = Rng::new(0);
        loop {
            arena.plan_edges(&Topology::Full, &mut rng);
            if arena.plan.pick(0).is_some() {
                break;
            }
        }
        arena.snapshot_participants(&params);
        let k = arena.plan.pick(0).unwrap();
        assert!(arena.has_snap(0));
        assert!(arena.has_snap(k));
        for i in 0..4 {
            if i != 0 && i != k {
                assert!(!arena.has_snap(i), "worker {i} snapshotted needlessly");
            }
        }
        assert_eq!(arena.snap(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn begin_round_invalidates_previous_snapshots() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 2, &[true, true]);
        arena.snapshot(0, &[1.0, 2.0]);
        assert!(arena.has_snap(0));
        arena.begin_round(2, 2, &[false, false]);
        assert!(!arena.has_snap(0));
    }

    #[test]
    fn arena_footprint_is_stable_after_warmup() {
        let mut arena = ScratchArena::new();
        let topo = Topology::Full;
        let w = 8;
        let n = 500;
        let params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
        let mut rng = Rng::new(3);
        // warm-up at full participation pins the high-water mark
        for _ in 0..3 {
            let comm = vec![true; w];
            arena.begin_round(w, n, &comm);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
        }
        let fp = arena.footprint();
        let mut mask_rng = Rng::new(11);
        for round in 0..60 {
            let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.4)).collect();
            arena.begin_round(w, n, &comm);
            arena.plan_edges(&topo, &mut rng);
            arena.snapshot_participants(&params);
            assert_eq!(arena.footprint(), fp, "arena reallocated at round {round}");
        }
    }

    #[test]
    fn elastic_apply_empty_kset_is_noop() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 3, &[false, false]);
        arena.plan_edges(&Topology::Full, &mut Rng::new(0));
        let mut dst = vec![1.0f32, 2.0, 3.0];
        arena.elastic_apply(&mut dst, 0, 0.5);
        assert_eq!(dst, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn push_mean_apply_averages() {
        let mut arena = ScratchArena::new();
        arena.begin_round(2, 2, &[false, true]);
        // force worker 1 to push to 0 (W=2: the only possible peer)
        arena.plan_edges(&Topology::Full, &mut Rng::new(0));
        assert_eq!(arena.plan.pick(1), Some(0));
        let params = vec![vec![0.0f32, 2.0], vec![4.0f32, 6.0]];
        arena.snapshot_participants(&params);
        let mut dst = params[0].clone();
        arena.push_mean_apply(&mut dst, 0);
        assert_eq!(dst, vec![2.0, 4.0]);
    }
}
