//! Communication strategies: the paper's algorithm zoo.
//!
//! Equation 3.5 of the thesis is a *generalized* update from which every
//! method here derives (§3.2):
//!
//! ```text
//! theta_i <- theta_i - eta grad f(theta_i) - alpha SUM_k (theta_i - theta_k)
//! ```
//!
//! * pairwise estimate of the sum, symmetric alpha  -> **Elastic Gossip** (Alg. 4)
//! * pairwise, one-sided averaging                  -> **Gossiping SGD** pull/push (Algs. 3/6)
//! * pairwise, push-sum weights                     -> **GoSGD**
//! * dedicated contact worker holding no data       -> **EASGD** (Alg. 2)
//! * exact sum via collective on gradients          -> **All-reduce SGD** (Alg. 1)
//! * alpha = 0                                      -> **No-communication** baseline
//!
//! Every strategy implements the *synchronous* round (the thesis's
//! reproducibility argument): each training step every worker computes
//! gradients from its shard, then a single communication round runs at
//! the barrier.  The round sees a consistent pre-round snapshot of all
//! parameters — "communication-related and gradient-related updates are
//! computed simultaneously" (§2.3).
//!
//! The pairwise gossip strategies *additionally* implement the
//! message-level protocol hooks (`on_send_due` / `on_message` /
//! `on_boundary_apply`) that the event-driven runtime
//! (`crate::runtime_async`) drives — the asynchronous regime the
//! thesis's future-work chapter calls for, with the synchronous round
//! recoverable as the zero-latency lockstep special case.

pub mod central;
pub mod gossip;
pub mod scratch;

pub use scratch::{EdgePlan, ScratchArena};

use crate::collective::AllReduceImpl;
use crate::comm::Fabric;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Training method selector (parsed from config / CLI).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    NoComm,
    AllReduce { imp: AllReduceImpl },
    ElasticGossip { alpha: f32 },
    GossipingSgdPull,
    GossipingSgdPush,
    GoSgd,
    Easgd { alpha: f32 },
}

impl Method {
    /// Parse e.g. `elastic-gossip:0.5`, `allreduce:ring`, `gossip-pull`,
    /// `easgd:0.1`, `gosgd`, `none`.
    pub fn parse(s: &str) -> anyhow::Result<Method> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        Ok(match head {
            "none" | "nocomm" => Method::NoComm,
            "allreduce" => Method::AllReduce {
                imp: AllReduceImpl::parse(arg.unwrap_or("ring"))?,
            },
            "elastic-gossip" | "eg" => Method::ElasticGossip {
                alpha: arg.unwrap_or("0.5").parse()?,
            },
            "gossip-pull" | "gossiping-sgd" | "gs" => Method::GossipingSgdPull,
            "gossip-push" => Method::GossipingSgdPush,
            "gosgd" => Method::GoSgd,
            "easgd" => Method::Easgd {
                alpha: arg.unwrap_or("0.125").parse()?,
            },
            other => anyhow::bail!("unknown method {other:?}"),
        })
    }

    /// Short label used in tables/figures (paper style: EG / GS / AR / NC).
    pub fn short_label(&self) -> String {
        match self {
            Method::NoComm => "NC".into(),
            Method::AllReduce { .. } => "AR".into(),
            Method::ElasticGossip { .. } => "EG".into(),
            Method::GossipingSgdPull => "GS".into(),
            Method::GossipingSgdPush => "GSpush".into(),
            Method::GoSgd => "GoSGD".into(),
            Method::Easgd { .. } => "EASGD".into(),
        }
    }

    /// Instantiate strategy state for a `w`-worker run.
    pub fn build(&self, w: usize, flat_size: usize) -> Box<dyn Strategy> {
        match self {
            Method::NoComm => Box::new(NoCommStrategy),
            Method::AllReduce { imp } => Box::new(central::AllReduceStrategy::new(*imp)),
            Method::ElasticGossip { alpha } => {
                Box::new(gossip::ElasticGossipStrategy::new(*alpha))
            }
            Method::GossipingSgdPull => Box::new(gossip::PullGossipStrategy),
            Method::GossipingSgdPush => Box::new(gossip::PushGossipStrategy),
            Method::GoSgd => Box::new(gossip::GoSgdStrategy::new(w)),
            Method::Easgd { alpha } => Box::new(central::EasgdStrategy::new(*alpha, flat_size)),
        }
    }

    /// Does this method use the per-step communication schedule?
    /// (All-reduce synchronizes gradients every step by definition.)
    pub fn uses_schedule(&self) -> bool {
        !matches!(self, Method::AllReduce { .. } | Method::NoComm)
    }

    /// Is this one of the pairwise gossip protocols (samples a peer per
    /// communicating worker)?  These are the methods with a message-level
    /// protocol in the event-driven runtime; the barrier/central methods
    /// (All-reduce, EASGD) are inherently synchronous.
    pub fn is_pairwise_gossip(&self) -> bool {
        matches!(
            self,
            Method::ElasticGossip { .. }
                | Method::GossipingSgdPull
                | Method::GossipingSgdPush
                | Method::GoSgd
        )
    }
}

// ---------------------------------------------------------------------------
// message-level protocol (event-driven async runtime)
// ---------------------------------------------------------------------------

/// One membership rumor of the SWIM-style failure-detection plane
/// (`fd:` configs): a claim about `node`'s liveness, stamped with the
/// failure-detector incarnation that made it.  Rumors piggyback on
/// every outgoing message (see [`RumorPack`]) — dissemination costs no
/// extra messages, only bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rumor {
    /// 0 = alive, 1 = suspect, 2 = confirmed dead.
    pub kind: u8,
    pub node: u16,
    /// Failure-detector incarnation of `node` at claim time.  An alive
    /// claim refutes a suspicion only with a *strictly higher*
    /// incarnation (SWIM's refutation rule).
    pub inc: u32,
}

impl Rumor {
    pub const ALIVE: u8 = 0;
    pub const SUSPECT: u8 = 1;
    pub const DEAD: u8 = 2;

    /// Wire footprint: kind(1) + pad(1) + node(2) + inc(4).
    pub const WIRE_BYTES: u64 = 8;
}

/// Up to [`RumorPack::CAP`] rumors riding on one message.  Slot 0 is
/// the implicit `Alive(sender)` heartbeat the runtime stamps at outbox
/// flush; the rest drain the sender's bounded rumor queue.  Fixed-size
/// and `Copy` so attaching rumors never allocates on the hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RumorPack {
    slots: [Rumor; RumorPack::CAP],
    len: u8,
}

impl Default for Rumor {
    fn default() -> Self {
        Rumor { kind: Rumor::ALIVE, node: 0, inc: 0 }
    }
}

impl RumorPack {
    pub const CAP: usize = 4;

    pub fn empty() -> Self {
        RumorPack::default()
    }

    pub fn push(&mut self, r: Rumor) -> bool {
        if (self.len as usize) < RumorPack::CAP {
            self.slots[self.len as usize] = r;
            self.len += 1;
            true
        } else {
            false
        }
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = &Rumor> {
        self.slots[..self.len as usize].iter()
    }

    /// Bytes these rumors add to the message (raw *and* wire: rumors
    /// are control data, never codec-encoded).  Zero when the failure
    /// detector is off — the pack stays empty and byte ledgers match
    /// the detector-free run exactly.
    pub fn wire_bytes(&self) -> u64 {
        self.len as u64 * Rumor::WIRE_BYTES
    }
}

/// One in-flight protocol message of the event-driven runtime
/// (`crate::runtime_async`).  Parameter payloads are pooled buffers
/// rented from the [`ScratchArena`] (returned after boundary apply), so
/// the async path stops allocating once the in-flight high-water mark
/// has been seen.
#[derive(Debug)]
pub struct NetMsg {
    pub src: usize,
    pub dst: usize,
    /// The worker whose schedule initiated the edge.  This is the
    /// boundary-apply ordering key: sorting a mailbox by ascending
    /// `picker` reproduces the k-set order of Algorithm 4 (own pick and
    /// reverse picks interleaved by picker index), which is what makes
    /// the zero-latency lockstep schedule bit-identical to the
    /// synchronous round.
    pub picker: usize,
    /// Sender's local step when the message entered the network.  The
    /// exchange's staleness is the **absolute step skew**
    /// `|receiver boundary step - sent_step|` — the same `|t_i - t_k|`
    /// definition as `sim::simulate_asynchronous`, so the measured
    /// histogram is directly comparable to the time-only replay.  (A
    /// fast sender's message applied by a lagging receiver counts as
    /// skew too: the exchange still mixes parameters from different
    /// optimizer steps, which is the quantity the thesis wants
    /// controlled.)
    pub sent_step: u64,
    pub payload: MsgPayload,
    /// Encoded wire form of the parameter payload, filled by the runtime
    /// when a `comm::codec` is in the path (pooled arena byte buffer;
    /// rented at outbox flush, decoded and returned at delivery).  While
    /// this is `Some`, the payload's f32 buffer holds stale pre-encode
    /// content and must not be read — delivery decodes over it.
    pub wire: Option<Vec<u8>>,
    /// Destination incarnation stamp (membership churn): the runtime
    /// copies the receiver's generation counter at outbox flush and
    /// drops the delivery if the receiver crashed (and possibly
    /// rejoined) in between — a message addressed to a dead incarnation
    /// never reaches its successor.  Always 0 on a fixed roster.
    pub gen: u32,
    /// Piggybacked membership rumors (failure-detection plane).  Empty
    /// — zero bytes, zero behavior — unless an `fd:` config is active;
    /// the runtime fills it at outbox flush and consumes it at
    /// delivery, before the strategy sees the message.
    pub rumors: RumorPack,
    /// Wire-plane redemption ticket (`transport:` != inproc): the
    /// per-sender frame sequence number assigned when the message's bytes
    /// actually left on a socket.  At delivery the runtime redeems the
    /// ticket — the applied payload is whatever crossed the wire, not the
    /// in-process copy.  0 = never transmitted (pure in-process path).
    pub wire_seq: u64,
}

/// Protocol message bodies.  One variant per arrow of the three gossip
/// protocols (plus GoSGD's weighted share).
#[derive(Debug)]
pub enum MsgPayload {
    /// Elastic Gossip: the initiator's snapshot.  The receiver applies
    /// the elastic term at its next step boundary and replies with its
    /// own state at receipt (real staleness under latency).
    ElasticPush(Vec<f32>),
    /// Elastic Gossip: the partner's state, for the initiator's own-pick
    /// term.
    ElasticReply(Vec<f32>),
    /// Gossiping SGD push (Algorithm 6): sender snapshot; the receiver
    /// averages over `{self} ∪ pushers` at its boundary.
    PushParams(Vec<f32>),
    /// Gossiping SGD pull (Algorithm 3): ask `dst` for its parameters
    /// (control message, no payload).
    PullRequest,
    /// Gossiping SGD pull: `dst`'s parameters at receipt of the request.
    PullReply(Vec<f32>),
    /// GoSGD push-sum share: parameters plus half the sender's weight.
    GoSgdShare { params: Vec<f32>, weight: f64 },
    /// Membership control plane: a joining node asks `dst` for a full
    /// state snapshot (control message, no parameter payload).  Handled
    /// by the runtime, never by a strategy.  `joiner_gen` is the
    /// requesting incarnation: a request that outlives its incarnation
    /// (the joiner crashed — and possibly rejoined — while it was in
    /// flight) is refused, so each incarnation completes at most one
    /// bootstrap handshake.
    JoinRequest { joiner_gen: u32 },
    /// Membership control plane: the donor's parameters at receipt of
    /// the join request.  Travels uncompressed (codec-exempt) so the
    /// bootstrap is exact under lossy codecs.
    JoinReply(Vec<f32>),
    /// Failure-detection probe (SWIM direct ping).  `origin` is the
    /// prober — carried in the message so an indirectly relayed ping
    /// still acks the *original* prober directly, without relay state.
    /// Handled by the runtime, never by a strategy.
    FdPing { probe: u64, origin: u32 },
    /// Failure-detection ack: the target answers `FdPing` with its
    /// current incarnation (an implicit refutation of any suspicion).
    FdAck { probe: u64, inc: u32 },
    /// Failure-detection indirect probe request (SWIM ping-req): asks
    /// `dst` to ping `target` on the origin's behalf after a direct
    /// probe timed out.
    FdPingReq { probe: u64, target: u32 },
}

impl MsgPayload {
    /// Raw (uncompressed) payload size: f32 parameters, 8-byte
    /// control/weight fields.  This is the *logical* traffic — what the
    /// fabric's `total_bytes` ledger records so byte totals stay
    /// comparable across codecs; the bytes actually on the wire come
    /// from the codec (`Fabric::send_async_coded`).  Parameter-bearing
    /// messages match the synchronous fabric accounting exactly
    /// (elastic: 2 x n*4 per edge; push: n*4; gosgd: n*4 + 8).  Pull
    /// differs by design: the synchronous round accounts only the reply
    /// (n*4), while the async protocol also pays for the 8-byte request
    /// it actually sends — cross-regime byte totals for pull are
    /// therefore +8 per edge (and +1 message) on the async side.
    pub fn raw_bytes(&self) -> u64 {
        match self {
            MsgPayload::ElasticPush(p)
            | MsgPayload::ElasticReply(p)
            | MsgPayload::PushParams(p)
            | MsgPayload::PullReply(p)
            | MsgPayload::JoinReply(p) => (p.len() * 4) as u64,
            MsgPayload::PullRequest | MsgPayload::JoinRequest { .. } => 8,
            MsgPayload::GoSgdShare { params, .. } => (params.len() * 4 + 8) as u64,
            // probe id (8) + origin/inc/target (4) + kind tag (4)
            MsgPayload::FdPing { .. } | MsgPayload::FdAck { .. } | MsgPayload::FdPingReq { .. } => {
                16
            }
        }
    }

    /// The parameter buffer carried by this payload, if any (for
    /// returning it to the arena pool after apply).
    pub fn take_params(self) -> Option<Vec<f32>> {
        match self {
            MsgPayload::ElasticPush(p)
            | MsgPayload::ElasticReply(p)
            | MsgPayload::PushParams(p)
            | MsgPayload::PullReply(p)
            | MsgPayload::JoinReply(p) => Some(p),
            MsgPayload::PullRequest
            | MsgPayload::JoinRequest { .. }
            | MsgPayload::FdPing { .. }
            | MsgPayload::FdAck { .. }
            | MsgPayload::FdPingReq { .. } => None,
            MsgPayload::GoSgdShare { params, .. } => Some(params),
        }
    }

    /// Variant name for diagnostics (the Debug impl would dump the full
    /// parameter vector into the error string).
    pub fn kind(&self) -> &'static str {
        match self {
            MsgPayload::ElasticPush(_) => "ElasticPush",
            MsgPayload::ElasticReply(_) => "ElasticReply",
            MsgPayload::PushParams(_) => "PushParams",
            MsgPayload::PullRequest => "PullRequest",
            MsgPayload::PullReply(_) => "PullReply",
            MsgPayload::GoSgdShare { .. } => "GoSgdShare",
            MsgPayload::JoinRequest { .. } => "JoinRequest",
            MsgPayload::JoinReply(_) => "JoinReply",
            MsgPayload::FdPing { .. } => "FdPing",
            MsgPayload::FdAck { .. } => "FdAck",
            MsgPayload::FdPingReq { .. } => "FdPingReq",
        }
    }

    /// Borrow the parameter buffer carried by this payload, if any.
    pub fn params(&self) -> Option<&[f32]> {
        match self {
            MsgPayload::ElasticPush(p)
            | MsgPayload::ElasticReply(p)
            | MsgPayload::PushParams(p)
            | MsgPayload::PullReply(p)
            | MsgPayload::JoinReply(p) => Some(p),
            MsgPayload::PullRequest
            | MsgPayload::JoinRequest { .. }
            | MsgPayload::FdPing { .. }
            | MsgPayload::FdAck { .. }
            | MsgPayload::FdPingReq { .. } => None,
            MsgPayload::GoSgdShare { params, .. } => Some(params),
        }
    }

    /// Mutably borrow the parameter buffer (the codec's decode
    /// destination at delivery).
    pub fn params_mut(&mut self) -> Option<&mut Vec<f32>> {
        match self {
            MsgPayload::ElasticPush(p)
            | MsgPayload::ElasticReply(p)
            | MsgPayload::PushParams(p)
            | MsgPayload::PullReply(p)
            | MsgPayload::JoinReply(p) => Some(p),
            MsgPayload::PullRequest
            | MsgPayload::JoinRequest { .. }
            | MsgPayload::FdPing { .. }
            | MsgPayload::FdAck { .. }
            | MsgPayload::FdPingReq { .. } => None,
            MsgPayload::GoSgdShare { params, .. } => Some(params),
        }
    }

    /// Bytes this payload puts on the wire *besides* its (codec-encoded)
    /// parameter buffer: GoSGD's f64 weight and the pull request's
    /// 8-byte control frame travel uncompressed.
    pub fn non_param_bytes(&self) -> u64 {
        match self {
            MsgPayload::PullRequest
            | MsgPayload::JoinRequest { .. }
            | MsgPayload::GoSgdShare { .. } => 8,
            MsgPayload::FdPing { .. } | MsgPayload::FdAck { .. } | MsgPayload::FdPingReq { .. } => {
                16
            }
            _ => 0,
        }
    }

    /// Membership / failure-detection control-plane payloads bypass the
    /// wire codec: a join bootstrap must hand the joiner the donor's
    /// *exact* state even when the gossip plane runs a lossy codec, and
    /// FD probes carry no parameters to encode.
    pub fn codec_exempt(&self) -> bool {
        matches!(
            self,
            MsgPayload::JoinRequest { .. }
                | MsgPayload::JoinReply(_)
                | MsgPayload::FdPing { .. }
                | MsgPayload::FdAck { .. }
                | MsgPayload::FdPingReq { .. }
        )
    }
}

/// What a strategy's protocol hooks may see/touch for one node of the
/// event-driven runtime: the node's live parameters, the shared arena
/// (boundary snapshot rows + message-buffer pool) and an outbox the
/// runtime stamps with delivery times.
pub struct ProtoCtx<'a> {
    pub node: usize,
    /// The node's local step: the step just finishing at a boundary, the
    /// in-flight step during a mid-step delivery.
    pub step: u64,
    pub params: &'a mut [f32],
    pub arena: &'a mut ScratchArena,
    pub outbox: &'a mut Vec<NetMsg>,
}

impl ProtoCtx<'_> {
    /// Rent a pooled buffer holding a copy of the node's live parameters
    /// (the send-time / receipt-time snapshot).
    pub fn snapshot_msg(&mut self) -> Vec<f32> {
        self.arena.rent_msg(self.params)
    }

    /// Queue a message; the runtime encodes its payload through the
    /// run's wire codec, accounts raw + encoded bytes on the fabric and
    /// schedules its delivery at `now + link transfer time` (priced by
    /// the encoded size).
    pub fn send(&mut self, dst: usize, picker: usize, payload: MsgPayload) {
        self.outbox.push(NetMsg {
            src: self.node,
            dst,
            picker,
            sent_step: self.step,
            payload,
            wire: None,
            gen: 0, // stamped with the receiver's incarnation at flush
            rumors: RumorPack::empty(), // filled at flush when fd is on
            wire_seq: 0, // assigned if/when the bytes hit a real socket
        });
    }
}

/// Everything a strategy may see/touch during one synchronized round.
pub struct CommCtx<'a> {
    /// per-worker flat parameters (pre-round state on entry)
    pub params: &'a mut [Vec<f32>],
    /// per-worker gradients of this step (All-reduce averages these)
    pub grads: &'a mut [Vec<f32>],
    pub fabric: &'a mut Fabric,
    pub topology: &'a Topology,
    /// global synchronized clock t
    pub step: u64,
    /// worker i engages in communication this round (Bernoulli(p) or
    /// `tau divides t` — decided by the coordinator's schedule)
    pub communicating: &'a [bool],
    /// persistent scratch (snapshot plane + edge plan), reused across
    /// rounds so the round is allocation-free after warm-up
    pub arena: &'a mut ScratchArena,
}

impl<'a> CommCtx<'a> {
    pub fn workers(&self) -> usize {
        self.params.len()
    }
}

/// A synchronous communication strategy, split into a leader **plan**
/// phase and a per-worker **apply** phase.
///
/// The split is what lets the threaded runtime shard the round: the
/// leader runs `plan_round` (matchmaking, snapshotting into the arena,
/// traffic accounting, strategy-global state) while every worker thread
/// is parked at the barrier, then each worker applies its *own* slot's
/// update concurrently via `apply_slot` reading the shared arena.  The
/// sequential coordinator runs the default `comm_round`, which is the
/// same plan followed by the same per-slot applications in worker order
/// — per-slot math touches only that slot and pre-round snapshots, so
/// the two execution orders are bit-identical (the equivalence test in
/// `coordinator::parallel` is the oracle).
pub trait Strategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Leader phase of one synchronized round.  Returns `true` if slot
    /// application was deferred to [`apply_slot`](Self::apply_slot)
    /// (sharded execution), `false` if the round is already complete
    /// (no-op rounds, or strategies like All-reduce that act on shared
    /// state directly).
    fn plan_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> anyhow::Result<bool>;

    /// Apply the planned round to worker `slot`'s parameters.  Reads
    /// only `&self` and the arena filled by `plan_round`, and writes
    /// only `params` — callable concurrently for distinct slots.
    fn apply_slot(&self, _slot: usize, _params: &mut [f32], _arena: &ScratchArena) {}

    /// Run one full synchronized round (plan + every slot, in worker
    /// order).  Called every step; the strategy must respect
    /// `ctx.communicating` for gossip semantics.
    fn comm_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> anyhow::Result<()> {
        if self.plan_round(ctx, rng)? {
            let arena: &ScratchArena = &*ctx.arena;
            for (i, p) in ctx.params.iter_mut().enumerate() {
                self.apply_slot(i, p, arena);
            }
        }
        Ok(())
    }

    /// Strategy-internal state relevant to the *aggregate* model, if any
    /// (EASGD exposes its center variable here so eval can report it).
    fn center(&self) -> Option<&[f32]> {
        None
    }

    // -- message-level protocol API (event-driven async runtime) ----------
    //
    // The asynchronous regime the thesis proposes studying: no rounds, no
    // barriers — nodes exchange messages whose delivery the virtual clock
    // schedules through the link model.  A strategy that implements these
    // three hooks runs under `crate::runtime_async`; the synchronous round
    // is *re-derived* from the same hooks as the zero-latency lockstep
    // special case (asserted bit-for-bit by the equivalence tests).

    /// This strategy speaks the message-level protocol (the pairwise
    /// gossip family + no-comm; the barrier/central methods do not).
    fn async_capable(&self) -> bool {
        false
    }

    /// `ctx.node`'s communication schedule fired at its step boundary:
    /// emit this round's protocol messages toward `peer` (its sampled
    /// gossip partner) into `ctx.outbox`.
    fn on_send_due(&mut self, _ctx: &mut ProtoCtx, _peer: usize) -> anyhow::Result<()> {
        anyhow::bail!("strategy {} has no message-level protocol", self.name())
    }

    /// A message reached `ctx.node`, possibly mid-step.  React
    /// immediately — e.g. reply with the node's *current* state (this is
    /// where real staleness enters under nonzero latency) — and return
    /// the message to retain in the node's mailbox for boundary
    /// application, or `None` if it was fully handled.
    fn on_message(&mut self, _ctx: &mut ProtoCtx, _msg: NetMsg) -> anyhow::Result<Option<NetMsg>> {
        anyhow::bail!("strategy {} has no message-level protocol", self.name())
    }

    /// `ctx.node` reached a step boundary with a non-empty mailbox
    /// (already sorted by ascending `picker` — k-set order) and its
    /// boundary snapshot parked at `ctx.arena.snap(ctx.node)`.  Apply the
    /// retained messages to `ctx.params`; the runtime drains the mailbox
    /// and returns every payload buffer to the arena pool after this
    /// hook, so implementations must not consume the messages themselves.
    fn on_boundary_apply(
        &mut self,
        _ctx: &mut ProtoCtx,
        _mailbox: &mut Vec<NetMsg>,
    ) -> anyhow::Result<()> {
        anyhow::bail!("strategy {} has no message-level protocol", self.name())
    }

    /// Push-sum weight mass, if this strategy carries one (GoSGD): the
    /// protocol invariant `SUM_i w_i + in-flight == 1`.
    fn push_sum_mass(&self) -> Option<f64> {
        None
    }

    // -- membership lifecycle hooks (event-driven runtime under churn) ----
    //
    // The elastic-membership subsystem (`crate::membership`) drives these
    // when a `churn:` schedule is active.  Defaults are correct for
    // stateless protocols; strategies carrying conserved quantities or
    // symmetric-update semantics override them.  None of these hooks is
    // reached on a fixed roster.

    /// Node `dead` departed (crash or leave); `alive` is the membership
    /// *after* the event.  Strategy-global fixup: GoSGD folds the
    /// departed node's residual push-sum weight into the lowest-indexed
    /// survivor so total mass stays exactly 1.
    fn on_peer_lost(&mut self, _dead: usize, _alive: &[bool]) {}

    /// Should a message **from** a departed sender still be delivered
    /// (in flight) or applied (parked in a mailbox)?
    ///
    /// * Elastic Gossip: `false` — the mirror half of the pair term can
    ///   never be applied, so the pending term is *rolled back* instead
    ///   of applied one-sided (which would break elastic symmetry).
    /// * Gossiping SGD pull: requests `false` (the reply would address a
    ///   dead node), replies `true` (valid one-sided data).
    /// * Push / GoSGD: `true` (one-sided averaging of valid pre-crash
    ///   state; GoSGD shares additionally *carry weight* that must land).
    fn deliver_from_lost(&self, _payload: &MsgPayload) -> bool {
        true
    }

    /// A message addressed **to** a departed node was dropped (in flight
    /// at the fabric, or parked in the dead node's mailbox).  Restore
    /// any conserved quantity it carried: GoSGD folds the dropped
    /// share's weight into `fallback` (the lowest-indexed survivor).
    fn on_drop_to_lost(&mut self, _payload: &MsgPayload, _fallback: usize) {}

    /// `ctx.node` is leaving gracefully: hand off conserved state to
    /// `peer` (an alive neighbor, `None` if the node is the last one
    /// standing) before going dark.  GoSGD ships its **full** weight
    /// with a final share; everyone else has nothing to hand off.
    fn on_leave(&mut self, _ctx: &mut ProtoCtx, _peer: Option<usize>) -> anyhow::Result<()> {
        Ok(())
    }

    /// `joiner` entered the cluster (fresh join or crash-recovery
    /// rejoin): extend per-node strategy state to cover it.  GoSGD gives
    /// joiners weight 0 — membership changes never mint push-sum mass;
    /// a joiner earns weight through the shares it receives.
    fn on_join_bootstrap(&mut self, _joiner: usize) {}
}

/// The no-communication lower bound (Table 4.1 "NC-4").
pub struct NoCommStrategy;

impl Strategy for NoCommStrategy {
    fn name(&self) -> &'static str {
        "none"
    }
    fn plan_round(&mut self, _ctx: &mut CommCtx, _rng: &mut Rng) -> anyhow::Result<bool> {
        Ok(false)
    }
    // trivially async: nodes free-run and never message each other
    fn async_capable(&self) -> bool {
        true
    }
    fn on_send_due(&mut self, _ctx: &mut ProtoCtx, _peer: usize) -> anyhow::Result<()> {
        Ok(())
    }
    fn on_message(&mut self, _ctx: &mut ProtoCtx, _msg: NetMsg) -> anyhow::Result<Option<NetMsg>> {
        Ok(None)
    }
    fn on_boundary_apply(
        &mut self,
        _ctx: &mut ProtoCtx,
        _mailbox: &mut Vec<NetMsg>,
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// gossip matchmaking — the set-K semantics of Algorithm 4
// ---------------------------------------------------------------------------

/// Each communicating worker selects a peer uniformly from its topology
/// neighborhood (`k' ~ W \ {i}` under `Topology::Full`).
///
/// Returns `picks[i] = Some(k)` iff worker `i` communicates this round.
/// Peer sampling consumes the rng in worker order — deterministic for a
/// given (seed, round) pair.
pub fn gossip_picks(
    communicating: &[bool],
    topology: &Topology,
    rng: &mut Rng,
) -> Vec<Option<usize>> {
    let n = communicating.len();
    (0..n)
        .map(|i| {
            if communicating[i] {
                topology.sample_peer(i, n, rng)
            } else {
                None
            }
        })
        .collect()
}

/// Algorithm 4 line 6: worker `i`'s interaction set **K** = its own pick
/// (if it communicated) ∪ every worker that picked `i`.
pub fn k_sets(picks: &[Option<usize>]) -> Vec<Vec<usize>> {
    let n = picks.len();
    let mut out = vec![Vec::new(); n];
    for (i, p) in picks.iter().enumerate() {
        if let Some(k) = *p {
            out[i].push(k); // own selection
            out[k].push(i); // reverse edge: k interacts with i too
        }
    }
    // A pair that mutually picked each other appears once in each list per
    // direction — dedup: the elastic term for that pair must apply once per
    // *edge*, and mutual selection creates two edges (i->k and k->i), both
    // of which Algorithm 4 counts. So do NOT dedup; but guard against the
    // same edge being inserted twice (cannot happen by construction).
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_methods() {
        assert_eq!(Method::parse("none").unwrap(), Method::NoComm);
        assert_eq!(
            Method::parse("elastic-gossip:0.25").unwrap(),
            Method::ElasticGossip { alpha: 0.25 }
        );
        assert_eq!(
            Method::parse("eg").unwrap(),
            Method::ElasticGossip { alpha: 0.5 }
        );
        assert_eq!(Method::parse("gossip-pull").unwrap(), Method::GossipingSgdPull);
        assert!(matches!(
            Method::parse("allreduce").unwrap(),
            Method::AllReduce { imp: AllReduceImpl::Ring }
        ));
        assert!(Method::parse("xyz").is_err());
    }

    #[test]
    fn picks_respect_mask_and_topology() {
        let mut rng = Rng::new(3);
        let comm = vec![true, false, true, true];
        for _ in 0..50 {
            let picks = gossip_picks(&comm, &Topology::Full, &mut rng);
            assert!(picks[1].is_none());
            for (i, p) in picks.iter().enumerate() {
                if let Some(k) = *p {
                    assert_ne!(k, i);
                    assert!(k < 4);
                }
            }
        }
    }

    #[test]
    fn k_sets_include_reverse_edges() {
        // 0 picks 2, 2 picks 0 (mutual), 3 picks 2, 1 silent
        let picks = vec![Some(2), None, Some(0), Some(2)];
        let k = k_sets(&picks);
        assert_eq!(k[0], vec![2, 2]); // own pick + reverse from 2 (two edges!)
        assert_eq!(k[1], Vec::<usize>::new());
        // 2: own pick 0, reverse from 0, reverse from 3
        let mut k2 = k[2].clone();
        k2.sort();
        assert_eq!(k2, vec![0, 0, 3]);
        assert_eq!(k[3], vec![2]);
    }

    #[test]
    fn rumor_pack_caps_and_counts_bytes() {
        let mut p = RumorPack::empty();
        assert!(p.is_empty());
        assert_eq!(p.wire_bytes(), 0);
        for i in 0..RumorPack::CAP {
            assert!(p.push(Rumor { kind: Rumor::SUSPECT, node: i as u16, inc: 1 }));
        }
        assert!(!p.push(Rumor::default())); // full: overflow rejected
        assert_eq!(p.len(), RumorPack::CAP);
        assert_eq!(p.wire_bytes(), RumorPack::CAP as u64 * Rumor::WIRE_BYTES);
        assert_eq!(p.iter().filter(|r| r.kind == Rumor::SUSPECT).count(), RumorPack::CAP);
    }

    #[test]
    fn fd_payloads_are_codec_exempt_control_frames() {
        let ping = MsgPayload::FdPing { probe: 7, origin: 2 };
        let ack = MsgPayload::FdAck { probe: 7, inc: 1 };
        let req = MsgPayload::FdPingReq { probe: 7, target: 3 };
        for p in [&ping, &ack, &req] {
            assert!(p.codec_exempt());
            assert_eq!(p.raw_bytes(), 16);
            assert_eq!(p.non_param_bytes(), 16);
            assert!(p.params().is_none());
        }
        assert!(ping.take_params().is_none());
    }

    #[test]
    fn silent_worker_can_still_be_in_k() {
        // Algorithm 4: K includes "those that selected i" even if i did
        // not itself trigger communication this round.
        let picks = vec![Some(1), None];
        let k = k_sets(&picks);
        assert_eq!(k[1], vec![0]);
    }
}
