//! Decentralized (pairwise) strategies: Elastic Gossip, Gossiping SGD
//! pull/push, and GoSGD push-sum.
//!
//! All four share the same matchmaking (each communicating worker samples
//! one peer) and the same *simultaneous* semantics: every update in a
//! round is computed from the pre-round parameter snapshot, matching the
//! thesis's modification of the original sequential formulations (§2.3).
//!
//! Snapshots live in the shared [`ScratchArena`] (plan phase copies only
//! edge endpoints), and the per-worker updates run through the fused
//! kernels in `tensor/` — see the `scratch` module docs for the
//! zero-allocation round design and `Strategy` for the plan/apply split
//! that lets the threaded runtime shard these rounds.

use anyhow::{bail, Result};

use super::{CommCtx, MsgPayload, NetMsg, ProtoCtx, ScratchArena, Strategy};
use crate::util::rng::Rng;

/// Elastic Gossip (Algorithm 4 / Algorithm 5 comm component).
///
/// For each worker `i` with interaction set `K_i`:
///
/// ```text
/// theta_i <- theta_i - alpha * SUM_{k in K_i} (theta_i - theta_k)
/// ```
///
/// where `K_i` = own pick ∪ reverse picks.  Because every edge (i,k)
/// contributes `-alpha (theta_i - theta_k)` to `i` and the exact mirror
/// `-alpha (theta_k - theta_i)` to `k`, the global parameter *sum* is
/// invariant under the communication round — the paper's elastic
/// symmetry, generalized from pairs to the whole round.
pub struct ElasticGossipStrategy {
    pub alpha: f32,
}

impl ElasticGossipStrategy {
    pub fn new(alpha: f32) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "moving rate must be in [0,1]");
        ElasticGossipStrategy { alpha }
    }
}

impl Strategy for ElasticGossipStrategy {
    fn name(&self) -> &'static str {
        "elastic-gossip"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<bool> {
        let n = ctx.params[0].len();
        ctx.arena.begin_round(ctx.params.len(), n, ctx.communicating);
        ctx.arena.plan_edges(ctx.topology, rng);
        if !ctx.arena.plan.any_edges() {
            return Ok(false);
        }
        // snapshot only the workers that participate in any edge
        ctx.arena.snapshot_participants(ctx.params);

        // traffic: each selected edge (i -> k) is realized by exchanging
        // parameter vectors so both ends can form the same delta locally
        for (i, p) in ctx.arena.plan.picks().iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(i, k, n);
                ctx.fabric.send_params(k, i, n);
            }
        }
        Ok(true)
    }

    fn apply_slot(&self, slot: usize, params: &mut [f32], arena: &ScratchArena) {
        arena.elastic_apply(params, slot, self.alpha);
    }

    // -- message-level protocol: symmetric push + reply-at-receipt --------
    //
    // Edge (i -> k) as messages: i pushes its snapshot; k replies with its
    // state *at receipt* (pre-round in lockstep, genuinely stale under
    // latency) and parks the push for its next boundary; both ends then
    // apply the pair term `-alpha (self_snap - partner)` at their own
    // boundaries.  Two parameter-sized messages per edge — the same
    // traffic the synchronous round accounts.

    fn async_capable(&self) -> bool {
        true
    }

    fn on_send_due(&mut self, ctx: &mut ProtoCtx, peer: usize) -> Result<()> {
        let me = ctx.node;
        let snap = ctx.snapshot_msg();
        ctx.send(peer, me, MsgPayload::ElasticPush(snap));
        Ok(())
    }

    fn on_message(&mut self, ctx: &mut ProtoCtx, msg: NetMsg) -> Result<Option<NetMsg>> {
        match msg.payload {
            MsgPayload::ElasticPush(_) => {
                let snap = ctx.snapshot_msg();
                ctx.send(msg.src, msg.picker, MsgPayload::ElasticReply(snap));
                Ok(Some(msg))
            }
            MsgPayload::ElasticReply(_) => Ok(Some(msg)),
            _ => bail!("elastic-gossip received foreign payload {}", msg.payload.kind()),
        }
    }

    fn on_boundary_apply(&mut self, ctx: &mut ProtoCtx, mailbox: &mut Vec<NetMsg>) -> Result<()> {
        // fused multi-peer application in mailbox (== k-set) order, every
        // term from the fixed boundary snapshot — the same shared kernel
        // as the synchronous `ScratchArena::elastic_apply`, fed from
        // message buffers (bit-identical either way, property-tested)
        crate::tensor::elastic_apply_grouped(
            ctx.params,
            ctx.arena.snap(ctx.node),
            mailbox.len(),
            |j| mailbox[j].payload.params().expect("elastic mailbox carries params"),
            self.alpha,
        );
        Ok(())
    }

    // -- membership: the elastic term is symmetric or it is nothing ------
    //
    // A push or reply from a node that has since departed must NOT be
    // applied: the mirror half of the pair term can never run, and a
    // one-sided application would silently break the round's
    // sum-conservation symmetry.  The runtime rolls the pending term
    // back (drops it from mailboxes and the in-flight set) instead.

    fn deliver_from_lost(&self, payload: &MsgPayload) -> bool {
        !matches!(payload, MsgPayload::ElasticPush(_) | MsgPayload::ElasticReply(_))
    }
}

/// Synchronous Pull-Gossiping SGD (Algorithm 3).
///
/// Each communicating worker pulls its peer's parameters and averages:
/// `theta_i <- (theta_i + theta_k)/2`.  One-sided: the peer is not
/// updated, so the global parameter sum is *not* conserved — the paper's
/// motivation for elastic symmetry.
pub struct PullGossipStrategy;

impl Strategy for PullGossipStrategy {
    fn name(&self) -> &'static str {
        "gossip-pull"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<bool> {
        let n = ctx.params[0].len();
        ctx.arena.begin_round(ctx.params.len(), n, ctx.communicating);
        ctx.arena.plan_edges(ctx.topology, rng);
        if !ctx.arena.plan.any_edges() {
            return Ok(false);
        }
        ctx.arena.snapshot_participants(ctx.params);
        for (i, p) in ctx.arena.plan.picks().iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(k, i, n); // pull: k's params travel to i
            }
        }
        Ok(true)
    }

    fn apply_slot(&self, slot: usize, params: &mut [f32], arena: &ScratchArena) {
        if let Some(k) = arena.plan.pick(slot) {
            crate::tensor::average_into(params, arena.snap(slot), arena.snap(k));
        }
    }

    // -- message-level protocol: request/reply ----------------------------
    //
    // The puller sends a control-sized request; the peer replies with its
    // state at receipt; the puller averages at its next boundary.  The
    // peer is never modified (one-sided, Algorithm 3).

    fn async_capable(&self) -> bool {
        true
    }

    fn on_send_due(&mut self, ctx: &mut ProtoCtx, peer: usize) -> Result<()> {
        let me = ctx.node;
        ctx.send(peer, me, MsgPayload::PullRequest);
        Ok(())
    }

    fn on_message(&mut self, ctx: &mut ProtoCtx, msg: NetMsg) -> Result<Option<NetMsg>> {
        match msg.payload {
            MsgPayload::PullRequest => {
                let snap = ctx.snapshot_msg();
                ctx.send(msg.src, msg.picker, MsgPayload::PullReply(snap));
                Ok(None)
            }
            MsgPayload::PullReply(_) => Ok(Some(msg)),
            _ => bail!("gossip-pull received foreign payload {}", msg.payload.kind()),
        }
    }

    fn on_boundary_apply(&mut self, ctx: &mut ProtoCtx, mailbox: &mut Vec<NetMsg>) -> Result<()> {
        // `0.5 * (self + reply)` in place; the live buffer is the node's
        // pre-apply state, so in lockstep this is bit-identical to the
        // synchronous `average_into(params, snap_i, snap_k)`
        for m in mailbox.iter() {
            let peer = match m.payload.params() {
                Some(p) => p,
                None => bail!("gossip-pull mailbox held a paramless message"),
            };
            crate::tensor::average_with(ctx.params, peer);
        }
        Ok(())
    }

    // -- membership: one-sided averaging tolerates a dead sender --------
    //
    // A reply carrying a departed peer's pre-crash parameters is still
    // valid one-sided data (the peer is never modified, so no symmetry
    // breaks); a *request* from a dead puller would only generate a
    // reply addressed to nobody — drop it.

    fn deliver_from_lost(&self, payload: &MsgPayload) -> bool {
        !matches!(payload, MsgPayload::PullRequest)
    }
}

/// Synchronous Push-Gossiping SGD (Algorithm 6, Appendix A.3).
///
/// Each communicating worker pushes its parameters to its peer; every
/// worker then averages over `K = {self} ∪ {pushers}`.
pub struct PushGossipStrategy;

impl Strategy for PushGossipStrategy {
    fn name(&self) -> &'static str {
        "gossip-push"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<bool> {
        let n = ctx.params[0].len();
        ctx.arena.begin_round(ctx.params.len(), n, ctx.communicating);
        ctx.arena.plan_edges(ctx.topology, rng);
        if !ctx.arena.plan.any_edges() {
            return Ok(false);
        }
        ctx.arena.snapshot_participants(ctx.params);
        for (j, p) in ctx.arena.plan.picks().iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(j, k, n);
            }
        }
        Ok(true)
    }

    fn apply_slot(&self, slot: usize, params: &mut [f32], arena: &ScratchArena) {
        arena.push_mean_apply(params, slot);
    }

    // -- message-level protocol: one-way push, mean at boundary -----------

    fn async_capable(&self) -> bool {
        true
    }

    fn on_send_due(&mut self, ctx: &mut ProtoCtx, peer: usize) -> Result<()> {
        let me = ctx.node;
        let snap = ctx.snapshot_msg();
        ctx.send(peer, me, MsgPayload::PushParams(snap));
        Ok(())
    }

    fn on_message(&mut self, _ctx: &mut ProtoCtx, msg: NetMsg) -> Result<Option<NetMsg>> {
        match msg.payload {
            MsgPayload::PushParams(_) => Ok(Some(msg)),
            _ => bail!("gossip-push received foreign payload {}", msg.payload.kind()),
        }
    }

    fn on_boundary_apply(&mut self, ctx: &mut ProtoCtx, mailbox: &mut Vec<NetMsg>) -> Result<()> {
        // mean over {self} ∪ pushers through the same fused kernel the
        // synchronous round uses, fed from message buffers instead of the
        // snapshot plane
        crate::tensor::push_mean_into(ctx.params, ctx.arena.snap(ctx.node), mailbox.len(), |j| {
            mailbox[j].payload.params().expect("push mailbox carries params")
        });
        Ok(())
    }
}

/// GoSGD (Blot et al., 2016): gossip via the push-sum protocol of Kempe
/// et al. (2003).  Each worker carries a weight `w_i` (summing to 1
/// across the cluster); a push sends half the sender's weight along with
/// its parameters, and the receiver takes the weight-proportional convex
/// combination.  In the absence of gradient steps the parameters converge
/// to the global average — mass conservation (`SUM w_i == 1`) is the
/// protocol invariant (tested in `rust/tests/proptests.rs`).
pub struct GoSgdStrategy {
    pub weights: Vec<f64>,
    /// post-send (pre-receive) weight per worker, captured each round.
    /// A sender that pushed half its weight keeps the other half, so
    /// `base_w[j]` is *also* the weight that `j`'s message carries —
    /// together with the arena's reverse-edge lists this is the entire
    /// round plan, with no per-round message buffers.
    base_w: Vec<f64>,
}

impl GoSgdStrategy {
    pub fn new(w: usize) -> Self {
        GoSgdStrategy {
            weights: vec![1.0 / w as f64; w],
            base_w: Vec::new(),
        }
    }
}

impl Strategy for GoSgdStrategy {
    fn name(&self) -> &'static str {
        "gosgd"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<bool> {
        let n = ctx.params[0].len();
        let w = ctx.workers();
        ctx.arena.begin_round(w, n, ctx.communicating);
        ctx.arena.plan_edges(ctx.topology, rng);
        if !ctx.arena.plan.any_edges() {
            return Ok(false);
        }
        ctx.arena.snapshot_participants(ctx.params);

        // each worker pushes at most once, so its weight is still the
        // pre-round value when its own send fires (worker order)
        for (j, p) in ctx.arena.plan.picks().iter().enumerate() {
            if let Some(k) = *p {
                let half = self.weights[j] / 2.0;
                self.weights[j] -= half; // sender keeps the other half
                ctx.fabric.send_params_extra(j, k, n, 8); // params + weight
            }
        }
        // post-send weights: both the push-sum self term and, for each
        // sender, exactly the weight its message carries
        self.base_w.clear();
        self.base_w.extend_from_slice(&self.weights);
        // fold received mass in now so `weights` is final — apply_slot
        // only writes params; senders arrive in picker order (the CSR
        // pusher lists), matching the reference accumulation order
        for i in 0..w {
            for &j in ctx.arena.plan.pushers(i) {
                self.weights[i] += self.base_w[j];
            }
        }
        Ok(true)
    }

    fn apply_slot(&self, slot: usize, params: &mut [f32], arena: &ScratchArena) {
        let pushers = arena.plan.pushers(slot);
        if pushers.is_empty() {
            return;
        }
        // fused convex combination through the shared kernel (f64 stack
        // accumulator, chunked); per-element op order matches the
        // reference: self term, each message in arrival order, one scale
        crate::tensor::weighted_mean_into(
            params,
            arena.snap(slot),
            self.base_w[slot],
            pushers.len(),
            |j| (self.base_w[pushers[j]], arena.snap(pushers[j])),
        );
    }

    // -- message-level protocol: weighted push-sum shares -----------------
    //
    // The sender halves its weight at send time and ships the other half
    // with its parameters; the receiver folds shares in at its boundary.
    // Weight mass is conserved *including in-flight messages* — the
    // push-sum invariant survives arbitrary latency.

    fn async_capable(&self) -> bool {
        true
    }

    fn on_send_due(&mut self, ctx: &mut ProtoCtx, peer: usize) -> Result<()> {
        let me = ctx.node;
        let half = self.weights[me] / 2.0;
        self.weights[me] -= half; // sender keeps the other half
        let snap = ctx.snapshot_msg();
        ctx.send(peer, me, MsgPayload::GoSgdShare { params: snap, weight: half });
        Ok(())
    }

    fn on_message(&mut self, _ctx: &mut ProtoCtx, msg: NetMsg) -> Result<Option<NetMsg>> {
        match msg.payload {
            MsgPayload::GoSgdShare { .. } => Ok(Some(msg)),
            _ => bail!("gosgd received foreign payload {}", msg.payload.kind()),
        }
    }

    fn on_boundary_apply(&mut self, ctx: &mut ProtoCtx, mailbox: &mut Vec<NetMsg>) -> Result<()> {
        let me = ctx.node;
        let base = self.weights[me];
        let total = crate::tensor::weighted_mean_into(
            ctx.params,
            ctx.arena.snap(me),
            base,
            mailbox.len(),
            |j| match &mailbox[j].payload {
                MsgPayload::GoSgdShare { params, weight } => (*weight, params.as_slice()),
                _ => unreachable!("gosgd mailbox carries shares only"),
            },
        );
        self.weights[me] = total;
        Ok(())
    }

    fn push_sum_mass(&self) -> Option<f64> {
        Some(self.weights.iter().sum())
    }

    // -- membership: push-sum mass survives arbitrary churn --------------
    //
    // The invariant is `SUM_i w_i + in-flight == 1` at all times.  Every
    // way weight can strand is routed back into the cluster:
    //
    // * a departed node's *held* weight folds into the lowest-indexed
    //   survivor (`on_peer_lost`);
    // * a share in flight to (or parked at) a departed node folds its
    //   carried weight into the survivor fallback (`on_drop_to_lost`);
    // * a share in flight *from* a departed node still delivers — its
    //   weight was already deducted from the (now dead) sender, so the
    //   receiver folding it in is exactly mass-preserving
    //   (`deliver_from_lost` stays `true`);
    // * a graceful leaver ships its full weight ahead of its departure
    //   (`on_leave`), so `on_peer_lost` then has nothing to reclaim;
    // * joiners start at weight 0 — churn never mints mass
    //   (`on_join_bootstrap`).

    fn on_peer_lost(&mut self, dead: usize, alive: &[bool]) {
        if dead >= self.weights.len() {
            return;
        }
        let w = std::mem::take(&mut self.weights[dead]);
        if w == 0.0 {
            return;
        }
        match alive.iter().position(|&a| a) {
            Some(f) => self.weights[f] += w,
            // no survivors: park the mass back on the dead slot so the
            // terminal invariant still reads 1 (degenerate cluster)
            None => self.weights[dead] = w,
        }
    }

    fn on_drop_to_lost(&mut self, payload: &MsgPayload, fallback: usize) {
        if let MsgPayload::GoSgdShare { weight, .. } = payload {
            if fallback < self.weights.len() {
                self.weights[fallback] += *weight;
            }
        }
    }

    fn on_leave(&mut self, ctx: &mut ProtoCtx, peer: Option<usize>) -> Result<()> {
        let me = ctx.node;
        let Some(peer) = peer else { return Ok(()) };
        let full = std::mem::take(&mut self.weights[me]);
        if full == 0.0 {
            return Ok(());
        }
        let snap = ctx.snapshot_msg();
        ctx.send(peer, me, MsgPayload::GoSgdShare { params: snap, weight: full });
        Ok(())
    }

    fn on_join_bootstrap(&mut self, joiner: usize) {
        // fresh slots start at weight 0 — churn never mints mass.  A
        // crash-recovery rejoin finds 0 here too (its old mass was
        // redistributed at death), except in the degenerate
        // no-survivors case where `on_peer_lost` parked the mass on the
        // dead slot; keeping the stored value preserves it either way.
        if joiner >= self.weights.len() {
            self.weights.resize(joiner + 1, 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ScratchArena;
    use crate::comm::{Fabric, LinkModel};
    use crate::topology::Topology;

    fn make_ctx<'a>(
        params: &'a mut [Vec<f32>],
        grads: &'a mut [Vec<f32>],
        fabric: &'a mut Fabric,
        communicating: &'a [bool],
        arena: &'a mut ScratchArena,
    ) -> CommCtx<'a> {
        CommCtx {
            params,
            grads,
            fabric,
            topology: &Topology::Full,
            step: 0,
            communicating,
            arena,
        }
    }

    fn params4() -> Vec<Vec<f32>> {
        vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
        ]
    }

    #[test]
    fn elastic_round_conserves_global_sum() {
        let mut params = params4();
        let sum0: f32 = params.iter().flat_map(|p| p.iter()).sum();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true; 4];
        let mut s = ElasticGossipStrategy::new(0.3);
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut ctx, &mut rng).unwrap();
            let sum: f32 = params.iter().flat_map(|p| p.iter()).sum();
            assert!((sum - sum0).abs() < 1e-3, "sum drifted: {sum} vs {sum0}");
        }
    }

    #[test]
    fn elastic_two_workers_alpha_half_averages() {
        let mut params = vec![vec![0.0f32, 4.0], vec![2.0f32, 0.0]];
        let mut grads = vec![vec![0.0; 2]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        // only worker 0 fires; with W=2 it must pick worker 1
        let comm = vec![true, false];
        let mut s = ElasticGossipStrategy::new(0.5);
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut ctx, &mut rng).unwrap();
        // single edge 0->1: both sides move halfway
        assert_eq!(params[0], vec![1.0, 2.0]);
        assert_eq!(params[1], vec![1.0, 2.0]);
    }

    #[test]
    fn elastic_accounts_two_transfers_per_edge() {
        let mut params = params4();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true, false, false, false];
        let mut s = ElasticGossipStrategy::new(0.5);
        let mut rng = Rng::new(1);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(fabric.report().total_messages, 2);
        assert_eq!(fabric.report().total_bytes, 2 * 2 * 4);
    }

    #[test]
    fn pull_only_updates_initiator() {
        let mut params = vec![vec![0.0f32], vec![8.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true, false];
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.0]); // average
        assert_eq!(params[1], vec![8.0]); // untouched (one-sided)
        assert_eq!(fabric.report().total_messages, 1);
    }

    #[test]
    fn pull_uses_snapshot_simultaneously() {
        // both pull each other: both must read PRE-round values
        let mut params = vec![vec![0.0f32], vec![8.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true, true];
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.0]);
        assert_eq!(params[1], vec![4.0]);
    }

    #[test]
    fn push_averages_over_k() {
        let mut params = vec![vec![0.0f32], vec![9.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![false, true]; // 1 pushes to 0
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        PushGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.5]); // mean of {self, pusher}
        assert_eq!(params[1], vec![9.0]); // pusher keeps its own copy
    }

    #[test]
    fn gosgd_conserves_mass_and_mean() {
        let w = 6;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; 3]).collect();
        let mut grads = vec![vec![0.0; 3]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut arena = ScratchArena::new();
        let mut s = GoSgdStrategy::new(w);
        let mut rng = Rng::new(2);
        // weighted mean must stay at the true mean; weights sum to 1
        for round in 0..50 {
            let comm: Vec<bool> = (0..w).map(|_| rng.bernoulli(0.7)).collect();
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut ctx, &mut rng).unwrap();
            let mass: f64 = s.weights.iter().sum();
            assert!((mass - 1.0).abs() < 1e-9, "round {round}: mass {mass}");
            let wmean: f64 = params
                .iter()
                .zip(&s.weights)
                .map(|(p, &wi)| p[0] as f64 * wi)
                .sum::<f64>()
                / 1.0;
            // push-sum conserves the weighted sum == initial mean (2.5)
            assert!((wmean - 2.5).abs() < 1e-3, "round {round}: wmean {wmean}");
        }
        // after many rounds all replicas approach the average
        for p in &params {
            assert!((p[0] - 2.5).abs() < 0.2, "not converged: {}", p[0]);
        }
    }

    #[test]
    fn no_communication_mask_is_noop() {
        let mut params = params4();
        let orig = params.clone();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![false; 4];
        let mut rng = Rng::new(3);
        for strategy in [0usize, 1, 2, 3] {
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            match strategy {
                0 => ElasticGossipStrategy::new(0.5).comm_round(&mut ctx, &mut rng).unwrap(),
                1 => PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap(),
                2 => PushGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap(),
                _ => GoSgdStrategy::new(4).comm_round(&mut ctx, &mut rng).unwrap(),
            }
            assert_eq!(params, orig);
        }
        assert_eq!(fabric.report().total_bytes, 0);
    }

    #[test]
    fn gosgd_churn_hooks_conserve_mass() {
        use crate::algos::Strategy as _;
        let mut s = GoSgdStrategy::new(4);
        // crash of node 2: its quarter folds into the lowest survivor
        let alive = [true, true, false, true];
        s.on_peer_lost(2, &alive);
        assert_eq!(s.weights[2], 0.0);
        assert!((s.weights[0] - 0.5).abs() < 1e-12);
        assert!((s.push_sum_mass().unwrap() - 1.0).abs() < 1e-12);
        // a share in flight to a dead node is reclaimed by the fallback
        let share = MsgPayload::GoSgdShare { params: vec![0.0; 2], weight: 0.125 };
        s.on_drop_to_lost(&share, 1);
        assert!((s.weights[1] - 0.375).abs() < 1e-12);
        // joins extend at weight 0 — no mass minted
        s.on_join_bootstrap(5);
        assert_eq!(s.weights.len(), 6);
        assert_eq!(s.weights[5], 0.0);
        assert!((s.push_sum_mass().unwrap() - 1.125).abs() < 1e-12);
        // non-share payloads carry no weight
        s.on_drop_to_lost(&MsgPayload::PullRequest, 0);
        assert!((s.push_sum_mass().unwrap() - 1.125).abs() < 1e-12);
    }

    #[test]
    fn churn_delivery_rules_per_strategy() {
        use crate::algos::Strategy as _;
        let eg = ElasticGossipStrategy::new(0.5);
        assert!(!eg.deliver_from_lost(&MsgPayload::ElasticPush(vec![])));
        assert!(!eg.deliver_from_lost(&MsgPayload::ElasticReply(vec![])));
        let pull = PullGossipStrategy;
        assert!(!pull.deliver_from_lost(&MsgPayload::PullRequest));
        assert!(pull.deliver_from_lost(&MsgPayload::PullReply(vec![])));
        let push = PushGossipStrategy;
        assert!(push.deliver_from_lost(&MsgPayload::PushParams(vec![])));
        let gosgd = GoSgdStrategy::new(2);
        assert!(gosgd.deliver_from_lost(&MsgPayload::GoSgdShare { params: vec![], weight: 0.1 }));
    }

    #[test]
    fn gosgd_leave_hands_off_full_weight() {
        use crate::algos::{ProtoCtx, Strategy as _};
        let mut s = GoSgdStrategy::new(2);
        let mut arena = ScratchArena::new();
        arena.ensure(2, 3);
        let mut params = vec![1.0f32, 2.0, 3.0];
        let mut outbox: Vec<NetMsg> = Vec::new();
        {
            let mut ctx = ProtoCtx {
                node: 0,
                step: 5,
                params: params.as_mut_slice(),
                arena: &mut arena,
                outbox: &mut outbox,
            };
            s.on_leave(&mut ctx, Some(1)).unwrap();
        }
        assert_eq!(s.weights[0], 0.0, "leaver keeps nothing");
        assert_eq!(outbox.len(), 1);
        match &outbox[0].payload {
            MsgPayload::GoSgdShare { params: p, weight } => {
                assert!((weight - 0.5).abs() < 1e-12, "full pre-leave weight travels");
                assert_eq!(p.as_slice(), &[1.0, 2.0, 3.0]);
            }
            other => panic!("unexpected payload {}", other.kind()),
        }
        // last node standing: nothing to send, weight parked by the
        // runtime's on_peer_lost instead
        let mut s = GoSgdStrategy::new(1);
        let mut outbox: Vec<NetMsg> = Vec::new();
        {
            let mut ctx = ProtoCtx {
                node: 0,
                step: 0,
                params: params.as_mut_slice(),
                arena: &mut arena,
                outbox: &mut outbox,
            };
            s.on_leave(&mut ctx, None).unwrap();
        }
        assert!(outbox.is_empty());
        assert_eq!(s.weights[0], 1.0, "no peer: weight stays for reclamation");
    }

    #[test]
    fn gossip_round_is_allocation_free_after_warmup() {
        // the acceptance assertion of the scratch-arena refactor: once the
        // arena has seen full participation, further rounds never move or
        // grow any internal buffer
        let w = 8;
        let n = 300;
        let mut grads = vec![vec![0.0f32; n]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let strategies: Vec<Box<dyn Strategy>> = vec![
            Box::new(ElasticGossipStrategy::new(0.4)),
            Box::new(PullGossipStrategy),
            Box::new(PushGossipStrategy),
            Box::new(GoSgdStrategy::new(w)),
        ];
        for mut s in strategies {
            let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
            let mut arena = ScratchArena::new();
            let mut rng = Rng::new(17);
            let full = vec![true; w];
            for _ in 0..3 {
                let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &full, &mut arena);
                s.comm_round(&mut ctx, &mut rng).unwrap();
            }
            let fp = arena.footprint();
            let mut mask_rng = Rng::new(23);
            for round in 0..40 {
                let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.5)).collect();
                let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
                s.comm_round(&mut ctx, &mut rng).unwrap();
                assert_eq!(
                    arena.footprint(),
                    fp,
                    "{} reallocated arena storage at round {round}",
                    s.name()
                );
            }
        }
    }
}
