//! Decentralized (pairwise) strategies: Elastic Gossip, Gossiping SGD
//! pull/push, and GoSGD push-sum.
//!
//! All four share the same matchmaking (each communicating worker samples
//! one peer) and the same *simultaneous* semantics: every update in a
//! round is computed from the pre-round parameter snapshot, matching the
//! thesis's modification of the original sequential formulations (§2.3).

use anyhow::Result;

use super::{gossip_picks, k_sets, CommCtx, Strategy};
use crate::util::rng::Rng;

/// Elastic Gossip (Algorithm 4 / Algorithm 5 comm component).
///
/// For each worker `i` with interaction set `K_i`:
///
/// ```text
/// theta_i <- theta_i - alpha * SUM_{k in K_i} (theta_i - theta_k)
/// ```
///
/// where `K_i` = own pick ∪ reverse picks.  Because every edge (i,k)
/// contributes `-alpha (theta_i - theta_k)` to `i` and the exact mirror
/// `-alpha (theta_k - theta_i)` to `k`, the global parameter *sum* is
/// invariant under the communication round — the paper's elastic
/// symmetry, generalized from pairs to the whole round.
pub struct ElasticGossipStrategy {
    pub alpha: f32,
    /// scratch: pre-round snapshot of every worker's parameters
    snapshot: Vec<Vec<f32>>,
}

impl ElasticGossipStrategy {
    pub fn new(alpha: f32) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "moving rate must be in [0,1]");
        ElasticGossipStrategy { alpha, snapshot: Vec::new() }
    }
}

impl Strategy for ElasticGossipStrategy {
    fn name(&self) -> &'static str {
        "elastic-gossip"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<()> {
        let picks = gossip_picks(ctx.communicating, ctx.topology, rng);
        if picks.iter().all(Option::is_none) {
            return Ok(());
        }
        let ks = k_sets(&picks);

        // snapshot only the workers that participate in any edge
        snapshot_into(&mut self.snapshot, ctx.params);

        // traffic: each selected edge (i -> k) is realized by exchanging
        // parameter vectors so both ends can form the same delta locally
        let n = ctx.params[0].len();
        for (i, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(i, k, n);
                ctx.fabric.send_params(k, i, n);
            }
        }

        for (i, kset) in ks.iter().enumerate() {
            if kset.is_empty() {
                continue;
            }
            let theta_i = &mut ctx.params[i];
            for &k in kset {
                let snap_i = &self.snapshot[i];
                let snap_k = &self.snapshot[k];
                let a = self.alpha;
                for ((t, &si), &sk) in theta_i.iter_mut().zip(snap_i).zip(snap_k) {
                    *t -= a * (si - sk);
                }
            }
        }
        Ok(())
    }
}

/// Synchronous Pull-Gossiping SGD (Algorithm 3).
///
/// Each communicating worker pulls its peer's parameters and averages:
/// `theta_i <- (theta_i + theta_k)/2`.  One-sided: the peer is not
/// updated, so the global parameter sum is *not* conserved — the paper's
/// motivation for elastic symmetry.
pub struct PullGossipStrategy;

impl Strategy for PullGossipStrategy {
    fn name(&self) -> &'static str {
        "gossip-pull"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<()> {
        let picks = gossip_picks(ctx.communicating, ctx.topology, rng);
        if picks.iter().all(Option::is_none) {
            return Ok(());
        }
        let n = ctx.params[0].len();
        let mut snapshot = Vec::new();
        snapshot_into(&mut snapshot, ctx.params);
        for (i, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(k, i, n); // pull: k's params travel to i
                let theta_i = &mut ctx.params[i];
                for ((t, &si), &sk) in theta_i.iter_mut().zip(&snapshot[i]).zip(&snapshot[k]) {
                    *t = 0.5 * (si + sk);
                }
            }
        }
        Ok(())
    }
}

/// Synchronous Push-Gossiping SGD (Algorithm 6, Appendix A.3).
///
/// Each communicating worker pushes its parameters to its peer; every
/// worker then averages over `K = {self} ∪ {pushers}`.
pub struct PushGossipStrategy;

impl Strategy for PushGossipStrategy {
    fn name(&self) -> &'static str {
        "gossip-push"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<()> {
        let picks = gossip_picks(ctx.communicating, ctx.topology, rng);
        if picks.iter().all(Option::is_none) {
            return Ok(());
        }
        let n = ctx.params[0].len();
        let w = ctx.workers();
        let mut snapshot = Vec::new();
        snapshot_into(&mut snapshot, ctx.params);

        // receivers[i] = set of workers that pushed to i
        let mut receivers: Vec<Vec<usize>> = vec![Vec::new(); w];
        for (j, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                ctx.fabric.send_params(j, k, n);
                receivers[k].push(j);
            }
        }
        for (i, rcv) in receivers.iter().enumerate() {
            if rcv.is_empty() {
                continue;
            }
            let inv = 1.0 / (rcv.len() + 1) as f32;
            let theta_i = &mut ctx.params[i];
            for (idx, t) in theta_i.iter_mut().enumerate() {
                let mut acc = snapshot[i][idx];
                for &j in rcv {
                    acc += snapshot[j][idx];
                }
                *t = acc * inv;
            }
        }
        Ok(())
    }
}

/// GoSGD (Blot et al., 2016): gossip via the push-sum protocol of Kempe
/// et al. (2003).  Each worker carries a weight `w_i` (summing to 1
/// across the cluster); a push sends half the sender's weight along with
/// its parameters, and the receiver takes the weight-proportional convex
/// combination.  In the absence of gradient steps the parameters converge
/// to the global average — mass conservation (`SUM w_i == 1`) is the
/// protocol invariant (tested in `rust/tests/proptests.rs`).
pub struct GoSgdStrategy {
    pub weights: Vec<f64>,
}

impl GoSgdStrategy {
    pub fn new(w: usize) -> Self {
        GoSgdStrategy { weights: vec![1.0 / w as f64; w] }
    }
}

impl Strategy for GoSgdStrategy {
    fn name(&self) -> &'static str {
        "gosgd"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, rng: &mut Rng) -> Result<()> {
        let picks = gossip_picks(ctx.communicating, ctx.topology, rng);
        if picks.iter().all(Option::is_none) {
            return Ok(());
        }
        let n = ctx.params[0].len();
        let w = ctx.workers();
        let mut snapshot = Vec::new();
        snapshot_into(&mut snapshot, ctx.params);
        let pre_weights = self.weights.clone();

        // messages[k] = list of (sender, weight) pushed to k this round
        let mut messages: Vec<Vec<(usize, f64)>> = vec![Vec::new(); w];
        for (j, p) in picks.iter().enumerate() {
            if let Some(k) = *p {
                let half = pre_weights[j] / 2.0;
                messages[k].push((j, half));
                self.weights[j] -= half; // sender keeps the other half
                ctx.fabric.send(j, k, (n * 4 + 8) as u64); // params + weight
            }
        }
        for (i, msgs) in messages.iter().enumerate() {
            if msgs.is_empty() {
                continue;
            }
            let mut total_w = self.weights[i];
            // own weight may already have been halved if i also pushed —
            // push-sum uses the post-send weight for the self term
            let mut acc: Vec<f64> = snapshot[i].iter().map(|&x| x as f64 * total_w).collect();
            for &(j, wj) in msgs {
                for (a, &x) in acc.iter_mut().zip(&snapshot[j]) {
                    *a += x as f64 * wj;
                }
                total_w += wj;
            }
            let inv = 1.0 / total_w;
            for (t, a) in ctx.params[i].iter_mut().zip(acc) {
                *t = (a * inv) as f32;
            }
            self.weights[i] = total_w;
        }
        Ok(())
    }
}

/// Clone the per-worker parameter buffers into reusable scratch storage.
fn snapshot_into(scratch: &mut Vec<Vec<f32>>, params: &[Vec<f32>]) {
    scratch.resize(params.len(), Vec::new());
    for (s, p) in scratch.iter_mut().zip(params) {
        s.clear();
        s.extend_from_slice(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Fabric, LinkModel};
    use crate::topology::Topology;

    fn make_ctx<'a>(
        params: &'a mut [Vec<f32>],
        grads: &'a mut [Vec<f32>],
        fabric: &'a mut Fabric,
        communicating: &'a [bool],
    ) -> CommCtx<'a> {
        CommCtx {
            params,
            grads,
            fabric,
            topology: &Topology::Full,
            step: 0,
            communicating,
        }
    }

    fn params4() -> Vec<Vec<f32>> {
        vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
        ]
    }

    #[test]
    fn elastic_round_conserves_global_sum() {
        let mut params = params4();
        let sum0: f32 = params.iter().flat_map(|p| p.iter()).sum();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let comm = vec![true; 4];
        let mut s = ElasticGossipStrategy::new(0.3);
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
            s.comm_round(&mut ctx, &mut rng).unwrap();
            let sum: f32 = params.iter().flat_map(|p| p.iter()).sum();
            assert!((sum - sum0).abs() < 1e-3, "sum drifted: {sum} vs {sum0}");
        }
    }

    #[test]
    fn elastic_two_workers_alpha_half_averages() {
        let mut params = vec![vec![0.0f32, 4.0], vec![2.0f32, 0.0]];
        let mut grads = vec![vec![0.0; 2]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        // only worker 0 fires; with W=2 it must pick worker 1
        let comm = vec![true, false];
        let mut s = ElasticGossipStrategy::new(0.5);
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut ctx, &mut rng).unwrap();
        // single edge 0->1: both sides move halfway
        assert_eq!(params[0], vec![1.0, 2.0]);
        assert_eq!(params[1], vec![1.0, 2.0]);
    }

    #[test]
    fn elastic_accounts_two_transfers_per_edge() {
        let mut params = params4();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let comm = vec![true, false, false, false];
        let mut s = ElasticGossipStrategy::new(0.5);
        let mut rng = Rng::new(1);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(fabric.report().total_messages, 2);
        assert_eq!(fabric.report().total_bytes, 2 * 2 * 4);
    }

    #[test]
    fn pull_only_updates_initiator() {
        let mut params = vec![vec![0.0f32], vec![8.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let comm = vec![true, false];
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
        PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.0]); // average
        assert_eq!(params[1], vec![8.0]); // untouched (one-sided)
        assert_eq!(fabric.report().total_messages, 1);
    }

    #[test]
    fn pull_uses_snapshot_simultaneously() {
        // both pull each other: both must read PRE-round values
        let mut params = vec![vec![0.0f32], vec![8.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let comm = vec![true, true];
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
        PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.0]);
        assert_eq!(params[1], vec![4.0]);
    }

    #[test]
    fn push_averages_over_k() {
        // workers 1 and 2 both push to 0 (forced via W=3 picks? use direct check)
        // With Full topology and rng we can't force; instead run the math on
        // a crafted scenario by monkey-checking k_sets semantics through
        // repeated rounds: here just verify a single pusher case.
        let mut params = vec![vec![0.0f32], vec![9.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let comm = vec![false, true]; // 1 pushes to 0
        let mut rng = Rng::new(0);
        let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
        PushGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap();
        assert_eq!(params[0], vec![4.5]); // mean of {self, pusher}
        assert_eq!(params[1], vec![9.0]); // pusher keeps its own copy
    }

    #[test]
    fn gosgd_conserves_mass_and_mean() {
        let w = 6;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; 3]).collect();
        let mut grads = vec![vec![0.0; 3]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut s = GoSgdStrategy::new(w);
        let mut rng = Rng::new(2);
        // weighted mean must stay at the true mean; weights sum to 1
        for round in 0..50 {
            let comm: Vec<bool> = (0..w).map(|_| rng.bernoulli(0.7)).collect();
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
            s.comm_round(&mut ctx, &mut rng).unwrap();
            let mass: f64 = s.weights.iter().sum();
            assert!((mass - 1.0).abs() < 1e-9, "round {round}: mass {mass}");
            let wmean: f64 = params
                .iter()
                .zip(&s.weights)
                .map(|(p, &wi)| p[0] as f64 * wi)
                .sum::<f64>()
                / 1.0;
            // push-sum conserves the weighted sum == initial mean (2.5)
            assert!((wmean - 2.5).abs() < 1e-3, "round {round}: wmean {wmean}");
        }
        // after many rounds all replicas approach the average
        for p in &params {
            assert!((p[0] - 2.5).abs() < 0.2, "not converged: {}", p[0]);
        }
    }

    #[test]
    fn no_communication_mask_is_noop() {
        let mut params = params4();
        let orig = params.clone();
        let mut grads = vec![vec![0.0; 2]; 4];
        let mut fabric = Fabric::new(5, LinkModel::default());
        let comm = vec![false; 4];
        let mut rng = Rng::new(3);
        for strategy in [0usize, 1, 2, 3] {
            let mut ctx = make_ctx(&mut params, &mut grads, &mut fabric, &comm);
            match strategy {
                0 => ElasticGossipStrategy::new(0.5).comm_round(&mut ctx, &mut rng).unwrap(),
                1 => PullGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap(),
                2 => PushGossipStrategy.comm_round(&mut ctx, &mut rng).unwrap(),
                _ => GoSgdStrategy::new(4).comm_round(&mut ctx, &mut rng).unwrap(),
            }
            assert_eq!(params, orig);
        }
        assert_eq!(fabric.report().total_bytes, 0);
    }
}
