//! Centralized strategies: Synchronous All-reduce SGD and Synchronous
//! EASGD.
//!
//! These are the paper's baselines (Algorithms 1 and 2).  All-reduce
//! averages *gradients* every step through a real collective over the
//! fabric; EASGD keeps a center variable at a dedicated coordinator slot
//! (fabric index `W` — the fabric is always created with one extra slot
//! for it) and applies the elastic update between every communicating
//! worker and the center.

use anyhow::Result;

use super::{CommCtx, Strategy};
use crate::collective::AllReduceImpl;
use crate::util::rng::Rng;

/// Synchronous All-reduce SGD (Algorithm 1): gradients are averaged
/// across all workers each step; every worker then applies the identical
/// aggregate.  Mathematically equivalent to single-worker SGD with
/// effective batch `|W| * b` (§2.1.1) — property-tested in
/// `rust/tests/proptests.rs`.
pub struct AllReduceStrategy {
    imp: AllReduceImpl,
}

impl AllReduceStrategy {
    pub fn new(imp: AllReduceImpl) -> Self {
        AllReduceStrategy { imp }
    }
}

impl Strategy for AllReduceStrategy {
    fn name(&self) -> &'static str {
        "allreduce"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, _rng: &mut Rng) -> Result<()> {
        // every step, unconditionally (uses_schedule() == false)
        self.imp.all_reduce_mean(ctx.grads, ctx.fabric);
        Ok(())
    }
}

/// Synchronous EASGD (Algorithm 2).
///
/// The center variable lives at a dedicated central process (no training
/// shard).  For every communicating worker, with moving rate alpha:
///
/// ```text
/// z_i      = alpha * (theta_i - center)     (line 5, pre-round snapshot)
/// theta_i -= z_i                            (line 6)
/// center  += z_i                            (line 7, summed over workers)
/// ```
///
/// Updates use the pre-round center for all workers (simultaneous
/// semantics, Eq. 2.4: `center += alpha * SUM_i (theta_i - center)`),
/// which preserves elastic symmetry between each worker and the center:
/// `theta_i + center` changes only by the *other* workers' contributions.
pub struct EasgdStrategy {
    pub alpha: f32,
    pub center: Vec<f32>,
    initialized: bool,
}

impl EasgdStrategy {
    pub fn new(alpha: f32, flat_size: usize) -> Self {
        EasgdStrategy {
            alpha,
            center: vec![0.0; flat_size],
            initialized: false,
        }
    }
}

impl Strategy for EasgdStrategy {
    fn name(&self) -> &'static str {
        "easgd"
    }

    fn comm_round(&mut self, ctx: &mut CommCtx, _rng: &mut Rng) -> Result<()> {
        // Algorithm 2 initializes the center to the shared initial
        // parameters; workers all start identical, so adopt worker 0's
        // params on the first round.
        if !self.initialized {
            self.center.copy_from_slice(&ctx.params[0]);
            self.initialized = true;
        }
        if !ctx.communicating.iter().any(|&c| c) {
            return Ok(());
        }
        let n = self.center.len();
        let w = ctx.workers();
        let central = w; // the fabric's extra slot
        let mut center_delta = vec![0.0f32; n];
        for i in 0..w {
            if !ctx.communicating[i] {
                continue;
            }
            // worker sends theta_i up, receives the center down
            ctx.fabric.send_params(i, central, n);
            ctx.fabric.send_params(central, i, n);
            let a = self.alpha;
            let theta = &mut ctx.params[i];
            for ((t, c), d) in theta.iter_mut().zip(&self.center).zip(center_delta.iter_mut()) {
                let z = a * (*t - *c);
                *t -= z;
                *d += z;
            }
        }
        crate::tensor::add_assign(&mut self.center, &center_delta);
        Ok(())
    }

    fn center(&self) -> Option<&[f32]> {
        if self.initialized {
            Some(&self.center)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Fabric, LinkModel};
    use crate::topology::Topology;

    fn ctx<'a>(
        params: &'a mut [Vec<f32>],
        grads: &'a mut [Vec<f32>],
        fabric: &'a mut Fabric,
        communicating: &'a [bool],
    ) -> CommCtx<'a> {
        CommCtx {
            params,
            grads,
            fabric,
            topology: &Topology::Full,
            step: 0,
            communicating,
        }
    }

    #[test]
    fn allreduce_averages_grads() {
        let mut params = vec![vec![0.0f32; 2]; 3];
        let mut grads = vec![vec![3.0f32, 0.0], vec![0.0, 3.0], vec![3.0, 3.0]];
        let mut fabric = Fabric::new(4, LinkModel::default());
        let comm = vec![true; 3];
        let mut s = AllReduceStrategy::new(AllReduceImpl::Ring);
        let mut rng = Rng::new(0);
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut c, &mut rng).unwrap();
        for g in &grads {
            assert!((g[0] - 2.0).abs() < 1e-6);
            assert!((g[1] - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn easgd_elastic_update_against_center() {
        let mut params = vec![vec![4.0f32], vec![0.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let comm = vec![true, true];
        let mut s = EasgdStrategy::new(0.5, 1);
        let mut rng = Rng::new(0);
        // first round: center initializes to worker0's params (= 4.0)
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut c, &mut rng).unwrap();
        // z0 = 0.5*(4-4)=0 ; z1 = 0.5*(0-4) = -2
        assert_eq!(params[0], vec![4.0]);
        assert_eq!(params[1], vec![2.0]);
        assert_eq!(s.center(), Some(&[2.0f32][..])); // 4 + 0 + (-2)
    }

    #[test]
    fn easgd_alpha_above_stability_bound_diverges() {
        // beta = alpha*|W| = 2.0 > 1: the center overshoots and the system
        // oscillates with growing amplitude — the instability the paper's
        // elastic-symmetry condition guards against.
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32]).collect();
        let mut grads = vec![vec![0.0]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut s = EasgdStrategy::new(0.5, 1);
        let mut rng = Rng::new(1);
        let comm = vec![true; w];
        for _ in 0..40 {
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
            s.comm_round(&mut c, &mut rng).unwrap();
        }
        let spread: f32 = params.iter().map(|p| p[0].abs()).fold(0.0, f32::max);
        assert!(spread > 100.0, "expected divergence, spread {spread}");
    }

    #[test]
    fn easgd_total_sum_with_center_is_conserved() {
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32 * 2.0; 3]).collect();
        let mut grads = vec![vec![0.0; 3]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut s = EasgdStrategy::new(0.25, 3);
        let mut rng = Rng::new(7);
        // initialize center
        let comm = vec![true; w];
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut c, &mut rng).unwrap();
        let total0: f32 = params.iter().flat_map(|p| p.iter()).sum::<f32>() + s.center.iter().sum::<f32>();
        for round in 0..20 {
            let comm: Vec<bool> = (0..w).map(|_| rng.bernoulli(0.6)).collect();
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
            s.comm_round(&mut c, &mut rng).unwrap();
            let total: f32 = params.iter().flat_map(|p| p.iter()).sum::<f32>() + s.center.iter().sum::<f32>();
            assert!((total - total0).abs() < 1e-3, "round {round}: {total} vs {total0}");
        }
    }

    #[test]
    fn easgd_workers_converge_to_center() {
        // Stability requires beta = alpha * |W| <= 1 (Zhang et al.; the
        // elastic-symmetry condition): with W=4 simultaneous updates,
        // alpha must be <= 0.25.
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32]).collect();
        let mut grads = vec![vec![0.0]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut s = EasgdStrategy::new(0.2, 1);
        let mut rng = Rng::new(1);
        let comm = vec![true; w];
        for _ in 0..40 {
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
            s.comm_round(&mut c, &mut rng).unwrap();
        }
        let center = s.center().unwrap()[0];
        for p in &params {
            assert!((p[0] - center).abs() < 0.05, "{} vs {center}", p[0]);
        }
    }

    #[test]
    fn easgd_accounts_roundtrip_traffic() {
        let mut params = vec![vec![0.0f32; 10]; 2];
        let mut grads = vec![vec![0.0; 10]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let comm = vec![true, false];
        let mut s = EasgdStrategy::new(0.5, 10);
        let mut rng = Rng::new(0);
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm);
        s.comm_round(&mut c, &mut rng).unwrap();
        // one communicating worker: up + down = 2 * 40 bytes
        assert_eq!(fabric.report().total_bytes, 80);
    }
}
