//! Centralized strategies: Synchronous All-reduce SGD and Synchronous
//! EASGD.
//!
//! These are the paper's baselines (Algorithms 1 and 2).  All-reduce
//! averages *gradients* every step through a real collective over the
//! fabric; EASGD keeps a center variable at a dedicated coordinator slot
//! (fabric index `W` — the fabric is always created with one extra slot
//! for it) and applies the elastic update between every communicating
//! worker and the center.

use anyhow::Result;

use super::{CommCtx, ScratchArena, Strategy};
use crate::collective::AllReduceImpl;
use crate::util::rng::Rng;

/// Synchronous All-reduce SGD (Algorithm 1): gradients are averaged
/// across all workers each step; every worker then applies the identical
/// aggregate.  Mathematically equivalent to single-worker SGD with
/// effective batch `|W| * b` (§2.1.1) — property-tested in
/// `rust/tests/proptests.rs`.
///
/// The collective works on the shared gradient buffers directly, so the
/// whole round happens in the leader's plan phase (`plan_round` returns
/// `false`: nothing to shard).
pub struct AllReduceStrategy {
    imp: AllReduceImpl,
}

impl AllReduceStrategy {
    pub fn new(imp: AllReduceImpl) -> Self {
        AllReduceStrategy { imp }
    }
}

impl Strategy for AllReduceStrategy {
    fn name(&self) -> &'static str {
        "allreduce"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, _rng: &mut Rng) -> Result<bool> {
        // every step, unconditionally (uses_schedule() == false)
        self.imp.all_reduce_mean(ctx.grads, ctx.fabric);
        Ok(false)
    }
}

/// Synchronous EASGD (Algorithm 2).
///
/// The center variable lives at a dedicated central process (no training
/// shard).  For every communicating worker, with moving rate alpha:
///
/// ```text
/// z_i      = alpha * (theta_i - center)     (line 5, pre-round snapshot)
/// theta_i -= z_i                            (line 6)
/// center  += z_i                            (line 7, summed over workers)
/// ```
///
/// Updates use the pre-round center for all workers (simultaneous
/// semantics, Eq. 2.4: `center += alpha * SUM_i (theta_i - center)`),
/// which preserves elastic symmetry between each worker and the center:
/// `theta_i + center` changes only by the *other* workers' contributions.
///
/// Plan phase: stash the pre-round center in the arena's aux plane,
/// accumulate the summed delta (aux2) and advance the center.  Apply
/// phase (shardable): each communicating worker pulls toward the stashed
/// pre-round center.
pub struct EasgdStrategy {
    pub alpha: f32,
    pub center: Vec<f32>,
    initialized: bool,
}

impl EasgdStrategy {
    pub fn new(alpha: f32, flat_size: usize) -> Self {
        EasgdStrategy {
            alpha,
            center: vec![0.0; flat_size],
            initialized: false,
        }
    }
}

impl Strategy for EasgdStrategy {
    fn name(&self) -> &'static str {
        "easgd"
    }

    fn plan_round(&mut self, ctx: &mut CommCtx, _rng: &mut Rng) -> Result<bool> {
        // Algorithm 2 initializes the center to the shared initial
        // parameters; workers all start identical, so adopt worker 0's
        // params on the first round.
        if !self.initialized {
            self.center.copy_from_slice(&ctx.params[0]);
            self.initialized = true;
        }
        if !ctx.communicating.iter().any(|&c| c) {
            return Ok(false);
        }
        let n = self.center.len();
        let w = ctx.workers();
        let central = w; // the fabric's extra slot
        ctx.arena.begin_round(w, n, ctx.communicating);
        // plane A: the pre-round center, read by every apply_slot
        ctx.arena.aux_mut().copy_from_slice(&self.center);
        // plane B: the summed center delta, accumulated worker-by-worker
        // in the same order as the sequential reference
        {
            let delta = ctx.arena.aux2_mut();
            for d in delta.iter_mut() {
                *d = 0.0;
            }
            let a = self.alpha;
            for i in 0..w {
                if !ctx.communicating[i] {
                    continue;
                }
                // worker sends theta_i up, receives the center down
                ctx.fabric.send_params(i, central, n);
                ctx.fabric.send_params(central, i, n);
                for ((d, &t), &c) in delta.iter_mut().zip(&ctx.params[i]).zip(&self.center) {
                    *d += a * (t - c);
                }
            }
        }
        crate::tensor::add_assign(&mut self.center, ctx.arena.aux2());
        Ok(true)
    }

    fn apply_slot(&self, slot: usize, params: &mut [f32], arena: &ScratchArena) {
        if !arena.mask()[slot] {
            return;
        }
        // theta_i -= alpha * (theta_i - center_pre); theta_i is untouched
        // by any other slot, so reading it live equals the pre-round value
        crate::tensor::elastic_pull(params, arena.aux(), self.alpha);
    }

    fn center(&self) -> Option<&[f32]> {
        if self.initialized {
            Some(&self.center)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ScratchArena;
    use crate::comm::{Fabric, LinkModel};
    use crate::topology::Topology;

    fn ctx<'a>(
        params: &'a mut [Vec<f32>],
        grads: &'a mut [Vec<f32>],
        fabric: &'a mut Fabric,
        communicating: &'a [bool],
        arena: &'a mut ScratchArena,
    ) -> CommCtx<'a> {
        CommCtx {
            params,
            grads,
            fabric,
            topology: &Topology::Full,
            step: 0,
            communicating,
            arena,
        }
    }

    #[test]
    fn allreduce_averages_grads() {
        let mut params = vec![vec![0.0f32; 2]; 3];
        let mut grads = vec![vec![3.0f32, 0.0], vec![0.0, 3.0], vec![3.0, 3.0]];
        let mut fabric = Fabric::new(4, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true; 3];
        let mut s = AllReduceStrategy::new(AllReduceImpl::Ring);
        let mut rng = Rng::new(0);
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut c, &mut rng).unwrap();
        for g in &grads {
            assert!((g[0] - 2.0).abs() < 1e-6);
            assert!((g[1] - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn easgd_elastic_update_against_center() {
        let mut params = vec![vec![4.0f32], vec![0.0f32]];
        let mut grads = vec![vec![0.0]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true, true];
        let mut s = EasgdStrategy::new(0.5, 1);
        let mut rng = Rng::new(0);
        // first round: center initializes to worker0's params (= 4.0)
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut c, &mut rng).unwrap();
        // z0 = 0.5*(4-4)=0 ; z1 = 0.5*(0-4) = -2
        assert_eq!(params[0], vec![4.0]);
        assert_eq!(params[1], vec![2.0]);
        assert_eq!(s.center(), Some(&[2.0f32][..])); // 4 + 0 + (-2)
    }

    #[test]
    fn easgd_alpha_above_stability_bound_diverges() {
        // beta = alpha*|W| = 2.0 > 1: the center overshoots and the system
        // oscillates with growing amplitude — the instability the paper's
        // elastic-symmetry condition guards against.
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32]).collect();
        let mut grads = vec![vec![0.0]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut arena = ScratchArena::new();
        let mut s = EasgdStrategy::new(0.5, 1);
        let mut rng = Rng::new(1);
        let comm = vec![true; w];
        for _ in 0..40 {
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut c, &mut rng).unwrap();
        }
        let spread: f32 = params.iter().map(|p| p[0].abs()).fold(0.0, f32::max);
        assert!(spread > 100.0, "expected divergence, spread {spread}");
    }

    #[test]
    fn easgd_total_sum_with_center_is_conserved() {
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32 * 2.0; 3]).collect();
        let mut grads = vec![vec![0.0; 3]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut arena = ScratchArena::new();
        let mut s = EasgdStrategy::new(0.25, 3);
        let mut rng = Rng::new(7);
        // initialize center
        let comm = vec![true; w];
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut c, &mut rng).unwrap();
        let total0: f32 = params.iter().flat_map(|p| p.iter()).sum::<f32>() + s.center.iter().sum::<f32>();
        for round in 0..20 {
            let comm: Vec<bool> = (0..w).map(|_| rng.bernoulli(0.6)).collect();
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut c, &mut rng).unwrap();
            let total: f32 = params.iter().flat_map(|p| p.iter()).sum::<f32>() + s.center.iter().sum::<f32>();
            assert!((total - total0).abs() < 1e-3, "round {round}: {total} vs {total0}");
        }
    }

    #[test]
    fn easgd_workers_converge_to_center() {
        // Stability requires beta = alpha * |W| <= 1 (Zhang et al.; the
        // elastic-symmetry condition): with W=4 simultaneous updates,
        // alpha must be <= 0.25.
        let w = 4;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32]).collect();
        let mut grads = vec![vec![0.0]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut arena = ScratchArena::new();
        let mut s = EasgdStrategy::new(0.2, 1);
        let mut rng = Rng::new(1);
        let comm = vec![true; w];
        for _ in 0..40 {
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut c, &mut rng).unwrap();
        }
        let center = s.center().unwrap()[0];
        for p in &params {
            assert!((p[0] - center).abs() < 0.05, "{} vs {center}", p[0]);
        }
    }

    #[test]
    fn easgd_accounts_roundtrip_traffic() {
        let mut params = vec![vec![0.0f32; 10]; 2];
        let mut grads = vec![vec![0.0; 10]; 2];
        let mut fabric = Fabric::new(3, LinkModel::default());
        let mut arena = ScratchArena::new();
        let comm = vec![true, false];
        let mut s = EasgdStrategy::new(0.5, 10);
        let mut rng = Rng::new(0);
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
        s.comm_round(&mut c, &mut rng).unwrap();
        // one communicating worker: up + down = 2 * 40 bytes
        assert_eq!(fabric.report().total_bytes, 80);
    }

    #[test]
    fn easgd_round_is_allocation_free_after_warmup() {
        let w = 6;
        let n = 64;
        let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
        let mut grads = vec![vec![0.0f32; n]; w];
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        let mut arena = ScratchArena::new();
        let mut s = EasgdStrategy::new(0.1, n);
        let mut rng = Rng::new(4);
        let full = vec![true; w];
        let mut c = ctx(&mut params, &mut grads, &mut fabric, &full, &mut arena);
        s.comm_round(&mut c, &mut rng).unwrap();
        let fp = arena.footprint();
        let mut mask_rng = Rng::new(9);
        for round in 0..30 {
            let comm: Vec<bool> = (0..w).map(|_| mask_rng.bernoulli(0.5)).collect();
            let mut c = ctx(&mut params, &mut grads, &mut fabric, &comm, &mut arena);
            s.comm_round(&mut c, &mut rng).unwrap();
            assert_eq!(arena.footprint(), fp, "arena reallocated at round {round}");
        }
    }
}
