//! Small shared utilities: deterministic RNG, math helpers, timing.

pub mod rng;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// (min, max) of a slice; panics on empty.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    assert!(!xs.is_empty());
    xs.iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &x| {
            (lo.min(x), hi.max(x))
        })
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// L2 norm.
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Max |a - b| over paired elements.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// FNV-1a over an arbitrary byte stream — the crate's one bit-digest.
///
/// Used to pin parameter state exactly: `membership::digest_params` and
/// the golden-trajectory suite both fold the little-endian bytes of
/// every f32 through this (same constants, same order), so a digest
/// computed in one place is comparable to one computed in the other.
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over the LE bytes of a flat f32 slice.
pub fn fnv_digest(params: &[f32]) -> u64 {
    fnv1a(params.iter().flat_map(|v| v.to_le_bytes()))
}

/// FNV-1a over the LE bytes of nested f32 slices, in order — equals
/// [`fnv_digest`] of their concatenation.
pub fn fnv_digest_nested<S: AsRef<[f32]>>(params: &[S]) -> u64 {
    fnv1a(
        params
            .iter()
            .flat_map(|p| p.as_ref().iter().flat_map(|v| v.to_le_bytes())),
    )
}

/// Wall-clock stopwatch returning seconds as f64.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }

    #[test]
    fn fnv_digest_flat_equals_nested_concat() {
        let pi = std::f32::consts::PI;
        let a = vec![1.0f32, -2.5, 0.0, pi];
        let nested = vec![vec![1.0f32, -2.5], vec![0.0, pi]];
        assert_eq!(fnv_digest(&a), fnv_digest_nested(&nested));
        // empty input is the FNV offset basis
        assert_eq!(fnv_digest(&[]), 0xcbf29ce484222325);
        // order matters
        let swapped = vec![vec![0.0f32, pi], vec![1.0, -2.5]];
        assert_ne!(fnv_digest_nested(&nested), fnv_digest_nested(&swapped));
    }
}
