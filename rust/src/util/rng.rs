//! Deterministic, splittable PRNG for the whole coordinator.
//!
//! The paper stresses reproducibility ("Each of these experiments are
//! initialized with the same random seed", Table 4.1) — every source of
//! randomness in this crate flows from a single experiment seed through
//! *named streams* so that e.g. the gossip peer-selection sequence is
//! independent of how many batches were drawn.
//!
//! Core generator: xoshiro256** (Blackman & Vigna), seeded via SplitMix64
//! — tiny, fast, and good enough statistical quality for simulation /
//! data-synthesis purposes (this is not a cryptographic RNG).

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent named stream: hash (seed, label) into a new rng.
    ///
    /// Streams with different labels are statistically independent of each
    /// other and of the parent.
    pub fn stream(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV offset
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = self.s[0] ^ h.rotate_left(17);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), bias-free via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly (panics on empty).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample from Gamma(shape=a, scale=1) (Marsaglia-Tsang; a > 0).
    pub fn gamma(&mut self, a: f64) -> f64 {
        if a < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(a + 1.0) * u.powf(1.0 / a);
        }
        let d = a - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Sample a probability vector from Dirichlet(alpha * 1) of dim n.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        g.iter_mut().for_each(|x| *x /= s);
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ_from_parent_and_each_other() {
        let root = Rng::new(7);
        let mut a = root.stream("gossip");
        let mut b = root.stream("batches");
        let mut c = root.stream("gossip");
        let av = a.next_u64();
        assert_ne!(av, b.next_u64());
        assert_eq!(av, c.next_u64()); // same label -> same stream
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(4);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.125)).count();
        let rate = hits as f64 / 10_000.0;
        assert!((rate - 0.125).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for &a in &[0.1, 1.0, 10.0] {
            let p = r.dirichlet(a, 8);
            assert_eq!(p.len(), 8);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }
}
