//! Controlled-asynchrony simulation — the thesis's stated future work
//! ("studying the effects of asynchrony that is controlled in a simulated
//! environment", Ch. 1/5), built as an extension on top of the fabric's
//! cost model.
//!
//! The simulator assigns each worker a compute-time distribution and
//! replays a training schedule *in virtual time* — no gradients, pure
//! timing.  For synchronous methods it quantifies straggler cost (every
//! round waits for the slowest worker — §2.1.2's motivation for
//! asynchrony); [`simulate_asynchronous`] estimates the staleness a
//! barrier-free run would see.
//!
//! This module prices schedules; it does not train.  The *real*
//! asynchronous regime — actual gradients, message passing, measured
//! (not estimated) staleness — lives in `crate::runtime_async`, which
//! reuses [`WorkerSpeed`] as its per-node compute model.  The time-only
//! replay is kept for quick what-if costing
//! (`examples/async_straggler.rs --dry`).

use crate::comm::LinkModel;
use crate::util::rng::Rng;

/// Per-worker compute-time model: lognormal-ish around `mean_s` with
/// multiplicative jitter, plus an optional slow factor for stragglers.
#[derive(Clone, Debug)]
pub struct WorkerSpeed {
    pub mean_s: f64,
    /// sigma of the multiplicative gaussian jitter (0 = deterministic)
    pub jitter: f64,
    /// persistent multiplier (straggler = e.g. 3.0)
    pub slow_factor: f64,
}

impl WorkerSpeed {
    pub fn uniform(mean_s: f64) -> Self {
        WorkerSpeed { mean_s, jitter: 0.1, slow_factor: 1.0 }
    }

    pub fn sample_step_time(&self, rng: &mut Rng) -> f64 {
        let mult = (1.0 + self.jitter * rng.gauss()).max(0.05);
        self.mean_s * self.slow_factor * mult
    }
}

/// Outcome of a virtual-time replay.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// total virtual seconds to complete all steps
    pub total_s: f64,
    /// seconds lost at barriers (sum over rounds of max-minus-mean)
    pub barrier_waste_s: f64,
    /// per-worker busy seconds
    pub busy_s: Vec<f64>,
    /// per-worker completion time (== total_s for synchronous runs where
    /// everyone leaves the last barrier together)
    pub finish_s: Vec<f64>,
    /// average staleness (in steps) an async run would see per exchange
    pub mean_async_staleness: f64,
}

impl SimOutcome {
    /// Fraction of total worker-time wasted waiting at barriers.
    pub fn waste_fraction(&self) -> f64 {
        let busy: f64 = self.busy_s.iter().sum();
        let w = self.busy_s.len() as f64;
        let wall = self.total_s * w;
        if wall <= 0.0 {
            0.0
        } else {
            (wall - busy) / wall
        }
    }

    /// Mean over workers of busy-time / own-completion-time: 1.0 means no
    /// worker ever waits.  Async runs score ~1.0; synchronous runs with a
    /// straggler score ~1/slow_factor for the fast workers.
    pub fn mean_self_utilization(&self) -> f64 {
        mean_self_utilization(&self.busy_s, &self.finish_s)
    }

    pub fn speedup_if_async(&self) -> f64 {
        if self.total_s - self.barrier_waste_s <= 0.0 {
            1.0
        } else {
            self.total_s / (self.total_s - self.barrier_waste_s)
        }
    }
}

/// Mean over workers of busy-time / own-completion-time (1.0 for a
/// worker that never existed on the clock).  The single definition both
/// the time-only replay ([`SimOutcome`]) and the event-driven runtime
/// (`crate::runtime_async::AsyncRunReport`) report, so async-vs-sync
/// utilization comparisons always use the same metric.
pub fn mean_self_utilization(busy_s: &[f64], finish_s: &[f64]) -> f64 {
    let n = busy_s.len() as f64;
    busy_s
        .iter()
        .zip(finish_s)
        .map(|(&b, &f)| if f > 0.0 { b / f } else { 1.0 })
        .sum::<f64>()
        / n
}

/// Replay `steps` synchronous rounds: each round costs
/// `max_i(compute_i) + comm_cost` in virtual time.
pub fn simulate_synchronous(
    speeds: &[WorkerSpeed],
    steps: u64,
    comm_bytes_per_round: u64,
    link: LinkModel,
    seed: u64,
) -> SimOutcome {
    let w = speeds.len();
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut waste = 0.0;
    let mut busy = vec![0.0f64; w];
    for _ in 0..steps {
        let times: Vec<f64> = speeds.iter().map(|s| s.sample_step_time(&mut rng)).collect();
        let slowest = times.iter().cloned().fold(0.0, f64::max);
        let comm = if comm_bytes_per_round > 0 {
            link.transfer_time_s(comm_bytes_per_round)
        } else {
            0.0
        };
        total += slowest + comm;
        for (b, t) in busy.iter_mut().zip(&times) {
            *b += t + comm;
        }
        waste += times.iter().map(|t| slowest - t).sum::<f64>() / w as f64;
    }
    let finish = vec![total; w];
    SimOutcome {
        total_s: total,
        barrier_waste_s: waste,
        busy_s: busy,
        finish_s: finish,
        mean_async_staleness: 0.0,
    }
}

/// Event-driven asynchronous replay: workers free-run; a gossip exchange
/// between i and k uses whatever step-count each is at, and the staleness
/// of the exchange is `|t_i - t_k|`.  Returns virtual completion time and
/// mean staleness — the controlled-asynchrony metric the thesis proposes.
pub fn simulate_asynchronous(
    speeds: &[WorkerSpeed],
    steps_per_worker: u64,
    gossip_prob: f64,
    seed: u64,
) -> SimOutcome {
    let w = speeds.len();
    let mut rng = Rng::new(seed);
    // (next completion time, steps done) per worker
    let mut clock = vec![0.0f64; w];
    let mut done = vec![0u64; w];
    let mut busy = vec![0.0f64; w];
    let mut staleness_sum = 0.0f64;
    let mut exchanges = 0u64;
    let mut remaining = w;
    while remaining > 0 {
        // next worker to finish a step
        let i = (0..w)
            .filter(|&i| done[i] < steps_per_worker)
            .min_by(|&a, &b| clock[a].partial_cmp(&clock[b]).unwrap())
            .unwrap();
        let dt = speeds[i].sample_step_time(&mut rng);
        clock[i] += dt;
        busy[i] += dt;
        done[i] += 1;
        if done[i] == steps_per_worker {
            remaining -= 1;
        }
        if w > 1 && rng.bernoulli(gossip_prob) {
            let mut k = rng.below(w - 1);
            if k >= i {
                k += 1;
            }
            staleness_sum += (done[i] as f64 - done[k] as f64).abs();
            exchanges += 1;
        }
    }
    let total = clock.iter().cloned().fold(0.0, f64::max);
    SimOutcome {
        total_s: total,
        barrier_waste_s: 0.0,
        busy_s: busy,
        finish_s: clock.clone(),
        mean_async_staleness: if exchanges > 0 {
            staleness_sum / exchanges as f64
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn speeds(n: usize) -> Vec<WorkerSpeed> {
        (0..n).map(|_| WorkerSpeed::uniform(0.1)).collect()
    }

    #[test]
    fn homogeneous_sync_has_low_waste() {
        let mut s = speeds(4);
        s.iter_mut().for_each(|x| x.jitter = 0.0);
        let out = simulate_synchronous(&s, 100, 0, LinkModel::default(), 1);
        assert!(out.waste_fraction() < 0.01, "{}", out.waste_fraction());
        assert!((out.total_s - 10.0).abs() < 1e-6);
    }

    #[test]
    fn straggler_dominates_sync_time() {
        let mut s = speeds(4);
        s[3].slow_factor = 3.0;
        let out = simulate_synchronous(&s, 200, 0, LinkModel::default(), 1);
        // wall time ~ straggler time: 200 * 0.3 = 60s
        assert!(out.total_s > 50.0, "{}", out.total_s);
        // the three fast workers idle ~2/3 of the time
        assert!(out.waste_fraction() > 0.3, "{}", out.waste_fraction());
        assert!(out.speedup_if_async() > 1.2);
    }

    #[test]
    fn async_removes_barrier_waste_but_adds_staleness() {
        let mut s = speeds(4);
        s[3].slow_factor = 3.0;
        let sync = simulate_synchronous(&s, 200, 0, LinkModel::default(), 1);
        let asy = simulate_asynchronous(&s, 200, 0.25, 1);
        // completion time is straggler-bound either way (fixed per-worker
        // step counts); the async win is utilization: nobody waits at a
        // barrier, so every worker is ~100% busy until its own finish
        assert!(asy.mean_self_utilization() > 0.99, "{}", asy.mean_self_utilization());
        assert!(sync.mean_self_utilization() < 0.7, "{}", sync.mean_self_utilization());
        // fast/slow mix => exchanges observe step skew
        assert!(asy.mean_async_staleness > 1.0, "{}", asy.mean_async_staleness);
    }

    #[test]
    fn async_homogeneous_low_staleness() {
        let mut s = speeds(4);
        s.iter_mut().for_each(|x| x.jitter = 0.02);
        let asy = simulate_asynchronous(&s, 300, 0.25, 2);
        assert!(asy.mean_async_staleness < 3.0, "{}", asy.mean_async_staleness);
    }

    #[test]
    fn comm_cost_adds_to_round() {
        let s = speeds(2);
        let link = LinkModel { latency_s: 0.0, bandwidth_bps: 1e6 };
        let quiet = simulate_synchronous(&s, 50, 0, link, 3);
        let chatty = simulate_synchronous(&s, 50, 1_000_000, link, 3);
        assert!((chatty.total_s - quiet.total_s - 50.0).abs() < 1.0);
    }
}
