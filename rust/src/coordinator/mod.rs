//! The synchronous training coordinator — the system's main loop.
//!
//! One `Coordinator::run` call executes one experiment (one table row /
//! one curve): it owns the worker replicas' flat parameters, the
//! optimizer states, the communication strategy, the schedule, the data
//! shards and the evaluation loop.  The loop implements Algorithm 5's
//! phase structure exactly:
//!
//! ```text
//! for t in 0..total_steps:
//!   [grad]   g_i    = engine.loss_and_grad(theta_i, batch_i)     ∀i   (line 2)
//!   [sched]  comm_i ~ Bernoulli(p)  or  tau | t                  ∀i   (line 4)
//!   [comm]   strategy.comm_round(...)   -- barrier semantics     (lines 5-8)
//!   [optim]  v_i = mu v_i - eta g_i;  theta_i += -eta g_i + mu v_i    (3, 9)
//! ```
//!
//! The velocity update commutes with the communication round (comm only
//! touches `theta`, the velocity only `v`/`g`), so running it after the
//! round is equivalent to the paper's line ordering while letting
//! All-reduce average gradients in the same hook.
//!
//! Workers are simulated in-process: the synchronous algorithms make the
//! sequential execution *exactly* equivalent to a barriered cluster (this
//! is the thesis's own reproducibility argument for studying synchronous
//! variants).  XLA CPU parallelizes each gradient computation internally.

pub mod checkpoint;
pub mod parallel;

use anyhow::{bail, Context, Result};

use crate::algos::{CommCtx, Method, ScratchArena, Strategy};
use crate::comm::{Fabric, LinkModel};
use crate::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use crate::data::{self, BatchCursor, Dataset, TaskKind};
use crate::metrics::{Curve, EvalPoint, RunMetrics};
use crate::optim::Optimizer;
use crate::runtime::{BatchX, EngineFactory, GradEngine, HloEngineSpec, SyntheticSpec};
use crate::trace::{Ev, Kind, Trace};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// Final report of one experiment run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    /// test accuracy of the rank-0 worker's model (paper's "Rank-0 Accuracy")
    pub rank0_accuracy: f32,
    /// test accuracy of the parameter-averaged model ("Aggregate Accuracy")
    pub aggregate_accuracy: f32,
    pub metrics: RunMetrics,
}

/// The coordinator. Construct with a config + engine factory, then `run`.
pub struct Coordinator<'a> {
    cfg: &'a ExperimentConfig,
    factory: &'a dyn EngineFactory,
    pub verbose: bool,
    /// optional per-step observer (async-sim and tests hook in here)
    pub on_step: Option<Box<dyn FnMut(u64, &[Vec<f32>]) + 'a>>,
}

impl<'a> Coordinator<'a> {
    pub fn new(cfg: &'a ExperimentConfig, factory: &'a dyn EngineFactory) -> Self {
        Coordinator { cfg, factory, verbose: false, on_step: None }
    }

    /// Execute the experiment.
    pub fn run(&mut self) -> Result<RunReport> {
        let cfg = self.cfg;
        let w = cfg.workers;
        anyhow::ensure!(w >= 1, "need at least one worker");
        match cfg.codec {
            // bit-exact payloads: every method, no trajectory impact
            crate::comm::codec::CodecKind::Identity => {}
            // TopK is an *overlay* codec (per-receiver residual state at
            // the sender); the sync round publishes one shared snapshot
            // per worker, which has no per-receiver stream to thread it
            // through — event-driven runtime only
            crate::comm::codec::CodecKind::TopK { .. } => bail!(
                "wire codec {:?} is an overlay codec and applies to the \
                 event-driven async runtime (`repro async-train --codec ...`)",
                cfg.codec.label()
            ),
            // lossy quantizers ride the gossip snapshot plane; barrier /
            // central methods (All-reduce, EASGD) must stay bit-exact
            _ => anyhow::ensure!(
                cfg.method.is_pairwise_gossip(),
                "lossy wire codec {:?} requires a pairwise gossip method in \
                 the synchronous coordinator; {:?} exchanges must stay exact",
                cfg.codec.label(),
                cfg.method
            ),
        }
        anyhow::ensure!(
            cfg.churn.is_empty(),
            "churn schedule {:?} applies to the event-driven async runtime \
             (`repro churn-train` / `async-train --churn ...`); the barriered \
             coordinator has a fixed roster by construction",
            cfg.churn.label()
        );
        anyhow::ensure!(
            cfg.faults.is_empty(),
            "fault plan {:?} applies to the event-driven async fabric \
             (`repro async-train --faults ...`); the synchronous coordinator \
             models perfect in-round exchanges",
            cfg.faults.label()
        );
        anyhow::ensure!(
            cfg.fd.is_empty(),
            "failure detection {:?} applies to the event-driven async runtime \
             (`repro async-train --fd ...`); the barriered coordinator has \
             oracle membership by construction",
            cfg.fd.label()
        );
        let root_rng = Rng::new(cfg.seed);

        // --- data ---------------------------------------------------------
        let full = build_dataset(cfg, &mut root_rng.stream("datagen"))?;
        let (train, val, test) = full.split(
            cfg.n_train.min(full.len()),
            cfg.n_val,
            cfg.n_test,
            &mut root_rng.stream("split"),
        );
        let shards = cfg
            .partition
            .assign(&train, w, &mut root_rng.stream("partition"));
        let mut cursors: Vec<BatchCursor> = shards
            .into_iter()
            .enumerate()
            .map(|(i, s)| BatchCursor::new(s, root_rng.stream(&format!("batches{i}"))))
            .collect();

        // --- engine + state -------------------------------------------------
        let mut engine = self.factory.build().context("building engine")?;
        let flat = engine.flat_size();
        let b = engine.train_batch();
        anyhow::ensure!(
            b == cfg.per_worker_batch(),
            "engine batch {b} != per-worker batch {} (cfg {})",
            cfg.per_worker_batch(),
            cfg.label
        );
        let init = engine.initial_params()?;
        anyhow::ensure!(init.len() == flat);
        // Table 4.1: every worker starts from the same seed/init
        let mut params: Vec<Vec<f32>> = vec![init; w];
        let mut grads: Vec<Vec<f32>> = vec![vec![0.0; flat]; w];
        let mut optims: Vec<Optimizer> = (0..w)
            .map(|_| Optimizer::new(cfg.optimizer, cfg.lr.clone(), flat))
            .collect();
        let mut strategy: Box<dyn Strategy> = cfg.method.build(w, flat);
        // +1 fabric slot: EASGD's central process
        let mut fabric = Fabric::new(w + 1, LinkModel::default());
        // wire codec for the gossip snapshot plane: `None` for identity
        // (raw snapshots, byte-identical to the pre-codec coordinator);
        // otherwise the published snapshots are passed through
        // encode/decode each round and every whole-parameter send is
        // priced at the encoded size via the fabric hint
        let mut codec: Option<Box<dyn crate::comm::codec::Codec>> =
            match cfg.codec {
                crate::comm::codec::CodecKind::Identity => None,
                _ => Some(cfg.codec.build()),
            };
        if let Some(c) = codec.as_ref() {
            fabric.set_param_wire(flat, c.encoded_len(flat) as u64);
        }
        // persistent comm-round scratch: snapshots + edge plans reuse
        // capacity across rounds (zero allocation after warm-up; sized
        // lazily by the first gossip round so NoComm/All-reduce runs pay
        // nothing)
        let mut arena = ScratchArena::new();

        let mut sched_rng = root_rng.stream("schedule");
        let mut gossip_rng = root_rng.stream("gossip");
        let mut seed_rng = root_rng.stream("dropout");

        // --- loop -----------------------------------------------------------
        let steps_per_epoch = cfg.steps_per_epoch();
        let mut curve = Curve::new(cfg.label.clone());
        // the barriered loop has no virtual clock; its timeline is keyed
        // by the step index (1 step = 1 "second"), which is just as
        // deterministic
        let mut trace = Trace::from_spec(&cfg.trace, &cfg.label);
        let watch = Stopwatch::start();
        let mut eval_time = 0.0f64;
        let mut step: u64 = 0;
        let mut batch_idx: Vec<usize> = Vec::new();
        let mut xbufs: Vec<crate::runtime::BatchXOwned> =
            vec![crate::runtime::BatchXOwned::F32(Vec::new()); w];
        let mut ybufs: Vec<Vec<i32>> = vec![Vec::new(); w];
        let mut seeds: Vec<i32> = vec![0; w];
        let mut step_losses: Vec<f32>;
        let mut communicating: Vec<bool> = Vec::with_capacity(w);

        for epoch in 0..cfg.epochs {
            for o in optims.iter_mut() {
                o.start_epoch(epoch);
            }
            let mut epoch_loss = 0.0f64;
            for _ in 0..steps_per_epoch {
                // [grad] phase — every worker from its shard, dispatched as
                // one stacked call when the engine has a vmapped artifact
                for i in 0..w {
                    cursors[i].next_batch(b, &mut batch_idx);
                    seeds[i] = seed_rng.next_u64() as i32;
                    match train.kind {
                        TaskKind::Classify => {
                            data::gather_f32(&train, &batch_idx, xbufs[i].clear_f32(), &mut ybufs[i]);
                        }
                        TaskKind::LanguageModel => {
                            data::gather_i32(&train, &batch_idx, xbufs[i].clear_i32(), &mut ybufs[i]);
                        }
                    }
                }
                step_losses = engine.loss_and_grad_all(&params, &xbufs, &ybufs, &seeds, &mut grads)?;
                epoch_loss += step_losses.iter().map(|&l| l as f64).sum::<f64>();

                // [sched] phase
                decide_schedule_into(
                    &cfg.method,
                    cfg.schedule,
                    step,
                    w,
                    &mut sched_rng,
                    &mut communicating,
                );

                // [comm] phase — synchronized round: plan, publish the
                // (possibly codec-roundtripped) snapshots, apply per slot
                // in worker order.  With `codec == None` this is exactly
                // `Strategy::comm_round`'s default body.
                let deferred = {
                    let mut ctx = CommCtx {
                        params: &mut params,
                        grads: &mut grads,
                        fabric: &mut fabric,
                        topology: &cfg.topology,
                        step,
                        communicating: &communicating,
                        arena: &mut arena,
                    };
                    strategy.plan_round(&mut ctx, &mut gossip_rng)?
                };
                if deferred {
                    if let Some(c) = codec.as_mut() {
                        arena.codec_roundtrip_snapshots(c.as_mut())?;
                    }
                    for (i, p) in params.iter_mut().enumerate() {
                        strategy.apply_slot(i, p, &arena);
                    }
                }
                fabric.end_round();
                if trace.is_on() {
                    let n_comm = communicating.iter().filter(|&&c| c).count() as u64;
                    trace.span(
                        step as f64,
                        (step + 1) as f64,
                        Ev { node: 0, kind: Kind::Round, class: 0, seq: step, a: n_comm, b: 0 },
                    );
                }

                // [optim] phase
                for i in 0..w {
                    optims[i].update_velocity(&grads[i]);
                    optims[i].apply(&mut params[i], &grads[i]);
                }

                if let Some(cb) = self.on_step.as_mut() {
                    cb(step, &params);
                }
                step += 1;
            }

            // --- evaluation ------------------------------------------------
            if (epoch + 1) % cfg.eval_every == 0 || epoch + 1 == cfg.epochs {
                let ew = Stopwatch::start();
                let mut worker_acc = Vec::with_capacity(w);
                let mut worker_loss = Vec::with_capacity(w);
                for p in params.iter() {
                    let (loss, acc) = evaluate(engine.as_mut(), p, &val)?;
                    worker_acc.push(acc);
                    worker_loss.push(loss);
                }
                let avg = average_params(&params);
                let (_, agg_acc) = evaluate(engine.as_mut(), &avg, &val)?;
                eval_time += ew.elapsed_s();
                trace.instant(
                    step as f64,
                    Ev {
                        node: 0,
                        kind: Kind::Eval,
                        class: 0,
                        seq: epoch as u64,
                        a: epoch as u64,
                        b: w as u64,
                    },
                );
                let point = EvalPoint {
                    epoch: epoch + 1,
                    step,
                    alive: w,
                    worker_acc,
                    worker_loss,
                    train_loss: (epoch_loss / (steps_per_epoch as f64 * w as f64)) as f32,
                    aggregate_acc: agg_acc,
                    wall_s: watch.elapsed_s(),
                };
                if self.verbose {
                    let (lo, hi) = point.acc_range();
                    eprintln!(
                        "[{}] epoch {:>3} step {:>6} train_loss {:.4} val_acc {:.4} [{:.4},{:.4}] agg {:.4}",
                        cfg.label,
                        epoch + 1,
                        step,
                        point.train_loss,
                        point.acc_mean(),
                        lo,
                        hi,
                        agg_acc
                    );
                }
                curve.push(point);
            }
        }

        // --- final test metrics ---------------------------------------------
        let (_, rank0_acc) = evaluate(engine.as_mut(), &params[0], &test)?;
        let avg = average_params(&params);
        let (_, agg_acc) = evaluate(engine.as_mut(), &avg, &test)?;

        trace
            .dump_if_requested()
            .context("writing flight-recorder dump")?;
        let report = fabric.report();
        let metrics = RunMetrics::from_traffic(
            curve,
            (rank0_acc, agg_acc),
            step,
            &report,
            watch.elapsed_s() - eval_time,
            eval_time,
        );
        Ok(RunReport {
            label: cfg.label.clone(),
            rank0_accuracy: rank0_acc,
            aggregate_accuracy: agg_acc,
            metrics,
        })
    }
}

/// Decide the per-worker communication mask for this step (convenience
/// wrapper over [`decide_schedule_into`]).
pub fn decide_schedule(
    method: &Method,
    schedule: CommSchedule,
    step: u64,
    w: usize,
    rng: &mut Rng,
) -> Vec<bool> {
    let mut out = Vec::with_capacity(w);
    decide_schedule_into(method, schedule, step, w, rng, &mut out);
    out
}

/// Decide the per-worker communication mask for this step, reusing the
/// caller's buffer (the hot loop allocates nothing per step).
pub fn decide_schedule_into(
    method: &Method,
    schedule: CommSchedule,
    step: u64,
    w: usize,
    rng: &mut Rng,
    out: &mut Vec<bool>,
) {
    out.clear();
    if !method.uses_schedule() {
        // All-reduce: every step; NoComm: round is a no-op anyway
        out.resize(w, true);
        return;
    }
    match schedule {
        CommSchedule::EveryStep => out.resize(w, true),
        // Algorithms 2-4: communication when tau divides t (skip t=0 where
        // all replicas are still identical)
        CommSchedule::Period(tau) => {
            let fire = step > 0 && step % tau == 0;
            out.resize(w, fire);
        }
        CommSchedule::Probability(p) => out.extend((0..w).map(|_| rng.bernoulli(p))),
    }
}

/// Mean of the worker replicas (the paper's "aggregate" model).
pub fn average_params(params: &[Vec<f32>]) -> Vec<f32> {
    let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    let mut out = vec![0.0f32; params[0].len()];
    crate::tensor::mean_of(&refs, &mut out);
    out
}

/// Evaluate `params` over a whole dataset with the engine's fixed eval
/// batch, masking the ragged tail.  Returns (mean loss per unit, accuracy).
pub fn evaluate(engine: &mut dyn GradEngine, params: &[f32], ds: &Dataset) -> Result<(f32, f32)> {
    let b = engine.eval_batch();
    let n = ds.len();
    if n == 0 {
        return Ok((0.0, 0.0));
    }
    let mut sum_loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut denom = 0.0f64;
    let mut xf = Vec::new();
    let mut xi = Vec::new();
    let mut y = Vec::new();
    let mut mask = vec![1.0f32; b];
    let mut idx: Vec<usize> = Vec::with_capacity(b);
    let mut start = 0usize;
    while start < n {
        let take = (n - start).min(b);
        idx.clear();
        idx.extend(start..start + take);
        // pad with repeats of the last row; the mask zeroes them out
        while idx.len() < b {
            idx.push(start + take - 1);
        }
        for (j, m) in mask.iter_mut().enumerate() {
            *m = if j < take { 1.0 } else { 0.0 };
        }
        let (l, c) = match ds.kind {
            TaskKind::Classify => {
                data::gather_f32(ds, &idx, &mut xf, &mut y);
                engine.eval_batch_masked(params, BatchX::F32(&xf), &y, &mask)?
            }
            TaskKind::LanguageModel => {
                data::gather_i32(ds, &idx, &mut xi, &mut y);
                engine.eval_batch_masked(params, BatchX::I32(&xi), &y, &mask)?
            }
        };
        sum_loss += l as f64;
        correct += c as f64;
        denom += match ds.kind {
            TaskKind::Classify => take as f64,
            TaskKind::LanguageModel => (take * ds.feat) as f64,
        };
        start += take;
    }
    Ok(((sum_loss / denom) as f32, (correct / denom) as f32))
}

/// Build the dataset a config asks for (public alias for the parallel
/// runtime).
pub fn build_dataset_pub(cfg: &ExperimentConfig, rng: &mut Rng) -> Result<Dataset> {
    build_dataset(cfg, rng)
}

/// Build the dataset a config asks for.
fn build_dataset(cfg: &ExperimentConfig, rng: &mut Rng) -> Result<Dataset> {
    let total = cfg.n_train + cfg.n_val + cfg.n_test;
    let seed = rng.next_u64();
    Ok(match &cfg.dataset {
        DatasetKind::SyntheticMnist => data::synthetic_mnist(total, seed),
        DatasetKind::SyntheticCifar => data::synthetic_cifar(total, seed),
        DatasetKind::SyntheticVectors { dim } => data::synthetic_vectors(total, *dim, 10, seed),
        DatasetKind::Corpus { seq } => data::synthetic_corpus(total, *seq, seed),
    })
}

/// A synthetic-engine config of arbitrary flat size — used by the
/// comm-cost harness and tests to exercise strategies at realistic
/// parameter counts without HLO artifacts.
pub fn synthetic_cfg(method: Method, workers: usize, dim: usize) -> ExperimentConfig {
    ExperimentConfig {
        label: format!("syn-{}", method.short_label()),
        method,
        workers,
        schedule: CommSchedule::Probability(0.25),
        engine: EngineKind::Synthetic { dim },
        dataset: DatasetKind::SyntheticVectors { dim: 8 },
        n_train: 64 * workers,
        n_val: 32,
        n_test: 32,
        effective_batch: 8 * workers,
        epochs: 1,
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

/// High-level entry: build the engine factory implied by the config and
/// run the experiment.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunReport> {
    run_experiment_verbose(cfg, false)
}

pub fn run_experiment_verbose(cfg: &ExperimentConfig, verbose: bool) -> Result<RunReport> {
    match &cfg.engine {
        EngineKind::Hlo { model } => {
            // Stacked (vmapped-over-workers) dispatch measured ~1.8x SLOWER
            // than per-worker dispatch on XLA:CPU (batched dot_general vs
            // separate dots — EXPERIMENTS.md §Perf), so it is opt-in.
            let stacked = std::env::var("EG_STACKED").map(|v| v == "1").unwrap_or(false);
            let spec = HloEngineSpec {
                artifact_dir: cfg.artifact_dir.clone(),
                model: model.clone(),
                train_batch: cfg.per_worker_batch(),
                workers: if stacked { cfg.workers } else { 1 },
            };
            let mut c = Coordinator::new(cfg, &spec);
            c.verbose = verbose;
            c.run()
        }
        EngineKind::Synthetic { .. } => {
            if !matches!(cfg.dataset, DatasetKind::SyntheticVectors { .. }) {
                bail!("synthetic engine requires dataset = SyntheticVectors");
            }
            let spec = SyntheticSpec::for_cfg(cfg)?;
            let mut c = Coordinator::new(cfg, &spec);
            c.verbose = verbose;
            c.run()
        }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
    use crate::optim::{LrSchedule, OptimKind};

    /// A small synthetic-engine experiment config for fast tests.
    pub fn tiny_cfg(method: Method, workers: usize) -> ExperimentConfig {
        ExperimentConfig {
            label: format!("test-{}", method.short_label()),
            method,
            workers,
            schedule: CommSchedule::Probability(0.5),
            optimizer: OptimKind::Nag { momentum: 0.9 },
            lr: LrSchedule::Const(0.05),
            engine: EngineKind::Synthetic { dim: 12 },
            dataset: DatasetKind::SyntheticVectors { dim: 6 },
            n_train: 256,
            n_val: 64,
            n_test: 64,
            effective_batch: 8 * workers,
            epochs: 4,
            seed: 42,
            partition: crate::data::Partition::Iid,
            topology: crate::topology::Topology::Full,
            eval_every: 1,
            artifact_dir: "artifacts".into(),
            codec: crate::comm::codec::CodecKind::Identity,
            churn: crate::membership::ChurnSpec::none(),
            faults: crate::membership::FaultSpec::none(),
            fd: crate::membership::FdSpec::none(),
            shards: 1,
            coalesce: false,
            transport: crate::comm::transport::TransportKind::InProc,
            trace: crate::trace::TraceSpec::off(),
        }
    }

    #[test]
    fn synthetic_run_all_methods() {
        for method in [
            Method::NoComm,
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
            Method::Easgd { alpha: 0.25 },
        ] {
            let cfg = tiny_cfg(method.clone(), 4);
            let report = run_experiment(&cfg).unwrap_or_else(|e| panic!("{method:?}: {e}"));
            assert_eq!(report.metrics.total_steps, cfg.total_steps());
            assert_eq!(report.metrics.curve.points.len(), cfg.epochs);
            // training should reduce loss on the quadratic task
            let first = report.metrics.curve.points.first().unwrap().train_loss;
            let last = report.metrics.curve.points.last().unwrap().train_loss;
            assert!(
                last < first,
                "{method:?}: loss did not decrease ({first} -> {last})"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.rank0_accuracy, b.rank0_accuracy);
        assert_eq!(a.metrics.comm_bytes, b.metrics.comm_bytes);
        let pa: Vec<f32> = a.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        let pb: Vec<f32> = b.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(pa, pb);
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = tiny_cfg(Method::GossipingSgdPull, 4);
        let a = run_experiment(&cfg).unwrap();
        cfg.seed = 43;
        let b = run_experiment(&cfg).unwrap();
        let pa: Vec<f32> = a.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        let pb: Vec<f32> = b.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        assert_ne!(pa, pb);
    }

    #[test]
    fn nocomm_has_zero_traffic_allreduce_has_lots() {
        let nc = run_experiment(&tiny_cfg(Method::NoComm, 4)).unwrap();
        assert_eq!(nc.metrics.comm_bytes, 0);
        let ar = run_experiment(&tiny_cfg(
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            4,
        ))
        .unwrap();
        assert!(ar.metrics.comm_bytes > 0);
        // ring all-reduce every step: 2(w-1) * n * 4 bytes per step
        let per_step = 2 * 3 * 12 * 4;
        assert_eq!(ar.metrics.comm_bytes, per_step * ar.metrics.total_steps);
        let eg = run_experiment(&tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4)).unwrap();
        assert!(
            eg.metrics.comm_bytes < ar.metrics.comm_bytes,
            "gossip must be cheaper than all-reduce"
        );
    }

    #[test]
    fn allreduce_keeps_replicas_identical() {
        let cfg = tiny_cfg(
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            4,
        );
        let spec = SyntheticSpec::for_cfg(&cfg).unwrap();
        let mut c = Coordinator::new(&cfg, &spec);
        c.on_step = Some(Box::new(|_step, params: &[Vec<f32>]| {
            for p in &params[1..] {
                for (a, b) in p.iter().zip(&params[0]) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "replicas diverged under all-reduce"
                    );
                }
            }
        }));
        let _ = c.run().unwrap();
    }

    #[test]
    fn period_schedule_fires_on_divisible_steps() {
        let mut rng = Rng::new(0);
        let m = Method::ElasticGossip { alpha: 0.5 };
        assert_eq!(decide_schedule(&m, CommSchedule::Period(4), 0, 3, &mut rng), vec![false; 3]);
        assert_eq!(decide_schedule(&m, CommSchedule::Period(4), 4, 3, &mut rng), vec![true; 3]);
        assert_eq!(decide_schedule(&m, CommSchedule::Period(4), 5, 3, &mut rng), vec![false; 3]);
    }

    #[test]
    fn probability_schedule_rate() {
        let mut rng = Rng::new(1);
        let m = Method::ElasticGossip { alpha: 0.5 };
        let mut fires = 0usize;
        for step in 0..2000 {
            fires += decide_schedule(&m, CommSchedule::Probability(0.125), step, 4, &mut rng)
                .iter()
                .filter(|&&x| x)
                .count();
        }
        let rate = fires as f64 / 8000.0;
        assert!((rate - 0.125).abs() < 0.02, "{rate}");
    }

    #[test]
    fn single_worker_runs() {
        let mut cfg = tiny_cfg(Method::NoComm, 1);
        cfg.label = "SGD-1-test".into();
        let r = run_experiment(&cfg).unwrap();
        assert_eq!(r.metrics.comm_bytes, 0);
        assert!(r.metrics.curve.points.len() == cfg.epochs);
    }

    #[test]
    fn sync_q8_codec_runs_and_shrinks_wire_bytes() {
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.codec = crate::comm::codec::CodecKind::Q8 { chunk: 1024 };
        let r = run_experiment(&cfg).unwrap();
        assert!(r.metrics.comm_bytes > 0);
        assert!(
            r.metrics.wire_bytes < r.metrics.comm_bytes / 2,
            "q8 wire {} not < half of raw {}",
            r.metrics.wire_bytes,
            r.metrics.comm_bytes
        );
        // lossy exchanges perturb but must not break training
        let first = r.metrics.curve.points.first().unwrap().train_loss;
        let last = r.metrics.curve.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not decrease under q8 ({first} -> {last})");
    }

    #[test]
    fn sync_q4_codec_runs_for_every_gossip_method() {
        for method in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
        ] {
            let mut cfg = tiny_cfg(method.clone(), 4);
            cfg.codec = crate::comm::codec::CodecKind::Q4 { chunk: 512 };
            let r = run_experiment(&cfg).unwrap_or_else(|e| panic!("{method:?}: {e}"));
            assert!(
                r.metrics.wire_bytes < r.metrics.comm_bytes / 4,
                "{method:?}: q4 wire {} not < quarter of raw {}",
                r.metrics.wire_bytes,
                r.metrics.comm_bytes
            );
        }
    }

    #[test]
    fn sync_identity_codec_is_trajectory_neutral_and_raw_priced() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let a = run_experiment(&cfg).unwrap();
        assert_eq!(a.metrics.wire_bytes, a.metrics.comm_bytes);
    }

    #[test]
    fn sync_rejects_lossy_codec_for_exact_methods_and_topk_everywhere() {
        for method in [
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            Method::Easgd { alpha: 0.25 },
            Method::NoComm,
        ] {
            let mut cfg = tiny_cfg(method, 4);
            cfg.codec = crate::comm::codec::CodecKind::Q8 { chunk: 1024 };
            assert!(run_experiment(&cfg).is_err(), "lossy codec accepted for exact method");
        }
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.codec = crate::comm::codec::CodecKind::TopK { frac: 0.1 };
        assert!(run_experiment(&cfg).is_err(), "overlay codec accepted in sync");
    }

    #[test]
    fn gossip_more_comm_at_higher_p() {
        let mut lo = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        lo.schedule = CommSchedule::Probability(0.05);
        let mut hi = lo.clone();
        hi.schedule = CommSchedule::Probability(0.8);
        let rl = run_experiment(&lo).unwrap();
        let rh = run_experiment(&hi).unwrap();
        assert!(rh.metrics.comm_bytes > rl.metrics.comm_bytes * 3);
    }
}
