//! Training-state checkpointing: save/restore a full distributed run.
//!
//! Format: a small JSON header (`checkpoint.json`) + one raw
//! little-endian f32 blob per worker (`worker_<i>.bin` holding params ++
//! velocity).  Deterministic RNG streams are reconstructed from
//! (seed, step), so a restored run continues bit-identically only if the
//! same config is supplied — the header records the config label + seed
//! + step and `restore` validates them.

use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;

use crate::manifest::json::{self, Json, JsonObj};

/// Snapshot of one run's mutable state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub label: String,
    pub seed: u64,
    pub step: u64,
    pub epoch: usize,
    pub flat_size: usize,
    /// per-worker parameters
    pub params: Vec<Vec<f32>>,
    /// per-worker velocity (empty vecs for SGD)
    pub velocity: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut o = JsonObj::new();
        o.insert("label", Json::Str(self.label.clone()));
        o.insert("seed", Json::Num(self.seed as f64));
        o.insert("step", Json::Num(self.step as f64));
        o.insert("epoch", Json::Num(self.epoch as f64));
        o.insert("flat_size", Json::Num(self.flat_size as f64));
        o.insert("workers", Json::Num(self.params.len() as f64));
        o.insert(
            "has_velocity",
            Json::Bool(self.velocity.iter().any(|v| !v.is_empty())),
        );
        std::fs::write(dir.join("checkpoint.json"), json::write(&Json::Obj(o)))?;
        for (i, (p, v)) in self.params.iter().zip(&self.velocity).enumerate() {
            ensure!(p.len() == self.flat_size, "worker {i}: bad param len");
            let mut bytes = Vec::with_capacity((p.len() + v.len()) * 4);
            for x in p.iter().chain(v.iter()) {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            std::fs::write(dir.join(format!("worker_{i}.bin")), bytes)?;
        }
        Ok(())
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let head = std::fs::read_to_string(dir.join("checkpoint.json"))
            .with_context(|| format!("reading {}/checkpoint.json", dir.display()))?;
        let h = json::parse(&head).map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let flat_size = h.path(&["flat_size"]).as_usize().ok_or_else(|| anyhow!("no flat_size"))?;
        let workers = h.path(&["workers"]).as_usize().ok_or_else(|| anyhow!("no workers"))?;
        let has_v = matches!(h.path(&["has_velocity"]), Json::Bool(true));
        let mut params = Vec::with_capacity(workers);
        let mut velocity = Vec::with_capacity(workers);
        for i in 0..workers {
            let bytes = std::fs::read(dir.join(format!("worker_{i}.bin")))?;
            let expect = if has_v { 2 * flat_size * 4 } else { flat_size * 4 };
            ensure!(bytes.len() == expect, "worker {i}: {} bytes != {expect}", bytes.len());
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(vals[..flat_size].to_vec());
            velocity.push(if has_v { vals[flat_size..].to_vec() } else { Vec::new() });
        }
        Ok(Checkpoint {
            label: h.path(&["label"]).as_str().unwrap_or("").to_string(),
            seed: h.path(&["seed"]).as_i64().unwrap_or(0) as u64,
            step: h.path(&["step"]).as_i64().unwrap_or(0) as u64,
            epoch: h.path(&["epoch"]).as_usize().unwrap_or(0),
            flat_size,
            params,
            velocity,
        })
    }

    /// Validate that a checkpoint belongs to `label`/`seed` before resuming.
    pub fn validate(&self, label: &str, seed: u64, flat_size: usize) -> Result<()> {
        ensure!(self.label == label, "checkpoint is for {:?}, not {label:?}", self.label);
        ensure!(self.seed == seed, "checkpoint seed {} != {seed}", self.seed);
        ensure!(self.flat_size == flat_size, "flat size mismatch");
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// async (per-node) checkpoints
// ---------------------------------------------------------------------------

/// One node's restorable state in the event-driven runtime, captured at
/// its own epoch boundary.  Unlike the synchronous [`Checkpoint`], nodes
/// progress independently — each carries its *own* step/epoch — and a
/// slot may be absent (a node that departed before its first boundary,
/// or a join slot that never activated).
///
/// This is both the on-disk format (via [`AsyncCheckpoint`]) and the
/// in-memory mirror the membership subsystem restores crash-recovery
/// rejoins from: a `rejoin@T:N` event copies params + velocity back,
/// resumes at the checkpointed step, and loses exactly the progress
/// since the last boundary — real checkpoint semantics, not a magic
/// crash-instant snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncNodeState {
    pub step: u64,
    pub epoch: usize,
    pub params: Vec<f32>,
    pub velocity: Vec<f32>,
}

impl AsyncNodeState {
    /// Refill this snapshot in place (buffer capacity persists across
    /// epoch boundaries — the churn-mode checkpoint mirror allocates
    /// only on a node's first boundary).
    pub fn refill(&mut self, step: u64, epoch: usize, params: &[f32], velocity: &[f32]) {
        self.step = step;
        self.epoch = epoch;
        self.params.clear();
        self.params.extend_from_slice(params);
        self.velocity.clear();
        self.velocity.extend_from_slice(velocity);
    }
}

/// Full-cluster async checkpoint: one optional [`AsyncNodeState`] per
/// node slot.  Format mirrors the synchronous one: a JSON header
/// (`async_checkpoint.json`) + one `node_<i>.bin` blob per present slot
/// (params ++ velocity, raw LE f32).
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncCheckpoint {
    pub label: String,
    pub seed: u64,
    pub flat_size: usize,
    pub nodes: Vec<Option<AsyncNodeState>>,
}

impl AsyncCheckpoint {
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut o = JsonObj::new();
        o.insert("label", Json::Str(self.label.clone()));
        o.insert("seed", Json::Num(self.seed as f64));
        o.insert("flat_size", Json::Num(self.flat_size as f64));
        o.insert("slots", Json::Num(self.nodes.len() as f64));
        o.insert(
            "nodes",
            Json::Arr(
                self.nodes
                    .iter()
                    .map(|n| match n {
                        None => Json::Null,
                        Some(s) => {
                            let mut no = JsonObj::new();
                            no.insert("step", Json::Num(s.step as f64));
                            no.insert("epoch", Json::Num(s.epoch as f64));
                            no.insert("velocity_len", Json::Num(s.velocity.len() as f64));
                            Json::Obj(no)
                        }
                    })
                    .collect(),
            ),
        );
        std::fs::write(dir.join("async_checkpoint.json"), json::write(&Json::Obj(o)))?;
        for (i, slot) in self.nodes.iter().enumerate() {
            let Some(s) = slot else { continue };
            ensure!(s.params.len() == self.flat_size, "node {i}: bad param len");
            let mut bytes = Vec::with_capacity((s.params.len() + s.velocity.len()) * 4);
            for x in s.params.iter().chain(s.velocity.iter()) {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            std::fs::write(dir.join(format!("node_{i}.bin")), bytes)?;
        }
        Ok(())
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<AsyncCheckpoint> {
        let dir = dir.as_ref();
        let head = std::fs::read_to_string(dir.join("async_checkpoint.json"))
            .with_context(|| format!("reading {}/async_checkpoint.json", dir.display()))?;
        let h = json::parse(&head).map_err(|e| anyhow!("async checkpoint header: {e}"))?;
        let flat_size = h.path(&["flat_size"]).as_usize().ok_or_else(|| anyhow!("no flat_size"))?;
        let slots = h.path(&["slots"]).as_usize().ok_or_else(|| anyhow!("no slots"))?;
        let heads = h
            .path(&["nodes"])
            .as_arr()
            .ok_or_else(|| anyhow!("no nodes array"))?;
        ensure!(heads.len() == slots, "header claims {slots} slots, lists {}", heads.len());
        let mut nodes = Vec::with_capacity(slots);
        for (i, nh) in heads.iter().enumerate() {
            if matches!(nh, Json::Null) {
                nodes.push(None);
                continue;
            }
            let step = nh.path(&["step"]).as_i64().ok_or_else(|| anyhow!("node {i}: no step"))? as u64;
            let epoch = nh.path(&["epoch"]).as_usize().ok_or_else(|| anyhow!("node {i}: no epoch"))?;
            let vlen = nh
                .path(&["velocity_len"])
                .as_usize()
                .ok_or_else(|| anyhow!("node {i}: no velocity_len"))?;
            let bytes = std::fs::read(dir.join(format!("node_{i}.bin")))?;
            let expect = (flat_size + vlen) * 4;
            ensure!(bytes.len() == expect, "node {i}: {} bytes != {expect}", bytes.len());
            let vals: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            nodes.push(Some(AsyncNodeState {
                step,
                epoch,
                params: vals[..flat_size].to_vec(),
                velocity: vals[flat_size..].to_vec(),
            }));
        }
        Ok(AsyncCheckpoint {
            label: h.path(&["label"]).as_str().unwrap_or("").to_string(),
            seed: h.path(&["seed"]).as_i64().unwrap_or(0) as u64,
            flat_size,
            nodes,
        })
    }

    /// Validate provenance before restoring into a run.
    pub fn validate(&self, label: &str, seed: u64, flat_size: usize) -> Result<()> {
        ensure!(self.label == label, "checkpoint is for {:?}, not {label:?}", self.label);
        ensure!(self.seed == seed, "checkpoint seed {} != {seed}", self.seed);
        ensure!(self.flat_size == flat_size, "flat size mismatch");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            label: "EG-4-0.031".into(),
            seed: 7,
            step: 1234,
            epoch: 3,
            flat_size: 5,
            params: vec![vec![1.0, 2.0, 3.0, 4.0, 5.0], vec![-1.0, 0.5, 0.0, 9.0, 2.5]],
            velocity: vec![vec![0.1; 5], vec![0.2; 5]],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("eg-ckpt-{}", std::process::id()));
        let c = sample();
        c.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn sgd_checkpoint_without_velocity() {
        let dir = std::env::temp_dir().join(format!("eg-ckpt-sgd-{}", std::process::id()));
        let mut c = sample();
        c.velocity = vec![Vec::new(), Vec::new()];
        c.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.velocity, vec![Vec::<f32>::new(), Vec::new()]);
        assert_eq!(back.params, c.params);
    }

    #[test]
    fn validate_catches_mismatches() {
        let c = sample();
        assert!(c.validate("EG-4-0.031", 7, 5).is_ok());
        assert!(c.validate("GS-4-0.031", 7, 5).is_err());
        assert!(c.validate("EG-4-0.031", 8, 5).is_err());
        assert!(c.validate("EG-4-0.031", 7, 6).is_err());
    }

    fn async_sample() -> AsyncCheckpoint {
        AsyncCheckpoint {
            label: "churn-EG".into(),
            seed: 11,
            flat_size: 4,
            nodes: vec![
                Some(AsyncNodeState {
                    step: 120,
                    epoch: 3,
                    params: vec![1.0, -2.0, 0.5, 9.0],
                    velocity: vec![0.1, 0.2, 0.3, 0.4],
                }),
                None, // crashed before its first boundary
                Some(AsyncNodeState {
                    step: 80,
                    epoch: 2,
                    params: vec![0.0, 0.0, 1.0, -1.0],
                    velocity: Vec::new(), // SGD node: no velocity
                }),
            ],
        }
    }

    #[test]
    fn async_save_load_roundtrip_with_absent_slots() {
        let dir = std::env::temp_dir().join(format!("eg-ackpt-{}", std::process::id()));
        let c = async_sample();
        c.save(&dir).unwrap();
        let back = AsyncCheckpoint::load(&dir).unwrap();
        assert_eq!(back, c);
        assert!(back.nodes[1].is_none());
        assert_eq!(back.nodes[0].as_ref().unwrap().step, 120);
        assert_eq!(back.nodes[2].as_ref().unwrap().velocity, Vec::<f32>::new());
    }

    #[test]
    fn async_validate_and_refill() {
        let c = async_sample();
        assert!(c.validate("churn-EG", 11, 4).is_ok());
        assert!(c.validate("churn-EG", 12, 4).is_err());
        assert!(c.validate("other", 11, 4).is_err());
        let mut s = c.nodes[0].clone().unwrap();
        let (pp, pv) = (s.params.as_ptr(), s.velocity.as_ptr());
        s.refill(121, 3, &[5.0, 6.0, 7.0, 8.0], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(s.step, 121);
        assert_eq!(s.params, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!((s.params.as_ptr(), s.velocity.as_ptr()), (pp, pv), "refill must reuse capacity");
    }

    #[test]
    fn load_rejects_truncated_blob() {
        let dir = std::env::temp_dir().join(format!("eg-ckpt-bad-{}", std::process::id()));
        let c = sample();
        c.save(&dir).unwrap();
        let path = dir.join("worker_0.bin");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }
}
