//! Threaded runtime mode: one OS thread per worker, each owning its own
//! gradient engine (PJRT clients are not `Send`, so engines are built
//! inside their threads via `EngineFactory`), synchronized by barriers
//! exactly like a barriered cluster.
//!
//! Round structure per step (mirrors `Coordinator::run`):
//!
//! ```text
//!   workers: compute grads into own slot; scheduled-to-
//!            communicate workers pre-snapshot their slot
//!            into the shared arena (sharded snapshot copy) [barrier A]
//!   leader:  Strategy::plan_round (matchmaking, snapshots
//!            of the remaining participants, traffic)       [barrier B]
//!   workers: Strategy::apply_slot on own slot (sharded
//!            comm apply) + optimizer velocity/apply        [barrier C]
//! ```
//!
//! Communication masks are pre-drawn for every step (same "schedule"
//! stream, same order) so each worker knows during its compute phase
//! whether this step's round will want its snapshot; workers with the
//! mask bit set copy their own slot into the arena concurrently, and
//! the leader's `snapshot_participants` then only fills rows for
//! reverse-edge participants it could not predict.  The copied bytes
//! are identical either way, so trajectories are unchanged.
//!
//! Two things changed from the seed runtime.  First, the leader no
//! longer clones every worker's parameter and gradient buffers each
//! round: all slots live in a [`SlotStore`] that both sides access
//! directly, with exclusivity enforced by the barrier phases (see the
//! safety comment on `SlotStore`).  Second, the communication round
//! itself is sharded: the leader only *plans* (picks, K-sets, snapshots
//! of edge participants, byte accounting), and each worker thread
//! applies its own slot's update from the shared scratch arena — the
//! per-slot updates of every gossip strategy touch only that slot and
//! read only pre-round snapshots, so running them on W threads is
//! *bit-identical* to the sequential coordinator for the same config.
//! The equivalence test below is the strongest correctness statement we
//! can make about this runtime (per the thesis's own reproducibility
//! argument for studying synchronous variants).

use anyhow::{Context, Result};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex};

use super::{decide_schedule_into, evaluate};
use crate::algos::{CommCtx, ScratchArena, Strategy};
use crate::comm::{Fabric, LinkModel};
use crate::config::ExperimentConfig;
use crate::data::{self, BatchCursor, TaskKind};
use crate::metrics::{Curve, EvalPoint, RunMetrics};
use crate::optim::Optimizer;
use crate::runtime::{BatchXOwned, EngineFactory};
use crate::trace::{Ev, Kind, Trace};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// Per-worker flat buffers shared between the leader and worker threads
/// without locks or per-round cloning.
///
/// # Safety model
///
/// Exclusivity is a *protocol* property enforced by the step barriers,
/// not by the type system:
///
/// * between barriers C (step t-1) and A (step t), worker `i` reads
///   slot `i` of `params` and exclusively writes slot `i` of `grads`;
///   the leader only reads `params` (epoch-boundary evaluation), which
///   no one writes in this phase;
/// * between A and B the leader has exclusive access to every slot
///   (plan phase / non-sharded rounds such as All-reduce);
/// * between B and C worker `i` has exclusive access to slot `i`
///   (sharded comm apply + optimizer update) and the leader touches no
///   slot.
///
/// `std::sync::Barrier::wait` provides the happens-before edge at every
/// phase boundary, so no access races with a write.
struct SlotStore {
    slots: Vec<UnsafeCell<Vec<f32>>>,
}

// SAFETY: see the phase discipline above — all concurrent access is
// either read-only or partitioned by slot index.
unsafe impl Sync for SlotStore {}

impl SlotStore {
    fn new(w: usize, init: impl Fn() -> Vec<f32>) -> Self {
        SlotStore {
            slots: (0..w).map(|_| UnsafeCell::new(init())).collect(),
        }
    }

    /// Read one slot. Caller must hold phase read ownership.
    unsafe fn slot(&self, i: usize) -> &Vec<f32> {
        &*self.slots[i].get()
    }

    /// Exclusive access to one slot. Caller must hold phase ownership.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slot_mut(&self, i: usize) -> &mut Vec<f32> {
        &mut *self.slots[i].get()
    }

    /// All slots as one shared slice (no concurrent writers).
    unsafe fn as_slice(&self) -> &[Vec<f32>] {
        // SAFETY of the cast: UnsafeCell<T> is repr(transparent) over T
        std::slice::from_raw_parts(self.slots.as_ptr() as *const Vec<f32>, self.slots.len())
    }

    /// All slots as one mutable slice (leader-exclusive phase only).
    #[allow(clippy::mut_from_ref)]
    unsafe fn as_mut_slice(&self) -> &mut [Vec<f32>] {
        std::slice::from_raw_parts_mut(self.slots.as_ptr() as *mut Vec<f32>, self.slots.len())
    }
}

/// Leader-written, worker-read round state: the strategy (plan output is
/// strategy-internal state, e.g. GoSGD messages) and the scratch arena
/// (snapshots + edge plan).  Same phase discipline as [`SlotStore`]:
/// leader takes `&mut` between A and B, workers take `&` between B and C.
struct CommShared {
    strategy: Box<dyn Strategy>,
    arena: ScratchArena,
}

struct CommCell(UnsafeCell<CommShared>);

// SAFETY: barrier-phase discipline, see above.
unsafe impl Sync for CommCell {}

/// Run one experiment with worker threads. Returns the same `RunReport`
/// as the sequential coordinator (and, for the same config, the same
/// numbers).
pub fn run_parallel(cfg: &ExperimentConfig, factory: &dyn EngineFactory) -> Result<super::RunReport> {
    let w = cfg.workers;
    anyhow::ensure!(w >= 1);
    // same codec admission rule as the sequential coordinator: identity
    // everywhere, lossy quantizers on the gossip snapshot plane only,
    // overlay codecs never (no per-receiver stream in a shared-snapshot
    // round)
    match cfg.codec {
        crate::comm::codec::CodecKind::Identity => {}
        crate::comm::codec::CodecKind::TopK { .. } => anyhow::bail!(
            "wire codec {:?} is an overlay codec and applies to the \
             event-driven async runtime (`repro async-train --codec ...`)",
            cfg.codec.label()
        ),
        _ => anyhow::ensure!(
            cfg.method.is_pairwise_gossip(),
            "lossy wire codec {:?} requires a pairwise gossip method in \
             the threaded synchronous runtime; {:?} exchanges must stay exact",
            cfg.codec.label(),
            cfg.method
        ),
    }
    anyhow::ensure!(
        cfg.churn.is_empty(),
        "churn schedule {:?} applies to the event-driven async runtime; the \
         threaded barriered runtime has a fixed roster by construction",
        cfg.churn.label()
    );
    anyhow::ensure!(
        cfg.faults.is_empty() && cfg.fd.is_empty(),
        "link faults / failure detection ({:?} / {:?}) apply to the \
         event-driven async runtime; the threaded barriered runtime has \
         perfect links and oracle membership by construction",
        cfg.faults.label(),
        cfg.fd.label()
    );
    let root_rng = Rng::new(cfg.seed);

    // data (leader side)
    let full = super::build_dataset_pub(cfg, &mut root_rng.stream("datagen"))?;
    let (train, val, test) = full.split(
        cfg.n_train.min(full.len()),
        cfg.n_val,
        cfg.n_test,
        &mut root_rng.stream("split"),
    );
    let shards = cfg.partition.assign(&train, w, &mut root_rng.stream("partition"));

    // leader engine for init + eval
    let mut leader_engine = factory.build().context("leader engine")?;
    let flat = leader_engine.flat_size();
    let b = leader_engine.train_batch();
    anyhow::ensure!(b == cfg.per_worker_batch(), "engine batch mismatch");
    let init = leader_engine.initial_params()?;

    // shared per-worker slots — no per-round cloning (see SlotStore)
    let params = SlotStore::new(w, || init.clone());
    let grads = SlotStore::new(w, || vec![0.0; flat]);
    let losses: Vec<Mutex<f32>> = (0..w).map(|_| Mutex::new(0.0)).collect();

    let steps_per_epoch = cfg.steps_per_epoch();
    let total_steps = cfg.total_steps();

    // pre-draw the per-(step, worker) dropout seeds in sequential order so
    // the parallel run consumes the stream identically to the sequential
    // coordinator
    let mut seed_rng = root_rng.stream("dropout");
    let seeds: Vec<Vec<i32>> = (0..total_steps)
        .map(|_| (0..w).map(|_| seed_rng.next_u64() as i32).collect())
        .collect();

    // pre-draw every step's communication mask ("schedule" stream, step
    // order — identical consumption to drawing in the loop) so worker
    // threads can pre-snapshot their own slot during the compute phase
    // instead of the leader copying W rows serially in the plan phase
    let mut sched_rng = root_rng.stream("schedule");
    let mut mask_row: Vec<bool> = Vec::with_capacity(w);
    let mut masks: Vec<bool> = Vec::with_capacity(total_steps as usize * w);
    for t in 0..total_steps {
        decide_schedule_into(&cfg.method, cfg.schedule, t, w, &mut sched_rng, &mut mask_row);
        masks.extend_from_slice(&mask_row);
    }
    // pre-snapshotting pays off only for the strategies that read the
    // snapshot plane (the pairwise gossip family); a worker whose mask
    // bit is set this step is always an edge endpoint, so its row is
    // always wanted — reverse-only endpoints are filled by the leader
    let presnap = cfg.method.is_pairwise_gossip();

    let barrier = Barrier::new(w + 1); // workers + leader
    let stop = AtomicBool::new(false);
    // leader -> workers: this round's application is sharded
    let sharded = AtomicBool::new(false);

    let comm = CommCell(UnsafeCell::new(CommShared {
        strategy: cfg.method.build(w, flat),
        // pre-sized when workers pre-snapshot into it from their compute
        // phase; otherwise sized lazily by the first gossip round's
        // begin_round (EASGD/All-reduce never pay for the snapshot plane)
        arena: {
            let mut a = ScratchArena::new();
            if presnap {
                a.ensure(w, flat);
            }
            a
        },
    }));
    let mut fabric = Fabric::new(w + 1, LinkModel::default());
    // leader-side wire codec (see `Coordinator::run`): `None` for
    // identity; otherwise published snapshots are encode/decode-d after
    // the plan phase and parameter sends are priced at the encoded size
    let mut codec: Option<Box<dyn crate::comm::codec::Codec>> = match cfg.codec {
        crate::comm::codec::CodecKind::Identity => None,
        _ => Some(cfg.codec.build()),
    };
    if let Some(c) = codec.as_ref() {
        fabric.set_param_wire(flat, c.encoded_len(flat) as u64);
    }
    let mut gossip_rng = root_rng.stream("gossip");

    let mut curve = Curve::new(cfg.label.clone());
    // leader-side timeline, keyed by the step index exactly like the
    // sequential coordinator's (workers never touch the recorder, so no
    // cross-thread ordering can leak into the ring)
    let mut trace = Trace::from_spec(&cfg.trace, &cfg.label);
    let watch = Stopwatch::start();
    let mut eval_time = 0.0f64;
    let epoch_losses: Mutex<Vec<f64>> = Mutex::new(vec![0.0; cfg.epochs]);

    std::thread::scope(|scope| -> Result<()> {
        // ---- worker threads ------------------------------------------------
        for (i, shard) in shards.into_iter().enumerate() {
            let params = &params;
            let grads = &grads;
            let losses = &losses;
            let barrier = &barrier;
            let stop = &stop;
            let sharded = &sharded;
            let comm = &comm;
            let seeds = &seeds;
            let masks = &masks;
            let train = &train;
            let cursor_rng = root_rng.stream(&format!("batches{i}"));
            let factory_ref = factory;
            let cfg_ref = cfg;
            scope.spawn(move || -> Result<()> {
                let mut engine = factory_ref.build().context("worker engine")?;
                let mut cursor = BatchCursor::new(shard, cursor_rng);
                let mut optim = Optimizer::new(cfg_ref.optimizer, cfg_ref.lr.clone(), flat);
                let mut batch_idx = Vec::new();
                let mut xbuf = BatchXOwned::F32(Vec::new());
                let mut ybuf: Vec<i32> = Vec::new();
                let mut step: u64 = 0;
                for epoch in 0..cfg_ref.epochs {
                    optim.start_epoch(epoch);
                    for _ in 0..steps_per_epoch {
                        if stop.load(Ordering::Relaxed) {
                            return Ok(());
                        }
                        cursor.next_batch(b, &mut batch_idx);
                        match train.kind {
                            TaskKind::Classify => {
                                data::gather_f32(train, &batch_idx, xbuf.clear_f32(), &mut ybuf)
                            }
                            TaskKind::LanguageModel => {
                                data::gather_i32(train, &batch_idx, xbuf.clear_i32(), &mut ybuf)
                            }
                        }
                        {
                            // phase C..A: worker i owns grads[i], reads params[i]
                            let p = unsafe { params.slot(i) };
                            let g = unsafe { grads.slot_mut(i) };
                            let loss = engine.loss_and_grad(
                                p,
                                xbuf.as_ref(),
                                &ybuf,
                                seeds[step as usize][i],
                                g,
                            )?;
                            *losses[i].lock().unwrap() = loss;
                            if presnap && masks[step as usize * w + i] {
                                // sharded snapshot copy: our slot's
                                // pre-round bytes go into the arena now,
                                // in parallel across workers, instead of
                                // serially in the leader's plan phase.
                                // SAFETY: phase C..A — row i has no other
                                // writer or reader; the valid bit is
                                // declared by the leader via set_presnap
                                let sc = unsafe { &*comm.0.get() };
                                unsafe { sc.arena.presnapshot_row(i, p) };
                            }
                        }
                        barrier.wait(); // A: grads ready
                        barrier.wait(); // B: leader planned (or ran) the round
                        {
                            // phase B..C: worker i owns params[i] + grads[i]
                            if sharded.load(Ordering::Relaxed) {
                                // sharded comm apply: own slot only, from
                                // the leader's plan + snapshot arena
                                let sc = unsafe { &*comm.0.get() };
                                let p = unsafe { params.slot_mut(i) };
                                sc.strategy.apply_slot(i, p, &sc.arena);
                            }
                            let p = unsafe { params.slot_mut(i) };
                            let g = unsafe { grads.slot(i) };
                            optim.update_velocity(g);
                            optim.apply(p, g);
                        }
                        barrier.wait(); // C: step complete
                        step += 1;
                    }
                }
                Ok(())
            });
        }

        // ---- leader --------------------------------------------------------
        let mut step: u64 = 0;
        for epoch in 0..cfg.epochs {
            let mut epoch_loss = 0.0f64;
            for _ in 0..steps_per_epoch {
                barrier.wait(); // A
                // phase A..B: leader owns every slot — plan the round
                {
                    epoch_loss += losses
                        .iter()
                        .map(|m| *m.lock().unwrap() as f64)
                        .sum::<f64>();
                    let communicating = &masks[step as usize * w..(step as usize + 1) * w];
                    let sc = unsafe { &mut *comm.0.get() };
                    let CommShared { strategy, arena } = sc;
                    if presnap {
                        // declare the rows the workers just wrote; the
                        // strategy's begin_round keeps exactly those valid
                        arena.set_presnap(communicating);
                    }
                    let mut ctx = CommCtx {
                        params: unsafe { params.as_mut_slice() },
                        grads: unsafe { grads.as_mut_slice() },
                        fabric: &mut fabric,
                        topology: &cfg.topology,
                        step,
                        communicating,
                        arena: &mut *arena,
                    };
                    let is_sharded = strategy.plan_round(&mut ctx, &mut gossip_rng)?;
                    fabric.end_round();
                    if trace.is_on() {
                        let n_comm = communicating.iter().filter(|&&c| c).count() as u64;
                        trace.span(
                            step as f64,
                            (step + 1) as f64,
                            Ev { node: 0, kind: Kind::Round, class: 0, seq: step, a: n_comm, b: 0 },
                        );
                    }
                    if is_sharded {
                        if let Some(c) = codec.as_mut() {
                            // publish quantized snapshots before the
                            // workers' sharded apply reads them —
                            // identical rows to the sequential
                            // coordinator's roundtrip
                            arena.codec_roundtrip_snapshots(c.as_mut())?;
                        }
                    }
                    sharded.store(is_sharded, Ordering::Relaxed);
                }
                barrier.wait(); // B
                barrier.wait(); // C
                step += 1;
            }
            epoch_losses.lock().unwrap()[epoch] = epoch_loss;

            // evaluation at the epoch boundary (workers are either parked
            // at barrier A or in their grad phase, where params are only
            // read — safe to read params between steps)
            if (epoch + 1) % cfg.eval_every == 0 || epoch + 1 == cfg.epochs {
                let ew = Stopwatch::start();
                let mut worker_acc = Vec::with_capacity(w);
                let mut worker_loss = Vec::with_capacity(w);
                for i in 0..w {
                    let p = unsafe { params.slot(i) };
                    let (l, a) = evaluate(leader_engine.as_mut(), p, &val)?;
                    worker_acc.push(a);
                    worker_loss.push(l);
                }
                let avg = super::average_params(unsafe { params.as_slice() });
                let (_, agg) = evaluate(leader_engine.as_mut(), &avg, &val)?;
                eval_time += ew.elapsed_s();
                trace.instant(
                    step as f64,
                    Ev {
                        node: 0,
                        kind: Kind::Eval,
                        class: 0,
                        seq: epoch as u64,
                        a: epoch as u64,
                        b: w as u64,
                    },
                );
                curve.push(EvalPoint {
                    epoch: epoch + 1,
                    step,
                    alive: w,
                    worker_acc,
                    worker_loss,
                    train_loss: (epoch_loss / (steps_per_epoch as f64 * w as f64)) as f32,
                    aggregate_acc: agg,
                    wall_s: watch.elapsed_s(),
                });
            }
        }
        Ok(())
    })?;

    // threads joined: exclusive access again
    trace
        .dump_if_requested()
        .context("writing flight-recorder dump")?;
    let (_, rank0) = evaluate(leader_engine.as_mut(), unsafe { params.slot(0) }, &test)?;
    let avg = super::average_params(unsafe { params.as_slice() });
    let (_, agg) = evaluate(leader_engine.as_mut(), &avg, &test)?;
    let report = fabric.report();
    Ok(super::RunReport {
        label: cfg.label.clone(),
        rank0_accuracy: rank0,
        aggregate_accuracy: agg,
        metrics: RunMetrics::from_traffic(
            curve,
            (rank0, agg),
            cfg.total_steps(),
            &report,
            watch.elapsed_s() - eval_time,
            eval_time,
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Method;
    use crate::coordinator::tests::tiny_cfg;
    use crate::coordinator::run_experiment;
    use crate::runtime::SyntheticSpec;

    fn spec(cfg: &ExperimentConfig) -> SyntheticSpec {
        SyntheticSpec::for_cfg(cfg).unwrap()
    }

    #[test]
    fn parallel_equals_sequential_elastic_gossip() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let seq = run_experiment(&cfg).unwrap();
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.rank0_accuracy, seq.rank0_accuracy);
        assert_eq!(par.aggregate_accuracy, seq.aggregate_accuracy);
        assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);
        let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        let lp: Vec<f32> = par.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(ls, lp, "parallel run diverged from sequential");
    }

    #[test]
    fn parallel_equals_sequential_allreduce() {
        let cfg = tiny_cfg(
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            3,
        );
        let seq = run_experiment(&cfg).unwrap();
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.rank0_accuracy, seq.rank0_accuracy);
        assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);
    }

    #[test]
    fn parallel_equals_sequential_all_sharded_methods() {
        // every strategy with a sharded apply phase must stay bit-identical
        // to the sequential coordinator
        for method in [
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
            Method::Easgd { alpha: 0.2 },
        ] {
            let cfg = tiny_cfg(method.clone(), 4);
            let seq = run_experiment(&cfg).unwrap();
            let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
            assert_eq!(
                par.rank0_accuracy, seq.rank0_accuracy,
                "{method:?} diverged (rank0)"
            );
            assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes, "{method:?} bytes");
            let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            let lp: Vec<f32> = par.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, lp, "{method:?} diverged (loss curve)");
        }
    }

    #[test]
    fn parallel_equals_sequential_under_lossy_codec() {
        // the codec roundtrip publishes the same quantized rows in both
        // runtimes, so lossy trajectories must stay bit-identical too
        for kind in [
            crate::comm::codec::CodecKind::Q8 { chunk: 256 },
            crate::comm::codec::CodecKind::Q4 { chunk: 256 },
        ] {
            let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
            cfg.codec = kind.clone();
            let seq = run_experiment(&cfg).unwrap();
            let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
            assert_eq!(par.rank0_accuracy, seq.rank0_accuracy, "{kind:?}");
            assert_eq!(par.metrics.wire_bytes, seq.metrics.wire_bytes, "{kind:?}");
            let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            let lp: Vec<f32> = par.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, lp, "{kind:?} diverged under codec");
        }
    }

    #[test]
    fn parallel_single_worker() {
        let cfg = tiny_cfg(Method::NoComm, 1);
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.metrics.comm_bytes, 0);
        assert_eq!(par.metrics.curve.points.len(), cfg.epochs);
    }
}
