//! Threaded runtime mode: one OS thread per worker, each owning its own
//! gradient engine (PJRT clients are not `Send`, so engines are built
//! inside their threads via `EngineFactory`), synchronized by barriers
//! exactly like a barriered cluster.
//!
//! Round structure per step (mirrors `Coordinator::run`):
//!
//! ```text
//!   workers: lock own params -> compute grads -> update velocity? no:
//!            grads only                                   [barrier A]
//!   leader:  schedule + comm round over all param slots   [barrier B]
//!   workers: optimizer velocity update + apply            [barrier C]
//! ```
//!
//! Because the algorithms are synchronous, the parallel schedule is
//! *bit-identical* to the sequential coordinator for the same config —
//! the equivalence test below is the strongest correctness statement we
//! can make about this runtime (per the thesis's own reproducibility
//! argument for studying synchronous variants).

use anyhow::{Context, Result};
use std::sync::{Barrier, Mutex};

use super::{decide_schedule_pub as decide_schedule, evaluate};
use crate::algos::{CommCtx, Strategy};
use crate::comm::{Fabric, LinkModel};
use crate::config::ExperimentConfig;
use crate::data::{self, BatchCursor, TaskKind};
use crate::metrics::{Curve, EvalPoint, RunMetrics};
use crate::optim::Optimizer;
use crate::runtime::{BatchXOwned, EngineFactory};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// Run one experiment with worker threads. Returns the same `RunReport`
/// as the sequential coordinator (and, for the same config, the same
/// numbers).
pub fn run_parallel(cfg: &ExperimentConfig, factory: &dyn EngineFactory) -> Result<super::RunReport> {
    let w = cfg.workers;
    anyhow::ensure!(w >= 1);
    let root_rng = Rng::new(cfg.seed);

    // data (leader side)
    let full = super::build_dataset_pub(cfg, &mut root_rng.stream("datagen"))?;
    let (train, val, test) = full.split(
        cfg.n_train.min(full.len()),
        cfg.n_val,
        cfg.n_test,
        &mut root_rng.stream("split"),
    );
    let shards = cfg.partition.assign(&train, w, &mut root_rng.stream("partition"));

    // leader engine for init + eval
    let mut leader_engine = factory.build().context("leader engine")?;
    let flat = leader_engine.flat_size();
    let b = leader_engine.train_batch();
    anyhow::ensure!(b == cfg.per_worker_batch(), "engine batch mismatch");
    let init = leader_engine.initial_params()?;

    // shared state: one mutex per worker slot (threads lock their own;
    // the leader locks all during the comm round)
    let params: Vec<Mutex<Vec<f32>>> = (0..w).map(|_| Mutex::new(init.clone())).collect();
    let grads: Vec<Mutex<Vec<f32>>> = (0..w).map(|_| Mutex::new(vec![0.0; flat])).collect();
    let losses: Vec<Mutex<f32>> = (0..w).map(|_| Mutex::new(0.0)).collect();

    let steps_per_epoch = cfg.steps_per_epoch();
    let total_steps = cfg.total_steps();

    // pre-draw the per-(step, worker) dropout seeds in sequential order so
    // the parallel run consumes the stream identically to the sequential
    // coordinator
    let mut seed_rng = root_rng.stream("dropout");
    let seeds: Vec<Vec<i32>> = (0..total_steps)
        .map(|_| (0..w).map(|_| seed_rng.next_u64() as i32).collect())
        .collect();

    let barrier = Barrier::new(w + 1); // workers + leader
    let stop = std::sync::atomic::AtomicBool::new(false);

    let mut strategy: Box<dyn Strategy> = cfg.method.build(w, flat);
    let mut fabric = Fabric::new(w + 1, LinkModel::default());
    let mut sched_rng = root_rng.stream("schedule");
    let mut gossip_rng = root_rng.stream("gossip");

    let mut curve = Curve::new(cfg.label.clone());
    let watch = Stopwatch::start();
    let mut eval_time = 0.0f64;
    let epoch_losses: Mutex<Vec<f64>> = Mutex::new(vec![0.0; cfg.epochs]);

    std::thread::scope(|scope| -> Result<()> {
        // ---- worker threads ------------------------------------------------
        for (i, shard) in shards.into_iter().enumerate() {
            let params = &params;
            let grads = &grads;
            let losses = &losses;
            let barrier = &barrier;
            let stop = &stop;
            let seeds = &seeds;
            let train = &train;
            let cursor_rng = root_rng.stream(&format!("batches{i}"));
            let factory_ref = factory;
            let cfg_ref = cfg;
            scope.spawn(move || -> Result<()> {
                let mut engine = factory_ref.build().context("worker engine")?;
                let mut cursor = BatchCursor::new(shard, cursor_rng);
                let mut optim = Optimizer::new(cfg_ref.optimizer, cfg_ref.lr.clone(), flat);
                let mut batch_idx = Vec::new();
                let mut xbuf = BatchXOwned::F32(Vec::new());
                let mut ybuf: Vec<i32> = Vec::new();
                let mut step: u64 = 0;
                for epoch in 0..cfg_ref.epochs {
                    optim.start_epoch(epoch);
                    for _ in 0..steps_per_epoch {
                        if stop.load(std::sync::atomic::Ordering::Relaxed) {
                            return Ok(());
                        }
                        cursor.next_batch(b, &mut batch_idx);
                        match train.kind {
                            TaskKind::Classify => {
                                data::gather_f32(train, &batch_idx, xbuf.clear_f32(), &mut ybuf)
                            }
                            TaskKind::LanguageModel => {
                                data::gather_i32(train, &batch_idx, xbuf.clear_i32(), &mut ybuf)
                            }
                        }
                        {
                            let p = params[i].lock().unwrap();
                            let mut g = grads[i].lock().unwrap();
                            let loss = engine.loss_and_grad(
                                &p,
                                xbuf.as_ref(),
                                &ybuf,
                                seeds[step as usize][i],
                                &mut g,
                            )?;
                            *losses[i].lock().unwrap() = loss;
                        }
                        barrier.wait(); // A: grads ready
                        barrier.wait(); // B: leader finished comm round
                        {
                            let mut p = params[i].lock().unwrap();
                            let g = grads[i].lock().unwrap();
                            optim.update_velocity(&g);
                            optim.apply(&mut p, &g);
                        }
                        barrier.wait(); // C: step complete
                        step += 1;
                    }
                }
                Ok(())
            });
        }

        // ---- leader --------------------------------------------------------
        let mut step: u64 = 0;
        for epoch in 0..cfg.epochs {
            let mut epoch_loss = 0.0f64;
            for _ in 0..steps_per_epoch {
                barrier.wait(); // A
                // collect state under lock, run the synchronized round
                {
                    let mut p: Vec<Vec<f32>> =
                        params.iter().map(|m| m.lock().unwrap().clone()).collect();
                    let mut g: Vec<Vec<f32>> =
                        grads.iter().map(|m| m.lock().unwrap().clone()).collect();
                    epoch_loss += losses
                        .iter()
                        .map(|m| *m.lock().unwrap() as f64)
                        .sum::<f64>();
                    let communicating =
                        decide_schedule(&cfg.method, cfg.schedule, step, w, &mut sched_rng);
                    let mut ctx = CommCtx {
                        params: &mut p,
                        grads: &mut g,
                        fabric: &mut fabric,
                        topology: &cfg.topology,
                        step,
                        communicating: &communicating,
                    };
                    strategy.comm_round(&mut ctx, &mut gossip_rng)?;
                    fabric.end_round();
                    for (slot, new) in params.iter().zip(p) {
                        *slot.lock().unwrap() = new;
                    }
                    for (slot, new) in grads.iter().zip(g) {
                        *slot.lock().unwrap() = new;
                    }
                }
                barrier.wait(); // B
                barrier.wait(); // C
                step += 1;
            }
            epoch_losses.lock().unwrap()[epoch] = epoch_loss;

            // evaluation at the epoch boundary (workers idle at barrier A of
            // the next step — safe to read params between steps)
            if (epoch + 1) % cfg.eval_every == 0 || epoch + 1 == cfg.epochs {
                let ew = Stopwatch::start();
                let snapshot: Vec<Vec<f32>> =
                    params.iter().map(|m| m.lock().unwrap().clone()).collect();
                let mut worker_acc = Vec::with_capacity(w);
                let mut worker_loss = Vec::with_capacity(w);
                for p in &snapshot {
                    let (l, a) = evaluate(leader_engine.as_mut(), p, &val)?;
                    worker_acc.push(a);
                    worker_loss.push(l);
                }
                let avg = super::average_params(&snapshot);
                let (_, agg) = evaluate(leader_engine.as_mut(), &avg, &val)?;
                eval_time += ew.elapsed_s();
                curve.push(EvalPoint {
                    epoch: epoch + 1,
                    step,
                    worker_acc,
                    worker_loss,
                    train_loss: (epoch_loss / (steps_per_epoch as f64 * w as f64)) as f32,
                    aggregate_acc: agg,
                    wall_s: watch.elapsed_s(),
                });
            }
        }
        Ok(())
    })?;

    let snapshot: Vec<Vec<f32>> = params.iter().map(|m| m.lock().unwrap().clone()).collect();
    let (_, rank0) = evaluate(leader_engine.as_mut(), &snapshot[0], &test)?;
    let avg = super::average_params(&snapshot);
    let (_, agg) = evaluate(leader_engine.as_mut(), &avg, &test)?;
    let report = fabric.report();
    Ok(super::RunReport {
        label: cfg.label.clone(),
        rank0_accuracy: rank0,
        aggregate_accuracy: agg,
        metrics: RunMetrics {
            curve,
            rank0_test_acc: rank0,
            aggregate_test_acc: agg,
            total_steps: cfg.total_steps(),
            comm_bytes: report.total_bytes,
            comm_messages: report.total_messages,
            comm_rounds: report.rounds,
            simulated_comm_s: report.simulated_comm_s,
            wall_train_s: watch.elapsed_s() - eval_time,
            wall_eval_s: eval_time,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Method;
    use crate::coordinator::tests::tiny_cfg;
    use crate::coordinator::run_experiment;
    use crate::runtime::SyntheticSpec;

    fn spec(cfg: &ExperimentConfig) -> SyntheticSpec {
        SyntheticSpec {
            n: 12,
            classes: 10,
            train_b: cfg.per_worker_batch(),
            eval_b: 32,
            seed: cfg.seed ^ 0x5EED,
        }
    }

    #[test]
    fn parallel_equals_sequential_elastic_gossip() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let seq = run_experiment(&cfg).unwrap();
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.rank0_accuracy, seq.rank0_accuracy);
        assert_eq!(par.aggregate_accuracy, seq.aggregate_accuracy);
        assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);
        let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        let lp: Vec<f32> = par.metrics.curve.points.iter().map(|p| p.train_loss).collect();
        assert_eq!(ls, lp, "parallel run diverged from sequential");
    }

    #[test]
    fn parallel_equals_sequential_allreduce() {
        let cfg = tiny_cfg(
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            3,
        );
        let seq = run_experiment(&cfg).unwrap();
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.rank0_accuracy, seq.rank0_accuracy);
        assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);
    }

    #[test]
    fn parallel_single_worker() {
        let cfg = tiny_cfg(Method::NoComm, 1);
        let par = run_parallel(&cfg, &spec(&cfg)).unwrap();
        assert_eq!(par.metrics.comm_bytes, 0);
        assert_eq!(par.metrics.curve.points.len(), cfg.epochs);
    }
}
