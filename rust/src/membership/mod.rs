//! Elastic membership: dynamic join/leave/crash with deterministic fault
//! injection for the event-driven async runtime.
//!
//! The paper motivates gossip training with heterogeneous deployments —
//! "training at data sources such as IoT devices and edge servers" —
//! where workers join, stall and vanish mid-run.  Gossip's decentralized
//! pairwise exchanges are exactly what should make training robust to
//! churn (no barrier to miss, no root to lose), and this module is the
//! machinery that lets us *measure* that claim instead of asserting it:
//!
//! * [`ChurnSpec`] — the `churn:<spec>` grammar (config TOML key
//!   `churn = "..."`, CLI `--churn`): an explicit event list
//!   (`crash@T:N`, `leave@T:N`, `join@T:N`, `rejoin@T:N`, comma
//!   separated; `T` in virtual seconds or `NN%` of the fastest node's
//!   expected completion time) or a seed-driven random schedule
//!   (`rand:<crashes>:<rejoins>:<seed>`).  Parsing is pure; the spec is
//!   resolved against a concrete run by [`ChurnSpec::materialize`], which
//!   is deterministic in (spec, workers, horizon) — same seed + same spec
//!   means the identical event trace, replayed bit-for-bit.
//! * [`MemberView`] — membership versioned in epochs: an alive bitset
//!   plus a compact sorted alive-list, rebuilt once per membership event
//!   (`kill`/`revive` bump the version).  Within an epoch every query —
//!   and the alive-constrained peer sampling in
//!   [`TopologyCache::sample_peer_alive`](crate::topology::TopologyCache::sample_peer_alive)
//!   that reads this view — is allocation-free.
//! * [`MembershipReport`] — the applied event log (what actually
//!   happened, with the membership version after each event), per-epoch
//!   alive counts, join-bootstrap records (donor/adopted parameter
//!   digests — the bootstrap-correctness observable), and the count of
//!   dead-sender messages the strategies refused (Elastic Gossip's
//!   rolled-back pair terms).
//!
//! The runtime semantics driven by these types live in
//! `crate::runtime_async`; the per-protocol churn rules (what happens to
//! a message from/to a departed node) are the `Strategy` lifecycle hooks
//! in `crate::algos` — see `on_peer_lost` / `deliver_from_lost` /
//! `on_drop_to_lost` / `on_leave` / `on_join_bootstrap`.
//!
//! With an **empty** schedule the runtime takes none of these paths: the
//! pre-drawn decision tables, stream consumption and event ordering are
//! byte-for-byte the PR-2 machinery, so every no-churn trajectory is
//! bit-identical to a build without this module (asserted by the
//! `prop_async_lockstep_*` suites and the explicit empty-schedule
//! property in `rust/tests/proptests.rs`).

use anyhow::{bail, ensure, Result};

use crate::manifest::json::{Json, JsonObj};
use crate::util::rng::{splitmix64, Rng};

/// Uniform parse diagnostic for the clause grammars (`churn:` events,
/// `faults:` clauses): names the offending token, which clause it sits
/// in (1-based) and the byte offset of that clause in the spec body, so
/// a bad entry in a long comma-separated schedule is locatable at a
/// glance.
fn clause_err(what: &str, token: &str, clause: &str, idx: usize, pos: usize, expect: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} {token:?} in clause {clause:?} (clause {}, byte offset {pos}): expected {expect}",
        idx + 1
    )
}

/// What happens to a node at a churn event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// Ungraceful death: in-flight work lost, the runtime reclaims
    /// conserved protocol state (push-sum weight) on the node's behalf.
    Crash,
    /// Graceful departure: the strategy's `on_leave` hook hands off
    /// conserved state (GoSGD ships its full weight to a live peer)
    /// before the node goes dark.
    Leave,
    /// A fresh node activates: initial parameters, step 0, then a
    /// bootstrap pull from a live donor before its first step.
    Join,
    /// A previously crashed/left node returns, restored from its last
    /// epoch checkpoint (`coordinator::checkpoint::AsyncNodeState`),
    /// then bootstrap-pulls like a join.
    Rejoin,
}

impl ChurnKind {
    pub fn label(&self) -> &'static str {
        match self {
            ChurnKind::Crash => "crash",
            ChurnKind::Leave => "leave",
            ChurnKind::Join => "join",
            ChurnKind::Rejoin => "rejoin",
        }
    }

    fn parse(s: &str) -> Result<ChurnKind> {
        Ok(match s {
            "crash" => ChurnKind::Crash,
            "leave" => ChurnKind::Leave,
            "join" => ChurnKind::Join,
            "rejoin" => ChurnKind::Rejoin,
            other => bail!("unknown churn event kind {other:?} (crash|leave|join|rejoin)"),
        })
    }
}

/// When a spec event fires: absolute virtual seconds, or a fraction of
/// the *fastest* node's expected completion time (so `35%` is mid-run
/// for every node regardless of straggler factors).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TimeSpec {
    Abs(f64),
    Frac(f64),
}

impl TimeSpec {
    fn resolve(&self, est_horizon: f64) -> f64 {
        match self {
            TimeSpec::Abs(t) => *t,
            TimeSpec::Frac(f) => f * est_horizon,
        }
    }
}

/// Parse one `<time>` token (virtual seconds or `NN%`) with positioned
/// diagnostics — shared by the `churn:` and `faults:` grammars.
fn parse_time(time: &str, clause: &str, idx: usize, pos: usize, grammar: &str) -> Result<TimeSpec> {
    match time.strip_suffix('%') {
        Some(p) => {
            let f: f64 = p.parse().map_err(|_| {
                clause_err(
                    &format!("bad {grammar} percent"),
                    time,
                    clause,
                    idx,
                    pos,
                    "a number in [0,100] before '%'",
                )
            })?;
            ensure!(
                (0.0..=100.0).contains(&f),
                "{grammar} percent {time:?} out of [0,100] in clause {clause:?} (clause {}, byte offset {pos})",
                idx + 1
            );
            Ok(TimeSpec::Frac(f / 100.0))
        }
        None => {
            let t: f64 = time.parse().map_err(|_| {
                clause_err(
                    &format!("bad {grammar} time"),
                    time,
                    clause,
                    idx,
                    pos,
                    "virtual seconds (e.g. 12.5) or a percent (e.g. 35%)",
                )
            })?;
            ensure!(
                t >= 0.0 && t.is_finite(),
                "{grammar} time {time:?} must be finite and >= 0 in clause {clause:?} (clause {}, byte offset {pos})",
                idx + 1
            );
            Ok(TimeSpec::Abs(t))
        }
    }
}

/// One parsed (not yet materialized) schedule entry.
#[derive(Clone, Debug, PartialEq)]
pub struct SpecEvent {
    pub at: TimeSpec,
    pub kind: ChurnKind,
    pub node: usize,
}

/// A parsed `churn:<spec>` — the experiment-level description of the
/// fault-injection schedule.  Default ([`ChurnSpec::none`]) is empty:
/// the membership-aware runtime degenerates to the fixed-roster PR-2
/// behavior bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnSpec {
    raw: String,
    events: Vec<SpecEvent>,
    /// `rand:<crashes>:<rejoins>:<seed>` — expanded at materialize time.
    rand: Option<(usize, usize, u64)>,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec::none()
    }
}

impl ChurnSpec {
    /// The empty schedule (no churn — the bit-identical default).
    pub fn none() -> Self {
        ChurnSpec { raw: "none".into(), events: Vec::new(), rand: None }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.rand.is_none()
    }

    /// The spec as written (for labels / reports).
    pub fn label(&self) -> &str {
        &self.raw
    }

    /// Parse `churn:<spec>` (the prefix is optional):
    ///
    /// ```text
    /// none
    /// crash@12.5:3                      absolute virtual seconds
    /// crash@35%:1,rejoin@75%:1          % of fastest node's horizon
    /// join@50%:8                        activate a brand-new node id
    /// rand:<crashes>:<rejoins>:<seed>   seed-driven random schedule
    /// ```
    pub fn parse(s: &str) -> Result<ChurnSpec> {
        let raw = s.trim();
        let body = raw.strip_prefix("churn:").unwrap_or(raw);
        if body.is_empty() || body == "none" {
            return Ok(ChurnSpec::none());
        }
        if let Some(rest) = body.strip_prefix("rand:") {
            let parts: Vec<&str> = rest.split(':').collect();
            ensure!(
                parts.len() == 3,
                "churn rand spec is rand:<crashes>:<rejoins>:<seed>, got {body:?}"
            );
            let crashes: usize = parts[0].parse()?;
            let rejoins: usize = parts[1].parse()?;
            let seed: u64 = match parts[2].strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16)?,
                None => parts[2].parse()?,
            };
            ensure!(crashes > 0, "rand churn needs at least one crash");
            return Ok(ChurnSpec {
                raw: body.to_string(),
                events: Vec::new(),
                rand: Some((crashes, rejoins, seed)),
            });
        }
        let mut events = Vec::new();
        let mut pos = 0usize; // byte offset of the current clause in `body`
        for (idx, raw_ev) in body.split(',').enumerate() {
            let ev = raw_ev.trim();
            let at_pos = pos + (raw_ev.len() - raw_ev.trim_start().len());
            let (kind, rest) = ev.split_once('@').ok_or_else(|| {
                clause_err("malformed churn event", ev, ev, idx, at_pos, "<kind>@<time>:<node>")
            })?;
            let (time, node) = rest.split_once(':').ok_or_else(|| {
                clause_err("missing `:<node>` after time", rest, ev, idx, at_pos, "<kind>@<time>:<node>")
            })?;
            let at = parse_time(time, ev, idx, at_pos, "churn")?;
            let kind = ChurnKind::parse(kind).map_err(|_| {
                clause_err("unknown churn event kind", kind, ev, idx, at_pos, "crash|leave|join|rejoin")
            })?;
            let node: usize = node.parse().map_err(|_| {
                clause_err("bad node id", node, ev, idx, at_pos, "a 0-based integer node id")
            })?;
            events.push(SpecEvent { at, kind, node });
            pos += raw_ev.len() + 1;
        }
        Ok(ChurnSpec { raw: body.to_string(), events, rand: None })
    }

    /// Highest node id the schedule mentions (a `join` may introduce ids
    /// beyond the initial roster; the runtime sizes its tables by
    /// `max(workers, max_node + 1)`).
    pub fn max_node(&self) -> Option<usize> {
        self.events.iter().map(|e| e.node).max()
    }

    /// Resolve the spec against a concrete run: `workers` initial nodes
    /// and an estimated horizon (fastest node's expected completion
    /// time, in virtual seconds).  Expands `rand:` deterministically and
    /// returns the event list sorted by firing time.
    pub fn materialize(&self, workers: usize, est_horizon: f64) -> Result<Vec<ChurnEvent>> {
        let mut out: Vec<ChurnEvent> = Vec::new();
        for e in &self.events {
            ensure!(e.node < 1024, "churn node id {} out of range", e.node);
            out.push(ChurnEvent { time: e.at.resolve(est_horizon), kind: e.kind, node: e.node });
        }
        if let Some((crashes, rejoins, seed)) = self.rand {
            ensure!(workers >= 2, "rand churn needs >= 2 workers");
            // victims drawn from 1..workers (node 0 always survives, so
            // the survivor-accuracy report has a stable rank-0)
            let mut rng = Rng::new(seed);
            let mut victims: Vec<usize> = (1..workers).collect();
            rng.shuffle(&mut victims);
            victims.truncate(crashes.min(workers - 1));
            for &v in &victims {
                let frac = 0.15 + 0.45 * rng.f64();
                out.push(ChurnEvent {
                    time: frac * est_horizon,
                    kind: ChurnKind::Crash,
                    node: v,
                });
            }
            for &v in victims.iter().take(rejoins.min(victims.len())) {
                let frac = 0.62 + 0.28 * rng.f64();
                out.push(ChurnEvent {
                    time: frac * est_horizon,
                    kind: ChurnKind::Rejoin,
                    node: v,
                });
            }
        }
        out.sort_by(|a, b| a.time.total_cmp(&b.time));
        Ok(out)
    }
}

/// The standard acceptance schedule — two of eight nodes crash mid-run,
/// one rejoins from its epoch checkpoint.  One definition shared by the
/// `churn-train` default, `examples/churn_study.rs`, `just bench-churn`
/// and the acceptance test, so they always measure the same scenario.
pub const STANDARD_CHURN: &str = "crash@30%:2,crash@45%:5,rejoin@70%:2";

/// A materialized schedule entry: fires at `time` on the virtual clock.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnEvent {
    pub time: f64,
    pub kind: ChurnKind,
    pub node: usize,
}

// ---------------------------------------------------------------------------
// link fault injection (`faults:` grammar)
// ---------------------------------------------------------------------------

/// A parsed `faults:<spec>` — deterministic link-level fault injection
/// for the async fabric.  Default ([`FaultSpec::none`]) is empty: no
/// message is ever touched and the runtime is byte-identical to a build
/// without this type.
///
/// Grammar (the `faults:` prefix is optional; clauses comma-separated):
///
/// ```text
/// none                       no faults (default)
/// drop:<p>                   iid per-message loss probability, 0 <= p < 1
/// jitter:<frac>              extra delivery delay, uniform in [0, frac] x
///                            the message's nominal link time
/// partition@<t0>-<t1>:<k>    while t0 <= now < t1, messages crossing the
///                            cut {0..k-1} | {k..} are severed; times are
///                            virtual seconds or NN% of the horizon
/// seed:<n|0xhex>             stream seed for the drop/jitter hash
/// ```
///
/// Loss and jitter decisions are *stateless* hashes of
/// (seed, src, dst, message sequence number) — no RNG stream is
/// consumed, so an empty spec changes nothing and a non-empty spec
/// replays bit-for-bit for the same seed.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    raw: String,
    drop_p: f64,
    jitter: f64,
    partitions: Vec<(TimeSpec, TimeSpec, usize)>,
    seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

impl FaultSpec {
    /// The empty fault plane (the byte-identical default).
    pub fn none() -> Self {
        FaultSpec {
            raw: "none".into(),
            drop_p: 0.0,
            jitter: 0.0,
            partitions: Vec::new(),
            seed: 0x6661756c74, // "fault"
        }
    }

    pub fn is_empty(&self) -> bool {
        self.drop_p == 0.0 && self.jitter == 0.0 && self.partitions.is_empty()
    }

    /// The spec as written (for labels / reports).
    pub fn label(&self) -> &str {
        &self.raw
    }

    pub fn parse(s: &str) -> Result<FaultSpec> {
        let raw = s.trim();
        let body = raw.strip_prefix("faults:").unwrap_or(raw);
        if body.is_empty() || body == "none" {
            return Ok(FaultSpec::none());
        }
        let mut spec = FaultSpec::none();
        spec.raw = body.to_string();
        let mut pos = 0usize;
        for (idx, raw_cl) in body.split(',').enumerate() {
            let cl = raw_cl.trim();
            let at_pos = pos + (raw_cl.len() - raw_cl.trim_start().len());
            if let Some(rest) = cl.strip_prefix("drop:") {
                let p: f64 = rest.parse().map_err(|_| {
                    clause_err("bad drop probability", rest, cl, idx, at_pos, "a float in [0,1)")
                })?;
                ensure!(
                    (0.0..1.0).contains(&p),
                    "drop probability {rest:?} out of [0,1) in clause {cl:?} (clause {}, byte offset {at_pos})",
                    idx + 1
                );
                spec.drop_p = p;
            } else if let Some(rest) = cl.strip_prefix("jitter:") {
                let j: f64 = rest.parse().map_err(|_| {
                    clause_err("bad jitter fraction", rest, cl, idx, at_pos, "a float >= 0")
                })?;
                ensure!(
                    j >= 0.0 && j.is_finite(),
                    "jitter fraction {rest:?} must be finite and >= 0 in clause {cl:?} (clause {}, byte offset {at_pos})",
                    idx + 1
                );
                spec.jitter = j;
            } else if let Some(rest) = cl.strip_prefix("partition@") {
                let (window, k) = rest.split_once(':').ok_or_else(|| {
                    clause_err("missing `:<k>` cut size", rest, cl, idx, at_pos, "partition@<t0>-<t1>:<k>")
                })?;
                let (t0, t1) = window.split_once('-').ok_or_else(|| {
                    clause_err("malformed partition window", window, cl, idx, at_pos, "partition@<t0>-<t1>:<k>")
                })?;
                let t0 = parse_time(t0, cl, idx, at_pos, "partition")?;
                let t1 = parse_time(t1, cl, idx, at_pos, "partition")?;
                let k: usize = k.parse().map_err(|_| {
                    clause_err("bad partition cut size", k, cl, idx, at_pos, "an integer >= 1")
                })?;
                ensure!(
                    k >= 1,
                    "partition cut size must be >= 1 in clause {cl:?} (clause {}, byte offset {at_pos})",
                    idx + 1
                );
                spec.partitions.push((t0, t1, k));
            } else if let Some(rest) = cl.strip_prefix("seed:") {
                spec.seed = match rest.strip_prefix("0x") {
                    Some(hex) => u64::from_str_radix(hex, 16).map_err(|_| {
                        clause_err("bad seed", rest, cl, idx, at_pos, "a decimal or 0x-hex u64")
                    })?,
                    None => rest.parse().map_err(|_| {
                        clause_err("bad seed", rest, cl, idx, at_pos, "a decimal or 0x-hex u64")
                    })?,
                };
            } else {
                return Err(clause_err(
                    "unknown fault clause",
                    cl,
                    cl,
                    idx,
                    at_pos,
                    "drop:<p> | jitter:<frac> | partition@<t0>-<t1>:<k> | seed:<n>",
                ));
            }
            pos += raw_cl.len() + 1;
        }
        Ok(spec)
    }

    /// Resolve percent times against a concrete horizon.  Deterministic
    /// in (spec, horizon).
    pub fn materialize(&self, est_horizon: f64) -> FaultPlan {
        FaultPlan {
            drop_p: self.drop_p,
            jitter: self.jitter,
            partitions: self
                .partitions
                .iter()
                .map(|(t0, t1, k)| (t0.resolve(est_horizon), t1.resolve(est_horizon), *k))
                .collect(),
            seed: self.seed,
        }
    }
}

/// A materialized fault plan: all times absolute.  Decisions are pure
/// functions of (plan, src, dst, seq, now) — replayable by construction.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub drop_p: f64,
    pub jitter: f64,
    /// (t0, t1, k): links crossing {0..k-1}|{k..} severed for t in [t0,t1)
    pub partitions: Vec<(f64, f64, usize)>,
    pub seed: u64,
}

impl FaultPlan {
    /// Stateless hash of (seed, salt, src, dst, seq) to [0, 1).
    fn hash01(&self, salt: u64, src: usize, dst: usize, seq: u64) -> f64 {
        let mut s = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(salt)
            .wrapping_add((src as u64) << 40)
            .wrapping_add((dst as u64) << 20)
            .wrapping_add(seq);
        let mut h = splitmix64(&mut s);
        let v = splitmix64(&mut h);
        (v >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Is the (src, dst) link severed by a scheduled partition at `now`?
    pub fn partitioned(&self, src: usize, dst: usize, now: f64) -> bool {
        self.partitions
            .iter()
            .any(|&(t0, t1, k)| now >= t0 && now < t1 && (src < k) != (dst < k))
    }

    /// Does message number `seq` on link (src, dst) get lost at `now`?
    pub fn loses(&self, src: usize, dst: usize, seq: u64, now: f64) -> bool {
        self.partitioned(src, dst, now)
            || (self.drop_p > 0.0 && self.hash01(0xd509, src, dst, seq) < self.drop_p)
    }

    /// Extra delivery delay for message `seq` on link (src, dst), given
    /// its nominal link time `dt`: uniform in [0, jitter * dt].
    pub fn extra_delay(&self, src: usize, dst: usize, seq: u64, dt: f64) -> f64 {
        if self.jitter == 0.0 {
            0.0
        } else {
            self.jitter * dt * self.hash01(0x71a7, src, dst, seq)
        }
    }
}

// ---------------------------------------------------------------------------
// failure-detector config (`fd:` grammar)
// ---------------------------------------------------------------------------

/// SWIM-style failure-detector parameters (`fd:` grammar).  Default
/// ([`FdSpec::none`]) is off: nodes learn of deaths from the runtime
/// oracle exactly as in the pre-detector builds, byte-for-byte.
///
/// ```text
/// off | none                          oracle membership (default)
/// on                                  detector on, default timing
/// <period>:<probe_to>:<suspect_to>:<fanout>
///                                     explicit timing, seconds + fanout
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FdSpec {
    raw: String,
    enabled: bool,
    /// seconds between a node's periodic probes
    pub period_s: f64,
    /// direct-probe ack deadline before escalating to ping-req
    pub probe_timeout_s: f64,
    /// suspicion deadline: suspect -> confirmed-dead unless refuted
    pub suspect_timeout_s: f64,
    /// ping-req relays per indirect probe
    pub fanout: usize,
}

impl Default for FdSpec {
    fn default() -> Self {
        FdSpec::none()
    }
}

impl FdSpec {
    /// Detector off — membership stays oracle-driven (the byte-identical
    /// default).
    pub fn none() -> Self {
        FdSpec {
            raw: "off".into(),
            enabled: false,
            period_s: 0.25,
            probe_timeout_s: 0.3,
            suspect_timeout_s: 1.0,
            fanout: 2,
        }
    }

    /// Detector on with the default timing.
    pub fn on() -> Self {
        FdSpec { raw: "on".into(), enabled: true, ..FdSpec::none() }
    }

    /// `is_empty` == detector off (naming symmetric with `ChurnSpec`).
    pub fn is_empty(&self) -> bool {
        !self.enabled
    }

    pub fn label(&self) -> &str {
        &self.raw
    }

    pub fn parse(s: &str) -> Result<FdSpec> {
        let raw = s.trim();
        let body = raw.strip_prefix("fd:").unwrap_or(raw);
        if body.is_empty() || body == "off" || body == "none" {
            return Ok(FdSpec::none());
        }
        if body == "on" {
            return Ok(FdSpec::on());
        }
        let parts: Vec<&str> = body.split(':').collect();
        ensure!(
            parts.len() == 4,
            "fd spec is `on`, `off`, or <period>:<probe_to>:<suspect_to>:<fanout>, got {body:?}"
        );
        let secs = |tok: &str, what: &str, idx: usize| -> Result<f64> {
            let v: f64 = tok
                .parse()
                .map_err(|_| clause_err(what, tok, body, idx, 0, "seconds as a positive float"))?;
            ensure!(v > 0.0 && v.is_finite(), "{what} {tok:?} must be finite and > 0");
            Ok(v)
        };
        let spec = FdSpec {
            raw: body.to_string(),
            enabled: true,
            period_s: secs(parts[0], "bad fd probe period", 0)?,
            probe_timeout_s: secs(parts[1], "bad fd probe timeout", 1)?,
            suspect_timeout_s: secs(parts[2], "bad fd suspicion timeout", 2)?,
            fanout: parts[3].parse().map_err(|_| {
                clause_err("bad fd ping-req fanout", parts[3], body, 3, 0, "an integer >= 0")
            })?,
        };
        Ok(spec)
    }
}

// ---------------------------------------------------------------------------
// membership view
// ---------------------------------------------------------------------------

/// Membership versioned in epochs: an alive bitset plus a compact sorted
/// alive-list, rebuilt once per membership event.  Queries and the
/// alive-constrained peer sampling that reads this view are
/// allocation-free between events (both buffers keep their capacity
/// across rebuilds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemberView {
    alive: Vec<bool>,
    alive_list: Vec<usize>,
    version: u64,
}

impl MemberView {
    /// `slots` total node slots, of which the first `initial` start
    /// alive (slots beyond the initial roster are reserved for `join`
    /// events).
    pub fn new(slots: usize, initial: usize) -> Self {
        let mut v = MemberView {
            alive: vec![false; slots],
            alive_list: Vec::with_capacity(slots),
            version: 0,
        };
        for a in v.alive.iter_mut().take(initial) {
            *a = true;
        }
        v.rebuild();
        v
    }

    fn rebuild(&mut self) {
        self.alive_list.clear();
        self.alive_list
            .extend(self.alive.iter().enumerate().filter_map(|(i, &a)| a.then_some(i)));
    }

    /// Mark `i` departed; bumps the membership version.
    pub fn kill(&mut self, i: usize) {
        debug_assert!(self.alive[i], "killing a dead node");
        self.alive[i] = false;
        self.version += 1;
        self.rebuild();
    }

    /// Mark `i` (re)joined; bumps the membership version.
    pub fn revive(&mut self, i: usize) {
        debug_assert!(!self.alive[i], "reviving a live node");
        self.alive[i] = true;
        self.version += 1;
        self.rebuild();
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.alive.get(i).copied().unwrap_or(false)
    }

    pub fn n_alive(&self) -> usize {
        self.alive_list.len()
    }

    pub fn slots(&self) -> usize {
        self.alive.len()
    }

    /// The membership epoch: bumped by every kill/revive.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn alive_flags(&self) -> &[bool] {
        &self.alive
    }

    /// Sorted list of alive node ids (rebuilt per membership epoch).
    pub fn alive_list(&self) -> &[usize] {
        &self.alive_list
    }

    /// Lowest-indexed alive node — the deterministic fallback recipient
    /// for reclaimed conserved state (dropped push-sum weight) and the
    /// survivor report's rank-0.
    pub fn first_alive(&self) -> Option<usize> {
        self.alive_list.first().copied()
    }
}

// ---------------------------------------------------------------------------
// per-node local view (failure detector)
// ---------------------------------------------------------------------------

/// What one node believes about one peer (SWIM's three states).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerStatus {
    Alive,
    Suspect,
    Dead,
}

/// One node's *believed* membership, maintained by the failure-detector
/// plane instead of the runtime oracle: per-peer status + the highest
/// incarnation heard.
///
/// The representation is **sparse**: the view stores only *deltas* from
/// the "initial roster prefix alive, join reserve dead" baseline —
/// sorted sets of prefix nodes confirmed dead, beyond-prefix nodes
/// believed alive, current suspects, and the (node, incarnation) pairs
/// that ever rose above 0.  A W-node detector-on run therefore costs
/// O(W + total churn) memory across all views instead of the dense
/// representation's O(W²) (four W-sized arrays *per node*), which is
/// what let the fd plane past 10⁴ nodes.  Peer sampling reads the view
/// through the [`AliveView`](crate::topology::AliveView) trait —
/// rng-identical to the dense oracle path by the trait's contract.
///
/// Incarnation rules (SWIM):
/// * `Alive(i, inc)` with `inc` **greater** than the recorded one
///   refutes a suspicion — and resurrects a locally confirmed death
///   (the reconciliation path for false confirms).
/// * `Suspect(i, inc)` with `inc >=` the recorded one moves Alive ->
///   Suspect.
/// * `Dead(i)` is accepted unconditionally (a confirmation already
///   out-voted the refutation window).
///
/// Suspects still count as believed-alive for gossip/probe targeting —
/// they must keep receiving traffic to be able to refute.
#[derive(Clone, Debug, PartialEq)]
pub struct LocalView {
    slots: usize,
    /// baseline: nodes `< prefix` believed alive unless in `dead`;
    /// nodes `>= prefix` believed dead unless in `extra`
    prefix: usize,
    /// sorted, subset of `[0, prefix)`: prefix nodes confirmed dead
    dead: Vec<u32>,
    /// sorted, subset of `[prefix, slots)`: late joiners believed alive
    extra: Vec<u32>,
    /// sorted; always a subset of the believed-alive set
    suspects: Vec<u32>,
    /// sorted by node; only incarnations that ever rose above 0
    incs: Vec<(u32, u32)>,
}

fn sorted_contains(v: &[u32], x: u32) -> bool {
    v.binary_search(&x).is_ok()
}

fn sorted_insert(v: &mut Vec<u32>, x: u32) {
    if let Err(p) = v.binary_search(&x) {
        v.insert(p, x);
    }
}

fn sorted_remove(v: &mut Vec<u32>, x: u32) {
    if let Ok(p) = v.binary_search(&x) {
        v.remove(p);
    }
}

impl LocalView {
    /// All `initial` roster slots believed alive; slots beyond that
    /// (join reserve) believed dead until their first rumor.  O(1)
    /// memory — the baseline is implicit.
    pub fn new(slots: usize, initial: usize) -> Self {
        debug_assert!(initial <= slots);
        LocalView {
            slots,
            prefix: initial,
            dead: Vec::new(),
            extra: Vec::new(),
            suspects: Vec::new(),
            incs: Vec::new(),
        }
    }

    /// A view seeded from a roster snapshot (the membership a join
    /// bootstrap hands a (re)joining node): alive where `flags` says so,
    /// dead elsewhere, all incarnations at 0 — the joiner relearns
    /// incarnations from the rumor stream.  Stores only the holes below
    /// the last alive node, so a mostly-alive roster stays O(churn).
    pub fn from_flags(flags: &[bool]) -> Self {
        let prefix = flags.iter().rposition(|&a| a).map_or(0, |p| p + 1);
        let mut v = LocalView::new(flags.len(), prefix);
        for (i, &a) in flags.iter().take(prefix).enumerate() {
            if !a {
                v.dead.push(i as u32); // ascending by construction
            }
        }
        v
    }

    pub fn status(&self, i: usize) -> PeerStatus {
        if !self.believes_alive(i) {
            PeerStatus::Dead
        } else if sorted_contains(&self.suspects, i as u32) {
            PeerStatus::Suspect
        } else {
            PeerStatus::Alive
        }
    }

    pub fn incarnation(&self, i: usize) -> u32 {
        match self.incs.binary_search_by_key(&(i as u32), |&(n, _)| n) {
            Ok(p) => self.incs[p].1,
            Err(_) => 0,
        }
    }

    fn set_incarnation(&mut self, i: usize, inc: u32) {
        if inc == 0 {
            return; // 0 is the implicit default — never stored
        }
        match self.incs.binary_search_by_key(&(i as u32), |&(n, _)| n) {
            Ok(p) => self.incs[p].1 = inc,
            Err(p) => self.incs.insert(p, (i as u32, inc)),
        }
    }

    /// Believed-alive = not confirmed dead (suspects included).
    pub fn believes_alive(&self, i: usize) -> bool {
        if i >= self.slots {
            false
        } else if i < self.prefix {
            !sorted_contains(&self.dead, i as u32)
        } else {
            sorted_contains(&self.extra, i as u32)
        }
    }

    /// Materialize the believed-alive set, ascending (tests and
    /// diagnostics; the hot paths enumerate through
    /// [`AliveView`](crate::topology::AliveView) without allocating).
    pub fn collect_alive(&self) -> Vec<usize> {
        use crate::topology::AliveView;
        (0..self.n_alive()).map(|k| self.kth_alive(k)).collect()
    }

    /// Apply an Alive rumor. Returns true if it changed the view
    /// (refuted a suspicion or resurrected a confirmed death).
    ///
    /// Both transitions require a *strictly* higher incarnation: the
    /// node itself bumps its incarnation to refute (and on every
    /// join/rejoin), so stale pre-crash rumors can never resurrect a
    /// confirmed death.
    pub fn note_alive(&mut self, i: usize, inc: u32) -> bool {
        if i >= self.slots {
            return false;
        }
        let cur = self.incarnation(i);
        let changed = self.status(i) != PeerStatus::Alive && inc > cur;
        if inc > cur {
            self.set_incarnation(i, inc);
        }
        if changed {
            sorted_remove(&mut self.suspects, i as u32);
            if i < self.prefix {
                sorted_remove(&mut self.dead, i as u32);
            } else {
                sorted_insert(&mut self.extra, i as u32);
            }
        }
        changed
    }

    /// Apply a Suspect rumor. Returns true if Alive -> Suspect fired.
    pub fn note_suspect(&mut self, i: usize, inc: u32) -> bool {
        if i >= self.slots || self.status(i) != PeerStatus::Alive || inc < self.incarnation(i) {
            return false;
        }
        self.set_incarnation(i, self.incarnation(i).max(inc));
        // suspects stay in the believed-alive set
        sorted_insert(&mut self.suspects, i as u32);
        true
    }

    /// Apply a Dead rumor / local confirmation. Returns true if the
    /// peer was not already confirmed dead.
    pub fn note_dead(&mut self, i: usize) -> bool {
        if i >= self.slots || self.status(i) == PeerStatus::Dead {
            return false;
        }
        sorted_remove(&mut self.suspects, i as u32);
        if i < self.prefix {
            sorted_insert(&mut self.dead, i as u32);
        } else {
            sorted_remove(&mut self.extra, i as u32);
        }
        true
    }

    /// Fraction of the given slots where this view's alive/dead belief
    /// disagrees with the oracle's flags (suspect counts as alive —
    /// suspicion is not yet a membership decision).
    pub fn divergence(&self, oracle_alive: &[bool]) -> f64 {
        let n = self.slots.min(oracle_alive.len());
        if n == 0 {
            return 0.0;
        }
        let wrong = (0..n)
            .filter(|&i| self.believes_alive(i) != oracle_alive[i])
            .count();
        wrong as f64 / n as f64
    }
}

impl crate::topology::AliveView for LocalView {
    fn n_alive(&self) -> usize {
        self.prefix - self.dead.len() + self.extra.len()
    }

    fn is_alive(&self, i: usize) -> bool {
        self.believes_alive(i)
    }

    fn kth_alive(&self, k: usize) -> usize {
        let in_prefix = self.prefix - self.dead.len();
        if k < in_prefix {
            // order statistics with exclusions: each dead node at or
            // below the running answer shifts it up by one
            let mut x = k;
            for &d in &self.dead {
                if (d as usize) <= x {
                    x += 1;
                } else {
                    break;
                }
            }
            x
        } else {
            self.extra[k - in_prefix] as usize
        }
    }

    fn alive_rank(&self, i: usize) -> usize {
        if i < self.prefix {
            i - self.dead.partition_point(|&d| (d as usize) < i)
        } else {
            (self.prefix - self.dead.len()) + self.extra.partition_point(|&e| (e as usize) < i)
        }
    }
}

// ---------------------------------------------------------------------------
// run report
// ---------------------------------------------------------------------------

/// One applied (not skipped) membership event, with the membership
/// version after it.
#[derive(Clone, Debug, PartialEq)]
pub struct AppliedChurn {
    pub time: f64,
    pub kind: ChurnKind,
    pub node: usize,
    pub alive_after: usize,
    pub version: u64,
}

/// One completed join bootstrap: the donor's parameter digest at
/// pull time must equal the joiner's digest after adoption (the
/// bootstrap-correctness observable, property-tested).
#[derive(Clone, Debug, PartialEq)]
pub struct BootstrapRecord {
    pub joiner: usize,
    pub donor: usize,
    /// FNV digest of the donor's parameters when the pull was answered.
    pub donor_digest: u64,
    /// FNV digest of the joiner's parameters after adoption.
    pub adopted_digest: u64,
    /// The joiner's local step at adoption (0 for fresh joins, the
    /// checkpoint step for crash-recovery rejoins).
    pub restored_step: u64,
}

/// Fixed-bucket histogram over latencies in virtual seconds (modeled on
/// `metrics::StalenessHist`; the last bucket saturates).  `PartialEq`
/// because replay determinism is asserted on whole reports.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyHist {
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    max: f64,
}

/// Upper edges of the latency buckets (seconds); one extra bucket
/// absorbs everything beyond the last edge.
pub const LATENCY_EDGES: [f64; 12] =
    [0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 5.0, 10.0];

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: vec![0; LATENCY_EDGES.len() + 1], sum: 0.0, n: 0, max: 0.0 }
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist::default()
    }

    pub fn record(&mut self, latency_s: f64) {
        let b = LATENCY_EDGES.partition_point(|&e| e < latency_s);
        self.counts[b] += 1;
        self.sum += latency_s;
        self.n += 1;
        self.max = self.max.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn bucket(&self, b: usize) -> u64 {
        self.counts[b.min(self.counts.len() - 1)]
    }

    /// Latency (seconds) at percentile `p` in `[0, 1]`, reported as the
    /// upper edge of the bucket holding that percentile — a conservative
    /// bound, like the bucketed quantiles of Prometheus histograms.  The
    /// overflow bucket (beyond the last edge) reports the observed max.
    /// 0 when empty.
    pub fn percentile_s(&self, p: f64) -> f64 {
        match crate::trace::percentile_bucket(&self.counts, p) {
            None => 0.0,
            Some(b) if b == LATENCY_EDGES.len() => self.max,
            Some(b) => LATENCY_EDGES[b],
        }
    }

    pub fn p50_s(&self) -> f64 {
        self.percentile_s(0.50)
    }

    pub fn p95_s(&self) -> f64 {
        self.percentile_s(0.95)
    }

    pub fn p99_s(&self) -> f64 {
        self.percentile_s(0.99)
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("count", Json::Num(self.n as f64));
        o.insert("mean_s", Json::Num(self.mean()));
        o.insert("p50_s", Json::Num(self.p50_s()));
        o.insert("p95_s", Json::Num(self.p95_s()));
        o.insert("p99_s", Json::Num(self.p99_s()));
        o.insert("max_s", Json::Num(self.max));
        let hi = self.counts.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        o.insert(
            "buckets",
            Json::Arr(self.counts[..hi].iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(o)
    }
}

/// What the failure-detector plane observed over one run (present in
/// [`MembershipReport`] only when `fd:` is enabled).  `false_*` counters
/// compare local beliefs against the runtime oracle — the quantities
/// ROADMAP direction 3 wanted first-class.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FdReport {
    /// direct probes sent
    pub probes: u64,
    /// ping-req relays sent after a missed direct ack
    pub indirect_probes: u64,
    /// acks received by the original prober
    pub acks: u64,
    /// Alive -> Suspect transitions across all observers
    pub suspicions: u64,
    /// suspicions raised while the target was oracle-alive
    pub false_suspicions: u64,
    /// suspicions cleared by a higher-incarnation Alive rumor
    pub refutations: u64,
    /// Suspect -> confirmed-dead transitions across all observers
    pub confirms: u64,
    /// confirmations of an oracle-alive target (never touch state —
    /// reconciled by the target's own refutation rumors)
    pub false_confirms: u64,
    /// oracle crash -> per-observer confirmation latency
    pub detection: LatencyHist,
    /// per-eval-tick mean view divergence vs the oracle (fraction of
    /// slots each live node's `LocalView` mislabels, averaged over
    /// live nodes)
    pub view_divergence: Vec<f64>,
    /// data following membership: `(dead, adopter, rows)` shard
    /// reassignments performed when a death was first truly confirmed
    /// (rows return to the owner on rejoin)
    pub shard_moves: Vec<(usize, usize, usize)>,
}

impl FdReport {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("probes", Json::Num(self.probes as f64));
        o.insert("indirect_probes", Json::Num(self.indirect_probes as f64));
        o.insert("acks", Json::Num(self.acks as f64));
        o.insert("suspicions", Json::Num(self.suspicions as f64));
        o.insert("false_suspicions", Json::Num(self.false_suspicions as f64));
        o.insert("refutations", Json::Num(self.refutations as f64));
        o.insert("confirms", Json::Num(self.confirms as f64));
        o.insert("false_confirms", Json::Num(self.false_confirms as f64));
        o.insert("detection", self.detection.to_json());
        o.insert(
            "view_divergence",
            Json::Arr(self.view_divergence.iter().map(|&d| Json::Num(d)).collect()),
        );
        o.insert(
            "shard_moves",
            Json::Arr(
                self.shard_moves
                    .iter()
                    .map(|&(dead, adopter, rows)| {
                        Json::Arr(vec![
                            Json::Num(dead as f64),
                            Json::Num(adopter as f64),
                            Json::Num(rows as f64),
                        ])
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

/// Everything the membership subsystem observed over one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MembershipReport {
    /// events in application order (skipped events — e.g. crashing an
    /// already-dead node — are not recorded)
    pub applied: Vec<AppliedChurn>,
    pub bootstraps: Vec<BootstrapRecord>,
    /// messages from departed senders that the strategy's churn rules
    /// refused — parked entries removed by the departure sweep plus
    /// in-flight deliveries rejected at the fabric.  For Elastic Gossip
    /// these are exactly the rolled-back pair terms; for gossip-pull
    /// they are requests from dead pullers.
    pub rolled_back_msgs: u64,
    /// alive count at each epoch evaluation (the per-epoch membership
    /// series next to the accuracy curve)
    pub per_epoch_alive: Vec<usize>,
    /// alive node ids at run end (the survivors the final accuracy
    /// report covers)
    pub final_alive: Vec<usize>,
    /// failure-detector observations — `Some` iff the `fd:` plane ran
    pub fd: Option<FdReport>,
}

impl MembershipReport {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert(
            "events",
            Json::Arr(
                self.applied
                    .iter()
                    .map(|e| {
                        let mut eo = JsonObj::new();
                        eo.insert("time", Json::Num(e.time));
                        eo.insert("kind", Json::Str(e.kind.label().into()));
                        eo.insert("node", Json::Num(e.node as f64));
                        eo.insert("alive_after", Json::Num(e.alive_after as f64));
                        eo.insert("version", Json::Num(e.version as f64));
                        Json::Obj(eo)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "bootstraps",
            Json::Arr(
                self.bootstraps
                    .iter()
                    .map(|b| {
                        let mut bo = JsonObj::new();
                        bo.insert("joiner", Json::Num(b.joiner as f64));
                        bo.insert("donor", Json::Num(b.donor as f64));
                        bo.insert("exact", Json::Bool(b.donor_digest == b.adopted_digest));
                        bo.insert("restored_step", Json::Num(b.restored_step as f64));
                        Json::Obj(bo)
                    })
                    .collect(),
            ),
        );
        o.insert("rolled_back_msgs", Json::Num(self.rolled_back_msgs as f64));
        o.insert(
            "per_epoch_alive",
            Json::Arr(self.per_epoch_alive.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        o.insert(
            "final_alive",
            Json::Arr(self.final_alive.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        if let Some(fd) = &self.fd {
            o.insert("fd", fd.to_json());
        }
        Json::Obj(o)
    }
}

/// FNV-1a over the little-endian bytes of a flat parameter buffer — the
/// digest the bootstrap records pin.  One shared implementation
/// (`util::fnv_digest`) backs this and the golden suite's nested
/// variant, so the two conventions can never drift apart.
pub fn digest_params(p: &[f32]) -> u64 {
    crate::util::fnv_digest(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_prefix() {
        assert!(ChurnSpec::parse("none").unwrap().is_empty());
        assert!(ChurnSpec::parse("churn:none").unwrap().is_empty());
        assert!(ChurnSpec::parse("").unwrap().is_empty());
        assert_eq!(ChurnSpec::default(), ChurnSpec::none());
    }

    #[test]
    fn parse_event_list() {
        let s = ChurnSpec::parse("churn:crash@35%:1,rejoin@75%:1,join@12.5:8").unwrap();
        assert!(!s.is_empty());
        assert_eq!(s.events.len(), 3);
        assert_eq!(s.events[0], SpecEvent { at: TimeSpec::Frac(0.35), kind: ChurnKind::Crash, node: 1 });
        assert_eq!(s.events[2], SpecEvent { at: TimeSpec::Abs(12.5), kind: ChurnKind::Join, node: 8 });
        assert_eq!(s.max_node(), Some(8));
        assert_eq!(s.label(), "crash@35%:1,rejoin@75%:1,join@12.5:8");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ChurnSpec::parse("explode@10:1").is_err());
        assert!(ChurnSpec::parse("crash@1").is_err());
        assert!(ChurnSpec::parse("crash:1@2").is_err());
        assert!(ChurnSpec::parse("crash@150%:1").is_err());
        assert!(ChurnSpec::parse("crash@-3:1").is_err());
        assert!(ChurnSpec::parse("rand:2:1").is_err());
        assert!(ChurnSpec::parse("rand:0:0:7").is_err());
    }

    #[test]
    fn materialize_resolves_and_sorts() {
        let s = ChurnSpec::parse("rejoin@75%:1,crash@25%:1").unwrap();
        let evs = s.materialize(4, 100.0).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], ChurnEvent { time: 25.0, kind: ChurnKind::Crash, node: 1 });
        assert_eq!(evs[1], ChurnEvent { time: 75.0, kind: ChurnKind::Rejoin, node: 1 });
    }

    #[test]
    fn materialize_rand_is_deterministic_and_spares_node_zero() {
        let s = ChurnSpec::parse("rand:3:2:42").unwrap();
        let a = s.materialize(8, 100.0).unwrap();
        let b = s.materialize(8, 100.0).unwrap();
        assert_eq!(a, b, "rand schedule must reproduce from its seed");
        let crashes: Vec<&ChurnEvent> = a.iter().filter(|e| e.kind == ChurnKind::Crash).collect();
        let rejoins: Vec<&ChurnEvent> = a.iter().filter(|e| e.kind == ChurnKind::Rejoin).collect();
        assert_eq!(crashes.len(), 3);
        assert_eq!(rejoins.len(), 2);
        for e in &a {
            assert_ne!(e.node, 0, "node 0 must survive rand schedules");
            assert!(e.time > 0.0 && e.time < 100.0);
        }
        // every rejoin targets a previously crashed node, later in time
        for r in &rejoins {
            let c = crashes.iter().find(|c| c.node == r.node).expect("rejoin of uncrashed node");
            assert!(r.time > c.time);
        }
        // a different seed gives a different trace
        let c = ChurnSpec::parse("rand:3:2:43").unwrap().materialize(8, 100.0).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn member_view_versioned_epochs() {
        let mut m = MemberView::new(6, 4);
        assert_eq!(m.n_alive(), 4);
        assert_eq!(m.alive_list(), &[0, 1, 2, 3]);
        assert!(!m.is_alive(4), "slots beyond the roster start dead");
        assert_eq!(m.version(), 0);
        m.kill(1);
        assert_eq!(m.version(), 1);
        assert_eq!(m.alive_list(), &[0, 2, 3]);
        assert_eq!(m.first_alive(), Some(0));
        m.revive(4);
        assert_eq!(m.version(), 2);
        assert_eq!(m.alive_list(), &[0, 2, 3, 4]);
        m.kill(0);
        assert_eq!(m.first_alive(), Some(2));
        assert!(!m.is_alive(100), "out-of-range ids are dead");
    }

    #[test]
    fn member_view_rebuild_keeps_capacity() {
        let mut m = MemberView::new(8, 8);
        let cap = (m.alive_list.as_ptr(), m.alive_list.capacity());
        for i in 1..8 {
            m.kill(i);
        }
        for i in 1..8 {
            m.revive(i);
        }
        assert_eq!(
            (m.alive_list.as_ptr(), m.alive_list.capacity()),
            cap,
            "epoch rebuilds must not reallocate"
        );
    }

    #[test]
    fn digest_is_order_and_bit_sensitive() {
        assert_ne!(digest_params(&[1.0, 2.0]), digest_params(&[2.0, 1.0]));
        assert_ne!(digest_params(&[0.0]), digest_params(&[-0.0]));
        assert_eq!(digest_params(&[f32::NAN]), digest_params(&[f32::NAN]));
    }

    #[test]
    fn parse_errors_name_token_and_position() {
        // the satellite claim: a bad clause reports what and where
        let e = format!("{:#}", ChurnSpec::parse("crash@35%:1,explode@10:2").unwrap_err());
        assert!(e.contains("explode"), "missing offending token: {e}");
        assert!(e.contains("clause 2"), "missing clause index: {e}");
        assert!(e.contains("byte offset 12"), "missing byte offset: {e}");
        let e = format!("{:#}", ChurnSpec::parse("crash@nope:1").unwrap_err());
        assert!(e.contains("\"nope\"") && e.contains("clause 1"), "{e}");
        let e = format!("{:#}", ChurnSpec::parse("crash@10:xx").unwrap_err());
        assert!(e.contains("\"xx\"") && e.contains("node id"), "{e}");
        let e = format!("{:#}", ChurnSpec::parse("crash@150%:1").unwrap_err());
        assert!(e.contains("150%") && e.contains("[0,100]"), "{e}");
        // the faults: grammar reuses the same diagnostics
        let e = format!("{:#}", FaultSpec::parse("drop:0.05,explode:1").unwrap_err());
        assert!(e.contains("explode") && e.contains("clause 2") && e.contains("byte offset 10"), "{e}");
        let e = format!("{:#}", FaultSpec::parse("drop:1.5").unwrap_err());
        assert!(e.contains("1.5") && e.contains("[0,1)"), "{e}");
    }

    #[test]
    fn fault_spec_parse_and_empty() {
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert!(FaultSpec::parse("none").unwrap().is_empty());
        assert!(FaultSpec::parse("faults:none").unwrap().is_empty());
        assert_eq!(FaultSpec::default(), FaultSpec::none());
        let s = FaultSpec::parse("faults:drop:0.05,jitter:0.3,partition@20%-40%:4,seed:0xbeef")
            .unwrap();
        assert!(!s.is_empty());
        assert_eq!(s.drop_p, 0.05);
        assert_eq!(s.jitter, 0.3);
        assert_eq!(s.seed, 0xbeef);
        assert_eq!(s.label(), "drop:0.05,jitter:0.3,partition@20%-40%:4,seed:0xbeef");
        let plan = s.materialize(100.0);
        assert_eq!(plan.partitions, vec![(20.0, 40.0, 4)]);
        assert!(FaultSpec::parse("partition@10-5").is_err()); // missing :<k>
        assert!(FaultSpec::parse("partition@10:3").is_err()); // missing -t1
        assert!(FaultSpec::parse("jitter:-1").is_err());
    }

    #[test]
    fn fault_plan_is_deterministic_and_respects_rates() {
        let plan = FaultSpec::parse("drop:0.1,jitter:0.5").unwrap().materialize(100.0);
        let again = FaultSpec::parse("drop:0.1,jitter:0.5").unwrap().materialize(100.0);
        let mut lost = 0usize;
        for seq in 0..10_000u64 {
            let l = plan.loses(0, 1, seq, 1.0);
            assert_eq!(l, again.loses(0, 1, seq, 1.0), "loss decision must replay");
            lost += l as usize;
            let d = plan.extra_delay(0, 1, seq, 0.01);
            assert!((0.0..=0.005).contains(&d), "jitter {d} out of [0, 0.5*dt]");
            assert_eq!(d, again.extra_delay(0, 1, seq, 0.01));
        }
        let rate = lost as f64 / 10_000.0;
        assert!((rate - 0.1).abs() < 0.02, "drop rate {rate} far from 0.1");
        // a different seed decides differently somewhere
        let other = FaultSpec::parse("drop:0.1,seed:99").unwrap().materialize(100.0);
        assert!((0..1000).any(|s| plan.loses(0, 1, s, 1.0) != other.loses(0, 1, s, 1.0)));
        // empty plan never loses and never delays
        let none = FaultSpec::none().materialize(100.0);
        assert!((0..100).all(|s| !none.loses(0, 1, s, 1.0)));
        assert_eq!(none.extra_delay(0, 1, 7, 0.01), 0.0);
    }

    #[test]
    fn fault_partition_severs_only_cross_cut_links_in_window() {
        let plan = FaultSpec::parse("partition@10-20:2").unwrap().materialize(100.0);
        assert!(plan.loses(1, 2, 0, 10.0), "cross-cut link inside window");
        assert!(plan.loses(5, 0, 0, 19.9));
        assert!(!plan.loses(0, 1, 0, 15.0), "same-side link untouched");
        assert!(!plan.loses(2, 3, 0, 15.0));
        assert!(!plan.loses(1, 2, 0, 9.9), "before the window");
        assert!(!plan.loses(1, 2, 0, 20.0), "window is half-open [t0, t1)");
    }

    #[test]
    fn fd_spec_parse() {
        assert!(FdSpec::parse("").unwrap().is_empty());
        assert!(FdSpec::parse("off").unwrap().is_empty());
        assert!(FdSpec::parse("fd:none").unwrap().is_empty());
        assert_eq!(FdSpec::default(), FdSpec::none());
        let on = FdSpec::parse("on").unwrap();
        assert!(!on.is_empty());
        assert_eq!(on.period_s, 0.25);
        assert_eq!(on.fanout, 2);
        let s = FdSpec::parse("fd:0.5:0.6:2.0:3").unwrap();
        assert!(!s.is_empty());
        assert_eq!((s.period_s, s.probe_timeout_s, s.suspect_timeout_s, s.fanout), (0.5, 0.6, 2.0, 3));
        assert_eq!(s.label(), "0.5:0.6:2.0:3");
        assert!(FdSpec::parse("0.5:0.6:2.0").is_err());
        assert!(FdSpec::parse("fd:-1:0.6:2.0:3").is_err());
        assert!(FdSpec::parse("fd:0.5:0.6:2.0:x").is_err());
    }

    #[test]
    fn local_view_swim_transitions() {
        let mut v = LocalView::new(6, 4);
        assert_eq!(v.collect_alive(), &[0, 1, 2, 3]);
        assert!(!v.believes_alive(4), "join-reserve slots start believed dead");
        // suspicion needs current-or-newer incarnation
        assert!(v.note_suspect(2, 0));
        assert_eq!(v.status(2), PeerStatus::Suspect);
        assert!(v.believes_alive(2), "suspects stay in the believed-alive set");
        assert!(!v.note_suspect(2, 0), "already suspect");
        // refutation requires a strictly higher incarnation
        assert!(!v.note_alive(2, 0), "stale alive cannot refute");
        assert!(v.note_alive(2, 1), "bumped incarnation refutes");
        assert_eq!(v.status(2), PeerStatus::Alive);
        assert!(!v.note_suspect(2, 0), "old-incarnation suspicion rejected");
        // confirm + resurrection
        assert!(v.note_suspect(2, 1));
        assert!(v.note_dead(2));
        assert!(!v.believes_alive(2));
        assert_eq!(v.collect_alive(), &[0, 1, 3]);
        assert!(!v.note_dead(2), "already dead");
        assert!(!v.note_alive(2, 1), "stale alive cannot resurrect");
        assert!(v.note_alive(2, 2), "higher incarnation resurrects");
        assert_eq!(v.collect_alive(), &[0, 1, 2, 3]);
        // divergence vs an oracle
        let oracle = [true, true, false, true, false, false];
        assert!((v.divergence(&oracle) - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_local_view_matches_dense_model_under_random_rumors() {
        use crate::topology::AliveView;
        use crate::util::rng::Rng;
        // dense reference model: per-peer (status, inc), replaying the
        // exact SWIM acceptance rules the sparse view must preserve
        #[derive(Clone, Copy, PartialEq)]
        enum S {
            A,
            Su,
            D,
        }
        let mut rng = Rng::new(0xFD_5EED);
        for trial in 0..40 {
            let slots = 3 + (trial % 13);
            let initial = trial % (slots + 1);
            let mut view = LocalView::new(slots, initial);
            let mut st: Vec<S> = (0..slots).map(|i| if i < initial { S::A } else { S::D }).collect();
            let mut inc: Vec<u32> = vec![0; slots];
            for step in 0..400 {
                let i = rng.below(slots + 1); // +1: occasional out-of-range
                let r = rng.below(3) as u32;
                let got = match rng.below(3) {
                    0 => {
                        let want = i < slots && st[i] != S::A && r > inc[i];
                        if i < slots && r > inc[i] {
                            inc[i] = r;
                        }
                        if want {
                            st[i] = S::A;
                        }
                        assert_eq!(view.note_alive(i, r), want, "alive({i},{r}) trial {trial} step {step}");
                        continue;
                    }
                    1 => {
                        let want = i < slots && st[i] == S::A && r >= inc[i];
                        if want {
                            inc[i] = inc[i].max(r);
                            st[i] = S::Su;
                        }
                        (view.note_suspect(i, r), want)
                    }
                    _ => {
                        let want = i < slots && st[i] != S::D;
                        if want {
                            st[i] = S::D;
                        }
                        (view.note_dead(i), want)
                    }
                };
                assert_eq!(got.0, got.1, "trial {trial} step {step}");
            }
            // every observable agrees with the dense model
            let model_alive: Vec<usize> =
                (0..slots).filter(|&i| st[i] != S::D).collect();
            assert_eq!(view.collect_alive(), model_alive, "trial {trial}");
            assert_eq!(view.n_alive(), model_alive.len());
            for i in 0..slots + 2 {
                let want_alive = i < slots && st[i] != S::D;
                assert_eq!(view.believes_alive(i), want_alive, "alive({i}) trial {trial}");
                assert_eq!(view.is_alive(i), want_alive);
                let want_status = if i >= slots {
                    PeerStatus::Dead
                } else {
                    match st[i] {
                        S::A => PeerStatus::Alive,
                        S::Su => PeerStatus::Suspect,
                        S::D => PeerStatus::Dead,
                    }
                };
                assert_eq!(view.status(i), want_status, "status({i}) trial {trial}");
                let want_inc = if i < slots { inc[i] } else { 0 };
                assert_eq!(view.incarnation(i), want_inc, "inc({i}) trial {trial}");
                assert_eq!(
                    view.alive_rank(i.min(slots)),
                    model_alive.iter().filter(|&&a| a < i.min(slots)).count(),
                    "rank({i}) trial {trial}"
                );
            }
            for (k, &a) in model_alive.iter().enumerate() {
                assert_eq!(view.kth_alive(k), a, "kth({k}) trial {trial}");
            }
            // dense/sparse sampling equivalence: same alive set, same rng
            // stream -> same peer sequence through the generic sampler
            let flags: Vec<bool> = (0..slots).map(|i| st[i] != S::D).collect();
            let mut cache = crate::topology::TopologyCache::new();
            cache.ensure(&crate::topology::Topology::Full, slots);
            let mut ra = Rng::new(trial as u64 ^ 0xA5);
            let mut rb = Rng::new(trial as u64 ^ 0xA5);
            for i in 0..slots {
                let dense = crate::topology::DenseAlive { alive: &flags, list: &model_alive };
                assert_eq!(
                    cache.sample_peer_alive_view(i, &view, &mut ra),
                    cache.sample_peer_alive_view(i, &dense, &mut rb),
                    "sampling diverged at {i} trial {trial}"
                );
            }
        }
    }

    #[test]
    fn local_view_from_flags_stores_only_holes() {
        let flags = [true, false, true, true, false, false];
        let v = LocalView::from_flags(&flags);
        assert_eq!(v.collect_alive(), &[0, 2, 3]);
        for (i, &a) in flags.iter().enumerate() {
            assert_eq!(v.believes_alive(i), a, "slot {i}");
        }
        // trailing dead slots live in the implicit baseline, not a list
        assert_eq!(v.prefix, 4);
        assert_eq!(v.dead, &[1]);
        assert!(v.extra.is_empty());
        // all-dead roster
        let v = LocalView::from_flags(&[false, false]);
        assert_eq!(v.collect_alive(), Vec::<usize>::new());
        assert_eq!(v.prefix, 0);
    }

    #[test]
    fn latency_hist_buckets_and_stats() {
        let mut h = LatencyHist::new();
        assert_eq!(h.mean(), 0.0);
        for s in [0.04, 0.3, 0.3, 99.0] {
            h.record(s);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.max(), 99.0);
        assert!((h.mean() - (0.04 + 0.3 + 0.3 + 99.0) / 4.0).abs() < 1e-12);
        assert_eq!(h.bucket(0), 1, "0.04 lands below the first edge");
        assert_eq!(h.bucket(3), 2, "0.3 lands in (0.2, 0.35]");
        assert_eq!(h.bucket(LATENCY_EDGES.len()), 1, "overflow bucket saturates");
        let j = crate::manifest::json::write(&h.to_json());
        let back = crate::manifest::json::parse(&j).unwrap();
        assert_eq!(back.path(&["count"]).as_f64(), Some(4.0));
    }

    #[test]
    fn report_json_shape() {
        let mut r = MembershipReport::default();
        r.applied.push(AppliedChurn {
            time: 1.5,
            kind: ChurnKind::Crash,
            node: 2,
            alive_after: 3,
            version: 1,
        });
        r.bootstraps.push(BootstrapRecord {
            joiner: 2,
            donor: 0,
            donor_digest: 7,
            adopted_digest: 7,
            restored_step: 40,
        });
        r.per_epoch_alive = vec![4, 3];
        r.final_alive = vec![0, 1, 3];
        let s = crate::manifest::json::write(&r.to_json());
        let back = crate::manifest::json::parse(&s).unwrap();
        assert_eq!(back.path(&["rolled_back_msgs"]).as_f64(), Some(0.0));
        assert_eq!(back.path(&["events"]).as_arr().unwrap().len(), 1);
        assert_eq!(back.path(&["final_alive"]).as_arr().unwrap().len(), 3);
    }
}
