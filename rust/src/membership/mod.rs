//! Elastic membership: dynamic join/leave/crash with deterministic fault
//! injection for the event-driven async runtime.
//!
//! The paper motivates gossip training with heterogeneous deployments —
//! "training at data sources such as IoT devices and edge servers" —
//! where workers join, stall and vanish mid-run.  Gossip's decentralized
//! pairwise exchanges are exactly what should make training robust to
//! churn (no barrier to miss, no root to lose), and this module is the
//! machinery that lets us *measure* that claim instead of asserting it:
//!
//! * [`ChurnSpec`] — the `churn:<spec>` grammar (config TOML key
//!   `churn = "..."`, CLI `--churn`): an explicit event list
//!   (`crash@T:N`, `leave@T:N`, `join@T:N`, `rejoin@T:N`, comma
//!   separated; `T` in virtual seconds or `NN%` of the fastest node's
//!   expected completion time) or a seed-driven random schedule
//!   (`rand:<crashes>:<rejoins>:<seed>`).  Parsing is pure; the spec is
//!   resolved against a concrete run by [`ChurnSpec::materialize`], which
//!   is deterministic in (spec, workers, horizon) — same seed + same spec
//!   means the identical event trace, replayed bit-for-bit.
//! * [`MemberView`] — membership versioned in epochs: an alive bitset
//!   plus a compact sorted alive-list, rebuilt once per membership event
//!   (`kill`/`revive` bump the version).  Within an epoch every query —
//!   and the alive-constrained peer sampling in
//!   [`TopologyCache::sample_peer_alive`](crate::topology::TopologyCache::sample_peer_alive)
//!   that reads this view — is allocation-free.
//! * [`MembershipReport`] — the applied event log (what actually
//!   happened, with the membership version after each event), per-epoch
//!   alive counts, join-bootstrap records (donor/adopted parameter
//!   digests — the bootstrap-correctness observable), and the count of
//!   dead-sender messages the strategies refused (Elastic Gossip's
//!   rolled-back pair terms).
//!
//! The runtime semantics driven by these types live in
//! `crate::runtime_async`; the per-protocol churn rules (what happens to
//! a message from/to a departed node) are the `Strategy` lifecycle hooks
//! in `crate::algos` — see `on_peer_lost` / `deliver_from_lost` /
//! `on_drop_to_lost` / `on_leave` / `on_join_bootstrap`.
//!
//! With an **empty** schedule the runtime takes none of these paths: the
//! pre-drawn decision tables, stream consumption and event ordering are
//! byte-for-byte the PR-2 machinery, so every no-churn trajectory is
//! bit-identical to a build without this module (asserted by the
//! `prop_async_lockstep_*` suites and the explicit empty-schedule
//! property in `rust/tests/proptests.rs`).

use anyhow::{bail, ensure, Result};

use crate::manifest::json::{Json, JsonObj};
use crate::util::rng::Rng;

/// What happens to a node at a churn event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// Ungraceful death: in-flight work lost, the runtime reclaims
    /// conserved protocol state (push-sum weight) on the node's behalf.
    Crash,
    /// Graceful departure: the strategy's `on_leave` hook hands off
    /// conserved state (GoSGD ships its full weight to a live peer)
    /// before the node goes dark.
    Leave,
    /// A fresh node activates: initial parameters, step 0, then a
    /// bootstrap pull from a live donor before its first step.
    Join,
    /// A previously crashed/left node returns, restored from its last
    /// epoch checkpoint (`coordinator::checkpoint::AsyncNodeState`),
    /// then bootstrap-pulls like a join.
    Rejoin,
}

impl ChurnKind {
    pub fn label(&self) -> &'static str {
        match self {
            ChurnKind::Crash => "crash",
            ChurnKind::Leave => "leave",
            ChurnKind::Join => "join",
            ChurnKind::Rejoin => "rejoin",
        }
    }

    fn parse(s: &str) -> Result<ChurnKind> {
        Ok(match s {
            "crash" => ChurnKind::Crash,
            "leave" => ChurnKind::Leave,
            "join" => ChurnKind::Join,
            "rejoin" => ChurnKind::Rejoin,
            other => bail!("unknown churn event kind {other:?} (crash|leave|join|rejoin)"),
        })
    }
}

/// When a spec event fires: absolute virtual seconds, or a fraction of
/// the *fastest* node's expected completion time (so `35%` is mid-run
/// for every node regardless of straggler factors).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TimeSpec {
    Abs(f64),
    Frac(f64),
}

impl TimeSpec {
    fn resolve(&self, est_horizon: f64) -> f64 {
        match self {
            TimeSpec::Abs(t) => *t,
            TimeSpec::Frac(f) => f * est_horizon,
        }
    }
}

/// One parsed (not yet materialized) schedule entry.
#[derive(Clone, Debug, PartialEq)]
pub struct SpecEvent {
    pub at: TimeSpec,
    pub kind: ChurnKind,
    pub node: usize,
}

/// A parsed `churn:<spec>` — the experiment-level description of the
/// fault-injection schedule.  Default ([`ChurnSpec::none`]) is empty:
/// the membership-aware runtime degenerates to the fixed-roster PR-2
/// behavior bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnSpec {
    raw: String,
    events: Vec<SpecEvent>,
    /// `rand:<crashes>:<rejoins>:<seed>` — expanded at materialize time.
    rand: Option<(usize, usize, u64)>,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec::none()
    }
}

impl ChurnSpec {
    /// The empty schedule (no churn — the bit-identical default).
    pub fn none() -> Self {
        ChurnSpec { raw: "none".into(), events: Vec::new(), rand: None }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.rand.is_none()
    }

    /// The spec as written (for labels / reports).
    pub fn label(&self) -> &str {
        &self.raw
    }

    /// Parse `churn:<spec>` (the prefix is optional):
    ///
    /// ```text
    /// none
    /// crash@12.5:3                      absolute virtual seconds
    /// crash@35%:1,rejoin@75%:1          % of fastest node's horizon
    /// join@50%:8                        activate a brand-new node id
    /// rand:<crashes>:<rejoins>:<seed>   seed-driven random schedule
    /// ```
    pub fn parse(s: &str) -> Result<ChurnSpec> {
        let raw = s.trim();
        let body = raw.strip_prefix("churn:").unwrap_or(raw);
        if body.is_empty() || body == "none" {
            return Ok(ChurnSpec::none());
        }
        if let Some(rest) = body.strip_prefix("rand:") {
            let parts: Vec<&str> = rest.split(':').collect();
            ensure!(
                parts.len() == 3,
                "churn rand spec is rand:<crashes>:<rejoins>:<seed>, got {body:?}"
            );
            let crashes: usize = parts[0].parse()?;
            let rejoins: usize = parts[1].parse()?;
            let seed: u64 = match parts[2].strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16)?,
                None => parts[2].parse()?,
            };
            ensure!(crashes > 0, "rand churn needs at least one crash");
            return Ok(ChurnSpec {
                raw: body.to_string(),
                events: Vec::new(),
                rand: Some((crashes, rejoins, seed)),
            });
        }
        let mut events = Vec::new();
        for ev in body.split(',') {
            let ev = ev.trim();
            let (kind, rest) = ev
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("churn event {ev:?} is <kind>@<time>:<node>"))?;
            let (time, node) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("churn event {ev:?} is <kind>@<time>:<node>"))?;
            let at = match time.strip_suffix('%') {
                Some(p) => {
                    let f: f64 = p.parse()?;
                    ensure!((0.0..=100.0).contains(&f), "churn percent {f} out of [0,100]");
                    TimeSpec::Frac(f / 100.0)
                }
                None => {
                    let t: f64 = time.parse()?;
                    ensure!(t >= 0.0 && t.is_finite(), "churn time {t} must be finite and >= 0");
                    TimeSpec::Abs(t)
                }
            };
            events.push(SpecEvent { at, kind: ChurnKind::parse(kind)?, node: node.parse()? });
        }
        Ok(ChurnSpec { raw: body.to_string(), events, rand: None })
    }

    /// Highest node id the schedule mentions (a `join` may introduce ids
    /// beyond the initial roster; the runtime sizes its tables by
    /// `max(workers, max_node + 1)`).
    pub fn max_node(&self) -> Option<usize> {
        self.events.iter().map(|e| e.node).max()
    }

    /// Resolve the spec against a concrete run: `workers` initial nodes
    /// and an estimated horizon (fastest node's expected completion
    /// time, in virtual seconds).  Expands `rand:` deterministically and
    /// returns the event list sorted by firing time.
    pub fn materialize(&self, workers: usize, est_horizon: f64) -> Result<Vec<ChurnEvent>> {
        let mut out: Vec<ChurnEvent> = Vec::new();
        for e in &self.events {
            ensure!(e.node < 1024, "churn node id {} out of range", e.node);
            out.push(ChurnEvent { time: e.at.resolve(est_horizon), kind: e.kind, node: e.node });
        }
        if let Some((crashes, rejoins, seed)) = self.rand {
            ensure!(workers >= 2, "rand churn needs >= 2 workers");
            // victims drawn from 1..workers (node 0 always survives, so
            // the survivor-accuracy report has a stable rank-0)
            let mut rng = Rng::new(seed);
            let mut victims: Vec<usize> = (1..workers).collect();
            rng.shuffle(&mut victims);
            victims.truncate(crashes.min(workers - 1));
            for &v in &victims {
                let frac = 0.15 + 0.45 * rng.f64();
                out.push(ChurnEvent {
                    time: frac * est_horizon,
                    kind: ChurnKind::Crash,
                    node: v,
                });
            }
            for &v in victims.iter().take(rejoins.min(victims.len())) {
                let frac = 0.62 + 0.28 * rng.f64();
                out.push(ChurnEvent {
                    time: frac * est_horizon,
                    kind: ChurnKind::Rejoin,
                    node: v,
                });
            }
        }
        out.sort_by(|a, b| a.time.total_cmp(&b.time));
        Ok(out)
    }
}

/// The standard acceptance schedule — two of eight nodes crash mid-run,
/// one rejoins from its epoch checkpoint.  One definition shared by the
/// `churn-train` default, `examples/churn_study.rs`, `just bench-churn`
/// and the acceptance test, so they always measure the same scenario.
pub const STANDARD_CHURN: &str = "crash@30%:2,crash@45%:5,rejoin@70%:2";

/// A materialized schedule entry: fires at `time` on the virtual clock.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnEvent {
    pub time: f64,
    pub kind: ChurnKind,
    pub node: usize,
}

// ---------------------------------------------------------------------------
// membership view
// ---------------------------------------------------------------------------

/// Membership versioned in epochs: an alive bitset plus a compact sorted
/// alive-list, rebuilt once per membership event.  Queries and the
/// alive-constrained peer sampling that reads this view are
/// allocation-free between events (both buffers keep their capacity
/// across rebuilds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemberView {
    alive: Vec<bool>,
    alive_list: Vec<usize>,
    version: u64,
}

impl MemberView {
    /// `slots` total node slots, of which the first `initial` start
    /// alive (slots beyond the initial roster are reserved for `join`
    /// events).
    pub fn new(slots: usize, initial: usize) -> Self {
        let mut v = MemberView {
            alive: vec![false; slots],
            alive_list: Vec::with_capacity(slots),
            version: 0,
        };
        for a in v.alive.iter_mut().take(initial) {
            *a = true;
        }
        v.rebuild();
        v
    }

    fn rebuild(&mut self) {
        self.alive_list.clear();
        self.alive_list
            .extend(self.alive.iter().enumerate().filter_map(|(i, &a)| a.then_some(i)));
    }

    /// Mark `i` departed; bumps the membership version.
    pub fn kill(&mut self, i: usize) {
        debug_assert!(self.alive[i], "killing a dead node");
        self.alive[i] = false;
        self.version += 1;
        self.rebuild();
    }

    /// Mark `i` (re)joined; bumps the membership version.
    pub fn revive(&mut self, i: usize) {
        debug_assert!(!self.alive[i], "reviving a live node");
        self.alive[i] = true;
        self.version += 1;
        self.rebuild();
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.alive.get(i).copied().unwrap_or(false)
    }

    pub fn n_alive(&self) -> usize {
        self.alive_list.len()
    }

    pub fn slots(&self) -> usize {
        self.alive.len()
    }

    /// The membership epoch: bumped by every kill/revive.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn alive_flags(&self) -> &[bool] {
        &self.alive
    }

    /// Sorted list of alive node ids (rebuilt per membership epoch).
    pub fn alive_list(&self) -> &[usize] {
        &self.alive_list
    }

    /// Lowest-indexed alive node — the deterministic fallback recipient
    /// for reclaimed conserved state (dropped push-sum weight) and the
    /// survivor report's rank-0.
    pub fn first_alive(&self) -> Option<usize> {
        self.alive_list.first().copied()
    }
}

// ---------------------------------------------------------------------------
// run report
// ---------------------------------------------------------------------------

/// One applied (not skipped) membership event, with the membership
/// version after it.
#[derive(Clone, Debug, PartialEq)]
pub struct AppliedChurn {
    pub time: f64,
    pub kind: ChurnKind,
    pub node: usize,
    pub alive_after: usize,
    pub version: u64,
}

/// One completed join bootstrap: the donor's parameter digest at
/// pull time must equal the joiner's digest after adoption (the
/// bootstrap-correctness observable, property-tested).
#[derive(Clone, Debug, PartialEq)]
pub struct BootstrapRecord {
    pub joiner: usize,
    pub donor: usize,
    /// FNV digest of the donor's parameters when the pull was answered.
    pub donor_digest: u64,
    /// FNV digest of the joiner's parameters after adoption.
    pub adopted_digest: u64,
    /// The joiner's local step at adoption (0 for fresh joins, the
    /// checkpoint step for crash-recovery rejoins).
    pub restored_step: u64,
}

/// Everything the membership subsystem observed over one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MembershipReport {
    /// events in application order (skipped events — e.g. crashing an
    /// already-dead node — are not recorded)
    pub applied: Vec<AppliedChurn>,
    pub bootstraps: Vec<BootstrapRecord>,
    /// messages from departed senders that the strategy's churn rules
    /// refused — parked entries removed by the departure sweep plus
    /// in-flight deliveries rejected at the fabric.  For Elastic Gossip
    /// these are exactly the rolled-back pair terms; for gossip-pull
    /// they are requests from dead pullers.
    pub rolled_back_msgs: u64,
    /// alive count at each epoch evaluation (the per-epoch membership
    /// series next to the accuracy curve)
    pub per_epoch_alive: Vec<usize>,
    /// alive node ids at run end (the survivors the final accuracy
    /// report covers)
    pub final_alive: Vec<usize>,
}

impl MembershipReport {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert(
            "events",
            Json::Arr(
                self.applied
                    .iter()
                    .map(|e| {
                        let mut eo = JsonObj::new();
                        eo.insert("time", Json::Num(e.time));
                        eo.insert("kind", Json::Str(e.kind.label().into()));
                        eo.insert("node", Json::Num(e.node as f64));
                        eo.insert("alive_after", Json::Num(e.alive_after as f64));
                        eo.insert("version", Json::Num(e.version as f64));
                        Json::Obj(eo)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "bootstraps",
            Json::Arr(
                self.bootstraps
                    .iter()
                    .map(|b| {
                        let mut bo = JsonObj::new();
                        bo.insert("joiner", Json::Num(b.joiner as f64));
                        bo.insert("donor", Json::Num(b.donor as f64));
                        bo.insert("exact", Json::Bool(b.donor_digest == b.adopted_digest));
                        bo.insert("restored_step", Json::Num(b.restored_step as f64));
                        Json::Obj(bo)
                    })
                    .collect(),
            ),
        );
        o.insert("rolled_back_msgs", Json::Num(self.rolled_back_msgs as f64));
        o.insert(
            "per_epoch_alive",
            Json::Arr(self.per_epoch_alive.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        o.insert(
            "final_alive",
            Json::Arr(self.final_alive.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        Json::Obj(o)
    }
}

/// FNV-1a over the little-endian bytes of a flat parameter buffer — the
/// digest the bootstrap records pin (shared with the golden suite's
/// convention).
pub fn digest_params(p: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in p {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_prefix() {
        assert!(ChurnSpec::parse("none").unwrap().is_empty());
        assert!(ChurnSpec::parse("churn:none").unwrap().is_empty());
        assert!(ChurnSpec::parse("").unwrap().is_empty());
        assert_eq!(ChurnSpec::default(), ChurnSpec::none());
    }

    #[test]
    fn parse_event_list() {
        let s = ChurnSpec::parse("churn:crash@35%:1,rejoin@75%:1,join@12.5:8").unwrap();
        assert!(!s.is_empty());
        assert_eq!(s.events.len(), 3);
        assert_eq!(s.events[0], SpecEvent { at: TimeSpec::Frac(0.35), kind: ChurnKind::Crash, node: 1 });
        assert_eq!(s.events[2], SpecEvent { at: TimeSpec::Abs(12.5), kind: ChurnKind::Join, node: 8 });
        assert_eq!(s.max_node(), Some(8));
        assert_eq!(s.label(), "crash@35%:1,rejoin@75%:1,join@12.5:8");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ChurnSpec::parse("explode@10:1").is_err());
        assert!(ChurnSpec::parse("crash@1").is_err());
        assert!(ChurnSpec::parse("crash:1@2").is_err());
        assert!(ChurnSpec::parse("crash@150%:1").is_err());
        assert!(ChurnSpec::parse("crash@-3:1").is_err());
        assert!(ChurnSpec::parse("rand:2:1").is_err());
        assert!(ChurnSpec::parse("rand:0:0:7").is_err());
    }

    #[test]
    fn materialize_resolves_and_sorts() {
        let s = ChurnSpec::parse("rejoin@75%:1,crash@25%:1").unwrap();
        let evs = s.materialize(4, 100.0).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], ChurnEvent { time: 25.0, kind: ChurnKind::Crash, node: 1 });
        assert_eq!(evs[1], ChurnEvent { time: 75.0, kind: ChurnKind::Rejoin, node: 1 });
    }

    #[test]
    fn materialize_rand_is_deterministic_and_spares_node_zero() {
        let s = ChurnSpec::parse("rand:3:2:42").unwrap();
        let a = s.materialize(8, 100.0).unwrap();
        let b = s.materialize(8, 100.0).unwrap();
        assert_eq!(a, b, "rand schedule must reproduce from its seed");
        let crashes: Vec<&ChurnEvent> = a.iter().filter(|e| e.kind == ChurnKind::Crash).collect();
        let rejoins: Vec<&ChurnEvent> = a.iter().filter(|e| e.kind == ChurnKind::Rejoin).collect();
        assert_eq!(crashes.len(), 3);
        assert_eq!(rejoins.len(), 2);
        for e in &a {
            assert_ne!(e.node, 0, "node 0 must survive rand schedules");
            assert!(e.time > 0.0 && e.time < 100.0);
        }
        // every rejoin targets a previously crashed node, later in time
        for r in &rejoins {
            let c = crashes.iter().find(|c| c.node == r.node).expect("rejoin of uncrashed node");
            assert!(r.time > c.time);
        }
        // a different seed gives a different trace
        let c = ChurnSpec::parse("rand:3:2:43").unwrap().materialize(8, 100.0).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn member_view_versioned_epochs() {
        let mut m = MemberView::new(6, 4);
        assert_eq!(m.n_alive(), 4);
        assert_eq!(m.alive_list(), &[0, 1, 2, 3]);
        assert!(!m.is_alive(4), "slots beyond the roster start dead");
        assert_eq!(m.version(), 0);
        m.kill(1);
        assert_eq!(m.version(), 1);
        assert_eq!(m.alive_list(), &[0, 2, 3]);
        assert_eq!(m.first_alive(), Some(0));
        m.revive(4);
        assert_eq!(m.version(), 2);
        assert_eq!(m.alive_list(), &[0, 2, 3, 4]);
        m.kill(0);
        assert_eq!(m.first_alive(), Some(2));
        assert!(!m.is_alive(100), "out-of-range ids are dead");
    }

    #[test]
    fn member_view_rebuild_keeps_capacity() {
        let mut m = MemberView::new(8, 8);
        let cap = (m.alive_list.as_ptr(), m.alive_list.capacity());
        for i in 1..8 {
            m.kill(i);
        }
        for i in 1..8 {
            m.revive(i);
        }
        assert_eq!(
            (m.alive_list.as_ptr(), m.alive_list.capacity()),
            cap,
            "epoch rebuilds must not reallocate"
        );
    }

    #[test]
    fn digest_is_order_and_bit_sensitive() {
        assert_ne!(digest_params(&[1.0, 2.0]), digest_params(&[2.0, 1.0]));
        assert_ne!(digest_params(&[0.0]), digest_params(&[-0.0]));
        assert_eq!(digest_params(&[f32::NAN]), digest_params(&[f32::NAN]));
    }

    #[test]
    fn report_json_shape() {
        let mut r = MembershipReport::default();
        r.applied.push(AppliedChurn {
            time: 1.5,
            kind: ChurnKind::Crash,
            node: 2,
            alive_after: 3,
            version: 1,
        });
        r.bootstraps.push(BootstrapRecord {
            joiner: 2,
            donor: 0,
            donor_digest: 7,
            adopted_digest: 7,
            restored_step: 40,
        });
        r.per_epoch_alive = vec![4, 3];
        r.final_alive = vec![0, 1, 3];
        let s = crate::manifest::json::write(&r.to_json());
        let back = crate::manifest::json::parse(&s).unwrap();
        assert_eq!(back.path(&["rolled_back_msgs"]).as_f64(), Some(0.0));
        assert_eq!(back.path(&["events"]).as_arr().unwrap().len(), 1);
        assert_eq!(back.path(&["final_alive"]).as_arr().unwrap().len(), 3);
    }
}
