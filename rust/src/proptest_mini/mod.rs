//! A minimal property-testing harness (the vendored dependency set has no
//! `proptest`/`quickcheck`).
//!
//! Usage:
//!
//! ```no_run
//! use elastic_gossip::proptest_mini::{forall, prop_assert};
//! forall("addition commutes", 200, |g| {
//!     let a = g.f32_in(-100.0, 100.0);
//!     let b = g.f32_in(-100.0, 100.0);
//!     prop_assert(a + b == b + a, format!("{a} + {b}"))
//! });
//! ```
//!
//! Failures report the generator seed and case index so a run can be
//! replayed exactly (`replay(seed, case, f)`), plus a size-ramped retry
//! that approximates shrinking: cases are generated small-first, so the
//! first failing case is usually near-minimal.

use crate::util::rng::Rng;

/// Random-value source handed to properties; sizes ramp up with the case
/// index so early failures are small.
pub struct Gen {
    rng: Rng,
    /// 0.0..=1.0 — fraction of the size budget unlocked for this case
    ramp: f64,
}

impl Gen {
    pub fn new(seed: u64, ramp: f64) -> Self {
        Gen { rng: Rng::new(seed), ramp: ramp.clamp(0.0, 1.0) }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in [lo, hi], ramped: early cases stay near lo.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.ramp).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_gauss(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.gauss_f32()).collect()
    }

    /// A boolean mask with each bit true with probability p.
    pub fn mask(&mut self, len: usize, p: f64) -> Vec<bool> {
        (0..len).map(|_| self.rng.bernoulli(p)).collect()
    }
}

/// Property outcome: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert approximate equality of two f32 slices.
pub fn prop_close(a: &[f32], b: &[f32], tol: f32, what: &str) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol + tol * x.abs().max(y.abs()) {
            return Err(format!("{what}: [{i}] {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Case-count multiplier from the environment: the nightly CI cron sets
/// `EG_PROPTEST_CASES_X=10` so properties get 10x the cases without the
/// per-commit suite paying for it.  Unset/invalid/zero means 1.
fn cases_multiplier() -> u64 {
    std::env::var("EG_PROPTEST_CASES_X")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&x| x >= 1)
        .unwrap_or(1)
}

/// Run `cases` random cases of property `f` (scaled by
/// `EG_PROPTEST_CASES_X`); panic with replay info on the first failure.
/// The seed derives from the property name, so adding a property
/// elsewhere never perturbs this one's cases.
pub fn forall(name: &str, cases: u64, f: impl FnMut(&mut Gen) -> PropResult) {
    forall_scaled(name, cases.saturating_mul(cases_multiplier()), f)
}

fn forall_scaled(name: &str, cases: u64, mut f: impl FnMut(&mut Gen) -> PropResult) {
    let mut h: u64 = 0x9E3779B97F4A7C15;
    for b in name.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let ramp = (case + 1) as f64 / cases as f64;
        let seed = h.wrapping_add(case.wrapping_mul(0x2545F4914F6CDD1D));
        let mut g = Gen::new(seed, ramp);
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}, ramp {ramp:.2}):\n  {msg}\n  replay: proptest_mini::replay({seed:#x}, {ramp:.4}, f)"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn replay(seed: u64, ramp: f64, f: impl Fn(&mut Gen) -> PropResult) -> PropResult {
    let mut g = Gen::new(seed, ramp);
    f(&mut g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        forall("trivially true", 50, |g| {
            let _ = g.usize_in(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_replay_info() {
        forall("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn ramp_makes_early_cases_small() {
        let mut first_size = None;
        let mut last_size = 0;
        forall("ramp check", 100, |g| {
            let n = g.usize_in(0, 1000);
            if first_size.is_none() {
                first_size = Some(n);
            }
            last_size = n;
            Ok(())
        });
        assert!(first_size.unwrap() <= 10, "{first_size:?}");
    }

    #[test]
    fn scaled_entry_point_runs_exactly_the_requested_cases() {
        let mut count = 0u64;
        forall_scaled("scaled count", 30, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 30);
    }

    #[test]
    fn replay_reproduces() {
        let f = |g: &mut Gen| -> PropResult {
            let v = g.vec_f32(5, -1.0, 1.0);
            Err(format!("{v:?}"))
        };
        let a = replay(0x1234, 0.5, f).unwrap_err();
        let b = replay(0x1234, 0.5, f).unwrap_err();
        assert_eq!(a, b);
    }

    #[test]
    fn prop_close_catches_mismatch() {
        assert!(prop_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, "x").is_ok());
        assert!(prop_close(&[1.0], &[1.1], 1e-3, "x").is_err());
        assert!(prop_close(&[1.0], &[1.0, 2.0], 1e-3, "x").is_err());
    }
}
