//! Worker interconnect topologies and peer sampling.
//!
//! The paper's experiments assume a fully-connected topology with uniform
//! communication cost (§5 conclusion), and its future-work section calls
//! out topology-aware protocols.  We implement Full plus Ring, Torus2D
//! and RandomRegular so the gossip strategies can be studied under
//! constrained connectivity (`examples/topology_study.rs`).

use crate::util::rng::Rng;

/// Interconnect shape; `neighbors(i)` defines who `i` may gossip with.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Every pair connected (the paper's setting).
    Full,
    /// Bidirectional ring: i <-> i±1 (mod n).
    Ring,
    /// 2D torus of given width; workers laid out row-major. Requires
    /// `n % width == 0`.
    Torus2D { width: usize },
    /// Random d-regular-ish graph (union of d random perfect matchings,
    /// deduplicated), deterministic in `seed`.
    RandomRegular { degree: usize, seed: u64 },
}

impl Topology {
    pub fn parse(s: &str) -> anyhow::Result<Topology> {
        let s = s.trim();
        if s == "full" {
            return Ok(Topology::Full);
        }
        if s == "ring" {
            return Ok(Topology::Ring);
        }
        if let Some(w) = s.strip_prefix("torus:") {
            return Ok(Topology::Torus2D { width: w.parse()? });
        }
        if let Some(d) = s.strip_prefix("regular:") {
            return Ok(Topology::RandomRegular { degree: d.parse()?, seed: 0xE1A57 });
        }
        anyhow::bail!("unknown topology {s:?} (full | ring | torus:W | regular:D)")
    }

    /// Adjacency list for `i` in a world of `n` workers, sorted ascending.
    pub fn neighbors(&self, i: usize, n: usize) -> Vec<usize> {
        assert!(i < n);
        if n <= 1 {
            return vec![];
        }
        let mut out = match self {
            Topology::Full => (0..n).filter(|&j| j != i).collect(),
            Topology::Ring => {
                if n == 2 {
                    vec![1 - i]
                } else {
                    vec![(i + n - 1) % n, (i + 1) % n]
                }
            }
            Topology::Torus2D { width } => {
                let w = *width;
                assert!(w > 0 && n % w == 0, "torus width {w} must divide n={n}");
                let h = n / w;
                let (r, c) = (i / w, i % w);
                let mut v = vec![
                    ((r + h - 1) % h) * w + c,
                    ((r + 1) % h) * w + c,
                    r * w + (c + w - 1) % w,
                    r * w + (c + 1) % w,
                ];
                v.retain(|&j| j != i);
                v
            }
            Topology::RandomRegular { degree, seed } => {
                let adj = random_regular_adjacency(n, *degree, *seed);
                adj[i].clone()
            }
        };
        out.sort();
        out.dedup();
        out
    }

    /// Sample a gossip peer for `i` uniformly among its neighbors.
    pub fn sample_peer(&self, i: usize, n: usize, rng: &mut Rng) -> Option<usize> {
        let nb = self.neighbors(i, n);
        if nb.is_empty() {
            None
        } else {
            Some(*rng.choose(&nb))
        }
    }

    /// True if the graph is connected (BFS).
    pub fn is_connected(&self, n: usize) -> bool {
        if n <= 1 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for v in self.neighbors(u, n) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == n
    }
}

/// Union of `degree` random matchings on n nodes (n even or one node idles
/// per matching), deterministic in seed.  Guarantees symmetry.
fn random_regular_adjacency(n: usize, degree: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut adj = vec![Vec::new(); n];
    let mut rng = Rng::new(seed ^ (n as u64) << 32 ^ degree as u64);
    for _ in 0..degree {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for pair in order.chunks_exact(2) {
            let (a, b) = (pair[0], pair[1]);
            if a != b && !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
    }
    // ensure connectivity by adding a ring as backstop (keeps degree small)
    for i in 0..n {
        let j = (i + 1) % n;
        if i != j && !adj[i].contains(&j) {
            adj[i].push(j);
            adj[j].push(i);
        }
    }
    adj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_neighbors() {
        let t = Topology::Full;
        assert_eq!(t.neighbors(1, 4), vec![0, 2, 3]);
        assert_eq!(t.neighbors(0, 1), Vec::<usize>::new());
    }

    #[test]
    fn ring_neighbors() {
        let t = Topology::Ring;
        assert_eq!(t.neighbors(0, 5), vec![1, 4]);
        assert_eq!(t.neighbors(2, 5), vec![1, 3]);
        assert_eq!(t.neighbors(0, 2), vec![1]); // no duplicate edge at n=2
    }

    #[test]
    fn torus_neighbors() {
        let t = Topology::Torus2D { width: 3 };
        // 3x3 torus, node 4 is the center: up 1, down 7, left 3, right 5
        assert_eq!(t.neighbors(4, 9), vec![1, 3, 5, 7]);
    }

    #[test]
    #[should_panic]
    fn torus_requires_divisible() {
        Topology::Torus2D { width: 3 }.neighbors(0, 8);
    }

    #[test]
    fn regular_symmetric_and_connected() {
        let t = Topology::RandomRegular { degree: 3, seed: 9 };
        let n = 16;
        for i in 0..n {
            for j in t.neighbors(i, n) {
                assert!(t.neighbors(j, n).contains(&i), "asymmetric edge {i}-{j}");
            }
        }
        assert!(t.is_connected(n));
    }

    #[test]
    fn all_connected() {
        for t in [
            Topology::Full,
            Topology::Ring,
            Topology::Torus2D { width: 4 },
            Topology::RandomRegular { degree: 2, seed: 3 },
        ] {
            assert!(t.is_connected(8), "{t:?} disconnected");
        }
    }

    #[test]
    fn sample_peer_is_neighbor() {
        let t = Topology::Ring;
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let p = t.sample_peer(3, 8, &mut rng).unwrap();
            assert!(t.neighbors(3, 8).contains(&p));
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Topology::parse("full").unwrap(), Topology::Full);
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert_eq!(Topology::parse("torus:4").unwrap(), Topology::Torus2D { width: 4 });
        assert!(matches!(Topology::parse("regular:3").unwrap(), Topology::RandomRegular { degree: 3, .. }));
        assert!(Topology::parse("blah").is_err());
    }
}
