//! Worker interconnect topologies and peer sampling.
//!
//! The paper's experiments assume a fully-connected topology with uniform
//! communication cost (§5 conclusion), and its future-work section calls
//! out topology-aware protocols.  We implement Full plus Ring, Torus2D
//! and RandomRegular so the gossip strategies can be studied under
//! constrained connectivity (`examples/topology_study.rs`).

use crate::util::rng::Rng;

/// Interconnect shape; `neighbors(i)` defines who `i` may gossip with.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Every pair connected (the paper's setting).
    Full,
    /// Bidirectional ring: i <-> i±1 (mod n).
    Ring,
    /// 2D torus of given width; workers laid out row-major. Requires
    /// `n % width == 0`.
    Torus2D { width: usize },
    /// Random d-regular-ish graph (union of d random perfect matchings,
    /// deduplicated), deterministic in `seed`.
    RandomRegular { degree: usize, seed: u64 },
}

/// Default random-regular seed, kept for configs written before the
/// topology grammar accepted an explicit seed.
pub const DEFAULT_RANDREG_SEED: u64 = 0xE1A57;

impl Topology {
    pub fn parse(s: &str) -> anyhow::Result<Topology> {
        let s = s.trim();
        if s == "full" {
            return Ok(Topology::Full);
        }
        if s == "ring" {
            return Ok(Topology::Ring);
        }
        if let Some(w) = s.strip_prefix("torus:") {
            return Ok(Topology::Torus2D { width: w.parse()? });
        }
        // `randreg:D:SEED` (and the legacy alias `regular:`) — the seed is
        // part of the experiment spec so random-regular studies reproduce
        // across configs; omitted seed falls back to the historical value.
        if let Some(rest) = s
            .strip_prefix("randreg:")
            .or_else(|| s.strip_prefix("regular:"))
        {
            let (degree, seed) = match rest.split_once(':') {
                Some((d, sd)) => {
                    let seed = match sd.strip_prefix("0x") {
                        Some(hex) => u64::from_str_radix(hex, 16)?,
                        None => sd.parse()?,
                    };
                    (d.parse()?, seed)
                }
                None => (rest.parse()?, DEFAULT_RANDREG_SEED),
            };
            return Ok(Topology::RandomRegular { degree, seed });
        }
        anyhow::bail!(
            "unknown topology {s:?} (full | ring | torus:W | randreg:D[:SEED])"
        )
    }

    /// Adjacency list for `i` in a world of `n` workers, sorted ascending.
    pub fn neighbors(&self, i: usize, n: usize) -> Vec<usize> {
        assert!(i < n);
        if n <= 1 {
            return vec![];
        }
        let mut out = match self {
            Topology::Full => (0..n).filter(|&j| j != i).collect(),
            Topology::Ring => {
                if n == 2 {
                    vec![1 - i]
                } else {
                    vec![(i + n - 1) % n, (i + 1) % n]
                }
            }
            Topology::Torus2D { width } => {
                let w = *width;
                assert!(w > 0 && n % w == 0, "torus width {w} must divide n={n}");
                let h = n / w;
                let (r, c) = (i / w, i % w);
                let mut v = vec![
                    ((r + h - 1) % h) * w + c,
                    ((r + 1) % h) * w + c,
                    r * w + (c + w - 1) % w,
                    r * w + (c + 1) % w,
                ];
                v.retain(|&j| j != i);
                v
            }
            Topology::RandomRegular { degree, seed } => {
                let adj = random_regular_adjacency(n, *degree, *seed);
                adj[i].clone()
            }
        };
        out.sort();
        out.dedup();
        out
    }

    /// Sample a gossip peer for `i` uniformly among its neighbors.
    pub fn sample_peer(&self, i: usize, n: usize, rng: &mut Rng) -> Option<usize> {
        let nb = self.neighbors(i, n);
        if nb.is_empty() {
            None
        } else {
            Some(*rng.choose(&nb))
        }
    }

    /// True if the graph is connected (BFS).
    pub fn is_connected(&self, n: usize) -> bool {
        if n <= 1 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for v in self.neighbors(u, n) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == n
    }
}

/// An ordered view of which nodes are alive — the sampling-side
/// abstraction over membership state.
///
/// The churn runtime's oracle view is dense (`&[bool]` plus a sorted
/// alive-list); the failure-detection plane's per-node [`LocalView`]s
/// are sparse (degree-sized delta sets over a `0..base_alive` prefix,
/// the fix for the fd O(W²) memory wall).  Both implement this trait,
/// and [`TopologyCache::sample_peer_alive_view`] consumes the rng
/// identically regardless of representation — swapping implementations
/// never moves a trajectory.
///
/// Contract: `kth_alive` enumerates the alive set in ascending node
/// order, and `alive_rank(i)` counts alive nodes strictly below `i`
/// (so `kth_alive(alive_rank(i)) == i` whenever `i` is alive).
///
/// [`LocalView`]: crate::membership::LocalView
pub trait AliveView {
    fn n_alive(&self) -> usize;
    fn is_alive(&self, i: usize) -> bool;
    /// The `k`-th alive node in ascending order; `k < n_alive()`.
    fn kth_alive(&self, k: usize) -> usize;
    /// Number of alive nodes strictly below `i`.
    fn alive_rank(&self, i: usize) -> usize;
}

/// Dense [`AliveView`]: the oracle membership representation (`alive`
/// flags plus the sorted alive-list kept by
/// [`MemberView`](crate::membership::MemberView)).
pub struct DenseAlive<'a> {
    pub alive: &'a [bool],
    pub list: &'a [usize],
}

impl AliveView for DenseAlive<'_> {
    fn n_alive(&self) -> usize {
        self.list.len()
    }
    fn is_alive(&self, i: usize) -> bool {
        self.alive.get(i).copied().unwrap_or(false)
    }
    fn kth_alive(&self, k: usize) -> usize {
        self.list[k]
    }
    fn alive_rank(&self, i: usize) -> usize {
        self.list.partition_point(|&x| x < i)
    }
}

/// Cached CSR adjacency for allocation-free peer sampling.
///
/// `Topology::neighbors` materializes a fresh `Vec` per call, and
/// `RandomRegular` rebuilds the *entire* matching union on every query —
/// per gossip pick, in the hot loop.  The cache builds the adjacency once
/// per `(topology, n)` and then samples without touching the allocator:
///
/// * Full / Ring — closed-form index arithmetic, no storage at all;
/// * Torus2D / RandomRegular — one CSR (`off`/`items`) built by `ensure`,
///   reused until the key changes (buffer capacity persists across
///   rebuilds, so a long-lived cache settles to zero allocation).
///
/// Sampling is rng-compatible with [`Topology::sample_peer`]: rows are
/// sorted ascending exactly like `neighbors`, and one `below(degree)`
/// draw selects the peer — the same stream position yields the same peer,
/// which is what keeps cached matchmaking bit-identical to the reference
/// (`rust/src/algos/scratch.rs` tests assert this per topology).
#[derive(Debug, Default)]
pub struct TopologyCache {
    key: Option<(Topology, usize)>,
    off: Vec<usize>,
    items: Vec<usize>,
}

impl TopologyCache {
    pub fn new() -> Self {
        TopologyCache::default()
    }

    /// Build (or reuse) the adjacency for `(topo, n)`. Idempotent: a
    /// matching key returns immediately without touching any buffer.
    pub fn ensure(&mut self, topo: &Topology, n: usize) {
        if self
            .key
            .as_ref()
            .map_or(false, |(t, m)| t == topo && *m == n)
        {
            return;
        }
        self.off.clear();
        self.items.clear();
        match topo {
            Topology::Full | Topology::Ring => {} // closed-form sampling
            Topology::RandomRegular { degree, seed } => {
                // one whole-graph build instead of n (the per-call rebuild
                // this cache exists to kill)
                let adj = random_regular_adjacency(n, *degree, *seed);
                self.off.push(0);
                for mut row in adj {
                    row.sort();
                    row.dedup();
                    self.items.extend(row);
                    self.off.push(self.items.len());
                }
            }
            Topology::Torus2D { .. } => {
                self.off.push(0);
                for i in 0..n {
                    self.items.extend(topo.neighbors(i, n));
                    self.off.push(self.items.len());
                }
            }
        }
        self.key = Some((topo.clone(), n));
    }

    /// Cached adjacency row (CSR-backed topologies only).
    pub fn neighbors(&self, i: usize) -> Option<&[usize]> {
        if self.off.is_empty() {
            None
        } else {
            Some(&self.items[self.off[i]..self.off[i + 1]])
        }
    }

    /// Sample a gossip peer for `i` — allocation-free, and consuming the
    /// rng identically to [`Topology::sample_peer`].
    pub fn sample_peer(&self, i: usize, rng: &mut Rng) -> Option<usize> {
        let (topo, n) = self.key.as_ref().expect("TopologyCache::ensure first");
        let n = *n;
        match topo {
            Topology::Full => {
                if n <= 1 {
                    None
                } else {
                    // sorted neighbors of i under Full are 0..i ++ i+1..n:
                    // index j maps to j (j < i) or j + 1 (j >= i)
                    let j = rng.below(n - 1);
                    Some(if j < i { j } else { j + 1 })
                }
            }
            Topology::Ring => {
                if n <= 1 {
                    None
                } else if n == 2 {
                    // single neighbor; `choose` still consumes one draw
                    let _ = rng.below(1);
                    Some(1 - i)
                } else {
                    let a = (i + n - 1) % n;
                    let b = (i + 1) % n;
                    let (lo, hi) = (a.min(b), a.max(b));
                    Some(if rng.below(2) == 0 { lo } else { hi })
                }
            }
            _ => {
                let nb = &self.items[self.off[i]..self.off[i + 1]];
                if nb.is_empty() {
                    None
                } else {
                    Some(nb[rng.below(nb.len())])
                }
            }
        }
    }

    /// Sample a gossip peer for `i` uniformly among its **alive**
    /// neighbors — the churn-mode counterpart of
    /// [`sample_peer`](Self::sample_peer).
    ///
    /// `alive` / `alive_list` come from a
    /// [`MemberView`](crate::membership::MemberView), rebuilt once per
    /// membership epoch; within an epoch this is allocation-free for
    /// every topology (Full maps a uniform draw over the sorted
    /// alive-list via binary search, Ring filters its ≤ 2 neighbors on
    /// the stack, CSR rows are count-then-scan).  Returns `None` when
    /// every neighbor is dead — the sampler skips the exchange.  This
    /// path consumes a *different* rng stream than the fixed-roster
    /// tables, so the no-churn trajectory is untouched.
    pub fn sample_peer_alive(
        &self,
        i: usize,
        alive: &[bool],
        alive_list: &[usize],
        rng: &mut Rng,
    ) -> Option<usize> {
        self.sample_peer_alive_view(i, &DenseAlive { alive, list: alive_list }, rng)
    }

    /// [`sample_peer_alive`](Self::sample_peer_alive) over any
    /// [`AliveView`] — the failure-detection plane samples through its
    /// sparse per-node views here.  The rng consumption per topology is
    /// identical for every implementation (Full: one draw mapped
    /// through rank arithmetic; Ring: one draw over ≤ 2 stack
    /// candidates; CSR: count-then-scan), so dense and sparse views
    /// with the same alive set produce the same peer sequence.
    pub fn sample_peer_alive_view(
        &self,
        i: usize,
        view: &dyn AliveView,
        rng: &mut Rng,
    ) -> Option<usize> {
        let (topo, n) = self.key.as_ref().expect("TopologyCache::ensure first");
        let n = *n;
        match topo {
            Topology::Full => {
                let self_alive = view.is_alive(i);
                let m = view.n_alive() - usize::from(self_alive);
                if m == 0 {
                    return None;
                }
                let j = rng.below(m);
                if self_alive {
                    let r = view.alive_rank(i);
                    Some(if j < r { view.kth_alive(j) } else { view.kth_alive(j + 1) })
                } else {
                    Some(view.kth_alive(j))
                }
            }
            Topology::Ring => {
                if n <= 1 {
                    return None;
                }
                let mut cand = [0usize; 2];
                let mut cnt = 0usize;
                if n == 2 {
                    let j = 1 - i;
                    if view.is_alive(j) {
                        cand[cnt] = j;
                        cnt += 1;
                    }
                } else {
                    let a = (i + n - 1) % n;
                    let b = (i + 1) % n;
                    let (lo, hi) = (a.min(b), a.max(b));
                    if view.is_alive(lo) {
                        cand[cnt] = lo;
                        cnt += 1;
                    }
                    if hi != lo && view.is_alive(hi) {
                        cand[cnt] = hi;
                        cnt += 1;
                    }
                }
                if cnt == 0 {
                    None
                } else {
                    Some(cand[rng.below(cnt)])
                }
            }
            _ => {
                let nb = &self.items[self.off[i]..self.off[i + 1]];
                let cnt = nb.iter().filter(|&&j| view.is_alive(j)).count();
                if cnt == 0 {
                    return None;
                }
                let mut r = rng.below(cnt);
                for &j in nb {
                    if view.is_alive(j) {
                        if r == 0 {
                            return Some(j);
                        }
                        r -= 1;
                    }
                }
                unreachable!("alive neighbor count changed mid-scan")
            }
        }
    }

    /// Capacity fingerprint of the CSR buffers (allocation-freedom tests).
    pub fn footprint_parts(&self) -> [(usize, usize); 2] {
        [
            (self.off.as_ptr() as usize, self.off.capacity()),
            (self.items.as_ptr() as usize, self.items.capacity()),
        ]
    }
}

/// Union of `degree` random matchings on n nodes (n even or one node idles
/// per matching), deterministic in seed.  Guarantees symmetry.
fn random_regular_adjacency(n: usize, degree: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut adj = vec![Vec::new(); n];
    let mut rng = Rng::new(seed ^ (n as u64) << 32 ^ degree as u64);
    for _ in 0..degree {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for pair in order.chunks_exact(2) {
            let (a, b) = (pair[0], pair[1]);
            if a != b && !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
    }
    // ensure connectivity by adding a ring as backstop (keeps degree small)
    for i in 0..n {
        let j = (i + 1) % n;
        if i != j && !adj[i].contains(&j) {
            adj[i].push(j);
            adj[j].push(i);
        }
    }
    adj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_neighbors() {
        let t = Topology::Full;
        assert_eq!(t.neighbors(1, 4), vec![0, 2, 3]);
        assert_eq!(t.neighbors(0, 1), Vec::<usize>::new());
    }

    #[test]
    fn ring_neighbors() {
        let t = Topology::Ring;
        assert_eq!(t.neighbors(0, 5), vec![1, 4]);
        assert_eq!(t.neighbors(2, 5), vec![1, 3]);
        assert_eq!(t.neighbors(0, 2), vec![1]); // no duplicate edge at n=2
    }

    #[test]
    fn torus_neighbors() {
        let t = Topology::Torus2D { width: 3 };
        // 3x3 torus, node 4 is the center: up 1, down 7, left 3, right 5
        assert_eq!(t.neighbors(4, 9), vec![1, 3, 5, 7]);
    }

    #[test]
    #[should_panic]
    fn torus_requires_divisible() {
        Topology::Torus2D { width: 3 }.neighbors(0, 8);
    }

    #[test]
    fn regular_symmetric_and_connected() {
        let t = Topology::RandomRegular { degree: 3, seed: 9 };
        let n = 16;
        for i in 0..n {
            for j in t.neighbors(i, n) {
                assert!(t.neighbors(j, n).contains(&i), "asymmetric edge {i}-{j}");
            }
        }
        assert!(t.is_connected(n));
    }

    #[test]
    fn all_connected() {
        for t in [
            Topology::Full,
            Topology::Ring,
            Topology::Torus2D { width: 4 },
            Topology::RandomRegular { degree: 2, seed: 3 },
        ] {
            assert!(t.is_connected(8), "{t:?} disconnected");
        }
    }

    #[test]
    fn sample_peer_is_neighbor() {
        let t = Topology::Ring;
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let p = t.sample_peer(3, 8, &mut rng).unwrap();
            assert!(t.neighbors(3, 8).contains(&p));
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Topology::parse("full").unwrap(), Topology::Full);
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert_eq!(Topology::parse("torus:4").unwrap(), Topology::Torus2D { width: 4 });
        assert!(matches!(Topology::parse("regular:3").unwrap(), Topology::RandomRegular { degree: 3, .. }));
        assert!(Topology::parse("blah").is_err());
    }

    #[test]
    fn parse_randreg_seed_grammar() {
        // explicit seed, both spellings
        assert_eq!(
            Topology::parse("randreg:3:42").unwrap(),
            Topology::RandomRegular { degree: 3, seed: 42 }
        );
        assert_eq!(
            Topology::parse("regular:2:0xBEEF").unwrap(),
            Topology::RandomRegular { degree: 2, seed: 0xBEEF }
        );
        // omitted seed keeps the historical default (config back-compat)
        assert_eq!(
            Topology::parse("randreg:4").unwrap(),
            Topology::RandomRegular { degree: 4, seed: DEFAULT_RANDREG_SEED }
        );
        assert!(Topology::parse("randreg:x:1").is_err());
        assert!(Topology::parse("randreg:3:zz").is_err());
    }

    #[test]
    fn randreg_seed_changes_graph() {
        let a = Topology::RandomRegular { degree: 2, seed: 1 };
        let b = Topology::RandomRegular { degree: 2, seed: 2 };
        let n = 16;
        let edges = |t: &Topology| -> Vec<Vec<usize>> { (0..n).map(|i| t.neighbors(i, n)).collect() };
        assert_ne!(edges(&a), edges(&b), "different seeds must give different graphs");
        assert_eq!(edges(&a), edges(&a), "same seed must reproduce");
    }

    #[test]
    fn cache_samples_match_reference_for_all_topologies() {
        for topo in [
            Topology::Full,
            Topology::Ring,
            Topology::Torus2D { width: 4 },
            Topology::RandomRegular { degree: 3, seed: 11 },
        ] {
            let n = 16;
            let mut cache = TopologyCache::new();
            cache.ensure(&topo, n);
            // identical rng stream -> identical peer sequence
            let mut ra = Rng::new(5);
            let mut rb = Rng::new(5);
            for i in 0..n {
                for _ in 0..20 {
                    assert_eq!(
                        cache.sample_peer(i, &mut ra),
                        topo.sample_peer(i, n, &mut rb),
                        "{topo:?} diverged at worker {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn cache_neighbors_match_and_are_stable() {
        let topo = Topology::RandomRegular { degree: 2, seed: 9 };
        let n = 12;
        let mut cache = TopologyCache::new();
        cache.ensure(&topo, n);
        for i in 0..n {
            assert_eq!(cache.neighbors(i).unwrap(), &topo.neighbors(i, n)[..]);
        }
        // re-ensure with the same key must not move the CSR buffers
        let fp = cache.footprint_parts();
        for _ in 0..10 {
            cache.ensure(&topo, n);
        }
        assert_eq!(cache.footprint_parts(), fp, "idempotent ensure reallocated");
        // key change rebuilds
        cache.ensure(&Topology::Full, n);
        assert!(cache.neighbors(0).is_none(), "Full is closed-form, no CSR");
    }

    #[test]
    fn alive_sampling_matches_membership_for_all_topologies() {
        // sample_peer_alive must only ever return alive neighbors, be
        // uniform over them, and degrade to None when the neighborhood
        // is dead
        for topo in [
            Topology::Full,
            Topology::Ring,
            Topology::Torus2D { width: 4 },
            Topology::RandomRegular { degree: 3, seed: 11 },
        ] {
            let n = 16;
            let mut cache = TopologyCache::new();
            cache.ensure(&topo, n);
            let mut alive = vec![true; n];
            for dead in [3usize, 7, 12] {
                alive[dead] = false;
            }
            let alive_list: Vec<usize> =
                (0..n).filter(|&i| alive[i]).collect();
            let mut rng = Rng::new(9);
            for i in (0..n).filter(|&i| alive[i]) {
                let nb = topo.neighbors(i, n);
                let live_nb: Vec<usize> = nb.iter().copied().filter(|&j| alive[j]).collect();
                let mut seen = std::collections::BTreeSet::new();
                for _ in 0..400 {
                    match cache.sample_peer_alive(i, &alive, &alive_list, &mut rng) {
                        Some(p) => {
                            assert!(live_nb.contains(&p), "{topo:?}: {i} sampled dead/non-neighbor {p}");
                            seen.insert(p);
                        }
                        None => assert!(live_nb.is_empty(), "{topo:?}: {i} gave up with live neighbors"),
                    }
                }
                if !live_nb.is_empty() {
                    assert_eq!(
                        seen.into_iter().collect::<Vec<_>>(),
                        live_nb,
                        "{topo:?}: {i} did not cover its live neighborhood"
                    );
                }
            }
        }
    }

    #[test]
    fn alive_sampling_with_all_peers_dead_is_none() {
        let mut cache = TopologyCache::new();
        cache.ensure(&Topology::Full, 4);
        let alive = vec![true, false, false, false];
        let alive_list = vec![0usize];
        assert_eq!(cache.sample_peer_alive(0, &alive, &alive_list, &mut Rng::new(1)), None);
        // ring: both neighbors of node 2 dead, the far node alive
        let mut cache = TopologyCache::new();
        cache.ensure(&Topology::Ring, 4);
        let alive = vec![true, false, true, false];
        let alive_list = vec![0usize, 2];
        assert_eq!(cache.sample_peer_alive(2, &alive, &alive_list, &mut Rng::new(1)), None);
        assert_eq!(cache.sample_peer_alive(0, &alive, &alive_list, &mut Rng::new(1)), None);
    }

    #[test]
    fn cache_single_worker_has_no_peer() {
        let mut cache = TopologyCache::new();
        cache.ensure(&Topology::Full, 1);
        assert_eq!(cache.sample_peer(0, &mut Rng::new(0)), None);
        let mut cache = TopologyCache::new();
        cache.ensure(&Topology::Ring, 2);
        assert_eq!(cache.sample_peer(0, &mut Rng::new(0)), Some(1));
    }
}
