//! Communication fabric: message accounting + simulated network costs.
//!
//! The coordinator simulates a cluster inside one process, so "sending" a
//! parameter vector is a memcpy — but the *accounting* is real: every
//! strategy routes its transfers through `Fabric::send`, which records
//! per-link bytes and message counts and advances a simulated network
//! clock using a simple `latency + bytes/bandwidth` cost model.  That is
//! what lets the benches quantify the paper's headline claim (gossip
//! methods need a small fraction of All-reduce's traffic) and lets the
//! async simulator (`sim`) reason about stragglers.

pub mod codec;
pub mod transport;

use std::collections::BTreeMap;

use crate::trace::{Ctr, Gauge, Registry};

/// Link cost model: `time(bytes) = latency_s + bytes / bandwidth_Bps`.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

impl Default for LinkModel {
    /// 25 us latency, 10 Gbit/s — a commodity-cluster Ethernet figure,
    /// matching the paper's "cloud computing" deployment assumption.
    fn default() -> Self {
        LinkModel {
            latency_s: 25e-6,
            bandwidth_bps: 10e9 / 8.0,
        }
    }
}

impl LinkModel {
    pub fn transfer_time_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// The ideal link: zero latency, infinite bandwidth.  Under this
    /// model the event-driven runtime's message deliveries collapse onto
    /// their send instants — the lockstep special case in which the
    /// asynchronous machinery reproduces the synchronous round exactly.
    pub fn zero() -> Self {
        LinkModel {
            latency_s: 0.0,
            bandwidth_bps: f64::INFINITY,
        }
    }
}

/// Aggregated traffic statistics — a *view* assembled by
/// [`Fabric::report`] from the unified counter [`Registry`]
/// (`trace::Registry`) plus the optional per-link detail maps.  The
/// field set and semantics predate the registry and are pinned by the
/// golden fixtures; only the backing store moved.
#[derive(Clone, Debug, Default)]
pub struct TrafficReport {
    pub total_bytes: u64,
    /// bytes actually on the wire after payload encoding — equals
    /// `total_bytes` unless a codec (`comm::codec`) shrank the payloads
    /// ([`Fabric::send_async_coded`] on the event-driven fabric,
    /// [`Fabric::send_coded`] / the [`Fabric::set_param_wire`] hint on
    /// synchronous rounds); the link model prices transfers by this
    /// number
    pub wire_bytes: u64,
    pub total_messages: u64,
    /// async mode with membership churn: messages that could not be
    /// delivered (receiver departed before the delivery instant, or a
    /// departed sender's payload was refused by the strategy's churn
    /// rules) — the undeliverable-traffic ledger
    pub dropped_messages: u64,
    /// raw payload bytes of the dropped messages
    pub dropped_bytes: u64,
    /// async mode with link fault injection (`faults:`): messages the
    /// *network* lost — per-link drop probability or a scheduled
    /// partition ([`Fabric::lose_in_flight`]).  Distinct from
    /// `dropped_messages`, which counts membership-rule refusals
    pub link_lost_messages: u64,
    /// raw payload bytes of the link-lost messages
    pub link_lost_bytes: u64,
    /// wire transports only (`transport:` != inproc): inbound datagrams
    /// that failed frame decoding — truncated, bit-flipped or foreign
    /// bytes.  A malformed frame is counted here and otherwise treated
    /// exactly like a lost one; the in-process virtual-clock fabric never
    /// produces them
    pub malformed_frames: u64,
    /// physical transfers on the wire.  Equals `total_messages` unless
    /// message coalescing ([`Fabric::send_frame_coded`]) packed several
    /// logical payloads into one frame — then each frame pays one link
    /// latency for all of its messages and this gauge counts frames
    pub frames: u64,
    /// bytes per (src, dst) directed link
    pub per_link: BTreeMap<(usize, usize), u64>,
    /// bytes sent by each worker
    pub per_worker_sent: BTreeMap<usize, u64>,
    /// simulated seconds spent on communication (critical path, per round
    /// max; see `Fabric::end_round`)
    pub simulated_comm_s: f64,
    pub rounds: u64,
}

impl TrafficReport {
    pub fn bytes_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.rounds as f64
        }
    }
}

/// The in-process message fabric.
///
/// Usage per synchronized step: strategies call `send` for every transfer
/// they perform; the coordinator calls `end_round` at the barrier, which
/// folds the round's per-worker transfer times into the simulated clock
/// (synchronous setting: the round costs the *maximum* over workers).
#[derive(Debug)]
pub struct Fabric {
    n: usize,
    link: LinkModel,
    /// unified scalar counters/gauges (see [`trace::Registry`]); the
    /// public [`TrafficReport`] is assembled from these on demand
    reg: Registry,
    /// bytes per (src, dst) directed link (detail ledger)
    per_link: BTreeMap<(usize, usize), u64>,
    /// bytes sent by each worker (detail ledger)
    per_worker_sent: BTreeMap<usize, u64>,
    /// per-worker communication time accumulated in the current round
    round_time: Vec<f64>,
    round_open: bool,
    /// async mode: messages currently traveling (sent, not yet delivered)
    in_flight: usize,
    /// async mode: high-water mark of `in_flight` over the run
    peak_in_flight: usize,
    /// keep the per-link / per-worker BTreeMap ledgers (on by default).
    /// The 10⁵–10⁶-node scale studies turn them off: a map entry per
    /// directed link is O(nodes x degree) memory and a tree lookup per
    /// message — pure observability, never consulted by the trajectory
    detail: bool,
    /// synchronous codec hint: `(n_f32, wire_bytes)` — a
    /// [`send_params`](Self::send_params) for exactly `n_f32` elements
    /// is priced at `wire_bytes` on the link (the coordinator sets this
    /// once per run from `codec.encoded_len`); other sizes ship raw
    param_wire: Option<(usize, u64)>,
}

impl Fabric {
    pub fn new(n: usize, link: LinkModel) -> Self {
        Fabric {
            n,
            link,
            reg: Registry::new(),
            per_link: BTreeMap::new(),
            per_worker_sent: BTreeMap::new(),
            round_time: vec![0.0; n],
            round_open: false,
            in_flight: 0,
            peak_in_flight: 0,
            detail: true,
            param_wire: None,
        }
    }

    /// Install the synchronous wire-codec hint: parameter-vector sends
    /// of exactly `n_f32` elements are priced at `wire` encoded bytes
    /// (identity codecs set `wire == 4 * n_f32`, leaving every gauge
    /// unchanged).  Raw-byte ledgers are never affected.
    pub fn set_param_wire(&mut self, n_f32: usize, wire: u64) {
        self.param_wire = Some((n_f32, wire));
    }

    /// Enable/disable the per-link and per-worker byte ledgers.  All
    /// scalar gauges (bytes, messages, frames, in-flight, simulated
    /// seconds) are unaffected; with `detail` off the two maps simply
    /// stay empty.  Trajectories never read them, so this cannot perturb
    /// a run.
    pub fn set_link_detail(&mut self, on: bool) {
        self.detail = on;
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    /// Record a directed transfer of `bytes` from `src` to `dst`.
    ///
    /// Both endpoints are busy for the transfer duration (store-and-forward
    /// model; fine-grained overlap is out of scope).
    pub fn send(&mut self, src: usize, dst: usize, bytes: u64) {
        self.send_coded(src, dst, bytes, bytes);
    }

    /// [`send`](Self::send) with a wire codec in the path: `raw_bytes`
    /// is the logical payload (what the protocol exchanges), `wire`
    /// is what crossed the link — the `wire_bytes` gauge and the
    /// transfer time use the encoded size, the raw ledgers
    /// (`total_bytes`, per-link/per-worker maps) stay comparable across
    /// codecs.  The synchronous mirror of
    /// [`send_async_coded`](Self::send_async_coded).
    pub fn send_coded(&mut self, src: usize, dst: usize, raw_bytes: u64, wire: u64) {
        assert!(src < self.n && dst < self.n && src != dst, "bad link {src}->{dst}");
        self.round_open = true;
        self.reg.add(Ctr::CommBytes, raw_bytes);
        self.reg.add(Ctr::WireBytes, wire);
        self.reg.inc(Ctr::Messages);
        self.reg.inc(Ctr::Frames);
        if self.detail {
            *self.per_link.entry((src, dst)).or_default() += raw_bytes;
            *self.per_worker_sent.entry(src).or_default() += raw_bytes;
        }
        let t = self.link.transfer_time_s(wire);
        self.round_time[src] += t;
        self.round_time[dst] += t;
    }

    /// Account a whole-parameter-vector transfer: raw `4 * n_f32`
    /// bytes, priced by the [`set_param_wire`](Self::set_param_wire)
    /// hint when one is installed for this element count.
    pub fn send_params(&mut self, src: usize, dst: usize, n_f32: usize) {
        let raw = (n_f32 * 4) as u64;
        let wire = match self.param_wire {
            Some((n, w)) if n == n_f32 => w,
            _ => raw,
        };
        self.send_coded(src, dst, raw, wire);
    }

    /// A parameter-vector transfer plus `extra` uncompressed side-channel
    /// bytes (e.g. GoSGD's push-sum weight) in the **same** message: one
    /// transfer, raw `4 * n_f32 + extra`, wire `codec(params) + extra`.
    pub fn send_params_extra(&mut self, src: usize, dst: usize, n_f32: usize, extra: u64) {
        let raw = (n_f32 * 4) as u64;
        let wire = match self.param_wire {
            Some((n, w)) if n == n_f32 => w,
            _ => raw,
        };
        self.send_coded(src, dst, raw + extra, wire + extra);
    }

    /// Async (event-driven) mode: record a message entering the network
    /// at virtual time `now` and return its delivery time under the link
    /// model.  Per-message accounting — bytes, message counts, per-link
    /// totals and the in-flight gauge — with no barrier semantics; the
    /// simulated clock advances by the *sum* of transfer times, since
    /// nothing ever waits on the round's slowest worker.
    pub fn send_async(&mut self, src: usize, dst: usize, bytes: u64, now: f64) -> f64 {
        self.send_async_coded(src, dst, bytes, bytes, now)
    }

    /// [`send_async`](Self::send_async) with a wire codec in the path:
    /// `raw_bytes` is the logical payload (what the protocol exchanges —
    /// comparable across codecs and regimes), `wire_bytes` is what the
    /// codec actually put on the link.  The transfer time — and the new
    /// `wire_bytes` gauge — use the encoded size; the per-link/per-worker
    /// ledgers stay in raw bytes so traffic tables remain comparable.
    pub fn send_async_coded(
        &mut self,
        src: usize,
        dst: usize,
        raw_bytes: u64,
        wire_bytes: u64,
        now: f64,
    ) -> f64 {
        self.send_frame_coded(src, dst, raw_bytes, wire_bytes, 1, now)
    }

    /// Coalesced wire frame: `n_msgs` logical messages bound for the same
    /// destination cross the link as **one** physical transfer —
    /// `raw_bytes`/`wire_bytes` are the frame totals, the transfer pays
    /// one link latency plus the summed encoded bytes over the bandwidth.
    /// Logical accounting is per message (`total_messages` and the
    /// in-flight gauge grow by `n_msgs`; each message is still delivered
    /// or dropped individually), while `frames` counts physical
    /// transfers.  With `n_msgs == 1` this is exactly
    /// [`send_async_coded`](Self::send_async_coded).
    pub fn send_frame_coded(
        &mut self,
        src: usize,
        dst: usize,
        raw_bytes: u64,
        wire_bytes: u64,
        n_msgs: u64,
        now: f64,
    ) -> f64 {
        assert!(src < self.n && dst < self.n && src != dst, "bad link {src}->{dst}");
        debug_assert!(n_msgs >= 1, "a frame carries at least one message");
        self.reg.add(Ctr::CommBytes, raw_bytes);
        self.reg.add(Ctr::WireBytes, wire_bytes);
        self.reg.add(Ctr::Messages, n_msgs);
        self.reg.inc(Ctr::Frames);
        if self.detail {
            *self.per_link.entry((src, dst)).or_default() += raw_bytes;
            *self.per_worker_sent.entry(src).or_default() += raw_bytes;
        }
        let dt = self.link.transfer_time_s(wire_bytes);
        self.reg.gauge_add(Gauge::SimulatedCommS, dt);
        self.in_flight += n_msgs as usize;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        now + dt
    }

    /// Async mode: a message previously accounted by
    /// [`send_async`](Self::send_async) reached its destination.
    pub fn deliver_async(&mut self) {
        debug_assert!(self.in_flight > 0, "delivery without a matching send");
        self.in_flight -= 1;
    }

    /// Async mode with membership churn: a message in flight could not
    /// be delivered (its receiver departed, or the strategy's churn
    /// rules refuse a departed sender's payload).  Settles the in-flight
    /// gauge like a delivery and records the loss in the
    /// `dropped_messages`/`dropped_bytes` ledger.
    pub fn drop_async(&mut self, raw_bytes: u64) {
        debug_assert!(self.in_flight > 0, "drop without a matching send");
        self.in_flight -= 1;
        self.reg.inc(Ctr::DroppedMessages);
        self.reg.add(Ctr::DroppedBytes, raw_bytes);
    }

    /// Async mode with link fault injection: a message previously
    /// accounted by [`send_async_coded`](Self::send_async_coded) was
    /// lost by the *network* (seeded per-link drop or a scheduled
    /// partition) — it occupied the wire but never arrives.  Settles the
    /// in-flight gauge and records the loss in the
    /// `link_lost_messages`/`link_lost_bytes` ledger, separate from the
    /// membership-rule `dropped_*` ledger.
    pub fn lose_in_flight(&mut self, raw_bytes: u64) {
        debug_assert!(self.in_flight > 0, "loss without a matching send");
        self.in_flight -= 1;
        self.reg.inc(Ctr::LinkLostMessages);
        self.reg.add(Ctr::LinkLostBytes, raw_bytes);
    }

    /// Messages currently in flight (async mode).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// High-water mark of in-flight messages over the run (async mode) —
    /// also the arena's message-pool steady-state size.
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight
    }

    /// Close the synchronous round: simulated comm time advances by the
    /// max over workers (everyone waits at the barrier).
    pub fn end_round(&mut self) {
        if self.round_open {
            let worst = self.round_time.iter().cloned().fold(0.0, f64::max);
            self.reg.gauge_add(Gauge::SimulatedCommS, worst);
            self.reg.inc(Ctr::Rounds);
            self.round_time.iter_mut().for_each(|t| *t = 0.0);
            self.round_open = false;
        }
    }

    /// Assemble the public traffic view from the counter registry and
    /// the detail ledgers.  Cheap relative to a run (two map clones);
    /// call once at teardown or in tests, not per event.
    pub fn report(&self) -> TrafficReport {
        TrafficReport {
            total_bytes: self.reg.get(Ctr::CommBytes),
            wire_bytes: self.reg.get(Ctr::WireBytes),
            total_messages: self.reg.get(Ctr::Messages),
            dropped_messages: self.reg.get(Ctr::DroppedMessages),
            dropped_bytes: self.reg.get(Ctr::DroppedBytes),
            link_lost_messages: self.reg.get(Ctr::LinkLostMessages),
            link_lost_bytes: self.reg.get(Ctr::LinkLostBytes),
            malformed_frames: self.reg.get(Ctr::MalformedFrames),
            frames: self.reg.get(Ctr::Frames),
            per_link: self.per_link.clone(),
            per_worker_sent: self.per_worker_sent.clone(),
            simulated_comm_s: self.reg.gauge(Gauge::SimulatedCommS),
            rounds: self.reg.get(Ctr::Rounds),
        }
    }

    /// Direct read access to the unified counter registry (the store
    /// behind [`report`](Self::report)).
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// Fold wire-transport decode failures into the traffic ledger.  The
    /// socket transports count malformed datagrams locally (`transport::
    /// TransportStats`); the runtime surfaces the sum here when the wire
    /// plane is torn down.
    pub fn note_malformed(&mut self, n: u64) {
        self.reg.add(Ctr::MalformedFrames, n);
    }

    pub fn reset(&mut self) {
        self.reg.reset();
        self.per_link.clear();
        self.per_worker_sent.clear();
        self.round_time.iter_mut().for_each(|t| *t = 0.0);
        self.round_open = false;
        self.in_flight = 0;
        self.peak_in_flight = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let mut f = Fabric::new(4, LinkModel::default());
        f.send(0, 1, 1000);
        f.send(1, 2, 500);
        f.send(0, 1, 1000);
        f.end_round();
        let r = f.report();
        assert_eq!(r.total_bytes, 2500);
        assert_eq!(r.total_messages, 3);
        assert_eq!(r.per_link[&(0, 1)], 2000);
        assert_eq!(r.per_worker_sent[&0], 2000);
        assert_eq!(r.rounds, 1);
    }

    #[test]
    fn round_time_is_max_over_workers() {
        let link = LinkModel { latency_s: 1.0, bandwidth_bps: 1e9 };
        let mut f = Fabric::new(3, link);
        // worker 0 does two sends (2s+eps); worker 2 one (1s+eps)
        f.send(0, 1, 0);
        f.send(0, 1, 0);
        f.send(2, 1, 0);
        f.end_round();
        // worker 1 participates in all three transfers -> 3s is the max
        assert!((f.report().simulated_comm_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let mut f = Fabric::new(2, LinkModel::default());
        f.end_round();
        f.end_round();
        assert_eq!(f.report().rounds, 0);
        assert_eq!(f.report().simulated_comm_s, 0.0);
    }

    #[test]
    #[should_panic]
    fn self_send_rejected() {
        let mut f = Fabric::new(2, LinkModel::default());
        f.send(1, 1, 10);
    }

    #[test]
    fn transfer_time_model() {
        let link = LinkModel { latency_s: 0.5, bandwidth_bps: 100.0 };
        assert!((link.transfer_time_s(200) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn async_send_accounts_and_tracks_in_flight() {
        let link = LinkModel { latency_s: 1.0, bandwidth_bps: 100.0 };
        let mut f = Fabric::new(3, link);
        let t1 = f.send_async(0, 1, 200, 10.0); // 1 + 2 = 3s transfer
        assert!((t1 - 13.0).abs() < 1e-9);
        let t2 = f.send_async(2, 1, 0, 10.0);
        assert!((t2 - 11.0).abs() < 1e-9);
        assert_eq!(f.in_flight(), 2);
        assert_eq!(f.peak_in_flight(), 2);
        f.deliver_async();
        assert_eq!(f.in_flight(), 1);
        f.deliver_async();
        assert_eq!(f.in_flight(), 0);
        assert_eq!(f.peak_in_flight(), 2, "peak survives deliveries");
        let r = f.report();
        assert_eq!(r.total_bytes, 200);
        assert_eq!(r.total_messages, 2);
        assert!((r.simulated_comm_s - 4.0).abs() < 1e-9, "sum of transfer times");
        assert_eq!(r.rounds, 0, "async sends are not rounds");
    }

    #[test]
    fn drop_async_settles_in_flight_and_ledgers() {
        let mut f = Fabric::new(3, LinkModel::zero());
        f.send_async(0, 1, 400, 0.0);
        f.send_async(2, 1, 100, 0.0);
        assert_eq!(f.in_flight(), 2);
        f.drop_async(400);
        f.deliver_async();
        assert_eq!(f.in_flight(), 0);
        let r = f.report();
        assert_eq!(r.dropped_messages, 1);
        assert_eq!(r.dropped_bytes, 400);
        // the send-side ledgers still count the dropped traffic (it was
        // put on the wire; churn wasted it)
        assert_eq!(r.total_bytes, 500);
        assert_eq!(r.total_messages, 2);
    }

    #[test]
    fn lose_in_flight_settles_gauge_and_ledgers_separately() {
        let mut f = Fabric::new(3, LinkModel::zero());
        f.send_async(0, 1, 400, 0.0);
        f.send_async(2, 1, 100, 0.0);
        f.lose_in_flight(400);
        f.deliver_async();
        assert_eq!(f.in_flight(), 0);
        let r = f.report();
        assert_eq!(r.link_lost_messages, 1);
        assert_eq!(r.link_lost_bytes, 400);
        assert_eq!(r.dropped_messages, 0, "network loss is not a membership drop");
        // send-side ledgers still count the lost traffic (it was on the
        // wire; the fault plane wasted it)
        assert_eq!(r.total_bytes, 500);
        assert_eq!(r.total_messages, 2);
    }

    #[test]
    fn coded_send_accounts_raw_and_wire_separately() {
        let link = LinkModel { latency_s: 0.0, bandwidth_bps: 100.0 };
        let mut f = Fabric::new(2, link);
        // 400 raw bytes leave as 100 encoded: the link is priced by 100
        let t = f.send_async_coded(0, 1, 400, 100, 0.0);
        assert!((t - 1.0).abs() < 1e-12, "transfer priced by wire bytes, got {t}");
        let r = f.report();
        assert_eq!(r.total_bytes, 400);
        assert_eq!(r.wire_bytes, 100);
        assert_eq!(r.per_link[&(0, 1)], 400, "ledgers stay in raw bytes");
        // the uncoded path keeps the two gauges equal
        f.deliver_async();
        f.send_async(1, 0, 50, 0.0);
        assert_eq!(f.report().total_bytes, 450);
        assert_eq!(f.report().wire_bytes, 150);
    }

    #[test]
    fn sync_send_counts_wire_bytes_too() {
        let mut f = Fabric::new(2, LinkModel::default());
        f.send(0, 1, 777);
        f.end_round();
        assert_eq!(f.report().wire_bytes, 777);
    }

    #[test]
    fn sync_coded_send_prices_wire_and_ledgers_raw() {
        let link = LinkModel { latency_s: 0.0, bandwidth_bps: 100.0 };
        let mut f = Fabric::new(2, link);
        f.send_coded(0, 1, 400, 100);
        f.end_round();
        let r = f.report();
        assert_eq!(r.total_bytes, 400);
        assert_eq!(r.wire_bytes, 100);
        assert_eq!(r.per_link[&(0, 1)], 400, "ledgers stay in raw bytes");
        assert!((r.simulated_comm_s - 1.0).abs() < 1e-12, "round priced by wire bytes");
    }

    #[test]
    fn param_wire_hint_prices_matching_sends_only() {
        let mut f = Fabric::new(3, LinkModel::default());
        f.set_param_wire(100, 120); // e.g. q4: 400 raw -> 120 wire
        f.send_params(0, 1, 100); // matches the hint
        f.send_params(1, 2, 64); // different size: ships raw
        f.end_round();
        let r = f.report();
        assert_eq!(r.total_bytes, 400 + 256);
        assert_eq!(r.wire_bytes, 120 + 256);
        assert_eq!(r.total_messages, 2);
    }

    #[test]
    fn send_params_extra_is_one_message_with_raw_side_channel() {
        let mut f = Fabric::new(2, LinkModel::default());
        // without a hint: raw == wire == 4n + extra
        f.send_params_extra(0, 1, 100, 8);
        f.end_round();
        assert_eq!(f.report().total_bytes, 408);
        assert_eq!(f.report().wire_bytes, 408);
        assert_eq!(f.report().total_messages, 1);
        // with a hint: only the parameter payload compresses
        f.reset();
        f.set_param_wire(100, 120);
        f.send_params_extra(0, 1, 100, 8);
        f.end_round();
        assert_eq!(f.report().total_bytes, 408);
        assert_eq!(f.report().wire_bytes, 128);
        assert_eq!(f.report().total_messages, 1);
    }

    #[test]
    fn zero_link_delivers_instantly() {
        let mut f = Fabric::new(2, LinkModel::zero());
        let t = f.send_async(0, 1, 1 << 30, 5.5);
        assert_eq!(t, 5.5);
        assert_eq!(f.report().simulated_comm_s, 0.0);
    }

    #[test]
    fn frame_send_prices_once_and_counts_each_message() {
        let link = LinkModel { latency_s: 1.0, bandwidth_bps: 100.0 };
        let mut f = Fabric::new(3, link);
        // 3 messages, 200 wire bytes total: one latency + 2s of bytes
        let t = f.send_frame_coded(0, 1, 300, 200, 3, 10.0);
        assert!((t - 13.0).abs() < 1e-9, "one latency for the whole frame, got {t}");
        assert_eq!(f.in_flight(), 3, "in-flight tracks logical messages");
        let r = f.report();
        assert_eq!(r.total_messages, 3);
        assert_eq!(r.frames, 1);
        assert_eq!(r.total_bytes, 300);
        assert_eq!(r.wire_bytes, 200);
        // each logical message settles individually
        f.deliver_async();
        f.drop_async(100);
        f.deliver_async();
        assert_eq!(f.in_flight(), 0);
        // the single-message path keeps frames == messages
        f.send_async(0, 2, 50, 0.0);
        assert_eq!(f.report().frames, 2);
        assert_eq!(f.report().total_messages, 4);
    }

    #[test]
    fn link_detail_toggle_only_gates_the_maps() {
        let mut f = Fabric::new(3, LinkModel::zero());
        f.set_link_detail(false);
        let t_off = f.send_async(0, 1, 400, 1.5);
        assert!(f.report().per_link.is_empty());
        assert!(f.report().per_worker_sent.is_empty());
        assert_eq!(f.report().total_bytes, 400);
        assert_eq!(f.report().total_messages, 1);
        // same send with detail on: identical scalar gauges + arrival time
        let mut g = Fabric::new(3, LinkModel::zero());
        let t_on = g.send_async(0, 1, 400, 1.5);
        assert_eq!(t_off, t_on);
        assert_eq!(f.report().wire_bytes, g.report().wire_bytes);
        assert_eq!(g.report().per_link[&(0, 1)], 400);
    }

    #[test]
    fn bytes_per_round() {
        let mut f = Fabric::new(2, LinkModel::default());
        f.send(0, 1, 100);
        f.end_round();
        f.send(1, 0, 300);
        f.end_round();
        assert_eq!(f.report().bytes_per_round(), 200.0);
    }
}
