//! Wire transports behind the Fabric seam.
//!
//! Everything above this module speaks `NetMsg`; everything below it speaks
//! *frames* — self-describing, length-delimited byte strings that carry a
//! codec-encoded parameter payload (or a 16-byte control payload) plus the
//! piggybacked failure-detection rumors.  Three implementations of the
//! [`Transport`] trait exist:
//!
//! * `InProcTransport` — a lock-guarded mailbox mesh inside one process.
//!   This is the virtual-clock path the simulator has always used,
//!   refactored behind the trait; frames round-trip through the same
//!   encoder/decoder the socket paths use, so the parser is exercised on
//!   every simulated run.
//! * `UdpTransport` — nonblocking `std::net::UdpSocket`, one datagram per
//!   frame, a per-peer address table.  Loss, duplication and reordering are
//!   real; the incarnation stamp in every frame (`gen`) feeds the PR 5/6
//!   dropped-message and refutation paths unchanged.
//! * `LoopbackUdp` — `UdpTransport` pinned to 127.0.0.1 ephemeral ports.
//!   The conformance suite runs the deterministic simulator with this
//!   transport spliced into the delivery path and asserts digest equality
//!   against the pure in-process run.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic        0x4547_5746 ("EGWF")
//!      4     1  version      1
//!      5     1  kind         payload tag, 0..=10 (see `kind` consts)
//!      6     1  nrumors      piggybacked rumor count, <= 4
//!      7     1  flags        bit0: payload is codec-encoded
//!      8     4  src          sender rank
//!     12     4  dst          destination rank
//!     16     4  picker       pairwise picker rank
//!     20     4  gen          incarnation stamp
//!     24     8  sent_step    sender's local step at send time
//!     32     8  seq          per-sender wire sequence number
//!     40    16  ctrl         two u64 control words (probe ids, mass bits…)
//!     56     4  payload_len  byte length of the payload section
//!     60     …  payload      codec bytes / raw LE f32 / empty
//!      …   8*n  rumors       n = nrumors, 8 bytes each (kind,pad,node,inc)
//! ```
//!
//! `decode_frame` is strict: every length is bounds-checked before any read,
//! unknown magic/version/kind and trailing bytes are errors, and malformed
//! input can never panic or over-read.  Callers count decode failures in the
//! `malformed_frames` ledger.

use std::collections::VecDeque;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

/// Which wire sits under the async runtime.  `InProc` is the default and
/// keeps the virtual-clock simulator pure; `LoopbackUdp` splices real
/// 127.0.0.1 sockets into the simulated delivery path (the conformance
/// mode); `Udp` is the free-running multi-process transport used by
/// `repro net-train`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    InProc,
    Udp,
    LoopbackUdp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.trim() {
            "inproc" | "in-proc" | "sim" => Ok(TransportKind::InProc),
            "udp" => Ok(TransportKind::Udp),
            "loopback-udp" | "loopback" => Ok(TransportKind::LoopbackUdp),
            other => bail!(
                "unknown transport '{}' (expected inproc | udp | loopback-udp)",
                other
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Udp => "udp",
            TransportKind::LoopbackUdp => "loopback-udp",
        }
    }
}

/// Payload kind tags.  The tag decides how `payload` and `ctrl` are
/// interpreted on the receiving side; it mirrors `MsgPayload` one-to-one.
pub mod kind {
    pub const ELASTIC_PUSH: u8 = 0;
    pub const ELASTIC_REPLY: u8 = 1;
    pub const PUSH_PARAMS: u8 = 2;
    pub const PULL_REQUEST: u8 = 3;
    pub const PULL_REPLY: u8 = 4;
    pub const GOSGD_SHARE: u8 = 5;
    pub const JOIN_REQUEST: u8 = 6;
    pub const JOIN_REPLY: u8 = 7;
    pub const FD_PING: u8 = 8;
    pub const FD_ACK: u8 = 9;
    pub const FD_PING_REQ: u8 = 10;
    pub const MAX: u8 = FD_PING_REQ;
}

/// Frame magic: "EGWF" (Elastic Gossip Wire Frame), little-endian.
pub const MAGIC: u32 = 0x4547_5746;
/// Current frame format version.
pub const VERSION: u8 = 1;
/// Fixed header length in bytes.
pub const HEADER_BYTES: usize = 60;
/// Wire bytes per piggybacked rumor.
pub const RUMOR_BYTES: usize = 8;
/// Rumor cap per frame (mirrors `RumorPack::CAP`).
pub const RUMOR_CAP: usize = 4;
/// Flag bit: the payload section holds codec output, not raw LE f32.
pub const FLAG_CODED: u8 = 1;

/// A decoded wire frame — the transport-level twin of `NetMsg`.  `payload`
/// carries codec bytes when `flags & FLAG_CODED != 0`, raw LE f32 for
/// bootstrap replies, and is empty for control frames.
#[derive(Clone, Debug, PartialEq)]
pub struct WireFrame {
    pub kind: u8,
    pub flags: u8,
    pub src: u32,
    pub dst: u32,
    pub picker: u32,
    pub gen: u32,
    pub sent_step: u64,
    pub seq: u64,
    pub ctrl: [u64; 2],
    pub payload: Vec<u8>,
    /// (kind, node, incarnation) triples, at most [`RUMOR_CAP`].
    pub rumors: Vec<(u8, u16, u32)>,
}

impl WireFrame {
    /// Encoded size in bytes.
    pub fn wire_len(&self) -> usize {
        HEADER_BYTES + self.payload.len() + self.rumors.len() * RUMOR_BYTES
    }
}

/// Serialize a frame.  The output buffer is cleared first.
pub fn encode_frame(f: &WireFrame, out: &mut Vec<u8>) {
    debug_assert!(f.kind <= kind::MAX);
    debug_assert!(f.rumors.len() <= RUMOR_CAP);
    out.clear();
    out.reserve(f.wire_len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(f.kind);
    out.push(f.rumors.len() as u8);
    out.push(f.flags);
    out.extend_from_slice(&f.src.to_le_bytes());
    out.extend_from_slice(&f.dst.to_le_bytes());
    out.extend_from_slice(&f.picker.to_le_bytes());
    out.extend_from_slice(&f.gen.to_le_bytes());
    out.extend_from_slice(&f.sent_step.to_le_bytes());
    out.extend_from_slice(&f.seq.to_le_bytes());
    out.extend_from_slice(&f.ctrl[0].to_le_bytes());
    out.extend_from_slice(&f.ctrl[1].to_le_bytes());
    out.extend_from_slice(&(f.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&f.payload);
    for &(k, node, inc) in &f.rumors {
        out.push(k);
        out.push(0);
        out.extend_from_slice(&node.to_le_bytes());
        out.extend_from_slice(&inc.to_le_bytes());
    }
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn rd_u64(b: &[u8], at: usize) -> u64 {
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(w)
}

/// Parse a frame.  Strictly bounds-checked: every failure mode (short
/// buffer, bad magic/version/kind, rumor count over cap, payload length
/// disagreeing with the buffer, trailing garbage) is a returned error —
/// never a panic, never a read past the input.
pub fn decode_frame(buf: &[u8]) -> Result<WireFrame> {
    if buf.len() < HEADER_BYTES {
        bail!("frame too short: {} bytes (header is {})", buf.len(), HEADER_BYTES);
    }
    let magic = rd_u32(buf, 0);
    if magic != MAGIC {
        bail!("bad frame magic {:#010x}", magic);
    }
    if buf[4] != VERSION {
        bail!("unsupported frame version {}", buf[4]);
    }
    let k = buf[5];
    if k > kind::MAX {
        bail!("unknown frame kind {}", k);
    }
    let nrumors = buf[6] as usize;
    if nrumors > RUMOR_CAP {
        bail!("rumor count {} exceeds cap {}", nrumors, RUMOR_CAP);
    }
    let flags = buf[7];
    let payload_len = rd_u32(buf, 56) as usize;
    let want = HEADER_BYTES
        .checked_add(payload_len)
        .and_then(|n| n.checked_add(nrumors * RUMOR_BYTES))
        .context("frame length overflow")?;
    if buf.len() != want {
        bail!(
            "frame length mismatch: have {} bytes, header declares {}",
            buf.len(),
            want
        );
    }
    let payload = buf[HEADER_BYTES..HEADER_BYTES + payload_len].to_vec();
    let mut rumors = Vec::with_capacity(nrumors);
    let mut at = HEADER_BYTES + payload_len;
    for _ in 0..nrumors {
        let rk = buf[at];
        let node = u16::from_le_bytes([buf[at + 2], buf[at + 3]]);
        let inc = rd_u32(buf, at + 4);
        rumors.push((rk, node, inc));
        at += RUMOR_BYTES;
    }
    Ok(WireFrame {
        kind: k,
        flags,
        src: rd_u32(buf, 8),
        dst: rd_u32(buf, 12),
        picker: rd_u32(buf, 16),
        gen: rd_u32(buf, 20),
        sent_step: rd_u64(buf, 24),
        seq: rd_u64(buf, 32),
        ctrl: [rd_u64(buf, 40), rd_u64(buf, 48)],
        payload,
        rumors,
    })
}

/// Per-endpoint traffic counters.  All atomics so `Transport` methods can
/// take `&self` and the pump threads can update them concurrently.
#[derive(Debug, Default)]
pub struct TransportStats {
    pub frames_sent: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub frames_recv: AtomicU64,
    pub bytes_recv: AtomicU64,
    pub malformed_frames: AtomicU64,
}

/// A plain-value snapshot of [`TransportStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_recv: u64,
    pub bytes_recv: u64,
    pub malformed_frames: u64,
}

impl TransportStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            malformed_frames: self.malformed_frames.load(Ordering::Relaxed),
        }
    }
}

/// One endpoint of a wire.  `send_frame` is addressed by rank; address
/// resolution (mailbox index, socket address) is the implementation's
/// business.  `try_recv_frame` never blocks: `Ok(None)` means "nothing
/// pending".  Malformed inbound bytes are counted in `stats` and skipped —
/// a bad datagram must look exactly like a lost one.
pub trait Transport: Send + Sync {
    fn kind(&self) -> TransportKind;
    fn send_frame(&self, dst: usize, frame: &WireFrame) -> Result<()>;
    fn try_recv_frame(&self) -> Result<Option<WireFrame>>;
    fn stats(&self) -> StatsSnapshot;
    fn local_addr(&self) -> Option<SocketAddr> {
        None
    }
}

/// The in-process mesh: one lock-guarded byte-string mailbox per rank.
/// Frames are fully encoded on send and decoded on receive, so the parser
/// sees the same bytes the socket paths would put on the wire.
pub struct InProcMesh {
    boxes: Vec<Arc<Mutex<VecDeque<Vec<u8>>>>>,
}

impl InProcMesh {
    pub fn new(n: usize) -> Self {
        InProcMesh {
            boxes: (0..n).map(|_| Arc::new(Mutex::new(VecDeque::new()))).collect(),
        }
    }

    /// The endpoint for rank `me`.
    pub fn endpoint(&self, me: usize) -> InProcTransport {
        InProcTransport {
            me,
            boxes: self.boxes.clone(),
            stats: Arc::new(TransportStats::default()),
        }
    }
}

pub struct InProcTransport {
    me: usize,
    boxes: Vec<Arc<Mutex<VecDeque<Vec<u8>>>>>,
    stats: Arc<TransportStats>,
}

impl InProcTransport {
    /// Inject raw bytes into this endpoint's inbox — the robustness tests
    /// use this to deliver deliberately corrupt "datagrams".
    pub fn inject_raw(&self, bytes: Vec<u8>) {
        self.boxes[self.me].lock().unwrap().push_back(bytes);
    }
}

impl Transport for InProcTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::InProc
    }

    fn send_frame(&self, dst: usize, frame: &WireFrame) -> Result<()> {
        if dst >= self.boxes.len() {
            bail!("send_frame: rank {} out of range ({} ranks)", dst, self.boxes.len());
        }
        let mut bytes = Vec::new();
        encode_frame(frame, &mut bytes);
        self.stats.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_sent.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.boxes[dst].lock().unwrap().push_back(bytes);
        Ok(())
    }

    fn try_recv_frame(&self) -> Result<Option<WireFrame>> {
        loop {
            let bytes = match self.boxes[self.me].lock().unwrap().pop_front() {
                Some(b) => b,
                None => return Ok(None),
            };
            match decode_frame(&bytes) {
                Ok(f) => {
                    self.stats.frames_recv.fetch_add(1, Ordering::Relaxed);
                    self.stats.bytes_recv.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    return Ok(Some(f));
                }
                Err(_) => {
                    // count and skip: a corrupt frame is a lost frame
                    self.stats.malformed_frames.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }
}

/// Maximum datagram we ever send or expect.  Loopback comfortably carries
/// 64 KiB datagrams; larger payloads belong to a future fragmentation layer
/// (ROADMAP direction 1) and are rejected loudly at send time.
pub const MAX_DATAGRAM: usize = 65_507;

/// Nonblocking UDP endpoint with a per-peer address table.  One frame per
/// datagram; `WouldBlock` maps to `Ok(None)`, undersized/corrupt datagrams
/// are counted as malformed and skipped.
pub struct UdpTransport {
    sock: UdpSocket,
    peers: Mutex<Vec<Option<SocketAddr>>>,
    stats: Arc<TransportStats>,
    kind: TransportKind,
}

impl UdpTransport {
    /// Bind to an explicit address.
    pub fn bind(addr: &str, npeers: usize) -> Result<UdpTransport> {
        let sock = UdpSocket::bind(addr).with_context(|| format!("udp bind {}", addr))?;
        sock.set_nonblocking(true).context("udp set_nonblocking")?;
        Ok(UdpTransport {
            sock,
            peers: Mutex::new(vec![None; npeers]),
            stats: Arc::new(TransportStats::default()),
            kind: TransportKind::Udp,
        })
    }

    /// Bind to a 127.0.0.1 ephemeral port (the conformance-test mode).
    pub fn loopback(npeers: usize) -> Result<UdpTransport> {
        let mut t = UdpTransport::bind("127.0.0.1:0", npeers)?;
        t.kind = TransportKind::LoopbackUdp;
        Ok(t)
    }

    /// Like [`Transport::try_recv_frame`], but also reports the sender's
    /// socket address.  The free-running `net-train` workers use this to
    /// learn peer addresses live: a restarted rank comes back on a fresh
    /// ephemeral port, and the first frame it sends re-teaches everyone
    /// where it lives.
    pub fn try_recv_frame_from(&self) -> Result<Option<(WireFrame, SocketAddr)>> {
        let mut buf = vec![0u8; MAX_DATAGRAM];
        loop {
            match self.sock.recv_from(&mut buf) {
                Ok((n, from)) => match decode_frame(&buf[..n]) {
                    Ok(f) => {
                        self.stats.frames_recv.fetch_add(1, Ordering::Relaxed);
                        self.stats.bytes_recv.fetch_add(n as u64, Ordering::Relaxed);
                        return Ok(Some((f, from)));
                    }
                    Err(_) => {
                        self.stats.malformed_frames.fetch_add(1, Ordering::Relaxed);
                    }
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
                Err(e) => return Err(e).context("udp recv_from"),
            }
        }
    }

    /// Record where rank `peer` listens.
    pub fn set_peer(&self, peer: usize, addr: SocketAddr) {
        let mut peers = self.peers.lock().unwrap();
        if peer >= peers.len() {
            peers.resize(peer + 1, None);
        }
        peers[peer] = Some(addr);
    }
}

impl Transport for UdpTransport {
    fn kind(&self) -> TransportKind {
        self.kind
    }

    fn send_frame(&self, dst: usize, frame: &WireFrame) -> Result<()> {
        let addr = {
            let peers = self.peers.lock().unwrap();
            peers
                .get(dst)
                .copied()
                .flatten()
                .with_context(|| format!("no address recorded for rank {}", dst))?
        };
        let mut bytes = Vec::new();
        encode_frame(frame, &mut bytes);
        if bytes.len() > MAX_DATAGRAM {
            bail!(
                "frame of {} bytes exceeds the {}-byte datagram limit \
                 (use a quantizing codec or raise the chunk granularity)",
                bytes.len(),
                MAX_DATAGRAM
            );
        }
        // Nonblocking send: if the OS buffer is momentarily full, retry
        // briefly rather than dropping a frame the simulator has already
        // decided must be delivered.
        let mut tries = 0u32;
        loop {
            match self.sock.send_to(&bytes, addr) {
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock && tries < 1000 => {
                    tries += 1;
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
                Err(e) => return Err(e).with_context(|| format!("udp send_to {}", addr)),
            }
        }
        self.stats.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_sent.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn try_recv_frame(&self) -> Result<Option<WireFrame>> {
        Ok(self.try_recv_frame_from()?.map(|(f, _)| f))
    }

    fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    fn local_addr(&self) -> Option<SocketAddr> {
        self.sock.local_addr().ok()
    }
}

/// Can this process bind loopback UDP sockets and pass a datagram between
/// them?  Sandboxed runners may forbid socket creation entirely; every net
/// test probes this once and emits a visible `skipped: no network` note
/// instead of failing (the `integration_hlo.rs` idiom).  The verdict is
/// cached for the process lifetime.
pub fn probe_loopback() -> bool {
    static VERDICT: OnceLock<bool> = OnceLock::new();
    *VERDICT.get_or_init(|| match try_probe() {
        Ok(()) => true,
        Err(_) => false,
    })
}

fn try_probe() -> Result<()> {
    let a = UdpTransport::loopback(2)?;
    let b = UdpTransport::loopback(2)?;
    let addr_b = b.local_addr().context("probe: no local addr")?;
    a.set_peer(1, addr_b);
    let frame = WireFrame {
        kind: kind::PULL_REQUEST,
        flags: 0,
        src: 0,
        dst: 1,
        picker: 0,
        gen: 0,
        sent_step: 0,
        seq: 1,
        ctrl: [0, 0],
        payload: Vec::new(),
        rumors: Vec::new(),
    };
    a.send_frame(1, &frame)?;
    // ~500 ms poll for the datagram to cross the loopback
    for _ in 0..500 {
        if let Some(got) = b.try_recv_frame()? {
            if got.seq == 1 && got.kind == kind::PULL_REQUEST {
                return Ok(());
            }
            bail!("probe frame mangled in flight");
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    bail!("probe frame never arrived")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_mini::{forall, prop_assert, Gen, PropResult};

    fn sample_frame(g: &mut Gen) -> WireFrame {
        let k = g.usize_in(0, kind::MAX as usize) as u8;
        let plen = g.usize_in(0, 96);
        let payload: Vec<u8> = (0..plen).map(|_| g.usize_in(0, 255) as u8).collect();
        let nr = g.usize_in(0, RUMOR_CAP);
        let rumors: Vec<(u8, u16, u32)> = (0..nr)
            .map(|_| {
                (
                    g.usize_in(0, 2) as u8,
                    g.usize_in(0, 64) as u16,
                    g.usize_in(0, 9) as u32,
                )
            })
            .collect();
        WireFrame {
            kind: k,
            flags: if g.bool() { FLAG_CODED } else { 0 },
            src: g.usize_in(0, 31) as u32,
            dst: g.usize_in(0, 31) as u32,
            picker: g.usize_in(0, 31) as u32,
            gen: g.usize_in(0, 7) as u32,
            sent_step: g.usize_in(0, 10_000) as u64,
            seq: g.usize_in(1, 1 << 20) as u64,
            ctrl: [g.usize_in(0, 1 << 30) as u64, g.usize_in(0, 1 << 30) as u64],
            payload,
            rumors,
        }
    }

    #[test]
    fn frame_roundtrip_every_kind() {
        for k in 0..=kind::MAX {
            let f = WireFrame {
                kind: k,
                flags: FLAG_CODED,
                src: 3,
                dst: 5,
                picker: 2,
                gen: 7,
                sent_step: 41,
                seq: 99,
                ctrl: [0xdead_beef, 0x1234_5678_9abc_def0],
                payload: vec![1, 2, 3, 4, 5],
                rumors: vec![(1, 4, 2), (2, 9, 3)],
            };
            let mut bytes = Vec::new();
            encode_frame(&f, &mut bytes);
            assert_eq!(bytes.len(), f.wire_len());
            let back = decode_frame(&bytes).unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn proptest_roundtrip() {
        forall("transport_roundtrip", 200, |g| -> PropResult {
            let f = sample_frame(g);
            let mut bytes = Vec::new();
            encode_frame(&f, &mut bytes);
            let back = decode_frame(&bytes).map_err(|e| format!("decode: {}", e))?;
            prop_assert(back == f, "roundtrip mismatch")
        });
    }

    #[test]
    fn proptest_truncation_never_panics() {
        forall("transport_truncation", 200, |g| -> PropResult {
            let f = sample_frame(g);
            let mut bytes = Vec::new();
            encode_frame(&f, &mut bytes);
            let cut = g.usize_in(0, bytes.len().saturating_sub(1));
            // any strict prefix must decode to an error, not a panic
            let res = decode_frame(&bytes[..cut]);
            prop_assert(res.is_err(), "truncated frame decoded successfully")
        });
    }

    #[test]
    fn proptest_bitflip_never_panics() {
        forall("transport_bitflip", 300, |g| -> PropResult {
            let f = sample_frame(g);
            let mut bytes = Vec::new();
            encode_frame(&f, &mut bytes);
            let at = g.usize_in(0, bytes.len() - 1);
            let bit = g.usize_in(0, 7);
            bytes[at] ^= 1 << bit;
            // a single bit flip either surfaces as a decode error or decodes
            // to a (different) well-formed frame — both fine; a panic or
            // over-read is the only failure mode
            let _ = decode_frame(&bytes);
            Ok(())
        });
    }

    #[test]
    fn proptest_random_bytes_never_panic() {
        forall("transport_random_bytes", 300, |g| -> PropResult {
            let n = g.usize_in(0, 200);
            let junk: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let _ = decode_frame(&junk);
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_bad_header_fields() {
        let f = WireFrame {
            kind: kind::PUSH_PARAMS,
            flags: 0,
            src: 0,
            dst: 1,
            picker: 0,
            gen: 0,
            sent_step: 0,
            seq: 7,
            ctrl: [0, 0],
            payload: vec![9; 8],
            rumors: vec![(0, 1, 1)],
        };
        let mut good = Vec::new();
        encode_frame(&f, &mut good);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_frame(&bad_magic).is_err());

        let mut bad_version = good.clone();
        bad_version[4] = 99;
        assert!(decode_frame(&bad_version).is_err());

        let mut bad_kind = good.clone();
        bad_kind[5] = kind::MAX + 1;
        assert!(decode_frame(&bad_kind).is_err());

        let mut bad_rumors = good.clone();
        bad_rumors[6] = RUMOR_CAP as u8 + 1;
        assert!(decode_frame(&bad_rumors).is_err());

        let mut bad_len = good.clone();
        // declare a payload larger than the buffer holds
        bad_len[56..60].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&bad_len).is_err());

        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_frame(&trailing).is_err());

        assert!(decode_frame(&good).is_ok());
    }

    #[test]
    fn inproc_mesh_counts_malformed_and_skips() {
        let mesh = InProcMesh::new(2);
        let a = mesh.endpoint(0);
        let b = mesh.endpoint(1);
        let f = WireFrame {
            kind: kind::FD_PING,
            flags: 0,
            src: 0,
            dst: 1,
            picker: 0,
            gen: 1,
            sent_step: 3,
            seq: 11,
            ctrl: [42, 0],
            payload: Vec::new(),
            rumors: Vec::new(),
        };
        // corrupt datagram first, then a good one: recv must skip the junk,
        // count it, and hand back the good frame
        b.inject_raw(vec![0xab; 17]);
        a.send_frame(1, &f).unwrap();
        let got = b.try_recv_frame().unwrap().expect("frame expected");
        assert_eq!(got, f);
        assert_eq!(b.stats().malformed_frames, 1);
        assert_eq!(b.stats().frames_recv, 1);
        assert!(b.try_recv_frame().unwrap().is_none());
        assert_eq!(a.stats().frames_sent, 1);
    }

    #[test]
    fn inproc_mesh_duplication_and_reorder() {
        let mesh = InProcMesh::new(2);
        let a = mesh.endpoint(0);
        let b = mesh.endpoint(1);
        let mk = |seq: u64| WireFrame {
            kind: kind::PULL_REQUEST,
            flags: 0,
            src: 0,
            dst: 1,
            picker: 0,
            gen: 0,
            sent_step: 0,
            seq,
            ctrl: [0, 0],
            payload: Vec::new(),
            rumors: Vec::new(),
        };
        // duplicate seq 2, deliver out of order: the transport surfaces
        // exactly what arrived — dedup/reorder is the redemption layer's job
        a.send_frame(1, &mk(2)).unwrap();
        a.send_frame(1, &mk(2)).unwrap();
        a.send_frame(1, &mk(1)).unwrap();
        let seqs: Vec<u64> = std::iter::from_fn(|| b.try_recv_frame().unwrap())
            .map(|f| f.seq)
            .collect();
        assert_eq!(seqs, vec![2, 2, 1]);
    }

    #[test]
    fn loopback_udp_roundtrip_or_skip() {
        if !probe_loopback() {
            eprintln!("[test] skipped: no network (loopback bind forbidden)");
            return;
        }
        let a = UdpTransport::loopback(2).unwrap();
        let b = UdpTransport::loopback(2).unwrap();
        a.set_peer(1, b.local_addr().unwrap());
        let f = WireFrame {
            kind: kind::GOSGD_SHARE,
            flags: FLAG_CODED,
            src: 0,
            dst: 1,
            picker: 0,
            gen: 2,
            sent_step: 17,
            seq: 5,
            ctrl: [0.5f64.to_bits(), 0],
            payload: vec![7; 32],
            rumors: vec![(1, 3, 1)],
        };
        a.send_frame(1, &f).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        loop {
            if let Some(got) = b.try_recv_frame().unwrap() {
                assert_eq!(got, f);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "loopback frame lost");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(a.stats().frames_sent, 1);
        assert_eq!(b.stats().frames_recv, 1);
    }

    #[test]
    fn transport_kind_parse() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::InProc);
        assert_eq!(TransportKind::parse("udp").unwrap(), TransportKind::Udp);
        assert_eq!(
            TransportKind::parse("loopback-udp").unwrap(),
            TransportKind::LoopbackUdp
        );
        assert_eq!(
            TransportKind::parse("loopback").unwrap(),
            TransportKind::LoopbackUdp
        );
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }
}
