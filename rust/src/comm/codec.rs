//! Wire codecs for gossip payloads: pluggable compression of the
//! parameter vectors the event-driven runtime puts on the fabric.
//!
//! The thesis motivates gossip training for bandwidth-starved
//! deployments (IoT devices, edge servers) and names payload compression
//! as future work (§5); GossipGraD's scaling argument is that
//! communication *volume*, not round count, is the bottleneck.  This
//! module shrinks bytes-on-wire without touching the protocol layer: a
//! [`Codec`] encodes a message's parameter payload at send
//! (`runtime_async` calls [`encode_into`](Codec::encode_into) when it
//! flushes the outbox) and reconstructs it at delivery
//! ([`decode_into`](Codec::decode_into)), with the [`Fabric`] accounting
//! both the raw and the encoded size (`wire_bytes` gauge) and pricing
//! the link by what actually travels.
//!
//! Four implementations:
//!
//! * [`IdentityCodec`] — f32 little-endian bytes, bit-exact roundtrip
//!   (including NaN payloads).  This is the default; with it in the path
//!   the async lockstep trajectories remain **bit-identical** to the
//!   sequential coordinator (the `prop_async_lockstep_*` suites run
//!   against exactly this configuration).  The byte loops are the bulk
//!   copies in [`tensor::simd`].
//! * [`Q8Codec`] — per-chunk affine int8 quantization
//!   ([`tensor::quantize_q8_into`]): ~4x smaller (8-bit codes plus an
//!   8-byte header per chunk), reconstruction error bounded by half the
//!   per-chunk quantization step (property-tested).
//! * [`Q4Codec`] — per-chunk affine **4-bit** quantization
//!   ([`tensor::quantize_q4_into`], two codes per byte): ~8x smaller,
//!   same bounded-error shape with a step of `range / 15`.  Like q8 it
//!   is stateless and non-overlay, so it is also accepted on the
//!   synchronous fabric for the gossip methods.
//! * [`TopKCodec`] — magnitude sparsification with per-worker
//!   **error-feedback residuals**.  Each sender keeps the full vector its
//!   wire stream has cumulatively conveyed (`sent`); a send selects the
//!   `k = frac * n` coordinates with the largest pending residual
//!   `|theta - sent|`, transmits their **absolute** values, and leaves
//!   the rest pending — dropped mass is carried into the next send, so
//!   every drifting coordinate is eventually transmitted (property:
//!   repeated sends of a fixed vector reconstruct it exactly after
//!   `ceil(n/k)` rounds).  Decode is an *overlay*: untransmitted
//!   coordinates keep the receiver's own values, so gossip mixing is
//!   restricted to the transmitted support.  GoSGD's push-sum weight
//!   travels outside the payload and is never encoded — weight mass
//!   conservation survives lossy params exactly (property-tested).
//!
//! Allocation discipline matches the rest of the comm stack: wire
//! buffers are pooled in the [`ScratchArena`]
//! ([`rent_bytes`](crate::algos::ScratchArena::rent_bytes) /
//! [`return_bytes`](crate::algos::ScratchArena::return_bytes)), codec
//! scratch (residual rows, index/delta buffers) keeps its capacity, and
//! after warm-up an encode/decode cycle performs zero heap allocation
//! (asserted by the fingerprint tests below).
//!
//! Parse grammar (config key `codec = "..."`, CLI `--codec ...`),
//! mirroring `randreg:<degree>:<seed>`:
//!
//! ```text
//! identity | none          bit-exact f32 payloads (default)
//! q8[:<chunk>]             per-chunk affine int8 (default chunk 4096)
//! q4[:<chunk>]             per-chunk affine 4-bit, two codes per byte
//! topk:<frac>              top-k sparsification, k = frac * n
//! ```
//!
//! [`Fabric`]: crate::comm::Fabric
//! [`ScratchArena`]: crate::algos::ScratchArena
//! [`tensor::quantize_q8_into`]: crate::tensor::quantize_q8_into

use anyhow::{bail, ensure, Result};

use crate::tensor;

/// Default Q8 chunk: large enough that the 8-byte chunk headers cost
/// <0.05% (reduction 3.99x of the theoretical 4x), small enough that the
/// per-chunk range — and with it the error bound — stays tight.
pub const Q8_DEFAULT_CHUNK: usize = 4096;

/// Default Q4 chunk (even, so nibble pairs never pad mid-stream): the
/// headers cost ~0.4% of the packed bytes, landing the measured paper-MLP
/// reduction at ~7.97x of the theoretical 8x.
pub const Q4_DEFAULT_CHUNK: usize = 4096;

/// Codec selector (parsed from config / CLI; carried by
/// [`ExperimentConfig`](crate::config::ExperimentConfig)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecKind {
    /// Bit-exact f32 payloads (the default; zero trajectory impact).
    Identity,
    /// Per-chunk affine int8 quantization.
    Q8 { chunk: usize },
    /// Per-chunk affine 4-bit quantization, two codes per byte.
    Q4 { chunk: usize },
    /// Top-k magnitude sparsification with error feedback; `frac` is the
    /// transmitted fraction of coordinates (k = max(1, round(frac * n))).
    TopK { frac: f64 },
}

impl Default for CodecKind {
    fn default() -> Self {
        CodecKind::Identity
    }
}

impl CodecKind {
    /// Parse `identity`, `q8`, `q8:1024`, `q4`, `q4:512`, `topk:0.01`
    /// (a leading `codec:` prefix is tolerated so the full flag grammar
    /// can be pasted verbatim).
    pub fn parse(s: &str) -> Result<CodecKind> {
        let s = s.strip_prefix("codec:").unwrap_or(s);
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        Ok(match head {
            "identity" | "none" | "raw" => CodecKind::Identity,
            "q8" => {
                let chunk: usize = match arg {
                    Some(a) => a.parse()?,
                    None => Q8_DEFAULT_CHUNK,
                };
                ensure!(chunk > 0, "q8 chunk must be positive");
                CodecKind::Q8 { chunk }
            }
            "q4" => {
                let chunk: usize = match arg {
                    Some(a) => a.parse()?,
                    None => Q4_DEFAULT_CHUNK,
                };
                ensure!(chunk > 0, "q4 chunk must be positive");
                CodecKind::Q4 { chunk }
            }
            "topk" => {
                let frac: f64 = arg
                    .ok_or_else(|| anyhow::anyhow!("topk needs a fraction: codec:topk:<frac>"))?
                    .parse()?;
                ensure!(
                    frac > 0.0 && frac <= 1.0,
                    "topk fraction must be in (0, 1], got {frac}"
                );
                CodecKind::TopK { frac }
            }
            other => {
                bail!("unknown codec {other:?} (identity | q8[:<chunk>] | q4[:<chunk>] | topk:<frac>)")
            }
        })
    }

    /// Canonical label (re-parses to the same kind; used in run labels
    /// and bench output).
    pub fn label(&self) -> String {
        match self {
            CodecKind::Identity => "identity".into(),
            CodecKind::Q8 { chunk } => {
                if *chunk == Q8_DEFAULT_CHUNK {
                    "q8".into()
                } else {
                    format!("q8:{chunk}")
                }
            }
            CodecKind::Q4 { chunk } => {
                if *chunk == Q4_DEFAULT_CHUNK {
                    "q4".into()
                } else {
                    format!("q4:{chunk}")
                }
            }
            CodecKind::TopK { frac } => format!("topk:{frac}"),
        }
    }

    /// Instantiate the codec's runtime state.
    pub fn build(&self) -> Box<dyn Codec> {
        match self {
            CodecKind::Identity => Box::new(IdentityCodec),
            CodecKind::Q8 { chunk } => Box::new(Q8Codec { chunk: *chunk }),
            CodecKind::Q4 { chunk } => Box::new(Q4Codec { chunk: *chunk }),
            CodecKind::TopK { frac } => Box::new(TopKCodec::new(*frac)),
        }
    }
}

/// A wire codec for parameter payloads.
///
/// Contract: `decode_into(encode_into(sender, src), dst)` reconstructs
/// an approximation of `src` into `dst` (for overlay codecs the
/// untransmitted coordinates keep `dst`'s prior contents — the runtime
/// pre-fills `dst` with the receiver's live parameters).  Encoding may
/// carry per-sender state (error feedback); decoding is stateless.
/// Implementations must be deterministic and must not allocate after
/// their scratch high-water mark has been seen.
pub trait Codec: Send {
    fn name(&self) -> &'static str;

    /// Encoded payload size for an `n`-element vector, in bytes (exact;
    /// used for planning and the bench tables).
    fn encoded_len(&self, n: usize) -> usize;

    /// Untransmitted coordinates keep the decode destination's prior
    /// contents (sparse codecs).  The runtime pre-fills the destination
    /// with the receiver's live parameters when this is true.
    fn is_overlay(&self) -> bool {
        false
    }

    /// Encode `src` into `out` (cleared first; capacity persists).
    /// `sender` keys any per-worker residual state.
    fn encode_into(&mut self, sender: usize, src: &[f32], out: &mut Vec<u8>);

    /// Reconstruct into `dst` (its length is the expected element
    /// count).  Errors on a malformed stream.
    fn decode_into(&self, wire: &[u8], dst: &mut [f32]) -> Result<()>;

    /// Capacity fingerprint of the codec's scratch state, mixed into the
    /// allocation-freedom assertions (0 for stateless codecs).
    fn footprint(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// identity
// ---------------------------------------------------------------------------

/// Bit-exact f32 little-endian payloads — the zero-loss reference whose
/// roundtrip preserves every bit pattern (including NaNs), so running it
/// through the full encode/decode path cannot perturb a trajectory.
pub struct IdentityCodec;

impl Codec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn encoded_len(&self, n: usize) -> usize {
        4 * n
    }

    fn encode_into(&mut self, _sender: usize, src: &[f32], out: &mut Vec<u8>) {
        tensor::simd::f32s_to_le_bytes(src, out);
    }

    fn decode_into(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        ensure!(
            wire.len() == 4 * dst.len(),
            "identity stream is {} bytes, expected {}",
            wire.len(),
            4 * dst.len()
        );
        tensor::simd::le_bytes_to_f32s(wire, dst);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// q8
// ---------------------------------------------------------------------------

/// Per-chunk affine int8 quantization (stateless — the whole wire format
/// lives in [`tensor::quantize_q8_into`]).
pub struct Q8Codec {
    pub chunk: usize,
}

impl Codec for Q8Codec {
    fn name(&self) -> &'static str {
        "q8"
    }

    fn encoded_len(&self, n: usize) -> usize {
        n + 8 * n.div_ceil(self.chunk)
    }

    fn encode_into(&mut self, _sender: usize, src: &[f32], out: &mut Vec<u8>) {
        tensor::quantize_q8_into(src, self.chunk, out);
    }

    fn decode_into(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        tensor::dequantize_q8_into(wire, self.chunk, dst)
    }
}

// ---------------------------------------------------------------------------
// q4
// ---------------------------------------------------------------------------

/// Per-chunk affine 4-bit quantization (stateless — the whole wire
/// format lives in [`tensor::quantize_q4_into`]).  Two codes per byte
/// put the paper-MLP payload at ~7.97x below raw f32.
pub struct Q4Codec {
    pub chunk: usize,
}

impl Codec for Q4Codec {
    fn name(&self) -> &'static str {
        "q4"
    }

    fn encoded_len(&self, n: usize) -> usize {
        tensor::q4_encoded_len(n, self.chunk)
    }

    fn encode_into(&mut self, _sender: usize, src: &[f32], out: &mut Vec<u8>) {
        tensor::quantize_q4_into(src, self.chunk, out);
    }

    fn decode_into(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        tensor::dequantize_q4_into(wire, self.chunk, dst)
    }
}

// ---------------------------------------------------------------------------
// top-k with error feedback
// ---------------------------------------------------------------------------

/// Magnitude sparsification with per-worker error-feedback residuals.
///
/// Wire layout: `[n: u32][k: u32][idx: u32 x k][val: f32 x k]`, indices
/// ascending.  `sent[w]` is worker `w`'s cumulative wire state (starts
/// at zero, the convention both ends share); the residual `theta - sent`
/// is the mass the stream still owes, and selection by its magnitude is
/// what carries dropped coordinates into later sends instead of
/// re-transmitting the currently-largest weights forever.
pub struct TopKCodec {
    pub frac: f64,
    /// per-sender cumulative transmitted state (lazily sized)
    sent: Vec<Vec<f32>>,
    /// scratch: pending residual per coordinate
    delta: Vec<f32>,
    /// scratch: selected indices
    idx: Vec<u32>,
}

impl TopKCodec {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk fraction must be in (0, 1]");
        TopKCodec { frac, sent: Vec::new(), delta: Vec::new(), idx: Vec::new() }
    }

    /// Transmitted coordinates per message for an `n`-element vector.
    pub fn k_for(&self, n: usize) -> usize {
        ((self.frac * n as f64).round() as usize).clamp(1, n.max(1))
    }
}

impl Codec for TopKCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encoded_len(&self, n: usize) -> usize {
        8 + 8 * self.k_for(n)
    }

    fn is_overlay(&self) -> bool {
        true
    }

    fn encode_into(&mut self, sender: usize, src: &[f32], out: &mut Vec<u8>) {
        let n = src.len();
        let k = self.k_for(n);
        if self.sent.len() <= sender {
            self.sent.resize_with(sender + 1, Vec::new);
        }
        let sent = &mut self.sent[sender];
        if sent.len() != n {
            sent.clear();
            sent.resize(n, 0.0);
        }
        self.delta.clear();
        self.delta.extend(src.iter().zip(sent.iter()).map(|(&a, &b)| a - b));
        tensor::top_k_select(&self.delta, k, &mut self.idx);
        out.clear();
        out.reserve(8 + 8 * self.idx.len());
        out.extend_from_slice(&(n as u32).to_le_bytes());
        out.extend_from_slice(&(self.idx.len() as u32).to_le_bytes());
        for &i in &self.idx {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &i in &self.idx {
            let v = src[i as usize];
            out.extend_from_slice(&v.to_le_bytes());
            sent[i as usize] = v; // residual for this coordinate is now 0
        }
    }

    fn decode_into(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        ensure!(wire.len() >= 8, "topk stream truncated ({} bytes)", wire.len());
        let n = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        let k = u32::from_le_bytes(wire[4..8].try_into().unwrap()) as usize;
        ensure!(n == dst.len(), "topk stream is for {n} f32s, expected {}", dst.len());
        ensure!(k <= n, "topk stream claims {k} of {n} coordinates");
        ensure!(
            wire.len() == 8 + 8 * k,
            "topk stream is {} bytes, expected {}",
            wire.len(),
            8 + 8 * k
        );
        let (ib, vb) = wire[8..].split_at(4 * k);
        for (ic, vc) in ib.chunks_exact(4).zip(vb.chunks_exact(4)) {
            let i = u32::from_le_bytes(ic.try_into().unwrap()) as usize;
            ensure!(i < n, "topk index {i} out of range {n}");
            dst[i] = f32::from_le_bytes(vc.try_into().unwrap());
        }
        Ok(())
    }

    fn footprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |ptr: usize, cap: usize| {
            for v in [ptr as u64, cap as u64] {
                h ^= v;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for s in &self.sent {
            mix(s.as_ptr() as usize, s.capacity());
        }
        mix(self.sent.as_ptr() as usize, self.sent.capacity());
        mix(self.delta.as_ptr() as usize, self.delta.capacity());
        mix(self.idx.as_ptr() as usize, self.idx.capacity());
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ScratchArena;
    use crate::util::rng::Rng;

    fn gauss_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gauss_f32()).collect()
    }

    #[test]
    fn parse_grammar() {
        assert_eq!(CodecKind::parse("identity").unwrap(), CodecKind::Identity);
        assert_eq!(CodecKind::parse("none").unwrap(), CodecKind::Identity);
        assert_eq!(
            CodecKind::parse("q8").unwrap(),
            CodecKind::Q8 { chunk: Q8_DEFAULT_CHUNK }
        );
        assert_eq!(CodecKind::parse("q8:512").unwrap(), CodecKind::Q8 { chunk: 512 });
        assert_eq!(
            CodecKind::parse("q4").unwrap(),
            CodecKind::Q4 { chunk: Q4_DEFAULT_CHUNK }
        );
        assert_eq!(CodecKind::parse("q4:512").unwrap(), CodecKind::Q4 { chunk: 512 });
        assert_eq!(CodecKind::parse("topk:0.01").unwrap(), CodecKind::TopK { frac: 0.01 });
        // the full flag grammar is tolerated verbatim
        assert_eq!(
            CodecKind::parse("codec:topk:0.25").unwrap(),
            CodecKind::TopK { frac: 0.25 }
        );
        assert!(CodecKind::parse("q8:0").is_err());
        assert!(CodecKind::parse("q4:0").is_err());
        assert!(CodecKind::parse("topk").is_err());
        assert!(CodecKind::parse("topk:1.5").is_err());
        assert!(CodecKind::parse("zstd").is_err());
        // labels reparse to the same kind
        for k in [
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 128 },
            CodecKind::Q8 { chunk: Q8_DEFAULT_CHUNK },
            CodecKind::Q4 { chunk: 128 },
            CodecKind::Q4 { chunk: Q4_DEFAULT_CHUNK },
            CodecKind::TopK { frac: 0.05 },
        ] {
            assert_eq!(CodecKind::parse(&k.label()).unwrap(), k);
        }
    }

    #[test]
    fn identity_roundtrip_is_bit_exact() {
        let mut src = gauss_vec(333, 5);
        src[7] = f32::NAN;
        src[8] = f32::NEG_INFINITY;
        src[9] = -0.0;
        let mut codec = IdentityCodec;
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        assert_eq!(wire.len(), codec.encoded_len(src.len()));
        let mut back = vec![0.0f32; src.len()];
        codec.decode_into(&wire, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(codec.decode_into(&wire[..wire.len() - 1], &mut back).is_err());
    }

    #[test]
    fn q8_encoded_len_matches_stream() {
        let src = gauss_vec(1000, 9);
        let mut codec = Q8Codec { chunk: 64 };
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        assert_eq!(wire.len(), codec.encoded_len(1000));
        let mut back = vec![0.0f32; 1000];
        codec.decode_into(&wire, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}"); // coarse sanity; bound tested in tensor
        }
    }

    #[test]
    fn q4_encoded_len_matches_stream_and_roundtrips() {
        let src = gauss_vec(1000, 11);
        let mut codec = Q4Codec { chunk: 64 };
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        assert_eq!(wire.len(), codec.encoded_len(1000));
        // ~8x below raw at this size (64-element chunks pay more header)
        assert!((4 * 1000) as f64 / wire.len() as f64 > 6.0);
        let mut back = vec![0.0f32; 1000];
        codec.decode_into(&wire, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            // 4-bit codes over a gaussian chunk: coarse, but bounded;
            // the exact per-chunk bound is tested in tensor
            assert!((a - b).abs() < 1.0, "{a} vs {b}");
        }
        assert!(codec.decode_into(&wire[..wire.len() - 1], &mut back).is_err());
    }

    #[test]
    fn topk_transmits_k_and_overlays() {
        let n = 40;
        let src = gauss_vec(n, 13);
        let mut codec = TopKCodec::new(0.1); // k = 4
        assert_eq!(codec.k_for(n), 4);
        let mut wire = Vec::new();
        codec.encode_into(2, &src, &mut wire);
        assert_eq!(wire.len(), codec.encoded_len(n));
        // overlay: untransmitted coordinates keep the base
        let base = vec![7.0f32; n];
        let mut dst = base.clone();
        codec.decode_into(&wire, &mut dst).unwrap();
        let changed = dst.iter().zip(&base).filter(|(a, b)| a != b).count();
        assert!(changed <= 4, "changed {changed} > k");
        // transmitted values are the sender's absolute values
        for (i, (&d, &b)) in dst.iter().zip(&base).enumerate() {
            if d != b {
                assert_eq!(d.to_bits(), src[i].to_bits());
            }
        }
    }

    #[test]
    fn topk_error_feedback_drains_a_fixed_vector() {
        // repeated sends of the same vector must eventually convey every
        // coordinate: the residual |theta - sent| of an untransmitted
        // coordinate persists until it wins selection
        let n = 37;
        let src = gauss_vec(n, 21);
        let mut codec = TopKCodec::new(0.1); // k = 4 per send
        let k = codec.k_for(n);
        let rounds = n.div_ceil(k);
        let mut recv = vec![0.0f32; n];
        let mut wire = Vec::new();
        for _ in 0..rounds {
            codec.encode_into(0, &src, &mut wire);
            codec.decode_into(&wire, &mut recv).unwrap();
        }
        for (i, (a, b)) in src.iter().zip(&recv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "coordinate {i} never transmitted");
        }
        // drained: the next send still moves k values (re-sends exact
        // ones with zero residual) but changes nothing at the receiver
        codec.encode_into(0, &src, &mut wire);
        let before = recv.clone();
        codec.decode_into(&wire, &mut recv).unwrap();
        assert_eq!(before, recv);
    }

    #[test]
    fn topk_residual_state_is_per_sender() {
        let src_a = gauss_vec(16, 1);
        let src_b = gauss_vec(16, 2);
        let mut codec = TopKCodec::new(0.25);
        let mut wa = Vec::new();
        let mut wb = Vec::new();
        codec.encode_into(0, &src_a, &mut wa);
        codec.encode_into(5, &src_b, &mut wb);
        // sender 0's stream state must be untouched by sender 5's send:
        // a fresh codec encoding only src_a produces the identical stream
        let mut fresh = TopKCodec::new(0.25);
        let mut wa2 = Vec::new();
        fresh.encode_into(0, &src_a, &mut wa2);
        assert_eq!(wa, wa2);
    }

    #[test]
    fn malformed_topk_streams_are_rejected() {
        let codec = TopKCodec::new(0.5);
        let mut dst = vec![0.0f32; 4];
        assert!(codec.decode_into(&[0, 0, 0], &mut dst).is_err()); // truncated header
        // n mismatch
        let mut wire = Vec::new();
        wire.extend_from_slice(&9u32.to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes());
        assert!(codec.decode_into(&wire, &mut dst).is_err());
        // index out of range
        let mut wire = Vec::new();
        wire.extend_from_slice(&4u32.to_le_bytes());
        wire.extend_from_slice(&1u32.to_le_bytes());
        wire.extend_from_slice(&17u32.to_le_bytes());
        wire.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(codec.decode_into(&wire, &mut dst).is_err());
    }

    #[test]
    fn codec_roundtrip_allocation_free_after_warmup() {
        // the async-runtime allocation discipline, extended to the codec
        // layer: once the wire-buffer pool and the codec scratch have
        // seen their high-water mark, encode/decode cycles never touch
        // the allocator (same fingerprint technique as the arena tests)
        let n = 700;
        let w = 4;
        for kind in [
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 64 },
            CodecKind::Q4 { chunk: 64 },
            CodecKind::TopK { frac: 0.05 },
        ] {
            let mut codec = kind.build();
            let mut arena = ScratchArena::new();
            let mut rng = Rng::new(77);
            let mut recv = vec![0.0f32; n];
            // warm-up: every sender encodes once, two wire buffers in
            // flight at peak
            for round in 0..3u64 {
                for s in 0..w {
                    let src = gauss_vec(n, round * 100 + s as u64);
                    let mut wire = arena.rent_bytes();
                    codec.encode_into(s, &src, &mut wire);
                    codec.decode_into(&wire, &mut recv).unwrap();
                    arena.return_bytes(wire);
                }
            }
            let fp = arena.footprint() ^ codec.footprint();
            for round in 0..40u64 {
                let s = rng.below(w);
                let src = gauss_vec(n, 7_000 + round);
                let mut wire = arena.rent_bytes();
                codec.encode_into(s, &src, &mut wire);
                codec.decode_into(&wire, &mut recv).unwrap();
                arena.return_bytes(wire);
                assert_eq!(
                    arena.footprint() ^ codec.footprint(),
                    fp,
                    "{} reallocated at round {round}",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn paper_mlp_reduction_ratios() {
        // the acceptance numbers at the paper MLP size, from the exact
        // wire formats (the bench measures the same thing end to end)
        let n = 2_913_290usize;
        let raw = 4 * n;
        let q8 = CodecKind::Q8 { chunk: Q8_DEFAULT_CHUNK }.build();
        let rq8 = raw as f64 / q8.encoded_len(n) as f64;
        assert!(rq8 > 3.98, "q8 reduction {rq8}");
        let q4 = CodecKind::Q4 { chunk: Q4_DEFAULT_CHUNK }.build();
        let rq4 = raw as f64 / q4.encoded_len(n) as f64;
        assert!(rq4 >= 7.5, "q4 reduction {rq4} misses the acceptance floor");
        let topk = CodecKind::TopK { frac: 0.01 }.build();
        let rtk = raw as f64 / topk.encoded_len(n) as f64;
        assert!(rtk >= 10.0, "topk:0.01 reduction {rtk}");
    }

    #[test]
    fn q4_measured_bytes_match_encoded_len_at_paper_size() {
        // the acceptance ratio from the *actual* stream, not just the
        // planning formula: encode a paper-MLP-sized payload once
        let n = 2_913_290usize;
        let mut rng = Rng::new(3);
        let src: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
        let mut codec = Q4Codec { chunk: Q4_DEFAULT_CHUNK };
        let mut wire = Vec::new();
        codec.encode_into(0, &src, &mut wire);
        assert_eq!(wire.len(), codec.encoded_len(n));
        let ratio = (4 * n) as f64 / wire.len() as f64;
        assert!(ratio >= 7.5, "measured q4 reduction {ratio}");
    }
}
