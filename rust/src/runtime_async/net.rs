//! Real-network halves of the async runtime.
//!
//! Two distinct modes live here, sharing the frame codec in
//! `comm::transport`:
//!
//! * [`WirePlane`] — the *conformance splice*.  The deterministic
//!   virtual-clock simulator keeps making every decision (who fires, who
//!   is picked, when a delivery pops), but each scheduled message's bytes
//!   are pushed through a real 127.0.0.1 UDP socket at send time and
//!   *redeemed* off the socket at the delivery instant: the payload the
//!   strategy applies is whatever actually crossed the wire.  With zero
//!   induced loss the trajectory is therefore digest-identical to the
//!   pure in-process run for any config — that equivalence is what
//!   `tests/transport_conformance.rs` pins.
//!
//! * [`run_net_worker`] / [`run_net_parent`] — the *free-running* mode
//!   behind `repro net-train`: N OS processes, one rank each, no virtual
//!   clock.  Ranks rendezvous through a handshake directory (`rank_<r>.addr`
//!   files), stamp every frame with their incarnation (bumped across
//!   restarts via `rank_<r>.inc`), checkpoint at epoch boundaries, and run
//!   a lite wall-clock SWIM loop (direct pings, suspicion timers,
//!   incarnation refutation) so a SIGKILLed-and-restarted rank is first
//!   confirmed dead and then refuted when it rejoins through the donor
//!   bootstrap.  Wall-clock runs are reproducible in aggregate (same data,
//!   same schedule tables, same protocol) but NOT bit-identical across
//!   runs — real sockets reorder and real clocks jitter; the comparison
//!   study (`examples/net_study.rs`) quantifies exactly that gap.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algos::{Method, MsgPayload, NetMsg, ProtoCtx, Rumor, RumorPack, ScratchArena, Strategy};
use crate::comm::codec::{Codec, CodecKind};
use crate::comm::transport::{
    kind as fk, Transport, UdpTransport, WireFrame, FLAG_CODED,
};
use crate::coordinator::checkpoint::{AsyncCheckpoint, AsyncNodeState};
use crate::coordinator::{build_dataset_pub, decide_schedule_into, evaluate};
use crate::data::{self, BatchCursor, TaskKind};
use crate::manifest::json::{self, Json, JsonObj};
use crate::membership::digest_params;
use crate::metrics::StalenessHist;
use crate::optim::Optimizer;
use crate::runtime::{BatchXOwned, EngineFactory};
use crate::trace::{Ev, Kind, Trace};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// NetMsg <-> WireFrame
// ---------------------------------------------------------------------------

/// The frame tag for a payload variant (mirrors `comm::transport::kind`).
pub fn payload_tag(p: &MsgPayload) -> u8 {
    match p {
        MsgPayload::ElasticPush(_) => fk::ELASTIC_PUSH,
        MsgPayload::ElasticReply(_) => fk::ELASTIC_REPLY,
        MsgPayload::PushParams(_) => fk::PUSH_PARAMS,
        MsgPayload::PullRequest => fk::PULL_REQUEST,
        MsgPayload::PullReply(_) => fk::PULL_REPLY,
        MsgPayload::GoSgdShare { .. } => fk::GOSGD_SHARE,
        MsgPayload::JoinRequest { .. } => fk::JOIN_REQUEST,
        MsgPayload::JoinReply(_) => fk::JOIN_REPLY,
        MsgPayload::FdPing { .. } => fk::FD_PING,
        MsgPayload::FdAck { .. } => fk::FD_ACK,
        MsgPayload::FdPingReq { .. } => fk::FD_PING_REQ,
    }
}

/// Build the wire frame for a prepared message.  Payload bytes come from
/// the codec buffer when one is attached (`msg.wire`), from the raw LE f32
/// parameters for codec-exempt bootstrap replies, and are empty for
/// control frames.  Sub-payload scalars ride the two `ctrl` words;
/// `wall_ctrl1` stamps a sender wall-clock value into the frames whose
/// second word is free (the net-train latency gauge — the simulator
/// passes 0).
pub fn frame_from_msg(msg: &NetMsg, seq: u64, wall_ctrl1: u64) -> WireFrame {
    let mut flags = 0u8;
    let payload: Vec<u8> = if let Some(wirebuf) = &msg.wire {
        flags |= FLAG_CODED;
        wirebuf.clone()
    } else if let Some(p) = msg.payload.params() {
        let mut b = Vec::with_capacity(p.len() * 4);
        for v in p {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    } else {
        Vec::new()
    };
    let mut ctrl = [0u64, wall_ctrl1];
    match &msg.payload {
        MsgPayload::GoSgdShare { weight, .. } => ctrl[0] = weight.to_bits(),
        MsgPayload::JoinRequest { joiner_gen } => ctrl[0] = *joiner_gen as u64,
        MsgPayload::FdPing { probe, origin } => ctrl = [*probe, *origin as u64],
        MsgPayload::FdAck { probe, inc } => ctrl = [*probe, *inc as u64],
        MsgPayload::FdPingReq { probe, target } => ctrl = [*probe, *target as u64],
        _ => {}
    }
    WireFrame {
        kind: payload_tag(&msg.payload),
        flags,
        src: msg.src as u32,
        dst: msg.dst as u32,
        picker: msg.picker as u32,
        gen: msg.gen,
        sent_step: msg.sent_step,
        seq,
        ctrl,
        payload,
        rumors: msg.rumors.iter().map(|r| (r.kind, r.node, r.inc)).collect(),
    }
}

/// Overwrite a message's transported content with what came off the wire:
/// payload bytes (codec buffer or raw f32), sub-payload control scalars,
/// header stamps and piggybacked rumors.  The frame's kind must match the
/// message's payload variant — a mismatch means sequence-number corruption
/// and is a hard error, not a silent mix-up.
pub fn apply_frame(msg: &mut NetMsg, f: &WireFrame) -> Result<()> {
    let expect = payload_tag(&msg.payload);
    ensure!(
        f.kind == expect,
        "frame kind {} does not match payload kind {} (seq {})",
        f.kind,
        expect,
        f.seq
    );
    ensure!(
        f.src as usize == msg.src && f.dst as usize == msg.dst,
        "frame link {}->{} does not match message link {}->{}",
        f.src,
        f.dst,
        msg.src,
        msg.dst
    );
    if f.flags & FLAG_CODED != 0 {
        let wirebuf = msg
            .wire
            .as_mut()
            .context("coded frame arrived for a message without a codec buffer")?;
        wirebuf.clear();
        wirebuf.extend_from_slice(&f.payload);
    } else if let Some(p) = msg.payload.params_mut() {
        // codec-exempt raw LE f32 (bootstrap reply)
        ensure!(
            f.payload.len() == p.len() * 4,
            "raw payload of {} bytes does not fit {} parameters",
            f.payload.len(),
            p.len()
        );
        for (slot, chunk) in p.iter_mut().zip(f.payload.chunks_exact(4)) {
            *slot = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
    } else {
        ensure!(
            f.payload.is_empty(),
            "control frame carries {} unexpected payload bytes",
            f.payload.len()
        );
    }
    match &mut msg.payload {
        MsgPayload::GoSgdShare { weight, .. } => *weight = f64::from_bits(f.ctrl[0]),
        MsgPayload::JoinRequest { joiner_gen } => *joiner_gen = f.ctrl[0] as u32,
        MsgPayload::FdPing { probe, origin } => {
            *probe = f.ctrl[0];
            *origin = f.ctrl[1] as u32;
        }
        MsgPayload::FdAck { probe, inc } => {
            *probe = f.ctrl[0];
            *inc = f.ctrl[1] as u32;
        }
        MsgPayload::FdPingReq { probe, target } => {
            *probe = f.ctrl[0];
            *target = f.ctrl[1] as u32;
        }
        _ => {}
    }
    msg.gen = f.gen;
    msg.sent_step = f.sent_step;
    msg.picker = f.picker as usize;
    let mut pack = RumorPack::empty();
    for &(k, node, inc) in &f.rumors {
        pack.push(Rumor { kind: k, node, inc });
    }
    msg.rumors = pack;
    msg.wire_seq = 0;
    Ok(())
}

// ---------------------------------------------------------------------------
// WirePlane — the conformance splice
// ---------------------------------------------------------------------------

/// Aggregate wire statistics returned by [`WirePlane::finish`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_recv: u64,
    pub bytes_recv: u64,
    pub malformed_frames: u64,
    pub redeemed: u64,
    pub duplicates: u64,
    /// frames still unclaimed at teardown (should be 0 on a clean run)
    pub leftover: u64,
}

/// One loopback UDP endpoint per simulated node, spliced into the
/// virtual-clock delivery path.  `transmit` pushes a message's frame onto
/// the sender's socket when the simulator commits to the delivery;
/// `redeem` blocks (bounded) until that exact frame has come off the
/// receiver's socket and overwrites the in-process message with it.  A
/// pump thread drains each socket continuously so OS receive buffers
/// never overflow while the simulator is busy elsewhere.
pub struct WirePlane {
    eps: Vec<Arc<UdpTransport>>,
    rx: Vec<mpsc::Receiver<WireFrame>>,
    /// frames that arrived ahead of their delivery event, per receiver,
    /// keyed by sequence number (real UDP reorders freely)
    pending: Vec<HashMap<u64, WireFrame>>,
    pumps: Vec<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    next_seq: u64,
    redeemed: u64,
    duplicates: u64,
    /// first socket send error, surfaced at finish() (transmit sites sit
    /// deep in the scheduling path and cannot return Result)
    deferred: Option<anyhow::Error>,
}

impl WirePlane {
    /// Bind `n` loopback endpoints, exchange addresses, and start one
    /// pump thread per endpoint.
    pub fn loopback(n: usize) -> Result<WirePlane> {
        let mut eps = Vec::with_capacity(n);
        for i in 0..n {
            eps.push(Arc::new(
                UdpTransport::loopback(n).with_context(|| format!("binding endpoint {i}"))?,
            ));
        }
        let addrs: Vec<SocketAddr> = eps
            .iter()
            .map(|e| e.local_addr().context("endpoint has no local addr"))
            .collect::<Result<_>>()?;
        for ep in &eps {
            for (p, &a) in addrs.iter().enumerate() {
                ep.set_peer(p, a);
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut rx = Vec::with_capacity(n);
        let mut pumps = Vec::with_capacity(n);
        for ep in &eps {
            let (tx, r) = mpsc::channel::<WireFrame>();
            rx.push(r);
            let ep = Arc::clone(ep);
            let stop = Arc::clone(&stop);
            pumps.push(std::thread::spawn(move || loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match ep.try_recv_frame() {
                    Ok(Some(f)) => {
                        if tx.send(f).is_err() {
                            break;
                        }
                    }
                    Ok(None) => std::thread::sleep(Duration::from_micros(200)),
                    Err(_) => break,
                }
            }));
        }
        Ok(WirePlane {
            eps,
            rx,
            pending: (0..n).map(|_| HashMap::new()).collect(),
            pumps,
            stop,
            next_seq: 0,
            redeemed: 0,
            duplicates: 0,
            deferred: None,
        })
    }

    /// Put a scheduled message's bytes on the sender's socket and stamp
    /// the redemption ticket.  Called after the fault plane's loss
    /// decision, so every transmitted frame is one the simulator has
    /// committed to deliver.  Errors are deferred to [`finish`] — the
    /// message keeps `wire_seq == 0` and falls back to its in-process
    /// content, so a failing socket degrades loudly at teardown instead
    /// of corrupting the run midway.
    pub fn transmit(&mut self, msg: &mut NetMsg) {
        if self.deferred.is_some() {
            return;
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        let frame = frame_from_msg(msg, seq, 0);
        match self.eps[msg.src].send_frame(msg.dst, &frame) {
            Ok(()) => msg.wire_seq = seq,
            Err(e) => {
                self.deferred =
                    Some(e.context(format!("transmitting seq {} {}->{}", seq, msg.src, msg.dst)));
            }
        }
    }

    /// The delivery event for `msg` has popped: fetch its exact frame off
    /// the receiver's socket (parking any frames that arrive ahead of
    /// their own events; counting duplicates) and overwrite the message
    /// with the transported content.
    pub fn redeem(&mut self, msg: &mut NetMsg) -> Result<()> {
        let dst = msg.dst;
        let seq = msg.wire_seq;
        let frame = match self.pending[dst].remove(&seq) {
            Some(f) => f,
            None => {
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        bail!(
                            "wire frame seq {} for {}->{} never arrived \
                             (2s timeout; {} frames parked at the receiver)",
                            seq,
                            msg.src,
                            dst,
                            self.pending[dst].len()
                        );
                    }
                    match self.rx[dst].recv_timeout(left) {
                        Ok(f) if f.seq == seq => break f,
                        Ok(f) => {
                            if self.pending[dst].insert(f.seq, f).is_some() {
                                self.duplicates += 1;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            bail!("wire pump for rank {dst} died")
                        }
                    }
                }
            }
        };
        apply_frame(msg, &frame)?;
        self.redeemed += 1;
        Ok(())
    }

    /// Stop the pumps, surface any deferred socket error, and return the
    /// aggregate wire statistics.
    pub fn finish(mut self) -> Result<WireStats> {
        self.stop.store(true, Ordering::Relaxed);
        for h in std::mem::take(&mut self.pumps) {
            let _ = h.join();
        }
        if let Some(e) = self.deferred.take() {
            return Err(e);
        }
        let mut s = WireStats {
            redeemed: self.redeemed,
            duplicates: self.duplicates,
            ..WireStats::default()
        };
        for ep in &self.eps {
            let st = ep.stats();
            s.frames_sent += st.frames_sent;
            s.bytes_sent += st.bytes_sent;
            s.frames_recv += st.frames_recv;
            s.bytes_recv += st.bytes_recv;
            s.malformed_frames += st.malformed_frames;
        }
        for (p, rx) in self.rx.iter().enumerate() {
            s.leftover += self.pending[p].len() as u64;
            while rx.try_recv().is_ok() {
                s.leftover += 1;
            }
        }
        Ok(s)
    }
}

impl Drop for WirePlane {
    fn drop(&mut self) {
        // finish() already drained everything; this covers early-error
        // paths where the plane is dropped mid-run
        self.stop.store(true, Ordering::Relaxed);
        for h in std::mem::take(&mut self.pumps) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// repro net-train — the free-running multi-process mode
// ---------------------------------------------------------------------------

/// Everything a `net-train` run needs, parent and worker alike.  The
/// parent spawns one worker process per rank with these values on the
/// command line ([`worker_args`]); every rank deterministically re-derives
/// the same dataset, schedule and pick tables from `seed`, so the only
/// nondeterminism in the run is the wall clock itself.
#[derive(Clone, Debug)]
pub struct NetTrainCfg {
    pub method: Method,
    pub workers: usize,
    pub epochs: usize,
    pub prob: f64,
    pub seed: u64,
    pub codec: CodecKind,
    /// per-step pacing sleep (stands in for gradient compute time; the
    /// synthetic engine is near-instant at dim 32)
    pub pace_ms: u64,
    /// pacing multiplier of the last rank (the straggler)
    pub straggler: f64,
    /// handshake directory: `rank_<r>.addr`, `rank_<r>.inc`, checkpoints
    pub rendezvous: PathBuf,
    /// per-rank summary JSON output directory
    pub out: PathBuf,
    /// how long a finished rank keeps serving its inbox (acks, bootstrap
    /// donations) before exiting
    pub linger_ms: u64,
    /// flight-recorder spec forwarded to every worker; each rank dumps
    /// to `<out>/trace_rank<r>.json` when on
    pub trace: crate::trace::TraceSpec,
}

/// The CLI string that round-trips through `Method::parse`.
pub fn method_cli_label(m: &Method) -> Result<String> {
    Ok(match m {
        Method::NoComm => "none".into(),
        Method::ElasticGossip { alpha } => format!("elastic-gossip:{alpha}"),
        Method::GossipingSgdPull => "gossip-pull".into(),
        Method::GossipingSgdPush => "gossip-push".into(),
        Method::GoSgd => "gosgd".into(),
        other => bail!("method {:?} has no async protocol for net-train", other),
    })
}

/// The argv a worker process for `rank` is spawned with.
pub fn worker_args(nc: &NetTrainCfg, rank: usize, rejoin: bool) -> Result<Vec<String>> {
    let mut a = vec![
        "net-train".into(),
        "--net-worker".into(),
        rank.to_string(),
        "--workers".into(),
        nc.workers.to_string(),
        "--method".into(),
        method_cli_label(&nc.method)?,
        "--epochs".into(),
        nc.epochs.to_string(),
        "--prob".into(),
        nc.prob.to_string(),
        "--seed".into(),
        nc.seed.to_string(),
        "--codec".into(),
        nc.codec.label(),
        "--pace-ms".into(),
        nc.pace_ms.to_string(),
        "--straggler".into(),
        nc.straggler.to_string(),
        "--rendezvous".into(),
        nc.rendezvous.display().to_string(),
        "--out".into(),
        nc.out.display().to_string(),
        "--linger-ms".into(),
        nc.linger_ms.to_string(),
    ];
    if !nc.trace.is_off() {
        a.push("--trace".into());
        a.push(nc.trace.label());
    }
    if rejoin {
        a.push("--rejoin".into());
    }
    Ok(a)
}

fn wall_micros(epoch: Instant) -> u64 {
    epoch.elapsed().as_micros() as u64
}

/// Lite wall-clock failure detection state for one peer.
struct PeerFd {
    last_heard: Instant,
    /// highest incarnation seen on any frame from this peer
    inc: u32,
    /// 0 alive, 1 suspect, 2 confirmed dead
    state: u8,
}

/// Run one free-running worker process.  See the module docs for the
/// mode's semantics; the deliberate differences from the virtual-clock
/// runtime are (a) frames carry the *sender's* incarnation (SWIM-style)
/// rather than the simulator's receiver-generation stamp, (b) failure
/// detection is the lite direct-ping variant (no ping-req relays), and
/// (c) staleness/latency are measured on the wall clock.
pub fn run_net_worker(nc: &NetTrainCfg, rank: usize, rejoin: bool) -> Result<()> {
    ensure!(rank < nc.workers, "rank {} out of range ({} workers)", rank, nc.workers);
    let w = nc.workers;
    let (mut cfg, spec) =
        super::study_setup(nc.method.clone(), w, nc.prob, nc.epochs, nc.seed);
    cfg.codec = nc.codec;
    cfg.trace = nc.trace.clone();
    ensure!(
        !matches!(nc.codec, CodecKind::TopK { .. }),
        "net-train does not support the top-k overlay codec yet (its \
         per-receiver residual state assumes the single-process runtime)"
    );
    let mut engine = spec.build()?;
    let flat = engine.flat_size();
    let b = engine.train_batch();

    // --- deterministic tables: identical in every rank ------------------
    let root_rng = Rng::new(cfg.seed);
    let full = build_dataset_pub(&cfg, &mut root_rng.stream("datagen"))?;
    let (train, _val, test) = full.split(
        cfg.n_train.min(full.len()),
        cfg.n_val,
        cfg.n_test,
        &mut root_rng.stream("split"),
    );
    let shards = cfg.partition.assign(&train, w, &mut root_rng.stream("partition"));
    let mut strategy = cfg.method.build(w, flat);
    ensure!(
        strategy.async_capable(),
        "method {:?} has no message-level protocol",
        strategy.name()
    );
    let init = engine.initial_params()?;
    let mut params = init.clone();
    let mut optim = Optimizer::new(cfg.optimizer, cfg.lr.clone(), flat);
    let mut cursor = BatchCursor::new(
        shards[rank].clone(),
        root_rng.stream(&format!("batches{rank}")),
    );
    let steps_per_epoch = cfg.steps_per_epoch();
    let ts = cfg.total_steps() as usize;
    let mut arena = ScratchArena::new();
    arena.ensure(w, flat);
    let mut masks: Vec<bool> = Vec::with_capacity(ts * w);
    let mut picks: Vec<u32> = vec![u32::MAX; ts * w];
    {
        let mut sched_rng = root_rng.stream("schedule");
        let mut gossip_rng = root_rng.stream("gossip");
        let mut mask_t: Vec<bool> = Vec::with_capacity(w);
        let pairwise = cfg.method.is_pairwise_gossip();
        let topo_cache = arena.topo_cache_mut();
        topo_cache.ensure(&cfg.topology, w);
        for t in 0..ts {
            decide_schedule_into(&cfg.method, cfg.schedule, t as u64, w, &mut sched_rng, &mut mask_t);
            masks.extend_from_slice(&mask_t);
            if pairwise {
                for (i, &firing) in mask_t.iter().enumerate() {
                    if firing {
                        picks[t * w + i] = topo_cache
                            .sample_peer(i, &mut gossip_rng)
                            .map(|p| p as u32)
                            .unwrap_or(u32::MAX);
                    }
                }
            }
        }
    }
    let mut seed_rng = root_rng.stream("dropout");
    let seeds: Vec<i32> = (0..ts * w).map(|_| seed_rng.next_u64() as i32).collect();
    let mut codec: Box<dyn Codec> = cfg.codec.build();

    // --- incarnation + rendezvous ----------------------------------------
    std::fs::create_dir_all(&nc.rendezvous)?;
    std::fs::create_dir_all(&nc.out)?;
    let inc_path = nc.rendezvous.join(format!("rank_{rank}.inc"));
    let inc: u32 = std::fs::read_to_string(&inc_path)
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
        + 1;
    std::fs::write(&inc_path, inc.to_string())?;
    let ep = UdpTransport::loopback(w).context("binding worker socket")?;
    let my_addr = ep.local_addr().context("worker socket has no addr")?;
    // atomic publish: a half-written addr file must never be parseable
    let tmp = nc.rendezvous.join(format!(".rank_{rank}.addr.tmp"));
    std::fs::write(&tmp, my_addr.to_string())?;
    std::fs::rename(&tmp, nc.rendezvous.join(format!("rank_{rank}.addr")))?;
    let deadline = Instant::now() + Duration::from_secs(10);
    for p in 0..w {
        if p == rank {
            ep.set_peer(p, my_addr);
            continue;
        }
        loop {
            if let Ok(s) = std::fs::read_to_string(nc.rendezvous.join(format!("rank_{p}.addr"))) {
                if let Ok(a) = s.trim().parse::<SocketAddr>() {
                    ep.set_peer(p, a);
                    break;
                }
            }
            ensure!(
                Instant::now() < deadline,
                "rendezvous timeout: rank {p} never published an address"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    // --- per-run state ----------------------------------------------------
    let epoch0 = Instant::now();
    let mut t: u64 = 0;
    let mut cur_epoch: usize = 0;
    let mut restored_step: u64 = 0;
    let mut donor_info: Option<(usize, u64)> = None; // (donor, adopted digest)
    let mut mailbox: Vec<NetMsg> = Vec::new();
    let mut outbox: Vec<NetMsg> = Vec::new();
    let mut staleness = StalenessHist::new();
    // wall-clock timeline (there is no virtual clock here): micros since
    // worker start, per rank — NOT byte-reproducible across runs, which
    // is the mode's documented property
    let mut trace = Trace::from_spec(&cfg.trace, &format!("{}-rank{rank}", cfg.label));
    let mut lat_us: Vec<u64> = Vec::new();
    let mut fd_events: Vec<String> = Vec::new();
    let mut fd: Vec<PeerFd> = (0..w)
        .map(|_| PeerFd { last_heard: Instant::now(), inc: 0, state: 0 })
        .collect();
    let mut next_seq: u64 = 0;
    let mut probe_ctr: u64 = 0;
    let mut served_bootstraps: u64 = 0;
    let mut grad = vec![0.0f32; flat];
    let mut xbuf = BatchXOwned::F32(Vec::new());
    let mut ybuf: Vec<i32> = Vec::new();
    let mut bidx: Vec<usize> = Vec::new();
    let pace = Duration::from_millis(if rank == w - 1 {
        (nc.pace_ms as f64 * nc.straggler) as u64
    } else {
        nc.pace_ms
    });
    let suspect_after = Duration::from_millis((8 * nc.pace_ms).max(600));
    let confirm_after = suspect_after * 2;
    let ckdir = nc.rendezvous.join(format!("ckpt_rank{rank}"));

    // --- crash-recovery rejoin (PR 5 donor-bootstrap over the wire) ------
    if rejoin {
        let c = AsyncCheckpoint::load(&ckdir)
            .with_context(|| format!("rank {rank} --rejoin with no checkpoint at {ckdir:?}"))?;
        c.validate(&cfg.label, cfg.seed, flat)?;
        let node = c
            .nodes
            .into_iter()
            .nth(rank)
            .flatten()
            .context("checkpoint has no state for this rank")?;
        ensure!(node.params.len() == flat, "checkpoint flat size mismatch");
        params.copy_from_slice(&node.params);
        optim.restore_velocity(&node.velocity);
        optim.start_epoch(node.epoch.min(cfg.epochs.saturating_sub(1)));
        t = node.step;
        cur_epoch = node.epoch;
        restored_step = node.step;
        // fast-forward the batch cursor to the restored step so the data
        // order stays the deterministic one
        for _ in 0..node.step {
            cursor.next_batch(b, &mut bidx);
        }
        // donor bootstrap: ask a live peer for its exact parameters,
        // announcing the fresh incarnation
        let donor = (rank + 1) % w;
        next_seq += 1;
        let req = WireFrame {
            kind: fk::JOIN_REQUEST,
            flags: 0,
            src: rank as u32,
            dst: donor as u32,
            picker: rank as u32,
            gen: inc,
            sent_step: t,
            seq: next_seq,
            ctrl: [inc as u64, 0],
            payload: Vec::new(),
            rumors: Vec::new(),
        };
        ep.send_frame(donor, &req)?;
        let give_up = Instant::now() + Duration::from_secs(3);
        let mut adopted = false;
        while Instant::now() < give_up {
            match ep.try_recv_frame_from()? {
                Some((f, from)) => {
                    let p = f.src as usize;
                    if p < w {
                        ep.set_peer(p, from);
                    }
                    if f.kind == fk::JOIN_REPLY && f.dst as usize == rank {
                        ensure!(f.payload.len() == flat * 4, "bootstrap reply size mismatch");
                        for (slot, chunk) in
                            params.iter_mut().zip(f.payload.chunks_exact(4))
                        {
                            *slot = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                        }
                        donor_info = Some((p, digest_params(&params)));
                        adopted = true;
                        break;
                    }
                    // anything else that arrives while we wait is normal
                    // traffic — too early to act on, drop it
                }
                None => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        if !adopted {
            // free-run from the checkpoint (same fallback as the
            // simulator's donor-less bootstrap)
            fd_events.push(format!("bootstrap-timeout donor={donor}"));
        }
        for f in fd.iter_mut() {
            f.last_heard = Instant::now();
        }
    }

    // --- helpers ----------------------------------------------------------
    // (closures would fight the borrow checker across engine/strategy/
    // params; plain code blocks below instead)

    let pairwise = cfg.method.is_pairwise_gossip();
    let mut finished_steps: u64 = 0;

    while t < ts as u64 {
        // ---- inbox: drain everything that has arrived -------------------
        loop {
            let (frame, from) = match ep.try_recv_frame_from()? {
                Some(x) => x,
                None => break,
            };
            handle_frame(
                frame, from, rank, w, inc, &ep, &mut fd, &mut fd_events, &mut params,
                &mut arena, strategy.as_mut(), &mut mailbox, &mut outbox, &mut next_seq,
                &mut served_bootstraps, codec.as_mut(), flat, &mut lat_us, epoch0, t,
                &mut trace,
            )?;
        }

        // ---- gradient (deterministic data order) ------------------------
        let step_t0 = if trace.is_on() { wall_micros(epoch0) } else { 0 };
        cursor.next_batch(b, &mut bidx);
        match train.kind {
            TaskKind::Classify => {
                data::gather_f32(&train, &bidx, xbuf.clear_f32(), &mut ybuf)
            }
            TaskKind::LanguageModel => {
                data::gather_i32(&train, &bidx, xbuf.clear_i32(), &mut ybuf)
            }
        }
        engine.loss_and_grad(
            &params,
            xbuf.as_ref(),
            &ybuf,
            seeds[t as usize * w + rank],
            &mut grad,
        )?;
        // pacing sleep stands in for compute time (the straggler rank
        // sleeps `straggler` times longer)
        std::thread::sleep(pace);
        if trace.is_on() {
            let now = wall_micros(epoch0);
            trace.span_us(
                step_t0,
                now.saturating_sub(step_t0),
                Ev { node: rank, kind: Kind::Step, class: 0, seq: t, a: t, b: 0 },
            );
        }

        // ---- send phase (pre-drawn schedule + pick tables) --------------
        if pairwise && masks[t as usize * w + rank] {
            let p = picks[t as usize * w + rank];
            if p != u32::MAX && p as usize != rank {
                let mut ctx = ProtoCtx {
                    node: rank,
                    step: t,
                    params: params.as_mut_slice(),
                    arena: &mut arena,
                    outbox: &mut outbox,
                };
                strategy.on_send_due(&mut ctx, p as usize)?;
            }
        }
        flush_outbox_wire(
            &mut outbox, &ep, codec.as_mut(), inc, &mut next_seq, epoch0, &mut arena, &mut trace,
        )?;

        // ---- boundary: apply parked gossip ------------------------------
        if !mailbox.is_empty() {
            mailbox.sort_by_key(|m| m.picker);
            for m in &mailbox {
                staleness.record(t.abs_diff(m.sent_step));
            }
            arena.snapshot(rank, &params);
            let mut ctx = ProtoCtx {
                node: rank,
                step: t,
                params: params.as_mut_slice(),
                arena: &mut arena,
                outbox: &mut outbox,
            };
            strategy.on_boundary_apply(&mut ctx, &mut mailbox)?;
            for mut m in mailbox.drain(..) {
                if let Some(buf) = m.payload.take_params() {
                    arena.return_msg(buf);
                }
            }
            flush_outbox_wire(
                &mut outbox, &ep, codec.as_mut(), inc, &mut next_seq, epoch0, &mut arena,
                &mut trace,
            )?;
        }

        // ---- optimizer step ---------------------------------------------
        optim.update_velocity(&grad);
        optim.apply(&mut params, &grad);
        t += 1;
        finished_steps += 1;

        // ---- epoch boundary: checkpoint ---------------------------------
        if t % steps_per_epoch == 0 {
            cur_epoch += 1;
            if cur_epoch < cfg.epochs {
                optim.start_epoch(cur_epoch);
            }
            let mut nodes: Vec<Option<AsyncNodeState>> = (0..w).map(|_| None).collect();
            nodes[rank] = Some(AsyncNodeState {
                step: t,
                epoch: cur_epoch,
                params: params.clone(),
                velocity: optim.velocity().to_vec(),
            });
            AsyncCheckpoint {
                label: cfg.label.clone(),
                seed: cfg.seed,
                flat_size: flat,
                nodes,
            }
            .save(&ckdir)?;
        }

        // ---- lite SWIM: ping round-robin, scan timers -------------------
        probe_ctr += 1;
        if w > 1 {
            let target = (rank + 1 + (probe_ctr as usize % (w - 1))) % w;
            if target != rank {
                next_seq += 1;
                let ping = WireFrame {
                    kind: fk::FD_PING,
                    flags: 0,
                    src: rank as u32,
                    dst: target as u32,
                    picker: rank as u32,
                    gen: inc,
                    sent_step: t,
                    seq: next_seq,
                    ctrl: [probe_ctr, rank as u64],
                    payload: Vec::new(),
                    rumors: Vec::new(),
                };
                let _ = ep.send_frame(target, &ping); // a lost ping is just silence
            }
        }
        for p in 0..w {
            if p == rank {
                continue;
            }
            let dt = fd[p].last_heard.elapsed();
            if fd[p].state == 0 && dt > suspect_after {
                fd[p].state = 1;
                fd_events.push(format!("suspect node={} inc={}", p, fd[p].inc));
            } else if fd[p].state == 1 && dt > confirm_after {
                fd[p].state = 2;
                fd_events.push(format!("confirm node={} inc={}", p, fd[p].inc));
            }
        }
    }

    // --- done: evaluate, linger serving the inbox, write the summary ----
    let (_, acc) = evaluate(engine.as_mut(), &params, &test)?;
    let digest = digest_params(&params);
    let linger_until = Instant::now() + Duration::from_millis(nc.linger_ms);
    while Instant::now() < linger_until {
        match ep.try_recv_frame_from()? {
            Some((frame, from)) => {
                handle_frame(
                    frame, from, rank, w, inc, &ep, &mut fd, &mut fd_events, &mut params,
                    &mut arena, strategy.as_mut(), &mut mailbox, &mut outbox, &mut next_seq,
                    &mut served_bootstraps, codec.as_mut(), flat, &mut lat_us, epoch0, t,
                    &mut trace,
                )?;
                // gossip parked during linger is never applied (training
                // is over) — drop it so buffers go home
                for mut m in mailbox.drain(..) {
                    if let Some(buf) = m.payload.take_params() {
                        arena.return_msg(buf);
                    }
                }
            }
            None => std::thread::sleep(Duration::from_millis(2)),
        }
        // keep the timers honest during linger too (the rejoin test reads
        // confirm/refute events that happen after the survivors finish)
        for p in 0..w {
            if p == rank {
                continue;
            }
            let dt = fd[p].last_heard.elapsed();
            if fd[p].state == 0 && dt > suspect_after {
                fd[p].state = 1;
                fd_events.push(format!("suspect node={} inc={}", p, fd[p].inc));
            } else if fd[p].state == 1 && dt > confirm_after {
                fd[p].state = 2;
                fd_events.push(format!("confirm node={} inc={}", p, fd[p].inc));
            }
        }
    }

    let st = ep.stats();
    let mut o = JsonObj::new();
    o.insert("rank", Json::Num(rank as f64));
    o.insert("incarnation", Json::Num(inc as f64));
    o.insert("digest", Json::Str(format!("{digest:016x}")));
    o.insert("accuracy", Json::Num(acc as f64));
    o.insert("steps", Json::Num(finished_steps as f64));
    o.insert("restored_step", Json::Num(restored_step as f64));
    match donor_info {
        Some((donor, adopted)) => {
            o.insert("bootstrap_donor", Json::Num(donor as f64));
            o.insert("adopted_digest", Json::Str(format!("{adopted:016x}")));
        }
        None => o.insert("bootstrap_donor", Json::Null),
    }
    o.insert("staleness", staleness.to_json());
    let mut lat = JsonObj::new();
    lat.insert("count", Json::Num(lat_us.len() as f64));
    let mean_ms = if lat_us.is_empty() {
        0.0
    } else {
        lat_us.iter().sum::<u64>() as f64 / lat_us.len() as f64 / 1e3
    };
    lat.insert("mean_ms", Json::Num(mean_ms));
    lat.insert(
        "max_ms",
        Json::Num(lat_us.iter().copied().max().unwrap_or(0) as f64 / 1e3),
    );
    o.insert("wire_latency", Json::Obj(lat));
    let mut tr = JsonObj::new();
    tr.insert("frames_sent", Json::Num(st.frames_sent as f64));
    tr.insert("bytes_sent", Json::Num(st.bytes_sent as f64));
    tr.insert("frames_recv", Json::Num(st.frames_recv as f64));
    tr.insert("bytes_recv", Json::Num(st.bytes_recv as f64));
    tr.insert("malformed_frames", Json::Num(st.malformed_frames as f64));
    o.insert("transport", Json::Obj(tr));
    o.insert("served_bootstraps", Json::Num(served_bootstraps as f64));
    o.insert(
        "fd_events",
        Json::Arr(fd_events.into_iter().map(Json::Str).collect()),
    );
    if trace.is_on() {
        // per-rank flight-recorder dump next to the summary; the default
        // dump path would collide across ranks, so pick one explicitly
        let tp = nc.out.join(format!("trace_rank{rank}.json"));
        trace
            .dump(Some(&tp))
            .with_context(|| format!("writing per-rank trace dump {tp:?}"))?;
        o.insert("trace", Json::Str(tp.display().to_string()));
    }
    let out_path = nc.out.join(format!("rank_{rank}.json"));
    std::fs::write(&out_path, json::write(&Json::Obj(o)))
        .with_context(|| format!("writing {out_path:?}"))?;
    Ok(())
}

/// Encode and transmit everything a strategy hook queued.  Frames carry
/// the sender's incarnation in `gen` and the send wall-clock (micros
/// since worker start) in `ctrl[1]` of param frames.
#[allow(clippy::too_many_arguments)]
fn flush_outbox_wire(
    outbox: &mut Vec<NetMsg>,
    ep: &UdpTransport,
    codec: &mut dyn Codec,
    inc: u32,
    next_seq: &mut u64,
    epoch0: Instant,
    arena: &mut ScratchArena,
    trace: &mut Trace,
) -> Result<()> {
    for mut m in outbox.drain(..) {
        m.gen = inc;
        if !m.payload.codec_exempt() {
            if let Some(p) = m.payload.params() {
                let mut buf = arena.rent_bytes();
                codec.encode_into(m.src, p, &mut buf);
                m.wire = Some(buf);
            }
        }
        *next_seq += 1;
        let frame = frame_from_msg(&m, *next_seq, wall_micros(epoch0));
        let dst = m.dst;
        // recycle pooled buffers before the send can fail
        if let Some(buf) = m.wire.take() {
            arena.return_bytes(buf);
        }
        if let Some(buf) = m.payload.take_params() {
            arena.return_msg(buf);
        }
        ep.send_frame(dst, &frame)?;
        trace.instant_us(
            wall_micros(epoch0),
            Ev {
                node: frame.src as usize,
                kind: Kind::Send,
                class: 0,
                seq: *next_seq,
                a: dst as u64,
                b: frame.payload.len() as u64,
            },
        );
    }
    Ok(())
}

/// Handle one inbound frame of the free-running worker: refresh the fd
/// plane (any frame is proof of life; a higher incarnation refutes a
/// confirmation), answer fd pings and bootstrap pulls inline (the
/// runtime-owned control plane, matching the simulator's split), and
/// route gossip payloads through the strategy's `on_message` hook —
/// protocol replies (elastic replies, pull replies) land in the outbox
/// and are flushed before returning; retained messages park in the
/// mailbox for the next boundary.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    f: WireFrame,
    from: SocketAddr,
    rank: usize,
    w: usize,
    inc: u32,
    ep: &UdpTransport,
    fd: &mut [PeerFd],
    fd_events: &mut Vec<String>,
    params: &mut [f32],
    arena: &mut ScratchArena,
    strategy: &mut dyn Strategy,
    mailbox: &mut Vec<NetMsg>,
    outbox: &mut Vec<NetMsg>,
    next_seq: &mut u64,
    served_bootstraps: &mut u64,
    codec: &mut dyn Codec,
    flat: usize,
    lat_us: &mut Vec<u64>,
    epoch0: Instant,
    step_now: u64,
    trace: &mut Trace,
) -> Result<()> {
    let src = f.src as usize;
    if f.dst as usize != rank || src >= w || src == rank {
        return Ok(()); // stray datagram (stale port reuse); drop
    }
    // live address learning: the envelope's source address is where this
    // peer's *current* incarnation listens
    ep.set_peer(src, from);
    trace.instant_us(
        wall_micros(epoch0),
        Ev {
            node: rank,
            kind: Kind::Recv,
            class: 0,
            seq: f.seq,
            a: src as u64,
            b: f.payload.len() as u64,
        },
    );
    // proof of life + SWIM refutation
    let pf = &mut fd[src];
    pf.last_heard = Instant::now();
    if f.gen > pf.inc {
        if pf.state == 2 {
            fd_events.push(format!("refute node={} inc={}", src, f.gen));
        }
        pf.inc = f.gen;
        pf.state = 0;
    } else if pf.state != 0 && f.gen == pf.inc {
        // same incarnation still talking: un-suspect quietly
        pf.state = 0;
    }
    match f.kind {
        fk::FD_PING => {
            *next_seq += 1;
            let ack = WireFrame {
                kind: fk::FD_ACK,
                flags: 0,
                src: rank as u32,
                dst: src as u32,
                picker: rank as u32,
                gen: inc,
                sent_step: step_now,
                seq: *next_seq,
                ctrl: [f.ctrl[0], inc as u64],
                payload: Vec::new(),
                rumors: Vec::new(),
            };
            let _ = ep.send_frame(src, &ack);
        }
        fk::FD_ACK | fk::FD_PING_REQ => {
            // ack: proof of life already recorded above.  ping-req: the
            // lite detector never emits relays; ignore if one arrives
        }
        fk::JOIN_REQUEST => {
            // donor bootstrap service: reply with the exact live
            // parameters (codec-exempt raw f32), any time — even during
            // the linger window after training finished
            *served_bootstraps += 1;
            let mut payload = Vec::with_capacity(flat * 4);
            for v in params.iter() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            *next_seq += 1;
            let reply = WireFrame {
                kind: fk::JOIN_REPLY,
                flags: 0,
                src: rank as u32,
                dst: src as u32,
                picker: src as u32,
                gen: inc,
                sent_step: step_now,
                seq: *next_seq,
                ctrl: [0, wall_micros(epoch0)],
                payload,
                rumors: Vec::new(),
            };
            ep.send_frame(src, &reply)?;
        }
        fk::JOIN_REPLY => {
            // a straggling bootstrap reply after the rejoin window
            // closed — the worker already free-ran; ignore
        }
        fk::ELASTIC_PUSH | fk::ELASTIC_REPLY | fk::PUSH_PARAMS | fk::PULL_REQUEST
        | fk::PULL_REPLY | fk::GOSGD_SHARE => {
            // param gossip: decode, then hand the message to the
            // strategy's receipt hook exactly as the simulator does —
            // the strategy decides what is answered now (pull replies,
            // elastic replies via ctx.send) and what parks for the
            // boundary
            if f.ctrl[1] != 0 {
                lat_us.push(wall_micros(epoch0).saturating_sub(f.ctrl[1]));
            }
            let payload = if f.kind == fk::PULL_REQUEST {
                ensure!(f.payload.is_empty(), "pull request carries payload bytes");
                MsgPayload::PullRequest
            } else {
                let mut buf = arena.rent_msg(&[]);
                if f.flags & FLAG_CODED != 0 {
                    if codec.is_overlay() {
                        buf.extend_from_slice(params);
                    } else {
                        buf.resize(flat, 0.0);
                    }
                    codec
                        .decode_into(&f.payload, &mut buf)
                        .context("decoding gossip payload")?;
                } else {
                    ensure!(f.payload.len() == flat * 4, "raw gossip payload size mismatch");
                    buf.resize(flat, 0.0);
                    for (slot, chunk) in buf.iter_mut().zip(f.payload.chunks_exact(4)) {
                        *slot = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                    }
                }
                match f.kind {
                    fk::ELASTIC_PUSH => MsgPayload::ElasticPush(buf),
                    fk::ELASTIC_REPLY => MsgPayload::ElasticReply(buf),
                    fk::PUSH_PARAMS => MsgPayload::PushParams(buf),
                    fk::PULL_REPLY => MsgPayload::PullReply(buf),
                    _ => MsgPayload::GoSgdShare {
                        params: buf,
                        weight: f64::from_bits(f.ctrl[0]),
                    },
                }
            };
            let msg = NetMsg {
                src,
                dst: rank,
                picker: f.picker as usize,
                sent_step: f.sent_step,
                payload,
                wire: None,
                gen: f.gen,
                rumors: RumorPack::empty(),
                wire_seq: 0,
            };
            let retained = {
                let mut ctx = ProtoCtx {
                    node: rank,
                    step: step_now,
                    params,
                    arena,
                    outbox,
                };
                strategy.on_message(&mut ctx, msg)?
            };
            if let Some(m) = retained {
                mailbox.push(m);
            }
            flush_outbox_wire(outbox, ep, codec, inc, next_seq, epoch0, arena, trace)?;
        }
        _ => {} // decode_frame already rejected unknown kinds
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// parent driver
// ---------------------------------------------------------------------------

/// Spawn one worker process per rank, wait for the fleet, merge the
/// per-rank summaries into `<out>/summary.json`, and return the parsed
/// rank objects (rank order).  `exe` is the `repro` binary to spawn —
/// normally `std::env::current_exe()`.
pub fn run_net_parent(nc: &NetTrainCfg, exe: &Path) -> Result<Vec<Json>> {
    // a stale rendezvous dir would feed old addresses/incarnations into
    // the fresh fleet
    if nc.rendezvous.exists() {
        std::fs::remove_dir_all(&nc.rendezvous)
            .with_context(|| format!("clearing rendezvous dir {:?}", nc.rendezvous))?;
    }
    std::fs::create_dir_all(&nc.rendezvous)?;
    std::fs::create_dir_all(&nc.out)?;
    let mut children = Vec::with_capacity(nc.workers);
    for rank in 0..nc.workers {
        let child = std::process::Command::new(exe)
            .args(worker_args(nc, rank, false)?)
            .spawn()
            .with_context(|| format!("spawning worker {rank}"))?;
        children.push((rank, child));
    }
    let mut failed = Vec::new();
    for (rank, mut child) in children {
        let status = child.wait()?;
        if !status.success() {
            failed.push(rank);
        }
    }
    ensure!(failed.is_empty(), "net-train workers failed: ranks {:?}", failed);
    collect_summaries(nc)
}

/// Read every `rank_<r>.json` the workers wrote, write the merged
/// `summary.json`, and return the parsed per-rank objects.
pub fn collect_summaries(nc: &NetTrainCfg) -> Result<Vec<Json>> {
    let mut ranks = Vec::with_capacity(nc.workers);
    for r in 0..nc.workers {
        let p = nc.out.join(format!("rank_{r}.json"));
        let s = std::fs::read_to_string(&p)
            .with_context(|| format!("worker {r} left no summary at {p:?}"))?;
        let v = json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {p:?}: {e}"))?;
        ranks.push(v);
    }
    let mut o = JsonObj::new();
    o.insert("workers", Json::Num(nc.workers as f64));
    o.insert("method", Json::Str(method_cli_label(&nc.method)?));
    o.insert("codec", Json::Str(nc.codec.label()));
    o.insert("transport", Json::Str("udp".into()));
    o.insert("ranks", Json::Arr(ranks.clone()));
    std::fs::write(nc.out.join("summary.json"), json::write(&Json::Obj(o)))?;
    Ok(ranks)
}

/// Print the wall-clock staleness / latency table for a finished fleet.
pub fn print_fleet_table(ranks: &[Json]) {
    println!(
        "{:>4} {:>5} {:>10} {:>9} {:>11} {:>11} {:>9}",
        "rank", "steps", "acc", "stale.mean", "lat.mean_ms", "frames_sent", "malformed"
    );
    for v in ranks {
        let o = match v.as_obj() {
            Some(o) => o,
            None => continue,
        };
        let num = |k: &str| o.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let sub = |k: &str, k2: &str| {
            o.get(k)
                .and_then(Json::as_obj)
                .and_then(|s| s.get(k2))
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
        };
        println!(
            "{:>4} {:>5} {:>10.4} {:>9.2} {:>11.2} {:>11} {:>9}",
            num("rank") as u64,
            num("steps") as u64,
            num("accuracy"),
            sub("staleness", "mean"),
            sub("wire_latency", "mean_ms"),
            sub("transport", "frames_sent") as u64,
            sub("transport", "malformed_frames") as u64,
        );
    }
    println!(
        "note: wall-clock UDP runs are reproducible in aggregate (same data, \
         schedule and protocol), not bit-identical across runs"
    );
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn base_msg(payload: MsgPayload) -> NetMsg {
        NetMsg {
            src: 1,
            dst: 2,
            picker: 1,
            sent_step: 17,
            payload,
            wire: None,
            gen: 3,
            rumors: RumorPack::empty(),
            wire_seq: 0,
        }
    }

    #[test]
    fn frame_roundtrip_raw_params() {
        // codec-exempt JoinReply travels as raw LE f32
        let mut msg = base_msg(MsgPayload::JoinReply(vec![1.5, -2.25, 0.0, 3.0]));
        let f = frame_from_msg(&msg, 9, 0);
        assert_eq!(f.kind, fk::JOIN_REPLY);
        assert_eq!(f.payload.len(), 16);
        // wipe the params, then apply the frame back
        if let MsgPayload::JoinReply(p) = &mut msg.payload {
            p.iter_mut().for_each(|v| *v = 0.0);
        }
        apply_frame(&mut msg, &f).unwrap();
        match &msg.payload {
            MsgPayload::JoinReply(p) => assert_eq!(p.as_slice(), &[1.5, -2.25, 0.0, 3.0]),
            other => panic!("payload changed variant: {}", other.kind()),
        }
    }

    #[test]
    fn frame_roundtrip_coded_payload_and_ctrl() {
        let mut msg = base_msg(MsgPayload::GoSgdShare {
            params: vec![0.0; 4],
            weight: 0.1875,
        });
        msg.wire = Some(vec![0xde, 0xad, 0xbe, 0xef]);
        msg.rumors.push(Rumor { kind: 2, node: 7, inc: 4 });
        let f = frame_from_msg(&msg, 42, 12345);
        assert_eq!(f.kind, fk::GOSGD_SHARE);
        assert_ne!(f.flags & FLAG_CODED, 0);
        assert_eq!(f.ctrl[0], 0.1875f64.to_bits());
        assert_eq!(f.rumors, vec![(2u8, 7u16, 4u32)]);

        let mut rx = base_msg(MsgPayload::GoSgdShare { params: vec![0.0; 4], weight: 0.0 });
        rx.wire = Some(Vec::new());
        apply_frame(&mut rx, &f).unwrap();
        assert_eq!(rx.wire.as_deref(), Some(&[0xde, 0xad, 0xbe, 0xef][..]));
        match &rx.payload {
            MsgPayload::GoSgdShare { weight, .. } => assert_eq!(*weight, 0.1875),
            other => panic!("payload changed variant: {}", other.kind()),
        }
        let rumors: Vec<_> = rx.rumors.iter().map(|r| (r.kind, r.node, r.inc)).collect();
        assert_eq!(rumors, vec![(2u8, 7u16, 4u32)]);
        assert_eq!(rx.sent_step, 17);
        assert_eq!(rx.gen, 3);
    }

    #[test]
    fn apply_frame_rejects_kind_mismatch() {
        let msg = base_msg(MsgPayload::PullRequest);
        let f = frame_from_msg(&msg, 1, 0);
        let mut other = base_msg(MsgPayload::PushParams(vec![0.0; 2]));
        other.wire = Some(Vec::new());
        assert!(apply_frame(&mut other, &f).is_err());
    }

    #[test]
    fn fd_ctrl_words_roundtrip() {
        let msg = base_msg(MsgPayload::FdPing { probe: 99, origin: 5 });
        let f = frame_from_msg(&msg, 1, 0);
        assert_eq!(f.ctrl, [99, 5]);
        let mut rx = base_msg(MsgPayload::FdPing { probe: 0, origin: 0 });
        apply_frame(&mut rx, &f).unwrap();
        match rx.payload {
            MsgPayload::FdPing { probe, origin } => {
                assert_eq!((probe, origin), (99, 5));
            }
            other => panic!("payload changed variant: {}", other.kind()),
        }
    }

    #[test]
    fn method_cli_labels_reparse() {
        for m in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
            Method::NoComm,
        ] {
            let label = method_cli_label(&m).unwrap();
            let back = Method::parse(&label).unwrap();
            assert_eq!(back, m, "label {label} did not round-trip");
        }
    }
}
