//! Sharded virtual-clock event queue + gradient shard workers.
//!
//! Scaling the async runtime to 10⁵–10⁶ simulated nodes has two costs:
//! the global event heap (every push/pop is `O(log total_events)` on one
//! core) and the gradient compute (the only genuinely heavy per-event
//! work).  This module shards both while keeping the trajectory
//! **bit-identical** to the single-queue runtime:
//!
//! * [`ShardedQueue`] — nodes are pinned to shards (`node % nshards`);
//!   each shard owns a local min-heap over its nodes' events.  The `seq`
//!   tiebreaker is assigned globally in scheduling order — exactly as
//!   the single queue would — so popping the minimum of the shard minima
//!   reproduces the single queue's `(time, class, seq)` pop order event
//!   for event.  With `nshards == 1` this *is* the single queue.
//! * [`GradRouter`] — one OS thread per shard, each owning a private
//!   `GradEngine` built from the run's [`EngineFactory`] (engines are
//!   not `Send`: the PJRT client is `Rc`-based, so they must be built
//!   inside the thread that uses them).  `begin_step` ships a
//!   [`GradJob`] (an addressed envelope: pooled parameter copy + the
//!   node's batch buffers) to the node's shard over an mpsc channel and
//!   schedules the `StepDone` as usual; when that `StepDone` pops, the
//!   driver blocks on the matching [`GradDone`] — by then the worker has
//!   usually long finished, so the virtual-clock gap between scheduling
//!   and popping is the conservative lookahead that buys parallelism.
//!
//! Why this is exact: a node's parameters are frozen between its
//! `begin_step` and its own next boundary (messages park in the mailbox
//! until then), and `loss_and_grad` is a pure function of
//! `(params, batch, seed)` — the same contract the synchronous threaded
//! runtime (`coordinator/parallel.rs`) already relies on.  Everything
//! order-sensitive — rng draws, f64 loss folds, fabric ledgers, strategy
//! hooks, the fd plane — stays on the driver thread, in merged pop
//! order.  Only the pure gradient evaluation runs on the shard threads.

use std::collections::BinaryHeap;
use std::sync::mpsc;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{BatchXOwned, EngineFactory};

use super::{Event, Queued};

// ---------------------------------------------------------------------------
// sharded event queue
// ---------------------------------------------------------------------------

/// Per-shard min-heaps over a global `(time, class, seq)` key space.
/// Drop-in replacement for the single `BinaryHeap<Queued>`: same `sched`
/// semantics (global seq counter), same pop order (tournament over the
/// shard heads).
pub(super) struct ShardedQueue {
    heaps: Vec<BinaryHeap<Queued>>,
    seq: u64,
    len: usize,
}

impl ShardedQueue {
    pub(super) fn new(nshards: usize) -> Self {
        assert!(nshards >= 1, "need at least one shard");
        ShardedQueue {
            heaps: (0..nshards).map(|_| BinaryHeap::new()).collect(),
            seq: 0,
            len: 0,
        }
    }

    pub(super) fn nshards(&self) -> usize {
        self.heaps.len()
    }

    /// The shard that owns node `i` — its events and its gradient jobs.
    #[inline]
    pub(super) fn shard_of(&self, node: usize) -> usize {
        node % self.heaps.len()
    }

    /// Home shard of an event: node-bearing events live with their node
    /// (deliveries with their *destination*), global events (churn,
    /// evaluation) on shard 0.
    #[inline]
    fn home(&self, ev: &Event) -> usize {
        match ev {
            Event::StepDone { node, .. }
            | Event::Boundary { node, .. }
            | Event::FdTick { node }
            | Event::FdProbeTimeout { node, .. }
            | Event::FdIndirectTimeout { node, .. }
            | Event::FdSuspectTimeout { node, .. } => self.shard_of(*node),
            Event::MsgDelivered { msg } => self.shard_of(msg.dst),
            Event::Churn { .. } | Event::EvalTick { .. } => 0,
        }
    }

    /// Schedule an event.  The `seq` tiebreaker is global across shards
    /// and assigned in call order — the exact key the single queue would
    /// assign — so the merged pop order cannot depend on the shard count.
    #[inline]
    pub(super) fn sched(&mut self, time: f64, class: u8, ev: Event) {
        let s = self.home(&ev);
        self.heaps[s].push(Queued { time, class, seq: self.seq, ev });
        self.seq += 1;
        self.len += 1;
    }

    /// Pop the globally earliest event: each shard heap exposes its own
    /// minimum, and the minimum of shard minima is the global minimum.
    /// `(time, class, seq)` keys are unique (`seq` strictly increases),
    /// so the winner is unambiguous and the merged order is identical to
    /// one global heap.
    pub(super) fn pop(&mut self) -> Option<Queued> {
        let mut best: Option<usize> = None;
        for (s, h) in self.heaps.iter().enumerate() {
            if let Some(q) = h.peek() {
                // Queued's Ord is inverted (BinaryHeap is a max-heap but
                // pops the earliest event): "greater" means earlier
                let earlier = match best {
                    None => true,
                    Some(b) => q > self.heaps[b].peek().expect("best shard has a head"),
                };
                if earlier {
                    best = Some(s);
                }
            }
        }
        let s = best?;
        self.len -= 1;
        self.heaps[s].pop()
    }

    pub(super) fn len(&self) -> usize {
        self.len
    }
}

// ---------------------------------------------------------------------------
// gradient shard workers
// ---------------------------------------------------------------------------

/// An addressed gradient-compute envelope: everything a shard worker
/// needs to evaluate one step, all buffers owned (pooled parameter copy
/// from the arena, the node's own batch buffers) so nothing is borrowed
/// across threads.
pub(super) struct GradJob {
    pub node: usize,
    pub gen: u32,
    pub seed: i32,
    pub params: Vec<f32>,
    pub x: BatchXOwned,
    pub y: Vec<i32>,
    pub grad: Vec<f32>,
}

/// The reply envelope: same buffers back (for recycling into the arena
/// pools and the node's batch slots) plus the computed loss/gradient.
pub(super) struct GradDone {
    pub node: usize,
    pub gen: u32,
    pub loss: Result<f32>,
    pub params: Vec<f32>,
    pub x: BatchXOwned,
    pub y: Vec<i32>,
    pub grad: Vec<f32>,
}

impl GradDone {
    /// Sentinel for a worker that could not build its engine: surfaces
    /// the build error at the driver's next collect.
    fn build_failure(e: anyhow::Error) -> GradDone {
        GradDone {
            node: usize::MAX,
            gen: 0,
            loss: Err(e),
            params: Vec::new(),
            x: BatchXOwned::F32(Vec::new()),
            y: Vec::new(),
            grad: Vec::new(),
        }
    }
}

/// Per-shard job channels + one shared result channel.  The channel ends
/// held here are `'static` values — only the worker threads borrow the
/// factory, and they live inside the caller's `std::thread::scope`.
/// Dropping the router closes every job channel, which is how the
/// workers learn the run is over.
pub(super) struct GradRouter {
    txs: Vec<mpsc::Sender<GradJob>>,
    rx: mpsc::Receiver<GradDone>,
}

impl GradRouter {
    /// Spawn one gradient worker per shard inside `scope`.  Each worker
    /// builds its own engine from the factory (inside the thread — see
    /// module docs), then loops: receive job, compute, send result.
    pub(super) fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        nshards: usize,
        factory: &'env dyn EngineFactory,
    ) -> GradRouter {
        let (res_tx, res_rx) = mpsc::channel::<GradDone>();
        let mut txs = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            let (tx, rx) = mpsc::channel::<GradJob>();
            let res = res_tx.clone();
            scope.spawn(move || {
                let mut engine = match factory.build() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = res.send(GradDone::build_failure(e));
                        return;
                    }
                };
                while let Ok(mut job) = rx.recv() {
                    let n = job.params.len();
                    if job.grad.len() != n {
                        // pooled buffers carry their capacity between
                        // jobs: after warm-up this resize is free
                        job.grad.resize(n, 0.0);
                    }
                    let loss = engine.loss_and_grad(
                        &job.params,
                        job.x.as_ref(),
                        &job.y,
                        job.seed,
                        &mut job.grad,
                    );
                    let done = GradDone {
                        node: job.node,
                        gen: job.gen,
                        loss,
                        params: job.params,
                        x: job.x,
                        y: job.y,
                        grad: job.grad,
                    };
                    if res.send(done).is_err() {
                        return; // driver hung up
                    }
                }
            });
            txs.push(tx);
        }
        GradRouter { txs, rx: res_rx }
    }

    /// Ship a job to its shard worker.  A closed channel means the
    /// worker exited on a build error — that error surfaces from the
    /// result channel at the driver's next [`recv`](Self::recv), so the
    /// send failure itself is ignored.
    pub(super) fn submit(&self, shard: usize, job: GradJob) {
        let _ = self.txs[shard].send(job);
    }

    /// Block for the next finished gradient (any shard).  The caller
    /// matches it against the popped `StepDone` by `(node, gen)`.
    pub(super) fn recv(&self) -> Result<GradDone> {
        let done = self
            .rx
            .recv()
            .map_err(|_| anyhow!("gradient shard workers disconnected"))?;
        if done.node == usize::MAX {
            return Err(match done.loss {
                Err(e) => e.context("building gradient engine in shard worker"),
                Ok(_) => anyhow!("gradient shard worker failed without an error"),
            });
        }
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CLASS_BOUNDARY, CLASS_CHURN, CLASS_EVAL, CLASS_MSG, CLASS_STEP};
    use super::*;
    use crate::util::rng::Rng;

    fn ev_for(node: usize, class: u8) -> Event {
        match class {
            CLASS_CHURN => Event::Churn { idx: node },
            CLASS_STEP => Event::StepDone { node, gen: 0 },
            CLASS_BOUNDARY => Event::Boundary { node, gen: 0 },
            CLASS_EVAL => Event::EvalTick { epoch: node },
            _ => Event::FdTick { node },
        }
    }

    fn key(q: &Queued) -> (u64, u8, u64) {
        (q.time.to_bits(), q.class, q.seq)
    }

    /// The core bit-identity argument, checked directly: any scheduling
    /// sequence pops in exactly the single-heap order, for any shard
    /// count, including interleaved sched/pop traffic.
    #[test]
    fn sharded_pop_order_equals_single_heap_for_any_shard_count() {
        for shards in [1usize, 2, 3, 4, 7] {
            let mut rng = Rng::new(0xC0FFEE + shards as u64);
            let mut single = ShardedQueue::new(1);
            let mut sharded = ShardedQueue::new(shards);
            let classes = [CLASS_CHURN, CLASS_STEP, CLASS_MSG, CLASS_BOUNDARY, CLASS_EVAL];
            let mut pending = 0usize;
            for round in 0..200 {
                // burst of schedules with heavy (time, class) collisions
                // so the seq tiebreaker does real work
                for _ in 0..(1 + rng.next_u64() as usize % 5) {
                    let time = (rng.next_u64() % 8) as f64 * 0.5;
                    let class = classes[rng.next_u64() as usize % classes.len()];
                    let node = rng.next_u64() as usize % 23;
                    // MSG needs a NetMsg; route it via an fd tick instead
                    let class = if class == CLASS_MSG { CLASS_STEP } else { class };
                    single.sched(time, class, ev_for(node, class));
                    sharded.sched(time, class, ev_for(node, class));
                    pending += 1;
                }
                // drain a few interleaved pops
                for _ in 0..(rng.next_u64() as usize % 3) {
                    if pending == 0 {
                        break;
                    }
                    let a = single.pop().expect("single has events");
                    let b = sharded.pop().expect("sharded has events");
                    assert_eq!(key(&a), key(&b), "round {round}, shards {shards}");
                    pending -= 1;
                }
            }
            while let Some(a) = single.pop() {
                let b = sharded.pop().expect("sharded drains in step");
                assert_eq!(key(&a), key(&b), "drain, shards {shards}");
            }
            assert!(sharded.pop().is_none());
            assert_eq!(sharded.len(), 0);
        }
    }

    #[test]
    fn events_land_on_their_node_shard() {
        let mut q = ShardedQueue::new(4);
        assert_eq!(q.nshards(), 4);
        assert_eq!(q.shard_of(0), 0);
        assert_eq!(q.shard_of(5), 1);
        assert_eq!(q.shard_of(7), 3);
        // node-bearing events route by node; global events go to shard 0
        q.sched(1.0, CLASS_STEP, Event::StepDone { node: 6, gen: 0 });
        q.sched(1.0, CLASS_EVAL, Event::EvalTick { epoch: 3 });
        assert_eq!(q.heaps[2].len(), 1);
        assert_eq!(q.heaps[0].len(), 1);
        assert_eq!(q.len(), 2);
        // churn orders before eval at the same instant even across shards
        q.sched(1.0, CLASS_CHURN, Event::Churn { idx: 0 });
        let order: Vec<u8> = std::iter::from_fn(|| q.pop()).map(|e| e.class).collect();
        assert_eq!(order, vec![CLASS_CHURN, CLASS_STEP, CLASS_EVAL]);
    }
}
