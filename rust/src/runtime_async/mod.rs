//! Event-driven asynchronous gossip runtime: message-passing nodes on a
//! virtual clock.
//!
//! This is the execution regime the thesis's future-work chapter asks
//! for ("studying the effects of asynchrony that is controlled in a
//! simulated environment"): no leader, no barriers.  Each worker is a
//! *node* with a mailbox; a single virtual-clock event queue — unifying
//! the per-worker compute-time model (`sim::WorkerSpeed`) with the
//! fabric's link model (`comm::LinkModel`) — schedules three kinds of
//! event:
//!
//! * **`StepDone`** — a node finished computing its local gradient step.
//!   If its communication schedule fired, the strategy's
//!   [`on_send_due`](crate::algos::Strategy::on_send_due) hook emits
//!   protocol messages; each is accounted on the fabric
//!   ([`Fabric::send_async`](crate::comm::Fabric::send_async)) and
//!   scheduled for delivery at `now + link transfer time`.
//! * **`MsgDelivered`** — a message reached its destination, *possibly
//!   mid-step*.  The strategy's
//!   [`on_message`](crate::algos::Strategy::on_message) hook reacts with
//!   the node's **current** state — this is where real staleness enters:
//!   a pull reply or elastic reply under a slow link carries parameters
//!   from whatever step the responder happens to be at — and parks
//!   apply-relevant messages in the node's mailbox.
//! * **`EvalTick`** — the last node crossed an epoch boundary; the
//!   harness evaluates every replica and the aggregate model, exactly
//!   like the synchronous coordinator's epoch-end evaluation.
//!
//! At a node's own step boundary the mailbox is applied
//! ([`on_boundary_apply`](crate::algos::Strategy::on_boundary_apply)),
//! one staleness sample is recorded per exchange
//! ([`metrics::StalenessHist`]), the optimizer runs, and the next step's
//! gradient is scheduled — the node never waits for anyone.
//!
//! # Synchronous execution as the zero-latency lockstep special case
//!
//! Under [`AsyncSimCfg::lockstep`] — deterministic uniform speeds and the
//! zero link ([`LinkModel::zero`]) — every node's `StepDone` lands on the
//! same virtual instant, deliveries collapse onto their send instants,
//! and the event classes order each instant as *all sends → all
//! deliveries (and replies) → all boundary applies*.  Mailboxes sorted by
//! edge initiator reproduce the k-set order of Algorithm 4, boundary
//! snapshots equal the pre-round snapshots, and the apply hooks route
//! through the same fused kernels as the synchronous round — so the
//! event-driven runtime's parameter trajectory is **bit-identical** to
//! [`Coordinator::run`](crate::coordinator::Coordinator) for every
//! pairwise gossip method (asserted by the equivalence tests below and
//! the property suite in `rust/tests/proptests.rs`).  The pre-drawn
//! schedule/pick/seed tables consume the root rng's named streams in
//! exactly the sequential coordinator's order, which is what makes the
//! tables — and therefore the whole trajectory — seed-for-seed
//! reproducible in both regimes.
//!
//! # Wire codecs
//!
//! Parameter payloads cross the fabric through a pluggable wire codec
//! ([`crate::comm::codec`], selected by `cfg.codec`): the outbox flush
//! encodes each payload into a pooled byte buffer, the fabric prices the
//! link by the *encoded* size (and tracks it in the `wire_bytes` gauge
//! next to the raw ledgers), and delivery decodes before the strategy's
//! `on_message` hook runs.  The default identity codec roundtrips bit
//! patterns exactly, so the lockstep equivalence above holds with the
//! codec layer in the path; `q8`/`topk:<frac>` trade bounded
//! approximation error for 4-50x less traffic (the bandwidth-starved
//! deployments of the thesis's §5 future work).
//!
//! Allocation discipline: message payloads and their encoded wire forms
//! are pooled buffers rented from the [`ScratchArena`] (returned after
//! boundary apply and after delivery-time decode respectively), node
//! snapshots live in the arena's persistent rows, codec scratch keeps
//! its capacity, mailbox sorting is in-place insertion sort, and the
//! event heap/mailboxes/outbox keep their capacity — after the
//! in-flight high-water mark has been seen, the steady-state loop
//! performs no heap allocation.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::{Context, Result};

use crate::algos::{Method, NetMsg, ProtoCtx, ScratchArena, Strategy};
use crate::comm::codec::Codec;
use crate::comm::{Fabric, LinkModel};
use crate::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use crate::coordinator::{average_params, build_dataset_pub, decide_schedule_into, evaluate, RunReport};
use crate::data::{self, BatchCursor, Dataset, TaskKind};
use crate::metrics::{Curve, EvalPoint, RunMetrics, StalenessHist};
use crate::optim::{LrSchedule, OptimKind, Optimizer};
use crate::runtime::{BatchXOwned, EngineFactory, GradEngine, SyntheticSpec};
use crate::sim::WorkerSpeed;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// The virtual-environment half of an async experiment: per-node compute
/// speeds and the network the messages travel through.  (The training
/// half is the ordinary [`ExperimentConfig`].)
#[derive(Clone, Debug)]
pub struct AsyncSimCfg {
    /// one entry per worker
    pub speeds: Vec<WorkerSpeed>,
    pub link: LinkModel,
    /// seed of the per-node compute-jitter streams (independent of the
    /// experiment seed so the trajectory tables stay comparable across
    /// speed scenarios)
    pub speed_seed: u64,
}

impl AsyncSimCfg {
    /// The synchronous special case: deterministic uniform speeds + the
    /// zero link.  Under this schedule the runtime is bit-identical to
    /// the sequential coordinator.
    pub fn lockstep(workers: usize) -> Self {
        AsyncSimCfg {
            speeds: (0..workers)
                .map(|_| WorkerSpeed { mean_s: 1.0, jitter: 0.0, slow_factor: 1.0 })
                .collect(),
            link: LinkModel::zero(),
            speed_seed: 0,
        }
    }

    /// A heterogeneous cluster: uniform `mean_s` compute with `jitter`,
    /// the last worker slowed by `slow_factor` (§2.1.2's straggler).
    pub fn straggler(workers: usize, mean_s: f64, jitter: f64, slow_factor: f64) -> Self {
        let mut speeds: Vec<WorkerSpeed> = (0..workers)
            .map(|_| WorkerSpeed { mean_s, jitter, slow_factor: 1.0 })
            .collect();
        if let Some(last) = speeds.last_mut() {
            last.slow_factor = slow_factor;
        }
        AsyncSimCfg { speeds, link: LinkModel::default(), speed_seed: 0x57A1E }
    }
}

/// Everything `run_async` returns: the ordinary run report plus the
/// asynchrony-specific measurements.
#[derive(Debug)]
pub struct AsyncRunReport {
    pub report: RunReport,
    /// each node's final parameters (the equivalence-test observable)
    pub final_params: Vec<Vec<f32>>,
    /// per-exchange steps-behind distribution
    pub staleness: StalenessHist,
    /// per-node virtual seconds spent computing
    pub busy_s: Vec<f64>,
    /// per-node virtual completion time
    pub finish_s: Vec<f64>,
    /// virtual wall clock: when the last node finished
    pub virtual_s: f64,
    /// network high-water mark (== the arena pool's steady-state size)
    pub peak_in_flight: usize,
    /// push-sum weight mass after the run, if the strategy carries one
    /// (GoSGD: must be 1 — mass is conserved even through in-flight
    /// messages)
    pub push_sum_mass: Option<f64>,
}

impl AsyncRunReport {
    /// Mean over nodes of busy-time / own-completion-time (the shared
    /// [`crate::sim::mean_self_utilization`] metric).  1.0 means no node
    /// ever waited; the synchronous barrier drags this to ~1/slow_factor
    /// for the fast workers under a straggler.
    pub fn mean_self_utilization(&self) -> f64 {
        crate::sim::mean_self_utilization(&self.busy_s, &self.finish_s)
    }
}

// ---------------------------------------------------------------------------
// event queue
// ---------------------------------------------------------------------------

// Same-instant ordering: all step completions, then all deliveries (and
// the replies they spawn), then all boundary applies, then evaluation —
// the phase structure that makes zero latency reproduce the barrier.
const CLASS_STEP: u8 = 0;
const CLASS_MSG: u8 = 1;
const CLASS_BOUNDARY: u8 = 2;
const CLASS_EVAL: u8 = 3;

enum Event {
    StepDone { node: usize },
    MsgDelivered { msg: NetMsg },
    Boundary { node: usize },
    EvalTick { epoch: usize },
}

struct Queued {
    time: f64,
    class: u8,
    seq: u64,
    ev: Event,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> Ordering {
        // inverted on every key: BinaryHeap is a max-heap, we pop earliest
        // (time, class, seq) first — seq breaks ties deterministically in
        // scheduling order
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[inline]
fn sched(heap: &mut BinaryHeap<Queued>, seq: &mut u64, time: f64, class: u8, ev: Event) {
    heap.push(Queued { time, class, seq: *seq, ev });
    *seq += 1;
}

/// Stable in-place insertion sort by edge initiator — k-set order
/// (Algorithm 4), no allocation (mailboxes are tiny).
fn sort_mailbox(mb: &mut [NetMsg]) {
    for i in 1..mb.len() {
        let mut j = i;
        while j > 0 && mb[j - 1].picker > mb[j].picker {
            mb.swap(j - 1, j);
            j -= 1;
        }
    }
}

// ---------------------------------------------------------------------------
// nodes
// ---------------------------------------------------------------------------

/// Per-node bookkeeping (parameters/gradients live in the engine's slot
/// vectors so the sync helpers — `average_params`, `evaluate` — apply
/// unchanged).
struct Node {
    cursor: BatchCursor,
    optim: Optimizer,
    xbuf: BatchXOwned,
    ybuf: Vec<i32>,
    batch_idx: Vec<usize>,
    mailbox: Vec<NetMsg>,
    /// local step currently in flight (== completed steps at a boundary,
    /// before the post-apply increment)
    step: u64,
    epoch: usize,
    /// loss of the in-flight step
    loss: f32,
    busy_s: f64,
    finish_s: f64,
    speed_rng: Rng,
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

struct AsyncEngine<'a> {
    cfg: &'a ExperimentConfig,
    speeds: Vec<WorkerSpeed>,
    engine: Box<dyn GradEngine>,
    train: Dataset,
    val: Dataset,
    test: Dataset,
    params: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    strategy: Box<dyn Strategy>,
    fabric: Fabric,
    arena: ScratchArena,
    /// wire codec for parameter payloads (`cfg.codec`): encode at outbox
    /// flush, decode at delivery, pooled byte buffers from the arena
    codec: Box<dyn Codec>,
    nodes: Vec<Node>,
    /// pre-drawn per-(step, worker) decision tables, consumed from the
    /// root rng's named streams in the sequential coordinator's exact
    /// order (see module docs)
    masks: Vec<bool>,
    picks: Vec<Option<usize>>,
    seeds: Vec<i32>,
    /// per-global-step f64 loss buckets, accumulated in arrival order
    /// (lockstep arrival == the sequential coordinator's summation order,
    /// so epoch losses fold bit-identically)
    loss_acc: Vec<f64>,
    epoch_done: Vec<usize>,
    heap: BinaryHeap<Queued>,
    seq: u64,
    outbox: Vec<NetMsg>,
    staleness: StalenessHist,
    curve: Curve,
    w: usize,
    b: usize,
    steps_per_epoch: u64,
    total_steps: u64,
    now: f64,
    finished: usize,
    watch: Stopwatch,
    eval_time: f64,
}

impl<'a> AsyncEngine<'a> {
    /// Gather the next batch, compute the step's gradient eagerly (node
    /// parameters cannot change until its own next boundary), and
    /// schedule the step completion on the virtual clock.
    fn begin_step(&mut self, i: usize) -> Result<()> {
        let t = self.nodes[i].step as usize;
        {
            let node = &mut self.nodes[i];
            node.cursor.next_batch(self.b, &mut node.batch_idx);
            match self.train.kind {
                TaskKind::Classify => {
                    data::gather_f32(&self.train, &node.batch_idx, node.xbuf.clear_f32(), &mut node.ybuf)
                }
                TaskKind::LanguageModel => {
                    data::gather_i32(&self.train, &node.batch_idx, node.xbuf.clear_i32(), &mut node.ybuf)
                }
            }
        }
        let seed = self.seeds[t * self.w + i];
        let loss = {
            let node = &self.nodes[i];
            self.engine.loss_and_grad(
                &self.params[i],
                node.xbuf.as_ref(),
                &node.ybuf,
                seed,
                &mut self.grads[i],
            )?
        };
        self.nodes[i].loss = loss;
        let dt = self.speeds[i].sample_step_time(&mut self.nodes[i].speed_rng);
        self.nodes[i].busy_s += dt;
        sched(&mut self.heap, &mut self.seq, self.now + dt, CLASS_STEP, Event::StepDone { node: i });
        Ok(())
    }

    /// Account + schedule everything the last hook put in the outbox.
    ///
    /// This is where payloads meet the wire: each parameter-bearing
    /// message is encoded through the run's codec into a pooled byte
    /// buffer; the fabric records the raw size in its ledgers and the
    /// encoded size in the `wire_bytes` gauge, and the link transfer
    /// time — hence the delivery instant — is priced by what actually
    /// travels.  Under the identity codec encoded == raw, so the
    /// delivery schedule (and with it the whole trajectory) is unchanged.
    fn flush_outbox(&mut self) {
        if self.outbox.is_empty() {
            return;
        }
        let mut ob = std::mem::take(&mut self.outbox);
        for mut msg in ob.drain(..) {
            let raw = msg.payload.raw_bytes();
            let encoded = if let Some(p) = msg.payload.params() {
                let mut buf = self.arena.rent_bytes();
                self.codec.encode_into(msg.src, p, &mut buf);
                let e = buf.len() as u64 + msg.payload.non_param_bytes();
                msg.wire = Some(buf);
                e
            } else {
                raw // control-only frames travel as-is
            };
            let at = self.fabric.send_async_coded(msg.src, msg.dst, raw, encoded, self.now);
            sched(&mut self.heap, &mut self.seq, at, CLASS_MSG, Event::MsgDelivered { msg });
        }
        self.outbox = ob; // keep the capacity
    }

    fn on_step_done(&mut self, i: usize) -> Result<()> {
        let t = self.nodes[i].step as usize;
        self.loss_acc[t] += self.nodes[i].loss as f64;
        if self.masks[t * self.w + i] {
            if let Some(peer) = self.picks[t * self.w + i] {
                let step = self.nodes[i].step;
                let mut ctx = ProtoCtx {
                    node: i,
                    step,
                    params: self.params[i].as_mut_slice(),
                    arena: &mut self.arena,
                    outbox: &mut self.outbox,
                };
                self.strategy.on_send_due(&mut ctx, peer)?;
                self.flush_outbox();
            }
        }
        sched(&mut self.heap, &mut self.seq, self.now, CLASS_BOUNDARY, Event::Boundary { node: i });
        Ok(())
    }

    fn on_delivered(&mut self, mut msg: NetMsg) -> Result<()> {
        self.fabric.deliver_async();
        // decode the payload out of its wire form before the strategy
        // sees it.  Overlay codecs (top-k) reconstruct onto the
        // receiver's *delivery-time* parameters: untransmitted
        // coordinates mix nothing, which confines the gossip update to
        // the transmitted support.
        if let Some(wire) = msg.wire.take() {
            let dst = msg.dst;
            let kind = msg.payload.kind();
            if let Some(p) = msg.payload.params_mut() {
                if self.codec.is_overlay() {
                    p.clear();
                    p.extend_from_slice(&self.params[dst]);
                }
                self.codec
                    .decode_into(&wire, p)
                    .with_context(|| format!("decoding {kind} payload"))?;
            }
            self.arena.return_bytes(wire);
        }
        let dst = msg.dst;
        let step = self.nodes[dst].step;
        let retained = {
            let mut ctx = ProtoCtx {
                node: dst,
                step,
                params: self.params[dst].as_mut_slice(),
                arena: &mut self.arena,
                outbox: &mut self.outbox,
            };
            self.strategy.on_message(&mut ctx, msg)?
        };
        if let Some(m) = retained {
            self.nodes[dst].mailbox.push(m);
        }
        self.flush_outbox();
        Ok(())
    }

    /// Apply node `i`'s retained mailbox against its boundary snapshot:
    /// sort to k-set order, record one staleness sample per exchange,
    /// run the strategy's boundary hook, recycle the buffers.  Shared by
    /// the per-step boundary and the post-loop late-mail pass so the two
    /// can never apply exchanges under different rules.
    fn apply_mailbox(&mut self, i: usize) -> Result<()> {
        if self.nodes[i].mailbox.is_empty() {
            return Ok(());
        }
        let step = self.nodes[i].step;
        let mut mailbox = std::mem::take(&mut self.nodes[i].mailbox);
        sort_mailbox(&mut mailbox);
        for m in &mailbox {
            self.staleness.record(step.abs_diff(m.sent_step));
        }
        // boundary snapshot: the fixed self-term every apply reads
        self.arena.snapshot(i, &self.params[i]);
        {
            let mut ctx = ProtoCtx {
                node: i,
                step,
                params: self.params[i].as_mut_slice(),
                arena: &mut self.arena,
                outbox: &mut self.outbox,
            };
            self.strategy.on_boundary_apply(&mut ctx, &mut mailbox)?;
        }
        // recycle payload buffers centrally — strategies only apply, so a
        // future protocol cannot leak pooled buffers by forgetting this
        for m in mailbox.drain(..) {
            if let Some(buf) = m.payload.take_params() {
                self.arena.return_msg(buf);
            }
        }
        self.nodes[i].mailbox = mailbox; // keep the capacity
        Ok(())
    }

    fn on_boundary(&mut self, i: usize) -> Result<()> {
        self.apply_mailbox(i)?;
        self.flush_outbox();
        // optimizer phase (Algorithm 5 line 9) — after comm, like the
        // synchronous round
        {
            let node = &mut self.nodes[i];
            node.optim.update_velocity(&self.grads[i]);
            node.optim.apply(&mut self.params[i], &self.grads[i]);
            node.step += 1;
        }
        if self.nodes[i].step % self.steps_per_epoch == 0 {
            let e = self.nodes[i].epoch;
            self.nodes[i].epoch += 1;
            if self.nodes[i].epoch < self.cfg.epochs {
                let next = self.nodes[i].epoch;
                self.nodes[i].optim.start_epoch(next);
            }
            self.epoch_done[e] += 1;
            if self.epoch_done[e] == self.w
                && ((e + 1) % self.cfg.eval_every == 0 || e + 1 == self.cfg.epochs)
            {
                sched(&mut self.heap, &mut self.seq, self.now, CLASS_EVAL, Event::EvalTick { epoch: e });
            }
        }
        if self.nodes[i].step < self.total_steps {
            self.begin_step(i)?;
        } else {
            self.nodes[i].finish_s = self.now;
            self.finished += 1;
        }
        Ok(())
    }

    fn on_eval(&mut self, e: usize) -> Result<()> {
        let ew = Stopwatch::start();
        let mut worker_acc = Vec::with_capacity(self.w);
        let mut worker_loss = Vec::with_capacity(self.w);
        for i in 0..self.w {
            let (l, a) = evaluate(self.engine.as_mut(), &self.params[i], &self.val)?;
            worker_acc.push(a);
            worker_loss.push(l);
        }
        let avg = average_params(&self.params);
        let (_, agg) = evaluate(self.engine.as_mut(), &avg, &self.val)?;
        self.eval_time += ew.elapsed_s();
        let s0 = e * self.steps_per_epoch as usize;
        let mut epoch_loss = 0.0f64;
        for t in s0..s0 + self.steps_per_epoch as usize {
            epoch_loss += self.loss_acc[t];
        }
        self.curve.push(EvalPoint {
            epoch: e + 1,
            step: (e as u64 + 1) * self.steps_per_epoch,
            worker_acc,
            worker_loss,
            train_loss: (epoch_loss / (self.steps_per_epoch as f64 * self.w as f64)) as f32,
            aggregate_acc: agg,
            wall_s: self.watch.elapsed_s(),
        });
        Ok(())
    }
}

/// The canonical synthetic straggler-study experiment + engine factory —
/// shared by `examples/async_straggler.rs` and `repro async-train` so the
/// two entry points run the *same* study (one place to change its
/// defaults, one engine-seed convention).
pub fn study_setup(
    method: Method,
    workers: usize,
    prob: f64,
    epochs: usize,
    seed: u64,
) -> (ExperimentConfig, SyntheticSpec) {
    let dim = 32usize;
    let cfg = ExperimentConfig {
        label: format!("async-{}", method.short_label()),
        method,
        workers,
        schedule: CommSchedule::Probability(prob),
        optimizer: OptimKind::Nag { momentum: 0.9 },
        lr: LrSchedule::Const(0.05),
        engine: EngineKind::Synthetic { dim },
        dataset: DatasetKind::SyntheticVectors { dim: 8 },
        n_train: 256 * workers,
        n_val: 128,
        n_test: 128,
        effective_batch: 8 * workers,
        epochs,
        seed,
        partition: crate::data::Partition::Iid,
        topology: crate::topology::Topology::Full,
        eval_every: 1,
        artifact_dir: "artifacts".into(),
        codec: crate::comm::codec::CodecKind::Identity,
    };
    let spec = SyntheticSpec::for_cfg(&cfg).expect("study config uses the synthetic engine");
    (cfg, spec)
}

/// Run one experiment on the event-driven asynchronous runtime.
///
/// Supports the pairwise gossip family (Elastic Gossip, Gossiping SGD
/// push/pull, GoSGD) plus the no-communication baseline; the barrier
/// methods (All-reduce, EASGD) are inherently synchronous and are
/// rejected with an error.
pub fn run_async(
    cfg: &ExperimentConfig,
    factory: &dyn EngineFactory,
    sim: &AsyncSimCfg,
) -> Result<AsyncRunReport> {
    let w = cfg.workers;
    anyhow::ensure!(w >= 1, "need at least one worker");
    anyhow::ensure!(
        sim.speeds.len() == w,
        "sim has {} speeds for {} workers",
        sim.speeds.len(),
        w
    );
    let root_rng = Rng::new(cfg.seed);

    // --- data (identical stream consumption to the sync coordinator) ----
    let full = build_dataset_pub(cfg, &mut root_rng.stream("datagen"))?;
    let (train, val, test) = full.split(
        cfg.n_train.min(full.len()),
        cfg.n_val,
        cfg.n_test,
        &mut root_rng.stream("split"),
    );
    let shards = cfg.partition.assign(&train, w, &mut root_rng.stream("partition"));

    // --- engine + state --------------------------------------------------
    let mut engine = factory.build().context("building engine")?;
    let flat = engine.flat_size();
    let b = engine.train_batch();
    anyhow::ensure!(
        b == cfg.per_worker_batch(),
        "engine batch {b} != per-worker batch {}",
        cfg.per_worker_batch()
    );
    let init = engine.initial_params()?;
    anyhow::ensure!(init.len() == flat);
    let strategy = cfg.method.build(w, flat);
    anyhow::ensure!(
        strategy.async_capable(),
        "method {:?} has no message-level protocol: the event-driven runtime \
         supports the pairwise gossip family (elastic-gossip, gossip-pull, \
         gossip-push, gosgd) and no-comm; All-reduce/EASGD are barrier-bound \
         by construction — use the synchronous coordinator",
        strategy.name()
    );
    let params: Vec<Vec<f32>> = vec![init; w];
    let grads: Vec<Vec<f32>> = vec![vec![0.0; flat]; w];
    let mut arena = ScratchArena::new();
    arena.ensure(w, flat);
    let codec = cfg.codec.build();

    // --- pre-drawn decision tables ---------------------------------------
    // the sequential coordinator consumes "schedule" (mask per step, worker
    // order), "gossip" (one peer draw per communicating worker, worker
    // order, via the cached adjacency) and "dropout" ((step, worker) order)
    // — replicated here verbatim so both regimes see the same decisions
    let steps_per_epoch = cfg.steps_per_epoch();
    let total_steps = cfg.total_steps();
    let ts = total_steps as usize;
    let mut sched_rng = root_rng.stream("schedule");
    let mut gossip_rng = root_rng.stream("gossip");
    let mut seed_rng = root_rng.stream("dropout");
    let mut masks: Vec<bool> = Vec::with_capacity(ts * w);
    let mut picks: Vec<Option<usize>> = vec![None; ts * w];
    let mut mask_t: Vec<bool> = Vec::with_capacity(w);
    let pairwise = cfg.method.is_pairwise_gossip();
    let topo_cache = arena.topo_cache_mut();
    topo_cache.ensure(&cfg.topology, w);
    for t in 0..ts {
        decide_schedule_into(&cfg.method, cfg.schedule, t as u64, w, &mut sched_rng, &mut mask_t);
        masks.extend_from_slice(&mask_t);
        if pairwise {
            for (i, &firing) in mask_t.iter().enumerate() {
                if firing {
                    picks[t * w + i] = topo_cache.sample_peer(i, &mut gossip_rng);
                }
            }
        }
    }
    let seeds: Vec<i32> = (0..ts * w).map(|_| seed_rng.next_u64() as i32).collect();

    // --- nodes ------------------------------------------------------------
    let speed_root = Rng::new(sim.speed_seed);
    let nodes: Vec<Node> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| Node {
            cursor: BatchCursor::new(shard, root_rng.stream(&format!("batches{i}"))),
            optim: Optimizer::new(cfg.optimizer, cfg.lr.clone(), flat),
            xbuf: BatchXOwned::F32(Vec::new()),
            ybuf: Vec::new(),
            batch_idx: Vec::new(),
            mailbox: Vec::new(),
            step: 0,
            epoch: 0,
            loss: 0.0,
            busy_s: 0.0,
            finish_s: 0.0,
            speed_rng: speed_root.stream(&format!("speed{i}")),
        })
        .collect();

    let mut eng = AsyncEngine {
        cfg,
        speeds: sim.speeds.clone(),
        engine,
        train,
        val,
        test,
        params,
        grads,
        strategy,
        fabric: Fabric::new(w + 1, sim.link),
        arena,
        codec,
        nodes,
        masks,
        picks,
        seeds,
        loss_acc: vec![0.0; ts],
        epoch_done: vec![0; cfg.epochs],
        heap: BinaryHeap::new(),
        seq: 0,
        outbox: Vec::new(),
        staleness: StalenessHist::new(),
        curve: Curve::new(cfg.label.clone()),
        w,
        b,
        steps_per_epoch,
        total_steps,
        now: 0.0,
        finished: 0,
        watch: Stopwatch::start(),
        eval_time: 0.0,
    };

    // --- event loop -------------------------------------------------------
    if total_steps > 0 {
        for i in 0..w {
            eng.begin_step(i)?;
        }
    }
    while let Some(q) = eng.heap.pop() {
        eng.now = q.time;
        match q.ev {
            Event::StepDone { node } => eng.on_step_done(node)?,
            Event::MsgDelivered { msg } => eng.on_delivered(msg)?,
            Event::Boundary { node } => eng.on_boundary(node)?,
            Event::EvalTick { epoch } => eng.on_eval(epoch)?,
        }
    }
    debug_assert!(
        total_steps == 0 || eng.finished == w,
        "every node must run to completion"
    );
    debug_assert_eq!(eng.fabric.in_flight(), 0, "heap drained with messages in flight");

    // Late mail: a message delivered after its receiver's final boundary
    // is still parked in the mailbox.  Apply it now (same rules as every
    // mid-run boundary) — final parameters incorporate every exchange,
    // and GoSGD's weight mass (partly carried by such messages) returns
    // to exactly 1.  In lockstep every mailbox is already empty here, so
    // this pass cannot perturb the equivalence.
    for i in 0..w {
        eng.apply_mailbox(i)?;
    }
    debug_assert!(eng.outbox.is_empty(), "boundary applies must not send");

    // --- final report -----------------------------------------------------
    let (_, rank0) = evaluate(eng.engine.as_mut(), &eng.params[0], &eng.test)?;
    let avg = average_params(&eng.params);
    let (_, agg) = evaluate(eng.engine.as_mut(), &avg, &eng.test)?;
    let traffic = eng.fabric.report();
    let busy_s: Vec<f64> = eng.nodes.iter().map(|n| n.busy_s).collect();
    let finish_s: Vec<f64> = eng.nodes.iter().map(|n| n.finish_s).collect();
    let virtual_s = finish_s.iter().cloned().fold(0.0, f64::max);
    let metrics = RunMetrics {
        curve: eng.curve,
        rank0_test_acc: rank0,
        aggregate_test_acc: agg,
        total_steps,
        comm_bytes: traffic.total_bytes,
        wire_bytes: traffic.wire_bytes,
        comm_messages: traffic.total_messages,
        comm_rounds: traffic.rounds,
        simulated_comm_s: traffic.simulated_comm_s,
        wall_train_s: eng.watch.elapsed_s() - eng.eval_time,
        wall_eval_s: eng.eval_time,
    };
    Ok(AsyncRunReport {
        report: RunReport {
            label: cfg.label.clone(),
            rank0_accuracy: rank0,
            aggregate_accuracy: agg,
            metrics,
        },
        final_params: eng.params,
        staleness: eng.staleness,
        busy_s,
        finish_s,
        virtual_s,
        peak_in_flight: eng.fabric.peak_in_flight(),
        push_sum_mass: eng.strategy.push_sum_mass(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Method;
    use crate::coordinator::tests::tiny_cfg;
    use crate::coordinator::Coordinator;
    use crate::runtime::SyntheticSpec;

    fn spec(cfg: &ExperimentConfig) -> SyntheticSpec {
        SyntheticSpec::for_cfg(cfg).unwrap()
    }

    /// Run the sequential coordinator and capture the final per-worker
    /// parameters through the step observer.
    fn run_sequential(cfg: &ExperimentConfig) -> (RunReport, Vec<Vec<f32>>) {
        let s = spec(cfg);
        let last = cfg.total_steps() - 1;
        let mut final_params: Vec<Vec<f32>> = Vec::new();
        let report = {
            let mut c = Coordinator::new(cfg, &s);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    final_params = p.to_vec();
                }
            }));
            c.run().unwrap()
        };
        (report, final_params)
    }

    #[test]
    fn lockstep_is_bit_identical_to_sequential_for_all_gossip_methods() {
        for method in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
            Method::NoComm,
        ] {
            let cfg = tiny_cfg(method.clone(), 4);
            let (seq, seq_params) = run_sequential(&cfg);
            let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4))
                .unwrap_or_else(|e| panic!("{method:?}: {e}"));
            // parameter trajectory: final state must match bit for bit
            assert_eq!(
                asy.final_params, seq_params,
                "{method:?}: async lockstep diverged from the synchronous round"
            );
            // and the observable metrics line up
            assert_eq!(asy.report.rank0_accuracy, seq.rank0_accuracy, "{method:?} rank0");
            assert_eq!(
                asy.report.aggregate_accuracy, seq.aggregate_accuracy,
                "{method:?} aggregate"
            );
            let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            let la: Vec<f32> = asy.report.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, la, "{method:?} loss curve");
            // zero latency + lockstep => nothing is ever stale
            assert_eq!(asy.staleness.max(), 0, "{method:?} saw staleness in lockstep");
            if matches!(method, Method::ElasticGossip { .. } | Method::GoSgd) {
                assert!(asy.staleness.count() > 0, "{method:?}: no exchanges recorded");
            }
        }
    }

    #[test]
    fn lockstep_elastic_matches_sync_traffic() {
        // elastic: two parameter-sized messages per edge, same as the
        // synchronous round's accounting
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let (seq, _) = run_sequential(&cfg);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(asy.report.metrics.comm_bytes, seq.metrics.comm_bytes);
        assert_eq!(asy.report.metrics.comm_messages, seq.metrics.comm_messages);
    }

    #[test]
    fn straggler_reports_real_staleness_and_full_utilization() {
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.schedule = crate::config::CommSchedule::Probability(0.5);
        let mut sim = AsyncSimCfg::straggler(4, 0.05, 0.0, 4.0);
        sim.link = LinkModel::zero(); // isolate compute skew
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        // exchanges between the 4x straggler and fast workers observe
        // real step skew
        assert!(asy.staleness.count() > 0);
        assert!(
            asy.staleness.mean() > 0.5,
            "expected nonzero staleness, mean {}",
            asy.staleness.mean()
        );
        assert!(asy.staleness.max() >= 2);
        // and nobody ever waits: every node is busy until its own finish
        assert!(
            asy.mean_self_utilization() >= 0.9,
            "utilization {}",
            asy.mean_self_utilization()
        );
        // ... while the synchronous barrier degrades under the same
        // speeds (§2.1.2's asynchrony argument, end to end)
        let sync_sim = crate::sim::simulate_synchronous(
            &sim.speeds,
            cfg.total_steps(),
            0,
            sim.link,
            sim.speed_seed,
        );
        assert!(
            sync_sim.mean_self_utilization() < 0.7,
            "barriered baseline should collapse under a 4x straggler, got {}",
            sync_sim.mean_self_utilization()
        );
        // training still works
        let pts = &asy.report.metrics.curve.points;
        assert!(pts.last().unwrap().train_loss < pts.first().unwrap().train_loss);
    }

    #[test]
    fn straggler_run_is_deterministic() {
        let cfg = tiny_cfg(Method::GossipingSgdPush, 4);
        let sim = AsyncSimCfg::straggler(4, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.staleness, b.staleness, "staleness histogram must reproduce");
        assert_eq!(a.report.metrics.comm_bytes, b.report.metrics.comm_bytes);
        assert_eq!(a.virtual_s, b.virtual_s);
    }

    #[test]
    fn gosgd_conserves_mass_through_in_flight_messages() {
        let cfg = tiny_cfg(Method::GoSgd, 6);
        // slow link: shares spend real time in flight mid-run
        let mut sim = AsyncSimCfg::straggler(6, 0.01, 0.2, 4.0);
        sim.link = LinkModel { latency_s: 0.02, bandwidth_bps: 1e6 };
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let mass = asy.push_sum_mass.expect("gosgd exposes its mass");
        assert!((mass - 1.0).abs() < 1e-9, "push-sum mass drifted: {mass}");
        assert!(asy.staleness.mean() > 0.0, "slow link must show staleness");
    }

    #[test]
    fn barrier_methods_are_rejected() {
        for method in [
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            Method::Easgd { alpha: 0.2 },
        ] {
            let cfg = tiny_cfg(method, 3);
            let err = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(3)).unwrap_err();
            assert!(
                err.to_string().contains("message-level protocol"),
                "unexpected error: {err}"
            );
        }
    }

    #[test]
    fn nonzero_latency_still_trains_and_is_deterministic() {
        let cfg = tiny_cfg(Method::GossipingSgdPull, 4);
        let mut sim = AsyncSimCfg::straggler(4, 0.01, 0.0, 1.0);
        sim.link = LinkModel { latency_s: 0.005, bandwidth_bps: 1e9 };
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        let pts = &a.report.metrics.curve.points;
        assert!(pts.last().unwrap().train_loss < pts.first().unwrap().train_loss);
        assert!(a.peak_in_flight > 0);
    }

    /// The async message path — send hook, outbox encode, delivery
    /// decode, reply, boundary apply, buffer recycling — driven exactly
    /// as the engine drives it, with each codec enabled: after warm-up,
    /// every encode/decode scratch buffer must come from the arena and
    /// the codec's persistent state, never the heap (the
    /// `*_allocation_free_after_warmup` discipline extended to the wire
    /// layer).
    #[test]
    fn async_message_path_is_allocation_free_after_warmup_for_every_codec() {
        use crate::algos::gossip::ElasticGossipStrategy;
        use crate::algos::{NetMsg, ProtoCtx};
        use crate::comm::codec::CodecKind;

        let flat = 300usize;
        for kind in [
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 64 },
            CodecKind::TopK { frac: 0.1 },
        ] {
            let mut codec = kind.build();
            let mut arena = ScratchArena::new();
            arena.ensure(2, flat);
            let mut strategy = ElasticGossipStrategy::new(0.4);
            let mut params: Vec<Vec<f32>> = (0..2).map(|i| vec![i as f32 * 0.1 + 0.01; flat]).collect();
            let mut outbox: Vec<NetMsg> = Vec::new();
            let mut mailbox: Vec<NetMsg> = Vec::new();
            let mut one: Vec<NetMsg> = Vec::with_capacity(2);

            let mut fp = 0u64;
            for round in 0..33u64 {
                let step = round;
                // node 0's schedule fires toward node 1
                {
                    let mut ctx = ProtoCtx {
                        node: 0,
                        step,
                        params: params[0].as_mut_slice(),
                        arena: &mut arena,
                        outbox: &mut outbox,
                    };
                    strategy.on_send_due(&mut ctx, 1).unwrap();
                }
                // event loop: encode on flush, decode at delivery, route
                // replies back through the same path
                while let Some(mut msg) = outbox.pop() {
                    if msg.wire.is_none() {
                        if let Some(p) = msg.payload.params() {
                            let mut buf = arena.rent_bytes();
                            codec.encode_into(msg.src, p, &mut buf);
                            msg.wire = Some(buf);
                        }
                    }
                    let dst = msg.dst;
                    if let Some(wire) = msg.wire.take() {
                        if let Some(p) = msg.payload.params_mut() {
                            if codec.is_overlay() {
                                p.clear();
                                p.extend_from_slice(&params[dst]);
                            }
                            codec.decode_into(&wire, p).unwrap();
                        }
                        arena.return_bytes(wire);
                    }
                    let retained = {
                        let mut ctx = ProtoCtx {
                            node: dst,
                            step,
                            params: params[dst].as_mut_slice(),
                            arena: &mut arena,
                            outbox: &mut outbox,
                        };
                        strategy.on_message(&mut ctx, msg).unwrap()
                    };
                    if let Some(m) = retained {
                        mailbox.push(m);
                    }
                }
                // boundary applies + payload-buffer recycling
                while let Some(m) = mailbox.pop() {
                    let node = m.dst;
                    arena.snapshot(node, &params[node]);
                    one.push(m);
                    {
                        let mut ctx = ProtoCtx {
                            node,
                            step,
                            params: params[node].as_mut_slice(),
                            arena: &mut arena,
                            outbox: &mut outbox,
                        };
                        strategy.on_boundary_apply(&mut ctx, &mut one).unwrap();
                    }
                    for m in one.drain(..) {
                        if let Some(buf) = m.payload.take_params() {
                            arena.return_msg(buf);
                        }
                    }
                }
                if round == 2 {
                    fp = arena.footprint() ^ codec.footprint();
                } else if round > 2 {
                    assert_eq!(
                        arena.footprint() ^ codec.footprint(),
                        fp,
                        "{}: message path reallocated at round {round}",
                        codec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn identity_codec_wire_bytes_equal_raw_and_trajectory_is_unchanged() {
        // the codec layer is in the path for every run; with the default
        // identity codec it must be observationally invisible
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(asy.report.metrics.wire_bytes, asy.report.metrics.comm_bytes);
        let (_, seq_params) = run_sequential(&cfg);
        assert_eq!(asy.final_params, seq_params);
    }

    #[test]
    fn lossy_codecs_shrink_wire_bytes_and_stay_deterministic() {
        use crate::comm::codec::CodecKind;
        for (kind, min_shrink) in [
            // tiny model (flat = 12): q8 → one 20-byte chunk vs 48 raw;
            // topk:0.25 → 8 + 8*3 = 32 bytes vs 48 raw
            (CodecKind::Q8 { chunk: 4096 }, 2.0),
            (CodecKind::TopK { frac: 0.25 }, 1.4),
        ] {
            let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
            cfg.codec = kind;
            let a = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
            let b = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
            assert_eq!(a.final_params, b.final_params, "{kind:?} nondeterministic");
            let m = &a.report.metrics;
            assert!(m.comm_bytes > 0);
            assert!(
                (m.comm_bytes as f64) >= (m.wire_bytes as f64) * min_shrink,
                "{kind:?}: wire {} vs raw {} — expected >= {min_shrink}x shrink",
                m.wire_bytes,
                m.comm_bytes
            );
            // approximate mixing still trains on the quadratic task
            let pts = &a.report.metrics.curve.points;
            assert!(
                pts.last().unwrap().train_loss < pts.first().unwrap().train_loss,
                "{kind:?}: loss did not decrease"
            );
        }
    }

    #[test]
    fn lossy_codecs_survive_stragglers_and_conserve_gosgd_mass() {
        use crate::comm::codec::CodecKind;
        for kind in [CodecKind::Q8 { chunk: 256 }, CodecKind::TopK { frac: 0.25 }] {
            let mut cfg = tiny_cfg(Method::GoSgd, 5);
            cfg.codec = kind;
            let mut sim = AsyncSimCfg::straggler(5, 0.02, 0.2, 3.0);
            // slow link: shares are in flight (encoded) mid-run
            sim.link = LinkModel { latency_s: 0.02, bandwidth_bps: 1e6 };
            let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
            let mass = asy.push_sum_mass.expect("gosgd exposes its mass");
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "{kind:?}: push-sum mass drifted through encoded in-flight shares: {mass}"
            );
            let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
            assert_eq!(asy.final_params, b.final_params, "{kind:?} nondeterministic");
        }
    }

    #[test]
    fn single_worker_free_runs() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 1);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(1)).unwrap();
        assert_eq!(asy.report.metrics.comm_bytes, 0);
        assert_eq!(asy.staleness.count(), 0);
        assert_eq!(asy.report.metrics.curve.points.len(), cfg.epochs);
    }
}
