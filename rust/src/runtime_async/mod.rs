//! Event-driven asynchronous gossip runtime: message-passing nodes on a
//! virtual clock.
//!
//! This is the execution regime the thesis's future-work chapter asks
//! for ("studying the effects of asynchrony that is controlled in a
//! simulated environment"): no leader, no barriers.  Each worker is a
//! *node* with a mailbox; a single virtual-clock event queue — unifying
//! the per-worker compute-time model (`sim::WorkerSpeed`) with the
//! fabric's link model (`comm::LinkModel`) — schedules three kinds of
//! event:
//!
//! * **`StepDone`** — a node finished computing its local gradient step.
//!   If its communication schedule fired, the strategy's
//!   [`on_send_due`](crate::algos::Strategy::on_send_due) hook emits
//!   protocol messages; each is accounted on the fabric
//!   ([`Fabric::send_async`](crate::comm::Fabric::send_async)) and
//!   scheduled for delivery at `now + link transfer time`.
//! * **`MsgDelivered`** — a message reached its destination, *possibly
//!   mid-step*.  The strategy's
//!   [`on_message`](crate::algos::Strategy::on_message) hook reacts with
//!   the node's **current** state — this is where real staleness enters:
//!   a pull reply or elastic reply under a slow link carries parameters
//!   from whatever step the responder happens to be at — and parks
//!   apply-relevant messages in the node's mailbox.
//! * **`EvalTick`** — the last node crossed an epoch boundary; the
//!   harness evaluates every replica and the aggregate model, exactly
//!   like the synchronous coordinator's epoch-end evaluation.
//!
//! At a node's own step boundary the mailbox is applied
//! ([`on_boundary_apply`](crate::algos::Strategy::on_boundary_apply)),
//! one staleness sample is recorded per exchange
//! ([`metrics::StalenessHist`]), the optimizer runs, and the next step's
//! gradient is scheduled — the node never waits for anyone.
//!
//! # Synchronous execution as the zero-latency lockstep special case
//!
//! Under [`AsyncSimCfg::lockstep`] — deterministic uniform speeds and the
//! zero link ([`LinkModel::zero`]) — every node's `StepDone` lands on the
//! same virtual instant, deliveries collapse onto their send instants,
//! and the event classes order each instant as *all sends → all
//! deliveries (and replies) → all boundary applies*.  Mailboxes sorted by
//! edge initiator reproduce the k-set order of Algorithm 4, boundary
//! snapshots equal the pre-round snapshots, and the apply hooks route
//! through the same fused kernels as the synchronous round — so the
//! event-driven runtime's parameter trajectory is **bit-identical** to
//! [`Coordinator::run`](crate::coordinator::Coordinator) for every
//! pairwise gossip method (asserted by the equivalence tests below and
//! the property suite in `rust/tests/proptests.rs`).  The pre-drawn
//! schedule/pick/seed tables consume the root rng's named streams in
//! exactly the sequential coordinator's order, which is what makes the
//! tables — and therefore the whole trajectory — seed-for-seed
//! reproducible in both regimes.
//!
//! # Wire codecs
//!
//! Parameter payloads cross the fabric through a pluggable wire codec
//! ([`crate::comm::codec`], selected by `cfg.codec`): the outbox flush
//! encodes each payload into a pooled byte buffer, the fabric prices the
//! link by the *encoded* size (and tracks it in the `wire_bytes` gauge
//! next to the raw ledgers), and delivery decodes before the strategy's
//! `on_message` hook runs.  The default identity codec roundtrips bit
//! patterns exactly, so the lockstep equivalence above holds with the
//! codec layer in the path; `q8`/`topk:<frac>` trade bounded
//! approximation error for 4-50x less traffic (the bandwidth-starved
//! deployments of the thesis's §5 future work).
//!
//! # Elastic membership
//!
//! With a `churn:` schedule (`cfg.churn`, see [`crate::membership`]) the
//! roster itself becomes dynamic: deterministic `crash`/`leave`/`join`/
//! `rejoin` events fire on the same virtual clock (ordered *before*
//! anything else at their instant), membership is versioned in epochs,
//! peers are sampled live from the alive neighborhood, undeliverable
//! messages land in the fabric's dropped ledger (a message never
//! outlives its addressee — incarnation stamps), joins bootstrap by
//! pulling a donor's exact state through a codec-exempt control plane,
//! and rejoins restore epoch-boundary checkpoints.  Per-protocol
//! departure semantics live in the `Strategy` churn hooks.  With an
//! **empty** schedule none of these paths execute and the runtime is
//! bit-identical to the fixed roster described above.
//!
//! # Failure detection (`fd:`) and link faults (`faults:`)
//!
//! With an `fd:` config the oracle is demoted to physics: nodes still
//! *die* by the churn schedule, but the survivors no longer learn of it
//! from the runtime.  Each node runs a SWIM-style detector — periodic
//! direct probes, ping-req indirection after a missed ack, an
//! alive→suspect→confirmed-dead state machine with incarnation-stamped
//! refutations — and maintains its own [`LocalView`], which replaces the
//! oracle for peer sampling and dead-sender delivery rules.  Membership
//! rumors piggyback on every outgoing message ([`RumorPack`]); protocol
//! consequences of a death (strategy reclamation via `on_peer_lost`,
//! elastic rollback sweeps, shard reassignment to survivors) fire at
//! *confirmation* time, per observer, not at the oracle crash instant.
//! Detection latency, false suspicions/confirms and view divergence are
//! reported in [`FdReport`].  A `faults:` plan injects deterministic
//! link loss / delay jitter / scheduled partitions at outbox flush —
//! decisions are stateless hashes of (seed, link, message ordinal), so
//! no RNG stream is consumed.  With both specs empty none of these
//! paths execute and the runtime is byte-identical to the oracle build.
//!
//! Allocation discipline: message payloads and their encoded wire forms
//! are pooled buffers rented from the [`ScratchArena`] (returned after
//! boundary apply and after delivery-time decode respectively), node
//! snapshots live in the arena's persistent rows, codec scratch keeps
//! its capacity, mailbox sorting is in-place insertion sort, and the
//! event heap/mailboxes/outbox keep their capacity — after the
//! in-flight high-water mark has been seen, the steady-state loop
//! performs no heap allocation.
//!
//! # Sharded event queue (`shards:<n>`, `--shards`)
//!
//! For fleet-scale rosters (10⁵–10⁶ nodes) the queue shards: node `i`
//! is pinned to shard `i % n`, each shard owns a local min-heap over
//! its nodes' events, and the driver pops the globally-earliest event
//! by a tournament over the shard heads under the same total
//! (time, class, seq) order — `seq` is issued by one global counter, so
//! the pop sequence is *identical* to the single heap's.  Gradient
//! compute (the only per-event work without cross-node data
//! dependencies: a node's params are frozen from `begin_step` to its
//! own next boundary) fans out to one worker thread per shard over
//! addressed job/result envelopes; every rng draw, f64 accumulation and
//! protocol hook stays on the driver thread in pop order.  The
//! conservative synchronization point is the step's own `StepDone`: its
//! result is collected exactly when the single-queue runtime would have
//! computed it inline, so the whole trajectory — parameters, ledgers,
//! membership, fd verdicts — is bit-identical for every `shards:`
//! value, which `shards:1` vs `shards:4` lockstep tests pin.
//! [`AsyncRunReport::events`] and [`AsyncRunReport::cross_shard_frac`]
//! expose the queue's throughput denominator and the fraction of
//! messages whose endpoints live on different shards.

pub mod net;
mod shard;

use std::cmp::Ordering;

use anyhow::{Context, Result};

use shard::{GradDone, GradJob, GradRouter, ShardedQueue};

use crate::algos::{Method, MsgPayload, NetMsg, ProtoCtx, Rumor, RumorPack, ScratchArena, Strategy};
use crate::comm::codec::Codec;
use crate::comm::{Fabric, LinkModel};
use crate::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use crate::coordinator::checkpoint::{AsyncCheckpoint, AsyncNodeState};
use crate::coordinator::{average_params, build_dataset_pub, decide_schedule_into, evaluate, RunReport};
use crate::data::{self, BatchCursor, Dataset, TaskKind};
use crate::membership::{
    digest_params, AppliedChurn, BootstrapRecord, ChurnEvent, ChurnKind, FaultPlan, FdReport,
    LocalView, MemberView, MembershipReport, PeerStatus,
};
use crate::metrics::{Curve, EvalPoint, RunMetrics, StalenessHist};
use crate::optim::{LrSchedule, OptimKind, Optimizer};
use crate::runtime::{BatchXOwned, EngineFactory, GradEngine, SyntheticSpec};
use crate::sim::WorkerSpeed;
use crate::trace::{Ev, Kind, Trace};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// The virtual-environment half of an async experiment: per-node compute
/// speeds and the network the messages travel through.  (The training
/// half is the ordinary [`ExperimentConfig`].)
#[derive(Clone, Debug)]
pub struct AsyncSimCfg {
    /// one entry per worker
    pub speeds: Vec<WorkerSpeed>,
    pub link: LinkModel,
    /// seed of the per-node compute-jitter streams (independent of the
    /// experiment seed so the trajectory tables stay comparable across
    /// speed scenarios)
    pub speed_seed: u64,
}

impl AsyncSimCfg {
    /// The synchronous special case: deterministic uniform speeds + the
    /// zero link.  Under this schedule the runtime is bit-identical to
    /// the sequential coordinator.
    pub fn lockstep(workers: usize) -> Self {
        AsyncSimCfg {
            speeds: (0..workers)
                .map(|_| WorkerSpeed { mean_s: 1.0, jitter: 0.0, slow_factor: 1.0 })
                .collect(),
            link: LinkModel::zero(),
            speed_seed: 0,
        }
    }

    /// A heterogeneous cluster: uniform `mean_s` compute with `jitter`,
    /// the last worker slowed by `slow_factor` (§2.1.2's straggler).
    pub fn straggler(workers: usize, mean_s: f64, jitter: f64, slow_factor: f64) -> Self {
        let mut speeds: Vec<WorkerSpeed> = (0..workers)
            .map(|_| WorkerSpeed { mean_s, jitter, slow_factor: 1.0 })
            .collect();
        if let Some(last) = speeds.last_mut() {
            last.slow_factor = slow_factor;
        }
        AsyncSimCfg { speeds, link: LinkModel::default(), speed_seed: 0x57A1E }
    }
}

/// Everything `run_async` returns: the ordinary run report plus the
/// asynchrony-specific measurements.
#[derive(Debug)]
pub struct AsyncRunReport {
    pub report: RunReport,
    /// each node's final parameters (the equivalence-test observable)
    pub final_params: Vec<Vec<f32>>,
    /// per-exchange steps-behind distribution
    pub staleness: StalenessHist,
    /// per-node virtual seconds spent computing
    pub busy_s: Vec<f64>,
    /// per-node virtual completion time
    pub finish_s: Vec<f64>,
    /// virtual wall clock: when the last node finished
    pub virtual_s: f64,
    /// network high-water mark (== the arena pool's steady-state size)
    pub peak_in_flight: usize,
    /// total events popped off the virtual-clock queue (the denominator
    /// of the scale bench's events/sec)
    pub events: u64,
    /// fraction of sent messages whose source and destination are pinned
    /// to different event-queue shards (0.0 under `shards:1`; the
    /// envelope traffic the sharded queue routes across threads)
    pub cross_shard_frac: f64,
    /// push-sum weight mass after the run, if the strategy carries one
    /// (GoSGD: must be 1 — mass is conserved even through in-flight
    /// messages *and arbitrary membership churn*)
    pub push_sum_mass: Option<f64>,
    /// what the membership subsystem observed: applied churn events,
    /// join-bootstrap records, per-epoch alive counts, survivors
    pub membership: MembershipReport,
    /// per-node epoch-boundary checkpoints (churn runs only) — the state
    /// crash-recovery rejoins restored from, saveable to disk via
    /// [`AsyncCheckpoint::save`]
    pub checkpoint: Option<AsyncCheckpoint>,
    /// Chrome trace-event JSON of the flight-recorder ring (`trace: on`
    /// runs only; `None` with tracing off).  Keyed by the virtual clock,
    /// so two same-seed runs produce byte-identical strings
    pub trace_json: Option<String>,
}

impl AsyncRunReport {
    /// Mean over nodes of busy-time / own-completion-time (the shared
    /// [`crate::sim::mean_self_utilization`] metric).  1.0 means no node
    /// ever waited; the synchronous barrier drags this to ~1/slow_factor
    /// for the fast workers under a straggler.
    pub fn mean_self_utilization(&self) -> f64 {
        crate::sim::mean_self_utilization(&self.busy_s, &self.finish_s)
    }
}

// ---------------------------------------------------------------------------
// event queue
// ---------------------------------------------------------------------------

// Same-instant ordering: membership churn first (a crash at instant t
// kills the node before anything else at t observes it), then all step
// completions, then all deliveries (and the replies they spawn), then
// all boundary applies, then evaluation — the phase structure that makes
// zero latency reproduce the barrier.  With an empty churn schedule no
// CLASS_CHURN event ever enters the heap, so the relative ordering of
// the remaining classes — and every no-churn trajectory — is unchanged.
const CLASS_CHURN: u8 = 0;
const CLASS_STEP: u8 = 1;
const CLASS_MSG: u8 = 2;
const CLASS_BOUNDARY: u8 = 3;
const CLASS_EVAL: u8 = 4;
/// Failure-detector ticks/timeouts order after everything else at an
/// instant (detection reacts to the instant's completed traffic).  No
/// CLASS_FD event enters the heap unless `fd:` is enabled.
const CLASS_FD: u8 = 5;

enum Event {
    /// Index into the materialized churn schedule.
    Churn { idx: usize },
    /// `gen` is the node's incarnation at scheduling time: a crash bumps
    /// the node's generation, so step/boundary events scheduled for a
    /// dead incarnation pop as no-ops even if the node rejoined since.
    StepDone { node: usize, gen: u32 },
    MsgDelivered { msg: NetMsg },
    Boundary { node: usize, gen: u32 },
    EvalTick { epoch: usize },
    /// `node`'s periodic failure-detector probe (self-rescheduling while
    /// the node is alive and not retired).
    FdTick { node: usize },
    /// Direct-probe ack deadline: escalate probe `probe` to ping-req.
    FdProbeTimeout { node: usize, probe: u64 },
    /// Indirect-probe deadline: still unacked -> suspect the target.
    FdIndirectTimeout { node: usize, probe: u64 },
    /// Suspicion deadline: unrefuted (same incarnation, still suspect)
    /// -> confirmed dead in `node`'s view.
    FdSuspectTimeout { node: usize, target: usize, inc: u32 },
}

struct Queued {
    time: f64,
    class: u8,
    seq: u64,
    ev: Event,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> Ordering {
        // inverted on every key: BinaryHeap is a max-heap, we pop earliest
        // (time, class, seq) first — seq breaks ties deterministically in
        // scheduling order
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Stable in-place insertion sort by edge initiator — k-set order
/// (Algorithm 4), no allocation (mailboxes are tiny).
fn sort_mailbox(mb: &mut [NetMsg]) {
    for i in 1..mb.len() {
        let mut j = i;
        while j > 0 && mb[j - 1].picker > mb[j].picker {
            mb.swap(j - 1, j);
            j -= 1;
        }
    }
}

// ---------------------------------------------------------------------------
// nodes
// ---------------------------------------------------------------------------

/// Per-node bookkeeping (parameters/gradients live in the engine's slot
/// vectors so the sync helpers — `average_params`, `evaluate` — apply
/// unchanged).
struct Node {
    cursor: BatchCursor,
    optim: Optimizer,
    xbuf: BatchXOwned,
    ybuf: Vec<i32>,
    batch_idx: Vec<usize>,
    mailbox: Vec<NetMsg>,
    /// local step currently in flight (== completed steps at a boundary,
    /// before the post-apply increment)
    step: u64,
    epoch: usize,
    /// loss of the in-flight step
    loss: f32,
    busy_s: f64,
    finish_s: f64,
    speed_rng: Rng,
    /// incarnation counter (membership churn): bumped at every death and
    /// revival.  Stamped into scheduled step/boundary events and into
    /// messages at outbox flush; a mismatch at pop/delivery time means
    /// the event belongs to a dead incarnation and is discarded.
    gen: u32,
    /// the node ran its full step schedule and was counted finished —
    /// guards against double-retiring when a fully-finished node's
    /// checkpoint is restored by a late rejoin
    retired: bool,
}

// ---------------------------------------------------------------------------
// failure-detector state (per node)
// ---------------------------------------------------------------------------

/// An unanswered probe: removed when the matching `FdAck` lands; still
/// present at the indirect deadline means the target gets suspected.
struct PendingProbe {
    id: u64,
    target: usize,
}

/// How many outgoing messages each queued rumor rides before it expires
/// (SWIM's O(log n) dissemination budget, fixed for determinism).
const RUMOR_SENDS: u8 = 8;
/// Bounded rumor queue per node; stale entries are superseded in place.
const RUMOR_QUEUE_CAP: usize = 32;

/// One node's failure-detector state: its believed membership, the
/// probes it is waiting on, and the rumors it still owes the cluster.
struct FdState {
    view: LocalView,
    pending: Vec<PendingProbe>,
    rumor_q: Vec<(Rumor, u8)>,
}

impl FdState {
    fn new(slots: usize, initial: usize) -> FdState {
        FdState {
            view: LocalView::new(slots, initial),
            pending: Vec::new(),
            rumor_q: Vec::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

struct AsyncEngine<'a> {
    cfg: &'a ExperimentConfig,
    speeds: Vec<WorkerSpeed>,
    engine: Box<dyn GradEngine>,
    train: Dataset,
    val: Dataset,
    test: Dataset,
    params: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    strategy: Box<dyn Strategy>,
    fabric: Fabric,
    arena: ScratchArena,
    /// wire codec for parameter payloads (`cfg.codec`): encode at outbox
    /// flush, decode at delivery, pooled byte buffers from the arena
    codec: Box<dyn Codec>,
    nodes: Vec<Node>,
    /// pre-drawn per-(step, worker) decision tables, consumed from the
    /// root rng's named streams in the sequential coordinator's exact
    /// order (see module docs)
    masks: Vec<bool>,
    /// pre-drawn peer per (step, worker); `u32::MAX` = no peer (packed —
    /// an `Option<usize>` per cell would double the table at 10⁵ nodes)
    picks: Vec<u32>,
    seeds: Vec<i32>,
    /// per-global-step f64 loss buckets, accumulated in arrival order
    /// (lockstep arrival == the sequential coordinator's summation order,
    /// so epoch losses fold bit-identically)
    loss_acc: Vec<f64>,
    epoch_done: Vec<usize>,
    /// nodes expected to complete each epoch: starts at the roster size,
    /// decremented when a node departs before finishing the epoch,
    /// incremented when a (re)join will run through it.  Evaluation for
    /// epoch e fires when `epoch_done[e] >= epoch_quota[e]` (once).
    epoch_quota: Vec<usize>,
    eval_emitted: Vec<bool>,
    /// per-epoch contributed step-loss count — the `train_loss`
    /// denominator (== steps_per_epoch * roster on a fixed roster,
    /// bit-identically; the survivor count under churn)
    epoch_contrib: Vec<u64>,
    // -- membership churn state (all dormant on an empty schedule) -------
    membership: MemberView,
    churn: Vec<ChurnEvent>,
    churn_active: bool,
    /// live peer sampling under churn (the fixed-roster pick tables
    /// cannot anticipate membership): consumes the "gossip" stream in
    /// event order — deterministic, but a *different* consumption
    /// pattern than the no-churn tables, which is why the two modes
    /// never share a trajectory unless the schedule is empty
    gossip_rng: Rng,
    /// engine-initial parameters (fresh joins start here)
    init_params: Vec<f32>,
    /// per-node epoch-boundary checkpoint mirror (crash-recovery rejoins
    /// restore from this; buffers refilled in place)
    ckpt: Vec<Option<AsyncNodeState>>,
    mreport: MembershipReport,
    /// (joiner, donor, donor_digest) awaiting the bootstrap reply
    pending_bootstrap: Vec<(usize, usize, u64)>,
    // -- failure-detection plane (all dormant unless `fd:` is enabled) ---
    fd_active: bool,
    fd: Vec<FdState>,
    /// probe-target sampling stream ("fdprobe"), independent of the
    /// gossip stream so enabling fd perturbs no existing draw
    fd_rng: Rng,
    /// monotonically increasing probe id (ack matching)
    probe_ctr: u64,
    /// oracle crash instants (NaN = alive/never crashed): the detection-
    /// latency reference the fd report measures against
    crash_time: Vec<f64>,
    /// per-slot guard: protocol reclamation (on_peer_lost + shard
    /// reassignment) runs once per true death, at the *first* true
    /// confirmation anywhere in the cluster; reset on rejoin
    reclaimed: Vec<bool>,
    /// the original data partition (shard reassignment source of truth)
    shards0: Vec<Vec<usize>>,
    /// (dead, adopter, row): rows currently adopted away from their
    /// owner — evicted back when the owner rejoins
    adopted_rows: Vec<(usize, usize, usize)>,
    fd_report: FdReport,
    // -- link-fault plane (dormant unless `faults:` is non-empty) --------
    faults_active: bool,
    fault_plan: FaultPlan,
    /// message ordinal for the stateless loss/jitter hashes
    wire_seq: u64,
    // -- sharded virtual-clock event queue (`cfg.shards`) ----------------
    /// per-shard min-heaps merged in global (time, class, seq) order —
    /// with one shard this *is* the single event queue
    queue: ShardedQueue,
    /// gradient shard workers (`shards > 1` only; `None` = gradients
    /// computed inline on the driver thread, the single-queue runtime)
    router: Option<GradRouter>,
    /// results that arrived before their own `StepDone` popped: one
    /// parking slot per node, plus an overflow list for the rare
    /// crash + fast-rejoin case where two generations of the same node
    /// are in flight at once
    grad_pending: Vec<Option<GradDone>>,
    grad_overflow: Vec<GradDone>,
    /// events popped off the queue (observability)
    events: u64,
    /// messages sent / messages whose endpoints live on different shards
    sent_msgs: u64,
    cross_shard_msgs: u64,
    /// coalescing scratch: (message, raw, encoded, fault seqno) of the
    /// frame being assembled (`cfg.coalesce`; keeps its capacity)
    frame_buf: Vec<(NetMsg, u64, u64, u64)>,
    outbox: Vec<NetMsg>,
    staleness: StalenessHist,
    curve: Curve,
    w: usize,
    b: usize,
    steps_per_epoch: u64,
    total_steps: u64,
    now: f64,
    finished: usize,
    watch: Stopwatch,
    eval_time: f64,
    /// real-socket splice (`transport: loopback-udp`): every scheduled
    /// delivery's bytes cross an actual 127.0.0.1 datagram and the
    /// applied payload is whatever came back off the wire.  `None` =
    /// pure in-process virtual-clock path (`transport: inproc`).
    wire: Option<net::WirePlane>,
    /// flight recorder (`cfg.trace`): records are keyed by the virtual
    /// clock and the queue's `(class, seq)` identity, so a traced run's
    /// ring is as deterministic as the trajectory itself.  `Trace::off()`
    /// is a `None` — every emission below is a dead branch
    trace: Trace,
}

impl<'a> AsyncEngine<'a> {
    /// Gather the next batch, compute the step's gradient eagerly (node
    /// parameters cannot change until its own next boundary), and
    /// schedule the step completion on the virtual clock.
    fn begin_step(&mut self, i: usize) -> Result<()> {
        let t = self.nodes[i].step as usize;
        {
            let node = &mut self.nodes[i];
            node.cursor.next_batch(self.b, &mut node.batch_idx);
            match self.train.kind {
                TaskKind::Classify => {
                    data::gather_f32(&self.train, &node.batch_idx, node.xbuf.clear_f32(), &mut node.ybuf)
                }
                TaskKind::LanguageModel => {
                    data::gather_i32(&self.train, &node.batch_idx, node.xbuf.clear_i32(), &mut node.ybuf)
                }
            }
        }
        let seed = self.seeds[t * self.w + i];
        if let Some(router) = &self.router {
            // ship the job to the node's shard worker; the result is
            // collected when this step's own `StepDone` pops.  The
            // parameter copy is safe because a node's params cannot
            // change between `begin_step` and its next boundary (the
            // mailbox parks deliveries) — the worker reads exactly the
            // value the inline path would have read.
            let node = &mut self.nodes[i];
            let x = std::mem::replace(&mut node.xbuf, BatchXOwned::F32(Vec::new()));
            let y = std::mem::take(&mut node.ybuf);
            let gen = node.gen;
            let params = self.arena.rent_msg(&self.params[i]);
            let grad = self.arena.rent_msg(&[]);
            router.submit(
                self.queue.shard_of(i),
                GradJob { node: i, gen, seed, params, x, y, grad },
            );
        } else {
            let loss = {
                let node = &self.nodes[i];
                self.engine.loss_and_grad(
                    &self.params[i],
                    node.xbuf.as_ref(),
                    &node.ybuf,
                    seed,
                    &mut self.grads[i],
                )?
            };
            self.nodes[i].loss = loss;
        }
        let dt = self.speeds[i].sample_step_time(&mut self.nodes[i].speed_rng);
        self.nodes[i].busy_s += dt;
        let gen = self.nodes[i].gen;
        self.trace.span(
            self.now,
            self.now + dt,
            Ev { node: i, kind: Kind::Step, class: CLASS_STEP, seq: t as u64, a: t as u64, b: 0 },
        );
        self.queue.sched(self.now + dt, CLASS_STEP, Event::StepDone { node: i, gen });
        Ok(())
    }

    /// Collect the sharded gradient result for node `i`'s step `gen`
    /// (no-op on the inline path).  Every shipped job is collected by
    /// its own `StepDone`, so the blocking `recv` below can never wait
    /// for a job that was not submitted.  Results that belong to other
    /// nodes (their `StepDone` is still in the queue) are parked.
    fn collect_grad(&mut self, i: usize, gen: u32) -> Result<()> {
        if self.router.is_none() {
            return Ok(());
        }
        let mut done = loop {
            if let Some(d) = self.grad_pending[i].take() {
                if d.gen == gen {
                    break d;
                }
                // same node, other incarnation (crash + fast rejoin):
                // keep it for the matching StepDone
                self.grad_overflow.push(d);
            }
            if let Some(k) = self
                .grad_overflow
                .iter()
                .position(|d| d.node == i && d.gen == gen)
            {
                break self.grad_overflow.swap_remove(k);
            }
            let d = self.router.as_ref().unwrap().recv()?;
            if d.node == i && d.gen == gen {
                break d;
            }
            if self.grad_pending[d.node].is_none() {
                self.grad_pending[d.node] = Some(d);
            } else {
                self.grad_overflow.push(d);
            }
        };
        let loss = done.loss?;
        self.arena.return_msg(done.params);
        if self.nodes[i].gen == gen {
            self.nodes[i].loss = loss;
            std::mem::swap(&mut self.grads[i], &mut done.grad);
        }
        // a stale result (node crashed since) is dropped: buffers are
        // recycled, live state untouched
        self.arena.return_msg(done.grad);
        self.nodes[i].xbuf = done.x;
        self.nodes[i].ybuf = done.y;
        Ok(())
    }

    /// Seed the virtual clock and pump the event queue dry.  Runs on the
    /// driver thread regardless of the shard count: shards parallelize
    /// gradient *compute*, never event *handling*, so the merged
    /// (time, class, seq) pop order — and every rng draw and f64 fold it
    /// triggers — is identical for every `shards:` value.
    fn drive(&mut self) -> Result<()> {
        for idx in 0..self.churn.len() {
            let t = self.churn[idx].time;
            self.queue.sched(t, CLASS_CHURN, Event::Churn { idx });
        }
        if self.total_steps > 0 {
            for i in 0..self.w {
                if self.membership.is_alive(i) {
                    self.begin_step(i)?;
                }
            }
            if self.fd_active {
                // stagger first probes across one period so the plane
                // does not fire in lockstep (deterministic: slot index,
                // not rng)
                for i in 0..self.w {
                    if self.membership.is_alive(i) {
                        let t0 = self.cfg.fd.period_s * ((i + 1) as f64) / (self.w as f64);
                        self.queue.sched(t0, CLASS_FD, Event::FdTick { node: i });
                    }
                }
            }
        }
        while let Some(q) = self.queue.pop() {
            self.now = q.time;
            self.events += 1;
            if self.trace.is_on() {
                let node = match &q.ev {
                    Event::Churn { .. } => 0,
                    Event::StepDone { node, .. }
                    | Event::Boundary { node, .. }
                    | Event::FdTick { node }
                    | Event::FdProbeTimeout { node, .. }
                    | Event::FdIndirectTimeout { node, .. }
                    | Event::FdSuspectTimeout { node, .. } => *node,
                    Event::MsgDelivered { msg } => msg.dst,
                    Event::EvalTick { .. } => 0,
                };
                self.trace.instant(
                    self.now,
                    Ev {
                        node,
                        kind: Kind::Pop,
                        class: q.class,
                        seq: q.seq,
                        a: q.class as u64,
                        b: self.queue.shard_of(node) as u64,
                    },
                );
            }
            match q.ev {
                Event::Churn { idx } => self.on_churn(idx)?,
                Event::StepDone { node, gen } => {
                    self.collect_grad(node, gen)?;
                    self.on_step_done(node, gen)?
                }
                Event::MsgDelivered { msg } => self.on_delivered(msg)?,
                Event::Boundary { node, gen } => self.on_boundary(node, gen)?,
                Event::EvalTick { epoch } => self.on_eval(epoch)?,
                Event::FdTick { node } => self.on_fd_tick(node)?,
                Event::FdProbeTimeout { node, probe } => self.on_fd_probe_timeout(node, probe)?,
                Event::FdIndirectTimeout { node, probe } => {
                    self.on_fd_indirect_timeout(node, probe)?
                }
                Event::FdSuspectTimeout { node, target, inc } => {
                    self.on_fd_suspect_timeout(node, target, inc)?
                }
            }
        }
        debug_assert!(
            self.grad_overflow.is_empty() && self.grad_pending.iter().all(Option::is_none),
            "every shipped gradient job must be collected by its own StepDone"
        );
        Ok(())
    }

    /// Stamp the receiver's incarnation, attach rumors, and encode the
    /// payload through the run's codec.  Returns (raw, encoded) bytes
    /// and bumps the cross-shard traffic gauges.
    fn prepare_wire(&mut self, msg: &mut NetMsg) -> (u64, u64) {
        // stamp the receiver's incarnation: if it crashes (and even
        // rejoins) before the delivery instant, the delivery is
        // refused — a message never outlives its addressee
        msg.gen = self.nodes[msg.dst].gen;
        // membership rumors ride every outgoing message; with the
        // detector off the pack stays empty and adds zero bytes
        if self.fd_active {
            self.fill_rumors(msg);
        }
        self.sent_msgs += 1;
        if self.queue.shard_of(msg.src) != self.queue.shard_of(msg.dst) {
            self.cross_shard_msgs += 1;
        }
        let rumor_bytes = msg.rumors.wire_bytes();
        let raw = msg.payload.raw_bytes() + rumor_bytes;
        let encoded = if msg.payload.codec_exempt() {
            raw // membership/fd control plane: exact state, no codec
        } else if let Some(p) = msg.payload.params() {
            let mut buf = self.arena.rent_bytes();
            self.codec.encode_into(msg.src, p, &mut buf);
            let e = buf.len() as u64 + msg.payload.non_param_bytes() + rumor_bytes;
            msg.wire = Some(buf);
            self.trace.instant(
                self.now,
                Ev {
                    node: msg.src,
                    kind: Kind::Encode,
                    class: CLASS_MSG,
                    seq: self.sent_msgs,
                    a: raw,
                    b: e,
                },
            );
            e
        } else {
            raw // control-only frames travel as-is
        };
        (raw, encoded)
    }

    /// Account + schedule everything the last hook put in the outbox.
    ///
    /// This is where payloads meet the wire: each parameter-bearing
    /// message is encoded through the run's codec into a pooled byte
    /// buffer; the fabric records the raw size in its ledgers and the
    /// encoded size in the `wire_bytes` gauge, and the link transfer
    /// time — hence the delivery instant — is priced by what actually
    /// travels.  Under the identity codec encoded == raw, so the
    /// delivery schedule (and with it the whole trajectory) is unchanged.
    fn flush_outbox(&mut self) {
        if self.outbox.is_empty() {
            return;
        }
        let mut ob = std::mem::take(&mut self.outbox);
        if self.cfg.coalesce {
            self.flush_coalesced(&mut ob);
            self.outbox = ob; // keep the capacity
            return;
        }
        for mut msg in ob.drain(..) {
            let (raw, encoded) = self.prepare_wire(&mut msg);
            // deterministic link faults: loss/jitter are stateless hashes
            // of (fault seed, link, message ordinal) — no RNG stream is
            // consumed, so an empty plan perturbs nothing.  The join
            // control plane is fault-exempt (a lost bootstrap handshake
            // would strand the joiner forever); losing probes and gossip
            // is exactly the false-suspicion cause under study.
            if self.faults_active
                && !matches!(
                    msg.payload,
                    MsgPayload::JoinRequest { .. } | MsgPayload::JoinReply(_)
                )
            {
                self.wire_seq += 1;
                let seqno = self.wire_seq;
                if self.fault_plan.loses(msg.src, msg.dst, seqno, self.now) {
                    // the sender paid for the send; the wire ate it.
                    // Conserved state folds back into the *sender*
                    // (GoSGD: w/2 sent + w/2 kept == w, bit-exact).
                    let _ = self.fabric.send_async_coded(msg.src, msg.dst, raw, encoded, self.now);
                    self.fabric.lose_in_flight(raw);
                    self.strategy.on_drop_to_lost(&msg.payload, msg.src);
                    self.recycle_msg(msg);
                    continue;
                }
                let at = self.fabric.send_async_coded(msg.src, msg.dst, raw, encoded, self.now);
                let at = at + self.fault_plan.extra_delay(msg.src, msg.dst, seqno, at - self.now);
                self.sched_delivery(at, msg);
                continue;
            }
            let at = self.fabric.send_async_coded(msg.src, msg.dst, raw, encoded, self.now);
            self.sched_delivery(at, msg);
        }
        self.outbox = ob; // keep the capacity
    }

    /// Schedule a surviving message's delivery.  This sits *after* the
    /// fault plane's loss decision on every path, so with a real wire
    /// spliced in (`transport: loopback-udp`) only messages the simulator
    /// has committed to deliver ever touch a socket — the loss model stays
    /// the simulator's, the bytes become real.
    fn sched_delivery(&mut self, at: f64, mut msg: NetMsg) {
        self.trace.span(
            self.now,
            at,
            Ev {
                node: msg.src,
                kind: Kind::Flight,
                class: CLASS_MSG,
                seq: self.sent_msgs,
                a: msg.dst as u64,
                b: msg.wire.as_ref().map_or(0, |w| w.len() as u64),
            },
        );
        if let Some(plane) = self.wire.as_mut() {
            plane.transmit(&mut msg);
        }
        self.queue.sched(at, CLASS_MSG, Event::MsgDelivered { msg });
    }

    /// Coalescing flush (`coalesce = true`): consecutive outbox messages
    /// that share a (src, dst) link are packed into one wire frame — one
    /// link transfer (latency paid once, sizes summed) instead of one
    /// per message.  Grouping is by *outbox adjacency*, never by shard,
    /// so the frame layout — and with it the whole trajectory — is
    /// independent of the shard count.  Per-message fault decisions
    /// (loss, jitter seqno) are drawn before grouping, in the exact
    /// order the per-message path draws them, so loss accounting is
    /// identical; only surviving messages ride frames.
    fn flush_coalesced(&mut self, ob: &mut Vec<NetMsg>) {
        let mut frame = std::mem::take(&mut self.frame_buf);
        let mut key: Option<(usize, usize)> = None;
        for mut msg in ob.drain(..) {
            let (raw, encoded) = self.prepare_wire(&mut msg);
            let exempt = matches!(
                msg.payload,
                MsgPayload::JoinRequest { .. } | MsgPayload::JoinReply(_)
            );
            let mut seqno = 0; // 0 = fault-exempt (or faults off): no jitter
            if self.faults_active && !exempt {
                self.wire_seq += 1;
                seqno = self.wire_seq;
                if self.fault_plan.loses(msg.src, msg.dst, seqno, self.now) {
                    // lost messages are priced individually, exactly as
                    // on the per-message path — a frame never carries
                    // a message the wire already ate
                    let _ = self.fabric.send_async_coded(msg.src, msg.dst, raw, encoded, self.now);
                    self.fabric.lose_in_flight(raw);
                    self.strategy.on_drop_to_lost(&msg.payload, msg.src);
                    self.recycle_msg(msg);
                    continue;
                }
            }
            if key != Some((msg.src, msg.dst)) {
                self.emit_frame(&mut frame);
                key = Some((msg.src, msg.dst));
            }
            frame.push((msg, raw, encoded, seqno));
        }
        self.emit_frame(&mut frame);
        self.frame_buf = frame; // keep the capacity
    }

    /// Price the assembled frame as one link transfer and schedule each
    /// carried message's delivery (shared frame arrival + that message's
    /// own deterministic jitter).
    fn emit_frame(&mut self, frame: &mut Vec<(NetMsg, u64, u64, u64)>) {
        if frame.is_empty() {
            return;
        }
        let (src, dst) = (frame[0].0.src, frame[0].0.dst);
        let raw: u64 = frame.iter().map(|(_, r, _, _)| r).sum();
        let enc: u64 = frame.iter().map(|(_, _, e, _)| e).sum();
        let at = self
            .fabric
            .send_frame_coded(src, dst, raw, enc, frame.len() as u64, self.now);
        for (msg, _, _, seqno) in frame.drain(..) {
            let at = if self.faults_active && seqno != 0 {
                at + self.fault_plan.extra_delay(src, dst, seqno, at - self.now)
            } else {
                at
            };
            self.sched_delivery(at, msg);
        }
    }

    /// Stamp the sender's implicit Alive heartbeat into rumor slot 0 and
    /// drain up to the pack's remaining capacity from the sender's
    /// bounded rumor queue (each queued rumor rides [`RUMOR_SENDS`]
    /// messages before expiring).
    fn fill_rumors(&mut self, msg: &mut NetMsg) {
        let src = msg.src;
        let mut pack = RumorPack::empty();
        pack.push(Rumor {
            kind: Rumor::ALIVE,
            node: src as u16,
            inc: self.fd[src].view.incarnation(src),
        });
        let q = &mut self.fd[src].rumor_q;
        let mut k = 0;
        while k < q.len() && pack.len() < RumorPack::CAP {
            let (r, left) = &mut q[k];
            pack.push(*r);
            *left -= 1;
            if *left == 0 {
                q.remove(k);
            } else {
                k += 1;
            }
        }
        msg.rumors = pack;
    }

    /// Queue a rumor for dissemination from node `i`.  A newer claim
    /// about the same subject supersedes in place (higher incarnation
    /// wins; at equal incarnation, dead > suspect > alive).
    fn enqueue_rumor(&mut self, i: usize, r: Rumor) {
        let q = &mut self.fd[i].rumor_q;
        if let Some(e) = q.iter_mut().find(|(e, _)| e.node == r.node) {
            if (r.inc, r.kind) > (e.0.inc, e.0.kind) {
                *e = (r, RUMOR_SENDS);
            }
            return;
        }
        if q.len() < RUMOR_QUEUE_CAP {
            q.push((r, RUMOR_SENDS));
        }
    }

    fn on_step_done(&mut self, i: usize, gen: u32) -> Result<()> {
        if self.churn_active && (!self.membership.is_alive(i) || self.nodes[i].gen != gen) {
            return Ok(()); // the incarnation that scheduled this is gone
        }
        let t = self.nodes[i].step as usize;
        self.loss_acc[t] += self.nodes[i].loss as f64;
        self.epoch_contrib[t / self.steps_per_epoch as usize] += 1;
        if self.masks[t * self.w + i] {
            // fixed roster: the pre-drawn pick table (bit-identical to
            // the sequential coordinator).  Under churn the table cannot
            // anticipate membership, so the peer is sampled live from
            // the alive neighborhood (own rng stream, event order).
            // With the detector on, "alive" means *believed* alive: the
            // node samples from its own LocalView, not the oracle.
            let peer = if self.fd_active {
                self.sample_viewed_peer(i)
            } else if self.churn_active {
                self.sample_alive_peer(i)
            } else {
                let p = self.picks[t * self.w + i];
                if p == u32::MAX {
                    None
                } else {
                    Some(p as usize)
                }
            };
            if let Some(peer) = peer {
                let step = self.nodes[i].step;
                let mut ctx = ProtoCtx {
                    node: i,
                    step,
                    params: self.params[i].as_mut_slice(),
                    arena: &mut self.arena,
                    outbox: &mut self.outbox,
                };
                self.strategy.on_send_due(&mut ctx, peer)?;
                self.flush_outbox();
            }
        }
        self.queue.sched(self.now, CLASS_BOUNDARY, Event::Boundary { node: i, gen });
        Ok(())
    }

    /// Recycle a message's pooled buffers without applying it.
    fn recycle_msg(&mut self, mut msg: NetMsg) {
        if let Some(wire) = msg.wire.take() {
            self.arena.return_bytes(wire);
        }
        if let Some(buf) = msg.payload.take_params() {
            self.arena.return_msg(buf);
        }
    }

    /// Can this message still be delivered under the current membership?
    /// (Trivially yes on a fixed roster.)
    fn deliverable(&self, msg: &NetMsg) -> bool {
        if !self.churn_active && !self.fd_active {
            return true;
        }
        if !self.membership.is_alive(msg.dst) || self.nodes[msg.dst].gen != msg.gen {
            return false; // the addressee (incarnation) is gone — physics
        }
        // a bootstrap request must come from the incarnation that sent
        // it: if the joiner crashed (and possibly rejoined) while the
        // request was in flight, refuse it — the new incarnation runs
        // its own handshake, and exactly one handshake per incarnation
        // ever completes
        if let MsgPayload::JoinRequest { joiner_gen } = msg.payload {
            return self.membership.is_alive(msg.src) && self.nodes[msg.src].gen == joiner_gen;
        }
        // fd control frames always land on a live receiver: a probe from
        // a peer the receiver believed dead is alive-evidence (its
        // piggybacked rumors carry the refutation)
        if matches!(
            msg.payload,
            MsgPayload::FdPing { .. } | MsgPayload::FdAck { .. } | MsgPayload::FdPingReq { .. }
        ) {
            return true;
        }
        if self.fd_active {
            // protocol knowledge is local: the receiver refuses traffic
            // from peers *it* has confirmed dead — the oracle no longer
            // decides dead-sender semantics
            if self.fd[msg.dst].view.status(msg.src) == PeerStatus::Dead {
                return match msg.payload {
                    MsgPayload::JoinReply(_) => true,
                    _ => self.strategy.deliver_from_lost(&msg.payload),
                };
            }
            return true;
        }
        if !self.membership.is_alive(msg.src) {
            // departed sender: the strategy's churn rules decide (the
            // membership control plane keeps join replies — valid state
            // from a donor that died after answering)
            return match msg.payload {
                MsgPayload::JoinReply(_) => true,
                _ => self.strategy.deliver_from_lost(&msg.payload),
            };
        }
        true
    }

    fn on_delivered(&mut self, mut msg: NetMsg) -> Result<()> {
        // wire splice active: the delivery instant has arrived, so redeem
        // the message's frame off the real socket — payload bytes, control
        // words and rumors are overwritten with what actually crossed the
        // wire before any of the logic below reads them
        if msg.wire_seq != 0 {
            if let Some(plane) = self.wire.as_mut() {
                plane.redeem(&mut msg)?;
            }
        }
        if !self.deliverable(&msg) {
            self.fabric.drop_async(msg.payload.raw_bytes());
            let receiver_gone =
                !self.membership.is_alive(msg.dst) || self.nodes[msg.dst].gen != msg.gen;
            if receiver_gone {
                // reclaim conserved state the message carried (GoSGD
                // share weight folds into the lowest-indexed survivor;
                // with no survivors it parks on the dead receiver's slot
                // so the terminal mass invariant still reads 1)
                let f = self.membership.first_alive().unwrap_or(msg.dst);
                self.strategy.on_drop_to_lost(&msg.payload, f);
                // a joiner whose bootstrap donor died mid-handshake
                // retries against another donor (or free-runs if alone)
                // — but only the incarnation that asked may retry
                if let MsgPayload::JoinRequest { joiner_gen } = msg.payload {
                    if self.membership.is_alive(msg.src)
                        && self.nodes[msg.src].gen == joiner_gen
                    {
                        let joiner = msg.src;
                        self.recycle_msg(msg);
                        self.begin_bootstrap(joiner)?;
                        return Ok(());
                    }
                }
            } else {
                self.mreport.rolled_back_msgs += 1; // dead-sender refusal
            }
            self.recycle_msg(msg);
            return Ok(());
        }
        self.fabric.deliver_async();
        // decode the payload out of its wire form before the strategy
        // sees it.  Overlay codecs (top-k) reconstruct onto the
        // receiver's *delivery-time* parameters: untransmitted
        // coordinates mix nothing, which confines the gossip update to
        // the transmitted support.
        if let Some(wire) = msg.wire.take() {
            let dst = msg.dst;
            let kind = msg.payload.kind();
            let mut decoded = 0u64;
            if let Some(p) = msg.payload.params_mut() {
                if self.codec.is_overlay() {
                    p.clear();
                    p.extend_from_slice(&self.params[dst]);
                }
                self.codec
                    .decode_into(&wire, p)
                    .with_context(|| format!("decoding {kind} payload"))?;
                decoded = p.len() as u64;
            }
            self.trace.instant(
                self.now,
                Ev {
                    node: dst,
                    kind: Kind::Decode,
                    class: CLASS_MSG,
                    seq: msg.sent_step,
                    a: wire.len() as u64,
                    b: decoded,
                },
            );
            self.arena.return_bytes(wire);
        }
        // failure-detection plane: consume piggybacked rumors, then
        // handle probe traffic — all before strategies see anything
        if self.fd_active {
            let rumors = msg.rumors;
            if !rumors.is_empty() {
                self.process_rumors(msg.dst, &rumors);
            }
            match msg.payload {
                MsgPayload::FdPing { probe, origin } => {
                    // ack the *original* prober directly (origin rides in
                    // the ping, so relayed pings need no relay state),
                    // stamping our incarnation as an implicit refutation
                    let me = msg.dst;
                    let inc = self.fd[me].view.incarnation(me);
                    let dst = origin as usize;
                    if dst < self.w && dst != me {
                        self.outbox.push(NetMsg {
                            src: me,
                            dst,
                            picker: me,
                            sent_step: self.nodes[me].step,
                            payload: MsgPayload::FdAck { probe, inc },
                            wire: None,
                            gen: 0,
                            rumors: RumorPack::empty(),
                            wire_seq: 0,
                        });
                    }
                    self.recycle_msg(msg);
                    self.flush_outbox();
                    return Ok(());
                }
                MsgPayload::FdPingReq { probe, target } => {
                    // relay: forward a direct ping on the origin's
                    // behalf; the target acks the origin, not us
                    let me = msg.dst;
                    let origin = msg.src;
                    let t = target as usize;
                    if t < self.w && t != me {
                        self.outbox.push(NetMsg {
                            src: me,
                            dst: t,
                            picker: me,
                            sent_step: self.nodes[me].step,
                            payload: MsgPayload::FdPing { probe, origin: origin as u32 },
                            wire: None,
                            gen: 0,
                            rumors: RumorPack::empty(),
                            wire_seq: 0,
                        });
                    }
                    self.recycle_msg(msg);
                    self.flush_outbox();
                    return Ok(());
                }
                MsgPayload::FdAck { probe, .. } => {
                    let me = msg.dst;
                    if let Some(pos) = self.fd[me].pending.iter().position(|p| p.id == probe) {
                        self.fd[me].pending.swap_remove(pos);
                        self.fd_report.acks += 1;
                    }
                    self.recycle_msg(msg);
                    return Ok(());
                }
                _ => {}
            }
        }
        // membership control plane: bootstrap handshakes are the
        // runtime's own protocol — strategies never see them
        match msg.payload {
            MsgPayload::JoinRequest { .. } => {
                // the donor answers with its state *at receipt* (the
                // pull-time semantics the bootstrap-correctness property
                // pins); the reply is codec-exempt, so adoption is exact
                let donor = msg.dst;
                let joiner = msg.src;
                let snap = self.arena.rent_msg(&self.params[donor]);
                self.pending_bootstrap.push((joiner, donor, digest_params(&snap)));
                self.outbox.push(NetMsg {
                    src: donor,
                    dst: joiner,
                    picker: joiner,
                    sent_step: self.nodes[donor].step,
                    payload: MsgPayload::JoinReply(snap),
                    wire: None,
                    gen: 0,
                    rumors: RumorPack::empty(),
                    wire_seq: 0,
                });
                self.recycle_msg(msg);
                self.flush_outbox();
                return Ok(());
            }
            MsgPayload::JoinReply(_) => {
                let joiner = msg.dst;
                {
                    let p = msg.payload.params().expect("join reply carries params");
                    self.params[joiner].copy_from_slice(p);
                }
                if let Some(pos) =
                    self.pending_bootstrap.iter().position(|&(j, _, _)| j == joiner)
                {
                    let (_, donor, donor_digest) = self.pending_bootstrap.swap_remove(pos);
                    self.mreport.bootstraps.push(BootstrapRecord {
                        joiner,
                        donor,
                        donor_digest,
                        adopted_digest: digest_params(&self.params[joiner]),
                        restored_step: self.nodes[joiner].step,
                    });
                }
                self.recycle_msg(msg);
                self.start_or_finish(joiner)?;
                return Ok(());
            }
            _ => {}
        }
        let dst = msg.dst;
        let step = self.nodes[dst].step;
        let retained = {
            let mut ctx = ProtoCtx {
                node: dst,
                step,
                params: self.params[dst].as_mut_slice(),
                arena: &mut self.arena,
                outbox: &mut self.outbox,
            };
            self.strategy.on_message(&mut ctx, msg)?
        };
        if let Some(m) = retained {
            self.nodes[dst].mailbox.push(m);
        }
        self.flush_outbox();
        Ok(())
    }

    /// Apply node `i`'s retained mailbox against its boundary snapshot:
    /// sort to k-set order, record one staleness sample per exchange,
    /// run the strategy's boundary hook, recycle the buffers.  Shared by
    /// the per-step boundary and the post-loop late-mail pass so the two
    /// can never apply exchanges under different rules.
    fn apply_mailbox(&mut self, i: usize) -> Result<()> {
        if self.nodes[i].mailbox.is_empty() {
            return Ok(());
        }
        let step = self.nodes[i].step;
        let mut mailbox = std::mem::take(&mut self.nodes[i].mailbox);
        sort_mailbox(&mut mailbox);
        for m in &mailbox {
            self.staleness.record(step.abs_diff(m.sent_step));
        }
        // boundary snapshot: the fixed self-term every apply reads
        self.arena.snapshot(i, &self.params[i]);
        self.trace.instant(
            self.now,
            Ev {
                node: i,
                kind: Kind::Snapshot,
                class: CLASS_BOUNDARY,
                seq: step,
                a: mailbox.len() as u64,
                b: 0,
            },
        );
        {
            let mut ctx = ProtoCtx {
                node: i,
                step,
                params: self.params[i].as_mut_slice(),
                arena: &mut self.arena,
                outbox: &mut self.outbox,
            };
            self.strategy.on_boundary_apply(&mut ctx, &mut mailbox)?;
        }
        // recycle payload buffers centrally — strategies only apply, so a
        // future protocol cannot leak pooled buffers by forgetting this
        for m in mailbox.drain(..) {
            if let Some(buf) = m.payload.take_params() {
                self.arena.return_msg(buf);
            }
        }
        self.nodes[i].mailbox = mailbox; // keep the capacity
        Ok(())
    }

    fn on_boundary(&mut self, i: usize, gen: u32) -> Result<()> {
        if self.churn_active && (!self.membership.is_alive(i) || self.nodes[i].gen != gen) {
            return Ok(()); // the incarnation that scheduled this is gone
        }
        self.apply_mailbox(i)?;
        self.flush_outbox();
        // optimizer phase (Algorithm 5 line 9) — after comm, like the
        // synchronous round
        {
            let node = &mut self.nodes[i];
            node.optim.update_velocity(&self.grads[i]);
            node.optim.apply(&mut self.params[i], &self.grads[i]);
            node.step += 1;
        }
        if self.nodes[i].step % self.steps_per_epoch == 0 {
            let e = self.nodes[i].epoch;
            self.nodes[i].epoch += 1;
            if self.nodes[i].epoch < self.cfg.epochs {
                let next = self.nodes[i].epoch;
                self.nodes[i].optim.start_epoch(next);
            }
            if self.churn_active {
                // epoch-boundary checkpoint: the state a crash-recovery
                // rejoin of this node restores (progress past the last
                // boundary is what a crash loses)
                let node = &self.nodes[i];
                match self.ckpt[i].as_mut() {
                    Some(c) => c.refill(node.step, node.epoch, &self.params[i], node.optim.velocity()),
                    None => {
                        self.ckpt[i] = Some(AsyncNodeState {
                            step: node.step,
                            epoch: node.epoch,
                            params: self.params[i].clone(),
                            velocity: node.optim.velocity().to_vec(),
                        })
                    }
                }
            }
            self.epoch_done[e] += 1;
            self.maybe_eval(e);
        }
        self.start_or_finish(i)
    }

    /// Begin the node's next step, or retire it if it has run its full
    /// schedule (shared by the boundary path and join bootstrap).
    fn start_or_finish(&mut self, i: usize) -> Result<()> {
        if self.nodes[i].step < self.total_steps {
            self.begin_step(i)
        } else {
            // a rejoin restored from a final-boundary checkpoint lands
            // here a second time — the node already retired, keep its
            // original finish time and count
            if !self.nodes[i].retired {
                self.nodes[i].retired = true;
                self.nodes[i].finish_s = self.now;
                self.finished += 1;
            }
            Ok(())
        }
    }

    /// Evaluation for epoch `e` fires exactly once, when every node
    /// expected to complete it has (`epoch_quota` tracks the roster as
    /// churn shrinks/grows it; on a fixed roster quota == W always, so
    /// this is the PR-2 condition verbatim).
    fn maybe_eval(&mut self, e: usize) {
        if !self.eval_emitted[e]
            && self.epoch_quota[e] > 0
            && self.epoch_done[e] >= self.epoch_quota[e]
            && ((e + 1) % self.cfg.eval_every == 0 || e + 1 == self.cfg.epochs)
        {
            self.eval_emitted[e] = true;
            self.queue.sched(self.now, CLASS_EVAL, Event::EvalTick { epoch: e });
        }
    }

    // -- membership churn ---------------------------------------------------

    /// Sample an alive gossip partner for `i` (live topology-constrained
    /// draw; `None` when `i`'s whole neighborhood is dead).
    fn sample_alive_peer(&mut self, i: usize) -> Option<usize> {
        self.arena.topo_cache_mut().sample_peer_alive(
            i,
            self.membership.alive_flags(),
            self.membership.alive_list(),
            &mut self.gossip_rng,
        )
    }

    // -- failure detection (`fd:` plane) ------------------------------------

    /// Sample a gossip partner from `i`'s *believed* membership (its
    /// sparse LocalView), not the oracle.  Suspects are still believed
    /// alive — they must keep receiving traffic to be able to refute.
    fn sample_viewed_peer(&mut self, i: usize) -> Option<usize> {
        self.arena
            .topo_cache_mut()
            .sample_peer_alive_view(i, &self.fd[i].view, &mut self.gossip_rng)
    }

    /// Timeline instant for a detector verdict: `what` = 0 suspect /
    /// 1 confirm / 2 refute, about `subject`.
    fn trace_fd(&mut self, node: usize, what: u64, subject: usize) {
        self.trace.instant(
            self.now,
            Ev {
                node,
                kind: Kind::Fd,
                class: CLASS_FD,
                seq: subject as u64,
                a: what,
                b: subject as u64,
            },
        );
    }

    /// Push one fd control frame from `src` and flush it immediately.
    fn send_fd(&mut self, src: usize, dst: usize, payload: MsgPayload) {
        self.outbox.push(NetMsg {
            src,
            dst,
            picker: src,
            sent_step: self.nodes[src].step,
            payload,
            wire: None,
            gen: 0,
            rumors: RumorPack::empty(),
            wire_seq: 0,
        });
        self.flush_outbox();
    }

    /// `node`'s periodic probe: ping one believed-alive peer (own
    /// "fdprobe" stream) and start the ack clock.  Reschedules itself
    /// while the node is alive and still training — ticks stop at
    /// retirement so the event heap drains.
    fn on_fd_tick(&mut self, node: usize) -> Result<()> {
        if !self.membership.is_alive(node) || self.nodes[node].retired {
            return Ok(());
        }
        if let Some(target) = self.arena.topo_cache_mut().sample_peer_alive_view(
            node,
            &self.fd[node].view,
            &mut self.fd_rng,
        ) {
            self.probe_ctr += 1;
            let id = self.probe_ctr;
            self.fd[node].pending.push(PendingProbe { id, target });
            self.fd_report.probes += 1;
            self.send_fd(node, target, MsgPayload::FdPing { probe: id, origin: node as u32 });
            self.queue.sched(
                self.now + self.cfg.fd.probe_timeout_s,
                CLASS_FD,
                Event::FdProbeTimeout { node, probe: id },
            );
        }
        self.queue
            .sched(self.now + self.cfg.fd.period_s, CLASS_FD, Event::FdTick { node });
        Ok(())
    }

    /// Direct-ack deadline: still unacked -> ask `fanout` other peers to
    /// ping the target on our behalf (SWIM ping-req), then arm the
    /// indirect deadline.  Relays are picked from the believed-alive
    /// list, rotated by probe id so the load spreads deterministically.
    fn on_fd_probe_timeout(&mut self, node: usize, probe: u64) -> Result<()> {
        if !self.membership.is_alive(node) {
            return Ok(());
        }
        let Some(pos) = self.fd[node].pending.iter().position(|p| p.id == probe) else {
            return Ok(()); // acked in time
        };
        let target = self.fd[node].pending[pos].target;
        let relays: Vec<usize> = {
            // enumerate the believed-alive set through the sparse view
            // (ascending order, same as the old dense alive-list)
            use crate::topology::AliveView;
            let view = &self.fd[node].view;
            let n = view.n_alive();
            let mut v = Vec::new();
            if n > 0 {
                let start = probe as usize % n;
                for k in 0..n {
                    let cand = view.kth_alive((start + k) % n);
                    if cand != node && cand != target {
                        v.push(cand);
                        if v.len() == self.cfg.fd.fanout {
                            break;
                        }
                    }
                }
            }
            v
        };
        for r in relays {
            self.fd_report.indirect_probes += 1;
            self.send_fd(node, r, MsgPayload::FdPingReq { probe, target: target as u32 });
        }
        self.queue.sched(
            self.now + self.cfg.fd.probe_timeout_s,
            CLASS_FD,
            Event::FdIndirectTimeout { node, probe },
        );
        Ok(())
    }

    /// Indirect deadline: no direct or relayed ack ever came back ->
    /// move the target to Suspect and start the refutation window.
    fn on_fd_indirect_timeout(&mut self, node: usize, probe: u64) -> Result<()> {
        if !self.membership.is_alive(node) {
            return Ok(());
        }
        let Some(pos) = self.fd[node].pending.iter().position(|p| p.id == probe) else {
            return Ok(()); // acked during the indirect window
        };
        let target = self.fd[node].pending.swap_remove(pos).target;
        self.suspect(node, target);
        Ok(())
    }

    /// Move `target` to Suspect in `node`'s view (no-op unless currently
    /// believed alive), gossip the suspicion, arm the confirm deadline.
    fn suspect(&mut self, node: usize, target: usize) {
        let inc = self.fd[node].view.incarnation(target);
        if !self.fd[node].view.note_suspect(target, inc) {
            return;
        }
        self.fd_report.suspicions += 1;
        self.trace_fd(node, 0, target);
        if self.membership.is_alive(target) {
            self.fd_report.false_suspicions += 1;
        }
        self.enqueue_rumor(node, Rumor { kind: Rumor::SUSPECT, node: target as u16, inc });
        self.queue.sched(
            self.now + self.cfg.fd.suspect_timeout_s,
            CLASS_FD,
            Event::FdSuspectTimeout { node, target, inc },
        );
    }

    /// Refutation window closed: if the suspicion still stands at the
    /// same incarnation, `node` confirms the death.
    fn on_fd_suspect_timeout(&mut self, node: usize, target: usize, inc: u32) -> Result<()> {
        if !self.membership.is_alive(node) {
            return Ok(());
        }
        if self.fd[node].view.status(target) == PeerStatus::Suspect
            && self.fd[node].view.incarnation(target) == inc
        {
            self.confirm_dead(node, target);
        }
        Ok(())
    }

    /// `observer` confirms `target` dead in its own view.  Metrics
    /// always; *protocol* consequences (strategy reclamation, shard
    /// reassignment) only on the first confirmation of a true death —
    /// false confirms never touch training state and are reconciled by
    /// the target's own higher-incarnation Alive rumors.
    fn confirm_dead(&mut self, observer: usize, target: usize) {
        if observer == target || !self.fd[observer].view.note_dead(target) {
            return;
        }
        self.fd_report.confirms += 1;
        self.trace_fd(observer, 1, target);
        let inc = self.fd[observer].view.incarnation(target);
        self.enqueue_rumor(observer, Rumor { kind: Rumor::DEAD, node: target as u16, inc });
        if self.membership.is_alive(target) {
            self.fd_report.false_confirms += 1;
        } else {
            if self.crash_time[target].is_finite() {
                self.fd_report.detection.record(self.now - self.crash_time[target]);
            }
            if !self.reclaimed[target] {
                self.reclaimed[target] = true;
                self.strategy.on_peer_lost(target, self.membership.alive_flags());
                self.reassign_shard(target);
            }
        }
        // locally-believed death: roll back parked messages from the
        // target wherever the strategy refuses them (Elastic Gossip's
        // pending pair terms) — per observer, at belief time
        let mut mb = std::mem::take(&mut self.nodes[observer].mailbox);
        let mut k = 0;
        while k < mb.len() {
            if mb[k].src == target && !self.strategy.deliver_from_lost(&mb[k].payload) {
                let m = mb.swap_remove(k);
                self.mreport.rolled_back_msgs += 1;
                self.recycle_msg(m);
            } else {
                k += 1;
            }
        }
        self.nodes[observer].mailbox = mb;
    }

    /// Data follows membership: the dead node's original shard is dealt
    /// round-robin over the oracle-alive survivors' batch cursors.  Rows
    /// a dead node had itself adopted are not re-dealt — they return to
    /// rotation when either owner rejoins.
    fn reassign_shard(&mut self, dead: usize) {
        if self.shards0.is_empty() || dead >= self.shards0.len() {
            return;
        }
        let alive: Vec<usize> = self.membership.alive_list().to_vec();
        if alive.is_empty() {
            return;
        }
        let shard = self.shards0[dead].clone();
        let mut per: Vec<Vec<usize>> = vec![Vec::new(); alive.len()];
        for (k, &row) in shard.iter().enumerate() {
            per[k % alive.len()].push(row);
        }
        for (&a, rows) in alive.iter().zip(&per) {
            if rows.is_empty() {
                continue;
            }
            self.nodes[a].cursor.adopt(rows);
            for &row in rows {
                self.adopted_rows.push((dead, a, row));
            }
            self.fd_report.shard_moves.push((dead, a, rows.len()));
        }
    }

    /// Apply a message's piggybacked rumors at `me` (before any payload
    /// handling): alive refutes/resurrects, suspect opens a refutation
    /// window, dead confirms — and a claim about *ourselves* is answered
    /// with a bumped incarnation (SWIM refutation).  Fresh information
    /// re-enters our own rumor queue so it keeps spreading.
    fn process_rumors(&mut self, me: usize, pack: &RumorPack) {
        for r in pack.iter() {
            let subject = r.node as usize;
            if subject >= self.w {
                continue;
            }
            match r.kind {
                Rumor::ALIVE => {
                    if subject == me {
                        // our own heartbeat echoed back: just track inc
                        self.fd[me].view.note_alive(me, r.inc);
                    } else if self.fd[me].view.note_alive(subject, r.inc) {
                        self.fd_report.refutations += 1;
                        self.trace_fd(me, 2, subject);
                        self.enqueue_rumor(me, *r);
                    }
                }
                Rumor::SUSPECT => {
                    if subject == me {
                        // someone suspects us: refute with a strictly
                        // higher incarnation and gossip it
                        let ni = self.fd[me].view.incarnation(me).max(r.inc).wrapping_add(1);
                        self.fd[me].view.note_alive(me, ni);
                        self.fd_report.refutations += 1;
                        self.enqueue_rumor(
                            me,
                            Rumor { kind: Rumor::ALIVE, node: me as u16, inc: ni },
                        );
                    } else if self.fd[me].view.note_suspect(subject, r.inc) {
                        self.fd_report.suspicions += 1;
                        if self.membership.is_alive(subject) {
                            self.fd_report.false_suspicions += 1;
                        }
                        self.enqueue_rumor(me, *r);
                        self.queue.sched(
                            self.now + self.cfg.fd.suspect_timeout_s,
                            CLASS_FD,
                            Event::FdSuspectTimeout { node: me, target: subject, inc: r.inc },
                        );
                    }
                }
                Rumor::DEAD => {
                    if subject == me {
                        // a death verdict about a live us: refute it
                        let ni = self.fd[me].view.incarnation(me).max(r.inc).wrapping_add(1);
                        self.fd[me].view.note_alive(me, ni);
                        self.fd_report.refutations += 1;
                        self.enqueue_rumor(
                            me,
                            Rumor { kind: Rumor::ALIVE, node: me as u16, inc: ni },
                        );
                    } else {
                        self.confirm_dead(me, subject);
                    }
                }
                _ => {}
            }
        }
    }

    fn on_churn(&mut self, idx: usize) -> Result<()> {
        let ev = self.churn[idx].clone();
        match ev.kind {
            ChurnKind::Crash | ChurnKind::Leave => self.depart(&ev),
            ChurnKind::Join | ChurnKind::Rejoin => self.arrive(&ev),
        }
    }

    /// A node departs.  Graceful leaves hand conserved state off first
    /// (`Strategy::on_leave`); crashes lose their in-flight step and the
    /// runtime reclaims protocol invariants on the dead node's behalf.
    fn depart(&mut self, ev: &ChurnEvent) -> Result<()> {
        let node = ev.node;
        if !self.membership.is_alive(node) {
            return Ok(()); // already gone — schedule no-op
        }
        if ev.kind == ChurnKind::Leave {
            // clean handoff before going dark: GoSGD ships its full
            // push-sum weight to an alive neighbor
            let peer = self.sample_alive_peer(node);
            let step = self.nodes[node].step;
            let mut ctx = ProtoCtx {
                node,
                step,
                params: self.params[node].as_mut_slice(),
                arena: &mut self.arena,
                outbox: &mut self.outbox,
            };
            self.strategy.on_leave(&mut ctx, peer)?;
            self.flush_outbox();
        }
        self.membership.kill(node);
        self.nodes[node].gen = self.nodes[node].gen.wrapping_add(1); // cancel pending events
        // the roster for every epoch this node had not yet completed
        // shrinks by one (a quota hitting its done-count completes it)
        let cur = self.nodes[node].epoch;
        for e in cur..self.cfg.epochs {
            self.epoch_quota[e] -= 1;
            self.maybe_eval(e);
        }
        // strategy-global reclamation (GoSGD: the departed node's held
        // weight folds into the lowest-indexed survivor).  Under the fd
        // plane the oracle stays silent: reclamation waits until some
        // survivor *confirms* the death (confirm_dead), which is the
        // whole point of gossip-native detection.
        if self.fd_active {
            self.crash_time[node] = self.now;
        } else {
            self.strategy.on_peer_lost(node, self.membership.alive_flags());
        }
        // a bootstrap this node was waiting on can never complete
        self.pending_bootstrap.retain(|&(j, _, _)| j != node);
        // the dead node's parked mailbox: messages addressed to it carry
        // conserved state (share weight) — reclaim, then recycle (with
        // no survivors the weight parks on the dead slot, keeping the
        // terminal mass invariant exact)
        let fallback = self.membership.first_alive().unwrap_or(node);
        let mut mb = std::mem::take(&mut self.nodes[node].mailbox);
        for m in mb.drain(..) {
            self.strategy.on_drop_to_lost(&m.payload, fallback);
            self.recycle_msg(m);
        }
        self.nodes[node].mailbox = mb; // keep the capacity
        // roll back parked messages FROM the departed node wherever the
        // strategy refuses them (Elastic Gossip: the pending pair term
        // whose mirror can never run).  Under fd this sweep runs per
        // observer at confirmation time instead (confirm_dead).
        if !self.fd_active {
            for j in 0..self.nodes.len() {
                if j == node || !self.membership.is_alive(j) {
                    continue;
                }
                let mut mb = std::mem::take(&mut self.nodes[j].mailbox);
                let mut k = 0;
                while k < mb.len() {
                    if mb[k].src == node && !self.strategy.deliver_from_lost(&mb[k].payload) {
                        let m = mb.swap_remove(k);
                        self.mreport.rolled_back_msgs += 1;
                        self.recycle_msg(m);
                    } else {
                        k += 1;
                    }
                }
                self.nodes[j].mailbox = mb;
            }
        }
        self.trace.instant(
            self.now,
            Ev {
                node,
                kind: Kind::Churn,
                class: CLASS_CHURN,
                seq: self.membership.version(),
                a: 0,
                b: self.membership.n_alive() as u64,
            },
        );
        self.mreport.applied.push(AppliedChurn {
            time: ev.time,
            kind: ev.kind,
            node,
            alive_after: self.membership.n_alive(),
            version: self.membership.version(),
        });
        Ok(())
    }

    /// A node joins (fresh slot from initial parameters) or rejoins
    /// (restored from its last epoch-boundary checkpoint), then
    /// bootstraps by pulling a live peer's parameters before its first
    /// step.
    fn arrive(&mut self, ev: &ChurnEvent) -> Result<()> {
        let node = ev.node;
        if self.membership.is_alive(node) {
            return Ok(()); // already present — schedule no-op
        }
        self.membership.revive(node);
        self.nodes[node].gen = self.nodes[node].gen.wrapping_add(1);
        let restored = ev.kind == ChurnKind::Rejoin && self.ckpt[node].is_some();
        if restored {
            let c = self.ckpt[node].as_ref().unwrap();
            self.params[node].copy_from_slice(&c.params);
            self.nodes[node].step = c.step;
            self.nodes[node].epoch = c.epoch;
            let epoch = c.epoch.min(self.cfg.epochs.saturating_sub(1));
            let o = &mut self.nodes[node].optim;
            o.restore_velocity(&c.velocity);
            o.start_epoch(epoch);
        } else {
            // fresh join (or a rejoin that never reached a checkpoint):
            // initial parameters, step 0, fresh optimizer state
            self.params[node].copy_from_slice(&self.init_params);
            self.nodes[node].step = 0;
            self.nodes[node].epoch = 0;
            self.nodes[node].optim =
                Optimizer::new(self.cfg.optimizer, self.cfg.lr.clone(), self.init_params.len());
        }
        let cur = self.nodes[node].epoch;
        for e in cur..self.cfg.epochs {
            self.epoch_quota[e] += 1;
        }
        self.strategy.on_join_bootstrap(node);
        if self.fd_active {
            // the rejoiner announces itself with a fresh (strictly
            // higher) incarnation so stale pre-crash rumors can never
            // resurrect or re-kill it; its view restarts from the
            // oracle roster it bootstraps against, and any rows
            // survivors adopted from its shard go back to it
            self.crash_time[node] = f64::NAN;
            self.reclaimed[node] = false;
            let mut k = 0;
            while k < self.adopted_rows.len() {
                let (dead, adopter, row) = self.adopted_rows[k];
                if dead == node {
                    self.nodes[adopter].cursor.evict(&[row]);
                    self.adopted_rows.swap_remove(k);
                } else {
                    k += 1;
                }
            }
            let inc = self.fd[node].view.incarnation(node).wrapping_add(1).max(1);
            self.fd[node] = FdState::new(self.w, 0);
            self.fd[node].view = LocalView::from_flags(self.membership.alive_flags());
            self.fd[node].view.note_alive(node, inc);
            self.enqueue_rumor(node, Rumor { kind: Rumor::ALIVE, node: node as u16, inc });
            self.queue
                .sched(self.now + self.cfg.fd.period_s, CLASS_FD, Event::FdTick { node });
        }
        self.trace.instant(
            self.now,
            Ev {
                node,
                kind: Kind::Churn,
                class: CLASS_CHURN,
                seq: self.membership.version(),
                a: 1,
                b: self.membership.n_alive() as u64,
            },
        );
        self.mreport.applied.push(AppliedChurn {
            time: ev.time,
            kind: ev.kind,
            node,
            alive_after: self.membership.n_alive(),
            version: self.membership.version(),
        });
        self.begin_bootstrap(node)
    }

    /// Send the joiner's bootstrap pull to an alive donor; a joiner with
    /// no live neighborhood free-runs from whatever state it has.
    fn begin_bootstrap(&mut self, joiner: usize) -> Result<()> {
        match self.sample_alive_peer(joiner) {
            Some(donor) => {
                let joiner_gen = self.nodes[joiner].gen;
                self.outbox.push(NetMsg {
                    src: joiner,
                    dst: donor,
                    picker: joiner,
                    sent_step: self.nodes[joiner].step,
                    payload: MsgPayload::JoinRequest { joiner_gen },
                    wire: None,
                    gen: 0,
                    rumors: RumorPack::empty(),
                    wire_seq: 0,
                });
                self.flush_outbox();
                Ok(())
            }
            None => self.start_or_finish(joiner),
        }
    }

    fn on_eval(&mut self, e: usize) -> Result<()> {
        // survivor accuracy: only alive replicas are evaluated, and the
        // aggregate model averages survivors only.  On a fixed roster the
        // alive list is 0..W, so this is the PR-2 evaluation verbatim.
        let alive: Vec<usize> = self.membership.alive_list().to_vec();
        if alive.is_empty() {
            // a same-instant crash emptied the cluster between this
            // tick's scheduling and its pop — nobody left to evaluate
            return Ok(());
        }
        let ew = Stopwatch::start();
        let mut worker_acc = Vec::with_capacity(alive.len());
        let mut worker_loss = Vec::with_capacity(alive.len());
        for &i in &alive {
            let (l, a) = evaluate(self.engine.as_mut(), &self.params[i], &self.val)?;
            worker_acc.push(a);
            worker_loss.push(l);
        }
        let avg = average_alive(&self.params, &alive);
        let (_, agg) = evaluate(self.engine.as_mut(), &avg, &self.val)?;
        self.eval_time += ew.elapsed_s();
        self.trace.instant(
            self.now,
            Ev {
                node: 0,
                kind: Kind::Eval,
                class: CLASS_EVAL,
                seq: e as u64,
                a: e as u64,
                b: alive.len() as u64,
            },
        );
        let s0 = e * self.steps_per_epoch as usize;
        let mut epoch_loss = 0.0f64;
        for t in s0..s0 + self.steps_per_epoch as usize {
            epoch_loss += self.loss_acc[t];
        }
        self.mreport.per_epoch_alive.push(alive.len());
        if self.fd_active {
            // mean fraction of slots where a survivor's local view
            // disagrees with the oracle, sampled at each epoch boundary
            let flags = self.membership.alive_flags().to_vec();
            let d = alive
                .iter()
                .map(|&i| self.fd[i].view.divergence(&flags))
                .sum::<f64>()
                / alive.len() as f64;
            self.fd_report.view_divergence.push(d);
        }
        self.curve.push(EvalPoint {
            epoch: e + 1,
            step: (e as u64 + 1) * self.steps_per_epoch,
            alive: alive.len(),
            worker_acc,
            worker_loss,
            train_loss: (epoch_loss / self.epoch_contrib[e] as f64) as f32,
            aggregate_acc: agg,
            wall_s: self.watch.elapsed_s(),
        });
        Ok(())
    }
}

/// The canonical synthetic straggler-study experiment + engine factory —
/// shared by `examples/async_straggler.rs` and `repro async-train` so the
/// two entry points run the *same* study (one place to change its
/// defaults, one engine-seed convention).
pub fn study_setup(
    method: Method,
    workers: usize,
    prob: f64,
    epochs: usize,
    seed: u64,
) -> (ExperimentConfig, SyntheticSpec) {
    let dim = 32usize;
    let cfg = ExperimentConfig {
        label: format!("async-{}", method.short_label()),
        method,
        workers,
        schedule: CommSchedule::Probability(prob),
        optimizer: OptimKind::Nag { momentum: 0.9 },
        lr: LrSchedule::Const(0.05),
        engine: EngineKind::Synthetic { dim },
        dataset: DatasetKind::SyntheticVectors { dim: 8 },
        n_train: 256 * workers,
        n_val: 128,
        n_test: 128,
        effective_batch: 8 * workers,
        epochs,
        seed,
        partition: crate::data::Partition::Iid,
        topology: crate::topology::Topology::Full,
        eval_every: 1,
        artifact_dir: "artifacts".into(),
        codec: crate::comm::codec::CodecKind::Identity,
        churn: crate::membership::ChurnSpec::none(),
        faults: crate::membership::FaultSpec::none(),
        fd: crate::membership::FdSpec::none(),
        shards: 1,
        coalesce: false,
        transport: crate::comm::transport::TransportKind::InProc,
        trace: crate::trace::TraceSpec::off(),
    };
    let spec = SyntheticSpec::for_cfg(&cfg).expect("study config uses the synthetic engine");
    (cfg, spec)
}

/// Mean of the alive replicas (the survivor "aggregate" model).  With
/// every node alive this is exactly `coordinator::average_params` —
/// same refs, same kernel, bit-identical.
fn average_alive(params: &[Vec<f32>], alive: &[usize]) -> Vec<f32> {
    let refs: Vec<&[f32]> = alive.iter().map(|&i| params[i].as_slice()).collect();
    let mut out = vec![0.0f32; params[0].len()];
    crate::tensor::mean_of(&refs, &mut out);
    out
}

/// Run one experiment on the event-driven asynchronous runtime.
///
/// Supports the pairwise gossip family (Elastic Gossip, Gossiping SGD
/// push/pull, GoSGD) plus the no-communication baseline; the barrier
/// methods (All-reduce, EASGD) are inherently synchronous and are
/// rejected with an error.
pub fn run_async(
    cfg: &ExperimentConfig,
    factory: &dyn EngineFactory,
    sim: &AsyncSimCfg,
) -> Result<AsyncRunReport> {
    let w0 = cfg.workers;
    anyhow::ensure!(w0 >= 1, "need at least one worker");
    anyhow::ensure!(
        sim.speeds.len() == w0,
        "sim has {} speeds for {} workers",
        sim.speeds.len(),
        w0
    );
    // --- membership: materialize the churn schedule ----------------------
    // `%` times resolve against the fastest node's expected completion —
    // "mid-run" means mid-run for every node.  A `join` may introduce
    // slots beyond the initial roster; every table below is sized by the
    // full slot count `w`.  With an empty schedule w == cfg.workers and
    // every consumption pattern is byte-identical to the fixed roster.
    let churn_active = !cfg.churn.is_empty();
    let est_horizon = cfg.total_steps() as f64
        * sim
            .speeds
            .iter()
            .map(|s| s.mean_s * s.slow_factor)
            .fold(f64::INFINITY, f64::min)
            .max(1e-9);
    let churn = cfg.churn.materialize(w0, est_horizon)?;
    // failure-detection plane and link-fault plan: both default to empty,
    // and every consumption below is gated so an empty spec is
    // byte-identical to the oracle-membership runtime
    let fd_active = !cfg.fd.is_empty();
    let faults_active = !cfg.faults.is_empty();
    let fault_plan = cfg.faults.materialize(est_horizon);
    for e in &churn {
        // only a `join` may introduce a brand-new slot; every other
        // event must target the existing roster (a typo'd node id would
        // otherwise silently enlarge the cluster)
        anyhow::ensure!(
            e.kind == ChurnKind::Join || e.node < w0,
            "churn event {}@{}:{} targets a node outside the initial roster of {w0}",
            e.kind.label(),
            e.time,
            e.node
        );
    }
    let w = churn
        .iter()
        .map(|e| e.node + 1)
        .max()
        .unwrap_or(0)
        .max(w0);
    // brand-new slots extend the gossip graph: only the fully-connected
    // topology absorbs extra nodes without changing the existing wiring
    // (ring/torus/randreg define a fixed geometry over exactly n slots —
    // rebuilding them over w > W would rewire the whole run, or panic
    // for a torus whose width no longer divides n)
    anyhow::ensure!(
        w == w0 || matches!(cfg.topology, crate::topology::Topology::Full),
        "join of brand-new node id {} requires topology=full; {:?} has a fixed \
         geometric roster of {w0}",
        w - 1,
        cfg.topology
    );
    let root_rng = Rng::new(cfg.seed);

    // --- data (identical stream consumption to the sync coordinator) ----
    let full = build_dataset_pub(cfg, &mut root_rng.stream("datagen"))?;
    let (train, val, test) = full.split(
        cfg.n_train.min(full.len()),
        cfg.n_val,
        cfg.n_test,
        &mut root_rng.stream("split"),
    );
    let shards = cfg.partition.assign(&train, w, &mut root_rng.stream("partition"));
    // under fd, a confirmed death re-deals the dead node's *original*
    // shard to survivors — keep a copy before the cursors consume it
    let shards0: Vec<Vec<usize>> = if fd_active { shards.clone() } else { Vec::new() };

    // --- engine + state --------------------------------------------------
    let mut engine = factory.build().context("building engine")?;
    let flat = engine.flat_size();
    let b = engine.train_batch();
    anyhow::ensure!(
        b == cfg.per_worker_batch(),
        "engine batch {b} != per-worker batch {}",
        cfg.per_worker_batch()
    );
    let init = engine.initial_params()?;
    anyhow::ensure!(init.len() == flat);
    // strategy state is sized by the *initial* roster: GoSGD's push-sum
    // weights start at 1/W over the live nodes, and `on_join_bootstrap`
    // extends (at weight 0) when a join activates a fresh slot
    let strategy = cfg.method.build(w0, flat);
    anyhow::ensure!(
        strategy.async_capable(),
        "method {:?} has no message-level protocol: the event-driven runtime \
         supports the pairwise gossip family (elastic-gossip, gossip-pull, \
         gossip-push, gosgd) and no-comm; All-reduce/EASGD are barrier-bound \
         by construction — use the synchronous coordinator",
        strategy.name()
    );
    let init_params = init.clone();
    let params: Vec<Vec<f32>> = vec![init; w];
    let grads: Vec<Vec<f32>> = vec![vec![0.0; flat]; w];
    let mut arena = ScratchArena::new();
    arena.ensure(w, flat);
    let codec = cfg.codec.build();
    // joiner slots beyond the physical roster reuse the initial workers'
    // speed profiles (a fresh edge device is drawn from the same fleet)
    let mut speeds = sim.speeds.clone();
    while speeds.len() < w {
        let profile = speeds[speeds.len() % w0].clone();
        speeds.push(profile);
    }

    // --- pre-drawn decision tables ---------------------------------------
    // the sequential coordinator consumes "schedule" (mask per step, worker
    // order), "gossip" (one peer draw per communicating worker, worker
    // order, via the cached adjacency) and "dropout" ((step, worker) order)
    // — replicated here verbatim so both regimes see the same decisions
    let steps_per_epoch = cfg.steps_per_epoch();
    let total_steps = cfg.total_steps();
    let ts = total_steps as usize;
    let mut sched_rng = root_rng.stream("schedule");
    let mut gossip_rng = root_rng.stream("gossip");
    let mut seed_rng = root_rng.stream("dropout");
    let mut masks: Vec<bool> = Vec::with_capacity(ts * w);
    let mut picks: Vec<u32> = vec![u32::MAX; ts * w];
    let mut mask_t: Vec<bool> = Vec::with_capacity(w);
    let pairwise = cfg.method.is_pairwise_gossip();
    let topo_cache = arena.topo_cache_mut();
    topo_cache.ensure(&cfg.topology, w);
    for t in 0..ts {
        decide_schedule_into(&cfg.method, cfg.schedule, t as u64, w, &mut sched_rng, &mut mask_t);
        masks.extend_from_slice(&mask_t);
        // fixed roster only: the pick tables cannot anticipate
        // membership, so under churn (or a local-view fd plane) peers
        // are sampled live at send time from the same "gossip" stream
        if pairwise && !churn_active && !fd_active {
            for (i, &firing) in mask_t.iter().enumerate() {
                if firing {
                    picks[t * w + i] = topo_cache
                        .sample_peer(i, &mut gossip_rng)
                        .map(|p| p as u32)
                        .unwrap_or(u32::MAX);
                }
            }
        }
    }
    let seeds: Vec<i32> = (0..ts * w).map(|_| seed_rng.next_u64() as i32).collect();

    // --- nodes ------------------------------------------------------------
    let speed_root = Rng::new(sim.speed_seed);
    let nodes: Vec<Node> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| Node {
            cursor: BatchCursor::new(shard, root_rng.stream(&format!("batches{i}"))),
            optim: Optimizer::new(cfg.optimizer, cfg.lr.clone(), flat),
            xbuf: BatchXOwned::F32(Vec::new()),
            ybuf: Vec::new(),
            batch_idx: Vec::new(),
            mailbox: Vec::new(),
            step: 0,
            epoch: 0,
            loss: 0.0,
            busy_s: 0.0,
            finish_s: 0.0,
            speed_rng: speed_root.stream(&format!("speed{i}")),
            gen: 0,
            retired: false,
        })
        .collect();

    // event-queue shards: node i lives on shard i % nshards.  More
    // shards than nodes would leave heaps permanently empty.
    let nshards = cfg.shards.max(1).min(w.max(1));

    // real-socket splice: loopback-udp binds one 127.0.0.1 endpoint per
    // node and routes every scheduled delivery's bytes through an actual
    // datagram (the conformance mode).  The free-running multi-process
    // `udp` transport has its own driver (`repro net-train`) — inside the
    // virtual-clock simulator it is a config error, not a silent fallback.
    let wire_plane = match cfg.transport {
        crate::comm::transport::TransportKind::InProc => None,
        crate::comm::transport::TransportKind::LoopbackUdp => {
            Some(net::WirePlane::loopback(w).context("binding loopback wire plane")?)
        }
        crate::comm::transport::TransportKind::Udp => anyhow::bail!(
            "transport 'udp' is the multi-process wire (`repro net-train`); \
             the in-process runtime supports 'inproc' or 'loopback-udp'"
        ),
    };

    let mut eng = AsyncEngine {
        cfg,
        speeds,
        engine,
        train,
        val,
        test,
        params,
        grads,
        strategy,
        fabric: Fabric::new(w + 1, sim.link),
        arena,
        codec,
        nodes,
        masks,
        picks,
        seeds,
        loss_acc: vec![0.0; ts],
        epoch_done: vec![0; cfg.epochs],
        epoch_quota: vec![w0; cfg.epochs],
        eval_emitted: vec![false; cfg.epochs],
        epoch_contrib: vec![0; cfg.epochs],
        membership: MemberView::new(w, w0),
        churn,
        churn_active,
        gossip_rng,
        init_params,
        ckpt: vec![None; w],
        mreport: MembershipReport::default(),
        pending_bootstrap: Vec::new(),
        fd_active,
        // every access is fd-gated, so with the detector off the O(w²)
        // view table is never built — at 10⁵+ nodes it would dominate
        // the footprint
        fd: if fd_active { (0..w).map(|_| FdState::new(w, w0)).collect() } else { Vec::new() },
        fd_rng: root_rng.stream("fdprobe"),
        probe_ctr: 0,
        crash_time: vec![f64::NAN; w],
        reclaimed: vec![false; w],
        shards0,
        adopted_rows: Vec::new(),
        fd_report: FdReport::default(),
        faults_active,
        fault_plan,
        wire_seq: 0,
        queue: ShardedQueue::new(nshards),
        router: None,
        grad_pending: (0..w).map(|_| None).collect(),
        grad_overflow: Vec::new(),
        events: 0,
        sent_msgs: 0,
        cross_shard_msgs: 0,
        frame_buf: Vec::new(),
        outbox: Vec::new(),
        staleness: StalenessHist::new(),
        curve: Curve::new(cfg.label.clone()),
        w,
        b,
        steps_per_epoch,
        total_steps,
        now: 0.0,
        finished: 0,
        watch: Stopwatch::start(),
        eval_time: 0.0,
        wire: wire_plane,
        trace: Trace::from_spec(&cfg.trace, &cfg.label),
    };

    // --- event loop -------------------------------------------------------
    // the per-link/per-sender byte ledgers are pure observability (no
    // trajectory reads them): at fleet scale their O(w·degree) maps cost
    // more than the nodes, so they switch off past this roster size
    if w > 4096 {
        eng.fabric.set_link_detail(false);
    }
    if nshards > 1 {
        // gradient compute fans out to one worker thread per shard; all
        // event handling (and every rng/f64 fold) stays on this thread,
        // which is what keeps the trajectory bit-identical to shards:1
        std::thread::scope(|scope| {
            eng.router = Some(GradRouter::spawn(scope, nshards, factory));
            let r = eng.drive();
            // drop the job senders so the workers exit before the scope
            // joins them (even on error paths)
            eng.router = None;
            r
        })?;
    } else {
        eng.drive()?;
    }
    debug_assert_eq!(eng.queue.len(), 0, "drive returned with events still queued");
    debug_assert!(
        churn_active || total_steps == 0 || eng.finished == w,
        "every node must run to completion on a fixed roster"
    );
    debug_assert_eq!(eng.fabric.in_flight(), 0, "heap drained with messages in flight");

    // Late mail: a message delivered after its receiver's final boundary
    // is still parked in the mailbox.  Apply it now (same rules as every
    // mid-run boundary) — final parameters incorporate every exchange,
    // and GoSGD's weight mass (partly carried by such messages) returns
    // to exactly 1.  In lockstep every mailbox is already empty here, so
    // this pass cannot perturb the equivalence.  (Departed nodes'
    // mailboxes were reclaimed by the death sweep.)
    for i in 0..w {
        if eng.membership.is_alive(i) {
            eng.apply_mailbox(i)?;
        }
    }
    debug_assert!(eng.outbox.is_empty(), "boundary applies must not send");

    // tear down the wire plane (if any): join the pump threads, surface
    // any deferred socket error, and fold the endpoints' malformed-frame
    // counts into the traffic ledger before the report is taken
    if let Some(plane) = eng.wire.take() {
        let ws = plane.finish()?;
        eng.fabric.note_malformed(ws.malformed_frames);
    }

    // --- final report -----------------------------------------------------
    // survivor accuracy: rank0 is the lowest-indexed alive node, the
    // aggregate averages survivors (on a fixed roster: node 0 / everyone,
    // exactly the PR-2 report)
    let rank0_node = eng.membership.first_alive().unwrap_or(0);
    let final_alive: Vec<usize> = eng.membership.alive_list().to_vec();
    if fd_active {
        eng.mreport.fd = Some(std::mem::take(&mut eng.fd_report));
    }
    let (_, rank0) = evaluate(eng.engine.as_mut(), &eng.params[rank0_node], &eng.test)?;
    let avg = if final_alive.is_empty() {
        average_params(&eng.params)
    } else {
        average_alive(&eng.params, &final_alive)
    };
    let (_, agg) = evaluate(eng.engine.as_mut(), &avg, &eng.test)?;
    eng.mreport.final_alive = final_alive;
    let checkpoint = if churn_active {
        Some(AsyncCheckpoint {
            label: cfg.label.clone(),
            seed: cfg.seed,
            flat_size: flat,
            nodes: eng.ckpt,
        })
    } else {
        None
    };
    let traffic = eng.fabric.report();
    let busy_s: Vec<f64> = eng.nodes.iter().map(|n| n.busy_s).collect();
    let finish_s: Vec<f64> = eng.nodes.iter().map(|n| n.finish_s).collect();
    let virtual_s = finish_s.iter().cloned().fold(0.0, f64::max);
    let trace_json = eng.trace.to_chrome_json();
    eng.trace
        .dump_if_requested()
        .context("writing flight-recorder dump")?;
    let metrics = RunMetrics::from_traffic(
        eng.curve,
        (rank0, agg),
        total_steps,
        &traffic,
        eng.watch.elapsed_s() - eng.eval_time,
        eng.eval_time,
    );
    Ok(AsyncRunReport {
        report: RunReport {
            label: cfg.label.clone(),
            rank0_accuracy: rank0,
            aggregate_accuracy: agg,
            metrics,
        },
        final_params: eng.params,
        staleness: eng.staleness,
        busy_s,
        finish_s,
        virtual_s,
        peak_in_flight: eng.fabric.peak_in_flight(),
        events: eng.events,
        cross_shard_frac: if eng.sent_msgs == 0 {
            0.0
        } else {
            eng.cross_shard_msgs as f64 / eng.sent_msgs as f64
        },
        push_sum_mass: eng.strategy.push_sum_mass(),
        membership: eng.mreport,
        checkpoint,
        trace_json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Method;
    use crate::coordinator::tests::tiny_cfg;
    use crate::coordinator::Coordinator;
    use crate::runtime::SyntheticSpec;

    fn spec(cfg: &ExperimentConfig) -> SyntheticSpec {
        SyntheticSpec::for_cfg(cfg).unwrap()
    }

    /// Run the sequential coordinator and capture the final per-worker
    /// parameters through the step observer.
    fn run_sequential(cfg: &ExperimentConfig) -> (RunReport, Vec<Vec<f32>>) {
        let s = spec(cfg);
        let last = cfg.total_steps() - 1;
        let mut final_params: Vec<Vec<f32>> = Vec::new();
        let report = {
            let mut c = Coordinator::new(cfg, &s);
            c.on_step = Some(Box::new(|step, p: &[Vec<f32>]| {
                if step == last {
                    final_params = p.to_vec();
                }
            }));
            c.run().unwrap()
        };
        (report, final_params)
    }

    #[test]
    fn lockstep_is_bit_identical_to_sequential_for_all_gossip_methods() {
        for method in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
            Method::NoComm,
        ] {
            let cfg = tiny_cfg(method.clone(), 4);
            let (seq, seq_params) = run_sequential(&cfg);
            let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4))
                .unwrap_or_else(|e| panic!("{method:?}: {e}"));
            // parameter trajectory: final state must match bit for bit
            assert_eq!(
                asy.final_params, seq_params,
                "{method:?}: async lockstep diverged from the synchronous round"
            );
            // and the observable metrics line up
            assert_eq!(asy.report.rank0_accuracy, seq.rank0_accuracy, "{method:?} rank0");
            assert_eq!(
                asy.report.aggregate_accuracy, seq.aggregate_accuracy,
                "{method:?} aggregate"
            );
            let ls: Vec<f32> = seq.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            let la: Vec<f32> = asy.report.metrics.curve.points.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, la, "{method:?} loss curve");
            // zero latency + lockstep => nothing is ever stale
            assert_eq!(asy.staleness.max(), 0, "{method:?} saw staleness in lockstep");
            if matches!(method, Method::ElasticGossip { .. } | Method::GoSgd) {
                assert!(asy.staleness.count() > 0, "{method:?}: no exchanges recorded");
            }
        }
    }

    #[test]
    fn lockstep_elastic_matches_sync_traffic() {
        // elastic: two parameter-sized messages per edge, same as the
        // synchronous round's accounting
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let (seq, _) = run_sequential(&cfg);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(asy.report.metrics.comm_bytes, seq.metrics.comm_bytes);
        assert_eq!(asy.report.metrics.comm_messages, seq.metrics.comm_messages);
    }

    #[test]
    fn straggler_reports_real_staleness_and_full_utilization() {
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.schedule = crate::config::CommSchedule::Probability(0.5);
        let mut sim = AsyncSimCfg::straggler(4, 0.05, 0.0, 4.0);
        sim.link = LinkModel::zero(); // isolate compute skew
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        // exchanges between the 4x straggler and fast workers observe
        // real step skew
        assert!(asy.staleness.count() > 0);
        assert!(
            asy.staleness.mean() > 0.5,
            "expected nonzero staleness, mean {}",
            asy.staleness.mean()
        );
        assert!(asy.staleness.max() >= 2);
        // and nobody ever waits: every node is busy until its own finish
        assert!(
            asy.mean_self_utilization() >= 0.9,
            "utilization {}",
            asy.mean_self_utilization()
        );
        // ... while the synchronous barrier degrades under the same
        // speeds (§2.1.2's asynchrony argument, end to end)
        let sync_sim = crate::sim::simulate_synchronous(
            &sim.speeds,
            cfg.total_steps(),
            0,
            sim.link,
            sim.speed_seed,
        );
        assert!(
            sync_sim.mean_self_utilization() < 0.7,
            "barriered baseline should collapse under a 4x straggler, got {}",
            sync_sim.mean_self_utilization()
        );
        // training still works
        let pts = &asy.report.metrics.curve.points;
        assert!(pts.last().unwrap().train_loss < pts.first().unwrap().train_loss);
    }

    #[test]
    fn straggler_run_is_deterministic() {
        let cfg = tiny_cfg(Method::GossipingSgdPush, 4);
        let sim = AsyncSimCfg::straggler(4, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.staleness, b.staleness, "staleness histogram must reproduce");
        assert_eq!(a.report.metrics.comm_bytes, b.report.metrics.comm_bytes);
        assert_eq!(a.virtual_s, b.virtual_s);
    }

    #[test]
    fn gosgd_conserves_mass_through_in_flight_messages() {
        let cfg = tiny_cfg(Method::GoSgd, 6);
        // slow link: shares spend real time in flight mid-run
        let mut sim = AsyncSimCfg::straggler(6, 0.01, 0.2, 4.0);
        sim.link = LinkModel { latency_s: 0.02, bandwidth_bps: 1e6 };
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let mass = asy.push_sum_mass.expect("gosgd exposes its mass");
        assert!((mass - 1.0).abs() < 1e-9, "push-sum mass drifted: {mass}");
        assert!(asy.staleness.mean() > 0.0, "slow link must show staleness");
    }

    #[test]
    fn barrier_methods_are_rejected() {
        for method in [
            Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring },
            Method::Easgd { alpha: 0.2 },
        ] {
            let cfg = tiny_cfg(method, 3);
            let err = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(3)).unwrap_err();
            assert!(
                err.to_string().contains("message-level protocol"),
                "unexpected error: {err}"
            );
        }
    }

    #[test]
    fn nonzero_latency_still_trains_and_is_deterministic() {
        let cfg = tiny_cfg(Method::GossipingSgdPull, 4);
        let mut sim = AsyncSimCfg::straggler(4, 0.01, 0.0, 1.0);
        sim.link = LinkModel { latency_s: 0.005, bandwidth_bps: 1e9 };
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        let pts = &a.report.metrics.curve.points;
        assert!(pts.last().unwrap().train_loss < pts.first().unwrap().train_loss);
        assert!(a.peak_in_flight > 0);
    }

    /// The async message path — send hook, outbox encode, delivery
    /// decode, reply, boundary apply, buffer recycling — driven exactly
    /// as the engine drives it, with each codec enabled: after warm-up,
    /// every encode/decode scratch buffer must come from the arena and
    /// the codec's persistent state, never the heap (the
    /// `*_allocation_free_after_warmup` discipline extended to the wire
    /// layer).  The disabled trace facade is driven on every hop of the
    /// same loop: `trace: off` must add nothing to the fingerprint —
    /// the zero-overhead-when-off claim, asserted where it matters.
    #[test]
    fn async_message_path_is_allocation_free_after_warmup_for_every_codec() {
        use crate::algos::gossip::ElasticGossipStrategy;
        use crate::algos::{NetMsg, ProtoCtx};
        use crate::comm::codec::CodecKind;

        let mut trace = Trace::off();
        assert!(!trace.is_on());
        let flat = 300usize;
        for kind in [
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 64 },
            CodecKind::Q4 { chunk: 64 },
            CodecKind::TopK { frac: 0.1 },
        ] {
            let mut codec = kind.build();
            let mut arena = ScratchArena::new();
            arena.ensure(2, flat);
            let mut strategy = ElasticGossipStrategy::new(0.4);
            let mut params: Vec<Vec<f32>> = (0..2).map(|i| vec![i as f32 * 0.1 + 0.01; flat]).collect();
            let mut outbox: Vec<NetMsg> = Vec::new();
            let mut mailbox: Vec<NetMsg> = Vec::new();
            let mut one: Vec<NetMsg> = Vec::with_capacity(2);

            let mut fp = 0u64;
            for round in 0..33u64 {
                let step = round;
                // node 0's schedule fires toward node 1
                {
                    let mut ctx = ProtoCtx {
                        node: 0,
                        step,
                        params: params[0].as_mut_slice(),
                        arena: &mut arena,
                        outbox: &mut outbox,
                    };
                    strategy.on_send_due(&mut ctx, 1).unwrap();
                }
                // event loop: encode on flush, decode at delivery, route
                // replies back through the same path
                while let Some(mut msg) = outbox.pop() {
                    if msg.wire.is_none() {
                        if let Some(p) = msg.payload.params() {
                            let mut buf = arena.rent_bytes();
                            codec.encode_into(msg.src, p, &mut buf);
                            trace.instant(
                                step as f64,
                                Ev {
                                    node: msg.src,
                                    kind: Kind::Encode,
                                    class: 0,
                                    seq: step,
                                    a: p.len() as u64,
                                    b: buf.len() as u64,
                                },
                            );
                            msg.wire = Some(buf);
                        }
                    }
                    let dst = msg.dst;
                    if let Some(wire) = msg.wire.take() {
                        if let Some(p) = msg.payload.params_mut() {
                            if codec.is_overlay() {
                                p.clear();
                                p.extend_from_slice(&params[dst]);
                            }
                            codec.decode_into(&wire, p).unwrap();
                        }
                        trace.instant(
                            step as f64,
                            Ev {
                                node: dst,
                                kind: Kind::Decode,
                                class: 0,
                                seq: step,
                                a: wire.len() as u64,
                                b: 0,
                            },
                        );
                        arena.return_bytes(wire);
                    }
                    let retained = {
                        let mut ctx = ProtoCtx {
                            node: dst,
                            step,
                            params: params[dst].as_mut_slice(),
                            arena: &mut arena,
                            outbox: &mut outbox,
                        };
                        strategy.on_message(&mut ctx, msg).unwrap()
                    };
                    if let Some(m) = retained {
                        mailbox.push(m);
                    }
                }
                // boundary applies + payload-buffer recycling
                while let Some(m) = mailbox.pop() {
                    let node = m.dst;
                    arena.snapshot(node, &params[node]);
                    one.push(m);
                    {
                        let mut ctx = ProtoCtx {
                            node,
                            step,
                            params: params[node].as_mut_slice(),
                            arena: &mut arena,
                            outbox: &mut outbox,
                        };
                        strategy.on_boundary_apply(&mut ctx, &mut one).unwrap();
                    }
                    for m in one.drain(..) {
                        if let Some(buf) = m.payload.take_params() {
                            arena.return_msg(buf);
                        }
                    }
                }
                if round == 2 {
                    fp = arena.footprint() ^ codec.footprint();
                } else if round > 2 {
                    assert_eq!(
                        arena.footprint() ^ codec.footprint(),
                        fp,
                        "{}: message path reallocated at round {round}",
                        codec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn trace_on_is_inert_and_same_seed_traces_are_byte_identical() {
        // (a) trace-off runs attach no JSON; (b) turning the recorder on
        // must not move the trajectory; (c) two same-seed traced runs
        // emit byte-identical Chrome trace JSON that validates against
        // the schema and contains the span/instant kinds the engine emits
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let sim = AsyncSimCfg::straggler(4, 0.01, 0.1, 3.0);
        let off = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert!(off.trace_json.is_none());
        cfg.trace = crate::trace::TraceSpec::parse("on,ring:512").unwrap();
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(off.final_params, a.final_params, "tracing moved the trajectory");
        let ja = a.trace_json.expect("traced run attaches JSON");
        let jb = b.trace_json.expect("traced run attaches JSON");
        assert_eq!(ja, jb, "same-seed traces must be byte-identical");
        let n = crate::trace::validate_chrome_trace(&ja).unwrap();
        assert!(n > 0, "traced run recorded no events");
        for name in ["step", "pop", "eval"] {
            assert!(ja.contains(&format!("\"name\":\"{name}\"")), "trace lacks {name} events");
        }
    }

    #[test]
    fn identity_codec_wire_bytes_equal_raw_and_trajectory_is_unchanged() {
        // the codec layer is in the path for every run; with the default
        // identity codec it must be observationally invisible
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(asy.report.metrics.wire_bytes, asy.report.metrics.comm_bytes);
        let (_, seq_params) = run_sequential(&cfg);
        assert_eq!(asy.final_params, seq_params);
    }

    #[test]
    fn lossy_codecs_shrink_wire_bytes_and_stay_deterministic() {
        use crate::comm::codec::CodecKind;
        for (kind, min_shrink) in [
            // tiny model (flat = 12): q8 → one 20-byte chunk vs 48 raw;
            // topk:0.25 → 8 + 8*3 = 32 bytes vs 48 raw
            (CodecKind::Q8 { chunk: 4096 }, 2.0),
            // q4 → one 8-byte header + ceil(12/2) packed = 14 vs 48 raw
            (CodecKind::Q4 { chunk: 4096 }, 3.0),
            (CodecKind::TopK { frac: 0.25 }, 1.4),
        ] {
            let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
            cfg.codec = kind;
            let a = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
            let b = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
            assert_eq!(a.final_params, b.final_params, "{kind:?} nondeterministic");
            let m = &a.report.metrics;
            assert!(m.comm_bytes > 0);
            assert!(
                (m.comm_bytes as f64) >= (m.wire_bytes as f64) * min_shrink,
                "{kind:?}: wire {} vs raw {} — expected >= {min_shrink}x shrink",
                m.wire_bytes,
                m.comm_bytes
            );
            // approximate mixing still trains on the quadratic task
            let pts = &a.report.metrics.curve.points;
            assert!(
                pts.last().unwrap().train_loss < pts.first().unwrap().train_loss,
                "{kind:?}: loss did not decrease"
            );
        }
    }

    #[test]
    fn lossy_codecs_survive_stragglers_and_conserve_gosgd_mass() {
        use crate::comm::codec::CodecKind;
        for kind in [
            CodecKind::Q8 { chunk: 256 },
            CodecKind::Q4 { chunk: 256 },
            CodecKind::TopK { frac: 0.25 },
        ] {
            let mut cfg = tiny_cfg(Method::GoSgd, 5);
            cfg.codec = kind;
            let mut sim = AsyncSimCfg::straggler(5, 0.02, 0.2, 3.0);
            // slow link: shares are in flight (encoded) mid-run
            sim.link = LinkModel { latency_s: 0.02, bandwidth_bps: 1e6 };
            let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
            let mass = asy.push_sum_mass.expect("gosgd exposes its mass");
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "{kind:?}: push-sum mass drifted through encoded in-flight shares: {mass}"
            );
            let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
            assert_eq!(asy.final_params, b.final_params, "{kind:?} nondeterministic");
        }
    }

    #[test]
    fn single_worker_free_runs() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 1);
        let asy = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(1)).unwrap();
        assert_eq!(asy.report.metrics.comm_bytes, 0);
        assert_eq!(asy.staleness.count(), 0);
        assert_eq!(asy.report.metrics.curve.points.len(), cfg.epochs);
    }

    // -- membership churn ---------------------------------------------------

    /// The PR's acceptance run, scaled to test size: W=8, two nodes
    /// crash mid-run, one rejoins — every gossip method completes under
    /// every codec, GoSGD's push-sum mass is exactly 1 at termination,
    /// and the survivors' training loss still decreases.
    #[test]
    fn churn_crash_rejoin_completes_for_all_methods_and_codecs() {
        use crate::comm::codec::CodecKind;
        use crate::membership::ChurnSpec;
        for method in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
        ] {
            for codec in [
                CodecKind::Identity,
                CodecKind::Q8 { chunk: 256 },
                CodecKind::Q4 { chunk: 256 },
                CodecKind::TopK { frac: 0.25 },
            ] {
                let mut cfg = tiny_cfg(method.clone(), 8);
                cfg.epochs = 6;
                cfg.codec = codec;
                cfg.churn = ChurnSpec::parse(crate::membership::STANDARD_CHURN).unwrap();
                let sim = AsyncSimCfg::straggler(8, 0.05, 0.1, 3.0);
                let asy = run_async(&cfg, &spec(&cfg), &sim)
                    .unwrap_or_else(|e| panic!("{method:?} {codec:?}: {e}"));
                // membership: 8 - 2 dead + 1 rejoined = 7 survivors
                assert_eq!(
                    asy.membership.final_alive.len(),
                    7,
                    "{method:?} {codec:?}: wrong survivor count ({:?})",
                    asy.membership.applied
                );
                assert!(asy.membership.final_alive.contains(&2), "rejoiner must be back");
                assert!(!asy.membership.final_alive.contains(&5), "node 5 stays dead");
                assert_eq!(asy.membership.applied.len(), 3, "all three events must apply");
                if matches!(method, Method::GoSgd) {
                    let mass = asy.push_sum_mass.expect("gosgd exposes its mass");
                    assert!(
                        (mass - 1.0).abs() < 1e-9,
                        "{codec:?}: push-sum mass drifted through churn: {mass}"
                    );
                }
                // survivor training still converges
                let pts = &asy.report.metrics.curve.points;
                assert!(pts.len() >= 2, "{method:?} {codec:?}: no curve");
                assert!(
                    pts.last().unwrap().train_loss < pts.first().unwrap().train_loss,
                    "{method:?} {codec:?}: survivor loss did not decrease"
                );
                // dropped-ledger consistency
                let m = &asy.report.metrics;
                assert_eq!(m.dropped_messages == 0, m.dropped_bytes == 0);
            }
        }
    }

    #[test]
    fn churn_run_is_deterministic_and_replays_the_event_trace() {
        use crate::membership::ChurnSpec;
        let mut cfg = tiny_cfg(Method::GoSgd, 6);
        cfg.epochs = 5;
        cfg.churn = ChurnSpec::parse("crash@25%:3,leave@40%:1,rejoin@70%:3").unwrap();
        let sim = AsyncSimCfg::straggler(6, 0.03, 0.2, 2.5);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.membership, b.membership, "membership trace must replay exactly");
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.report.metrics.dropped_messages, b.report.metrics.dropped_messages);
        assert_eq!(a.report.metrics.dropped_bytes, b.report.metrics.dropped_bytes);
        assert_eq!(a.staleness, b.staleness);
    }

    #[test]
    fn empty_churn_spec_changes_nothing() {
        use crate::membership::ChurnSpec;
        // `churn = "none"` must be byte-identical to not setting the key
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let mut cfg2 = cfg.clone();
        cfg2.churn = ChurnSpec::parse("churn:none").unwrap();
        let a = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
        let b = run_async(&cfg2, &spec(&cfg2), &AsyncSimCfg::lockstep(4)).unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.report.metrics.comm_bytes, b.report.metrics.comm_bytes);
        assert!(a.membership.applied.is_empty() && b.membership.applied.is_empty());
        assert!(a.checkpoint.is_none(), "fixed roster takes no churn checkpoints");
    }

    #[test]
    fn fresh_join_bootstraps_from_a_live_donor() {
        use crate::membership::ChurnSpec;
        // node 4 (beyond the initial W=4 roster) joins mid-run
        let mut cfg = tiny_cfg(Method::GossipingSgdPush, 4);
        cfg.epochs = 4;
        cfg.churn = ChurnSpec::parse("join@40%:4").unwrap();
        let sim = AsyncSimCfg::straggler(4, 0.05, 0.1, 1.5);
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(asy.membership.final_alive, vec![0, 1, 2, 3, 4]);
        let bs = &asy.membership.bootstraps;
        assert_eq!(bs.len(), 1, "exactly one bootstrap handshake");
        assert_eq!(bs[0].joiner, 4);
        assert_eq!(
            bs[0].donor_digest, bs[0].adopted_digest,
            "joiner must adopt the donor's exact pull-time state"
        );
        assert_eq!(bs[0].restored_step, 0, "fresh joins start at step 0");
        // the joiner ran real steps after bootstrapping
        assert_eq!(asy.final_params.len(), 5);
    }

    #[test]
    fn churn_schedules_outside_the_roster_are_rejected() {
        use crate::membership::ChurnSpec;
        // crashing a node id that never existed is a spec typo, not a
        // cluster enlargement
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.churn = ChurnSpec::parse("crash@50%:20").unwrap();
        let err = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap_err();
        assert!(err.to_string().contains("outside the initial roster"), "{err}");
        // brand-new join slots only extend the fully-connected topology;
        // geometric topologies would be silently rewired
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.topology = crate::topology::Topology::Ring;
        cfg.churn = ChurnSpec::parse("join@50%:4").unwrap();
        let err = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap_err();
        assert!(err.to_string().contains("requires topology=full"), "{err}");
        // the same join on the full topology is fine
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        cfg.churn = ChurnSpec::parse("join@50%:4").unwrap();
        run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(4)).unwrap();
    }

    #[test]
    fn rejoin_restores_the_epoch_checkpoint() {
        use crate::membership::ChurnSpec;
        let mut cfg = tiny_cfg(Method::GossipingSgdPull, 4);
        cfg.epochs = 6;
        cfg.churn = ChurnSpec::parse("crash@50%:2,rejoin@75%:2").unwrap();
        let sim = AsyncSimCfg::straggler(4, 0.05, 0.0, 1.0);
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let bs = &asy.membership.bootstraps;
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].joiner, 2);
        assert!(
            bs[0].restored_step > 0 && bs[0].restored_step % cfg.steps_per_epoch() == 0,
            "rejoin must resume from an epoch-boundary checkpoint, got step {}",
            bs[0].restored_step
        );
        let ckpt = asy.checkpoint.expect("churn runs return the checkpoint mirror");
        assert_eq!(ckpt.nodes.len(), 4);
        assert!(ckpt.nodes[0].is_some());
        ckpt.validate(&cfg.label, cfg.seed, 12).unwrap();
    }

    #[test]
    fn leave_hands_off_gosgd_weight_before_departing() {
        use crate::membership::ChurnSpec;
        let mut cfg = tiny_cfg(Method::GoSgd, 5);
        cfg.epochs = 5;
        cfg.churn = ChurnSpec::parse("leave@40%:1,leave@55%:3").unwrap();
        let sim = AsyncSimCfg::straggler(5, 0.04, 0.1, 2.0);
        let asy = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(asy.membership.final_alive, vec![0, 2, 4]);
        let mass = asy.push_sum_mass.unwrap();
        assert!((mass - 1.0).abs() < 1e-9, "graceful leave leaked mass: {mass}");
    }

    #[test]
    fn elastic_rollback_keeps_messages_balanced() {
        use crate::membership::ChurnSpec;
        // crash under a slow link: elastic pushes/replies to and from the
        // dead node are dropped or rolled back, and the run still
        // completes deterministically
        let mut cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 6);
        cfg.epochs = 4;
        cfg.schedule = crate::config::CommSchedule::Probability(0.8);
        cfg.churn = ChurnSpec::parse("crash@35%:4,crash@55%:5").unwrap();
        let mut sim = AsyncSimCfg::straggler(6, 0.02, 0.1, 2.0);
        sim.link = LinkModel { latency_s: 0.05, bandwidth_bps: 1e6 };
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.membership.final_alive.len(), 4);
        assert!(
            a.report.metrics.dropped_messages > 0 || a.membership.rolled_back_msgs > 0,
            "a crash under a slow link must strand some traffic"
        );
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
    }

    // -- failure detection + link faults --------------------------------------

    /// The PR's acceptance run, scaled to test size: W=8, two seeded
    /// crashes, 5% link loss, oracle reclamation off (`fd:` on) — every
    /// gossip method converges on the survivors, both deaths are
    /// *detected* (nonzero latency histogram), the false-suspicion
    /// counter is recorded explicitly, and the same seed + spec replays
    /// the identical event trace.
    #[test]
    fn fd_detects_crashes_and_converges_with_lossy_links_for_all_methods() {
        use crate::membership::{ChurnSpec, FaultSpec, FdSpec};
        for method in [
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
        ] {
            let mut cfg = tiny_cfg(method.clone(), 8);
            cfg.epochs = 6;
            cfg.churn = ChurnSpec::parse("crash@30%:5,crash@45%:6").unwrap();
            cfg.faults = FaultSpec::parse("drop:0.05,seed:11").unwrap();
            cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
            let sim = AsyncSimCfg::straggler(8, 0.05, 0.1, 3.0);
            let a = run_async(&cfg, &spec(&cfg), &sim)
                .unwrap_or_else(|e| panic!("{method:?}: {e}"));
            assert_eq!(a.membership.final_alive.len(), 6, "{method:?}: wrong survivors");
            let fd = a.membership.fd.as_ref().expect("fd run must attach an FdReport");
            assert!(fd.probes > 0, "{method:?}: no probes fired");
            assert!(fd.acks > 0, "{method:?}: no acks returned");
            assert!(
                fd.detection.count() > 0,
                "{method:?}: no death was ever detected (confirms {}, suspicions {})",
                fd.confirms,
                fd.suspicions
            );
            assert!(fd.confirms > 0, "{method:?}: no confirmation");
            // the counter exists and is consistent even when zero
            assert!(fd.false_suspicions <= fd.suspicions, "{method:?}");
            if matches!(method, Method::GoSgd) {
                let mass = a.push_sum_mass.expect("gosgd exposes its mass");
                assert!(
                    (mass - 1.0).abs() < 1e-9,
                    "push-sum mass drifted through lossy links + fd: {mass}"
                );
            }
            let pts = &a.report.metrics.curve.points;
            assert!(
                pts.last().unwrap().train_loss < pts.first().unwrap().train_loss,
                "{method:?}: survivor loss did not decrease"
            );
            // detection plane is deterministic: same seed + spec replays
            let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
            assert_eq!(a.final_params, b.final_params, "{method:?} nondeterministic");
            assert_eq!(a.membership, b.membership, "{method:?}: fd trace must replay");
        }
    }

    #[test]
    fn empty_fault_and_fd_specs_change_nothing() {
        use crate::membership::{FaultSpec, FdSpec};
        // explicit `faults = "none"` / `fd = "off"` must be byte-identical
        // to not setting the keys at all (which PR-5 goldens pin)
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 4);
        let mut cfg2 = cfg.clone();
        cfg2.faults = FaultSpec::parse("faults:none").unwrap();
        cfg2.fd = FdSpec::parse("off").unwrap();
        let sim = AsyncSimCfg::straggler(4, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let b = run_async(&cfg2, &spec(&cfg2), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.report.metrics.comm_bytes, b.report.metrics.comm_bytes);
        assert_eq!(a.report.metrics.wire_bytes, b.report.metrics.wire_bytes);
        assert_eq!(a.report.metrics.comm_messages, b.report.metrics.comm_messages);
        assert!(a.membership.fd.is_none() && b.membership.fd.is_none());
    }

    /// Detector safety: perfect links + generous timeouts => the plane
    /// probes continuously but never suspects, let alone confirms.
    #[test]
    fn fd_with_no_faults_never_confirms_a_death() {
        use crate::membership::FdSpec;
        let mut cfg = tiny_cfg(Method::GossipingSgdPull, 6);
        cfg.epochs = 4;
        cfg.fd = FdSpec::parse("fd:0.2:1.0:2.0:2").unwrap();
        let sim = AsyncSimCfg::straggler(6, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let fd = a.membership.fd.as_ref().unwrap();
        assert!(fd.probes > 0);
        assert!(fd.acks > 0);
        assert_eq!(fd.suspicions, 0, "no faults, generous timeouts: no suspicion");
        assert_eq!(fd.confirms, 0);
        assert_eq!(fd.false_confirms, 0);
        assert_eq!(a.membership.final_alive.len(), 6);
        // final epoch-boundary views agree with the oracle
        if let Some(d) = fd.view_divergence.last() {
            assert_eq!(*d, 0.0, "views diverged with nothing to diverge about");
        }
    }

    /// Data follows membership: a confirmed death re-deals the dead
    /// node's shard to survivors, and its rejoin takes the rows back.
    #[test]
    fn fd_confirmed_death_reassigns_shard_and_rejoin_restores_it() {
        use crate::membership::{ChurnSpec, FdSpec};
        let mut cfg = tiny_cfg(Method::GoSgd, 6);
        cfg.epochs = 6;
        cfg.churn = ChurnSpec::parse("crash@30%:4,rejoin@70%:4").unwrap();
        cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
        let sim = AsyncSimCfg::straggler(6, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.membership.final_alive.len(), 6, "rejoiner must return");
        let fd = a.membership.fd.as_ref().unwrap();
        assert!(fd.confirms > 0, "crash was never confirmed");
        assert!(!fd.shard_moves.is_empty(), "confirmed death must move shard rows");
        assert!(
            fd.shard_moves.iter().all(|&(dead, adopter, rows)| {
                dead == 4 && adopter != 4 && rows > 0
            }),
            "unexpected shard moves: {:?}",
            fd.shard_moves
        );
        let mass = a.push_sum_mass.unwrap();
        assert!((mass - 1.0).abs() < 1e-9, "mass drifted through confirm+rejoin: {mass}");
        let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.membership, b.membership);
    }

    /// The tentpole's contract as a unit test: the sharded queue + one
    /// gradient thread per shard reproduce the single-queue runtime bit
    /// for bit — under lockstep and under latency-bearing stragglers,
    /// at shard counts that divide, don't divide, and exceed the roster.
    #[test]
    fn sharded_queue_is_bit_identical_to_single_queue() {
        for method in [Method::ElasticGossip { alpha: 0.5 }, Method::GoSgd] {
            let base = tiny_cfg(method.clone(), 6);
            let mut lat = AsyncSimCfg::straggler(6, 0.05, 0.1, 3.0);
            lat.link = LinkModel { latency_s: 0.01, bandwidth_bps: 1e7 };
            for sim in [AsyncSimCfg::lockstep(6), lat] {
                let a = run_async(&base, &spec(&base), &sim).unwrap();
                assert_eq!(a.cross_shard_frac, 0.0, "{method:?}: shards:1 has one shard");
                for shards in [2usize, 3, 4, 7] {
                    let mut cfg = base.clone();
                    cfg.shards = shards;
                    let b = run_async(&cfg, &spec(&cfg), &sim).unwrap();
                    assert_eq!(
                        a.final_params, b.final_params,
                        "{method:?} shards:{shards} diverged"
                    );
                    assert_eq!(a.staleness, b.staleness, "{method:?} shards:{shards}");
                    assert_eq!(a.events, b.events, "{method:?} shards:{shards} event count");
                    assert_eq!(
                        a.report.metrics.comm_bytes, b.report.metrics.comm_bytes,
                        "{method:?} shards:{shards} byte ledger"
                    );
                    assert_eq!(
                        a.report.metrics.wire_bytes, b.report.metrics.wire_bytes,
                        "{method:?} shards:{shards} wire ledger"
                    );
                    if b.report.metrics.comm_messages > 0 {
                        assert!(
                            b.cross_shard_frac > 0.0,
                            "{method:?} shards:{shards}: gossip never crossed a shard"
                        );
                    }
                }
            }
        }
    }

    /// The whole robustness plane rides the driver thread: sharding must
    /// not perturb churn application, per-message loss decisions, or the
    /// detection plane's trace.
    #[test]
    fn sharded_run_replays_churn_faults_and_fd_exactly() {
        use crate::membership::{ChurnSpec, FaultSpec, FdSpec};
        let mut cfg = tiny_cfg(Method::GossipingSgdPush, 8);
        cfg.epochs = 6;
        cfg.churn = ChurnSpec::parse("crash@30%:5,rejoin@70%:5,crash@45%:6").unwrap();
        cfg.faults = FaultSpec::parse("drop:0.05,jitter:0.3,seed:11").unwrap();
        cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").unwrap();
        let sim = AsyncSimCfg::straggler(8, 0.05, 0.1, 3.0);
        let a = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let mut c4 = cfg.clone();
        c4.shards = 4;
        let b = run_async(&c4, &spec(&c4), &sim).unwrap();
        assert_eq!(a.final_params, b.final_params, "params diverged under shards:4");
        assert_eq!(a.membership, b.membership, "membership trace diverged");
        assert_eq!(a.staleness, b.staleness);
        assert_eq!(a.events, b.events);
        assert_eq!(a.report.metrics.dropped_messages, b.report.metrics.dropped_messages);
        assert_eq!(a.report.metrics.dropped_bytes, b.report.metrics.dropped_bytes);
    }

    /// Coalescing (`coalesce`) packs consecutive same-(src,dst) payloads
    /// into one wire frame.  Under zero-latency links the frame arrives
    /// exactly when each member message would have, so the trajectory is
    /// bit-identical; under real links the byte ledgers still match the
    /// per-message accounting while per-transfer latency is paid once
    /// per frame.
    #[test]
    fn coalesced_frames_keep_ledgers_and_lockstep_trajectory() {
        let cfg = tiny_cfg(Method::ElasticGossip { alpha: 0.5 }, 6);
        let mut co = cfg.clone();
        co.coalesce = true;
        let a = run_async(&cfg, &spec(&cfg), &AsyncSimCfg::lockstep(6)).unwrap();
        let b = run_async(&co, &spec(&co), &AsyncSimCfg::lockstep(6)).unwrap();
        assert_eq!(a.final_params, b.final_params, "lockstep coalescing diverged");
        assert_eq!(a.report.metrics.comm_bytes, b.report.metrics.comm_bytes);
        assert_eq!(a.report.metrics.wire_bytes, b.report.metrics.wire_bytes);
        assert_eq!(a.report.metrics.comm_messages, b.report.metrics.comm_messages);
        let mut sim = AsyncSimCfg::straggler(6, 0.05, 0.1, 3.0);
        sim.link = LinkModel { latency_s: 0.01, bandwidth_bps: 1e7 };
        let c = run_async(&cfg, &spec(&cfg), &sim).unwrap();
        let d = run_async(&co, &spec(&co), &sim).unwrap();
        assert_eq!(c.report.metrics.comm_bytes, d.report.metrics.comm_bytes);
        assert_eq!(c.report.metrics.wire_bytes, d.report.metrics.wire_bytes);
        assert!(
            d.report.metrics.simulated_comm_s <= c.report.metrics.simulated_comm_s + 1e-9,
            "framing must never cost more simulated comm time"
        );
        // and coalescing composes with the sharded queue
        let mut both = co.clone();
        both.shards = 3;
        let e = run_async(&both, &spec(&both), &sim).unwrap();
        assert_eq!(d.final_params, e.final_params, "coalesce + shards diverged");
    }
}
