//! PJRT runtime: loading and executing the AOT artifacts from rust.
//!
//! This is the only module that talks to XLA.  It follows the pattern of
//! `/opt/xla-example/load_hlo`: HLO **text** → `HloModuleProto::from_text_file`
//! → `XlaComputation` → `PjRtClient::compile` → `execute`.
//!
//! Two engine implementations sit behind the `GradEngine` trait:
//!
//! * [`HloEngine`] — the real thing.  Packs the worker's flat f32
//!   parameter buffer into per-tensor literals according to the manifest
//!   layout, executes the train/eval executable, and scatters gradient
//!   outputs back into a flat buffer.
//! * [`SyntheticEngine`] — a closed-form quadratic "model" used by unit
//!   and property tests so the coordinator logic can be verified without
//!   compiled artifacts (and fast enough for thousands of steps).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so engines are built *inside*
//! the thread that uses them via [`EngineFactory`].

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

// The PJRT surface this module is written against.  The default build
// links the vendored stub (compiles everywhere, errors at runtime with a
// clear message); artifact-equipped boxes swap in the real `xla-rs`
// bindings — see `xla_stub.rs` for the one-line switch.
#[path = "xla_stub.rs"]
mod xla;

use crate::data::TaskKind;
use crate::manifest::{Artifact, ArtifactKind, Dtype, Manifest, ModelMeta};

/// Batch features handed to an engine: classification uses f32 rows,
/// language modelling uses i32 token windows.
#[derive(Clone, Debug)]
pub enum BatchX<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> BatchX<'a> {
    pub fn len(&self) -> usize {
        match self {
            BatchX::F32(v) => v.len(),
            BatchX::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Computes gradients and evaluation metrics for one model replica.
pub trait GradEngine {
    /// Flat parameter count of the model.
    fn flat_size(&self) -> usize;

    /// Fixed train batch size (the AOT artifact's shape).
    fn train_batch(&self) -> usize;

    /// Fixed eval batch size.
    fn eval_batch(&self) -> usize;

    /// Compute `(loss, grads)` for one batch; writes the flat gradient
    /// into `grad_out` (len == flat_size).  `seed` drives dropout.
    fn loss_and_grad(
        &mut self,
        params: &[f32],
        x: BatchX,
        y: &[i32],
        seed: i32,
        grad_out: &mut [f32],
    ) -> Result<f32>;

    /// Evaluate one batch: returns `(sum_loss, num_correct)` over rows
    /// with `mask == 1.0`.
    fn eval_batch_masked(
        &mut self,
        params: &[f32],
        x: BatchX,
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)>;

    /// Initial parameters (the shared seed-0 init of Table 4.1).
    fn initial_params(&self) -> Result<Vec<f32>>;

    fn task_kind(&self) -> TaskKind;

    /// Compute loss+grads for ALL workers in one synchronized step.
    ///
    /// Default: loop over workers.  [`HloEngine`] overrides this with a
    /// single call into a vmapped-over-workers artifact when one was
    /// lowered for this (model, W, batch) — one PJRT dispatch per step
    /// instead of W (EXPERIMENTS.md §Perf).
    fn loss_and_grad_all(
        &mut self,
        params: &[Vec<f32>],
        xs: &[BatchXOwned],
        ys: &[Vec<i32>],
        seeds: &[i32],
        grad_out: &mut [Vec<f32>],
    ) -> Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(params.len());
        for i in 0..params.len() {
            losses.push(self.loss_and_grad(
                &params[i],
                xs[i].as_ref(),
                &ys[i],
                seeds[i],
                &mut grad_out[i],
            )?);
        }
        Ok(losses)
    }
}

/// Owned batch features (per-worker staging buffers in the coordinator).
#[derive(Clone, Debug)]
pub enum BatchXOwned {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchXOwned {
    pub fn as_ref(&self) -> BatchX<'_> {
        match self {
            BatchXOwned::F32(v) => BatchX::F32(v),
            BatchXOwned::I32(v) => BatchX::I32(v),
        }
    }
    pub fn clear_f32(&mut self) -> &mut Vec<f32> {
        if !matches!(self, BatchXOwned::F32(_)) {
            *self = BatchXOwned::F32(Vec::new());
        }
        match self {
            BatchXOwned::F32(v) => v,
            _ => unreachable!(),
        }
    }
    pub fn clear_i32(&mut self) -> &mut Vec<i32> {
        if !matches!(self, BatchXOwned::I32(_)) {
            *self = BatchXOwned::I32(Vec::new());
        }
        match self {
            BatchXOwned::I32(v) => v,
            _ => unreachable!(),
        }
    }
}

/// Builds engines inside worker threads (PJRT clients are not `Send`).
pub trait EngineFactory: Sync + Send {
    fn build(&self) -> Result<Box<dyn GradEngine>>;
    /// A human-readable description for logs.
    fn describe(&self) -> String;
}

// ---------------------------------------------------------------------------
// HLO engine
// ---------------------------------------------------------------------------

/// Configuration for constructing [`HloEngine`]s.
#[derive(Clone, Debug)]
pub struct HloEngineSpec {
    pub artifact_dir: PathBuf,
    pub model: String,
    pub train_batch: usize,
    /// worker count — used to pick up a stacked (vmapped) train artifact
    /// when one exists; 0/1 disables the stacked path
    pub workers: usize,
}

impl EngineFactory for HloEngineSpec {
    fn build(&self) -> Result<Box<dyn GradEngine>> {
        Ok(Box::new(HloEngine::load_for_workers(
            &self.artifact_dir,
            &self.model,
            self.train_batch,
            self.workers,
        )?))
    }
    fn describe(&self) -> String {
        format!("hlo:{}@b{}", self.model, self.train_batch)
    }
}

/// The PJRT-backed engine (see module docs).
pub struct HloEngine {
    client: xla::PjRtClient,
    meta: ModelMeta,
    train: LoadedArtifact,
    /// vmapped-over-workers step, when lowered for this (model, W, batch)
    train_stacked: Option<LoadedArtifact>,
    eval: LoadedArtifact,
    x_dtype: Dtype,
    task: TaskKind,
    /// staging buffer for stacked inputs (reused across steps)
    stack_buf: Vec<f32>,
}

struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    art: Artifact,
}

fn compile(client: &xla::PjRtClient, art: &Artifact) -> Result<LoadedArtifact> {
    let path = &art.file;
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("loading HLO text {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", art.name))?;
    Ok(LoadedArtifact {
        exe,
        batch: art.batch,
        art: art.clone(),
    })
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("literal f32 {dims:?}: {e:?}"))
}

fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("literal i32 {dims:?}: {e:?}"))
}

impl HloEngine {
    /// Load + compile the train/eval artifacts for `model` from `dir`.
    pub fn load(dir: impl AsRef<Path>, model: &str, train_batch: usize) -> Result<HloEngine> {
        Self::load_for_workers(dir, model, train_batch, 1)
    }

    /// Like [`HloEngine::load`], additionally compiling the stacked
    /// (vmapped over `workers`) train artifact when the manifest has one.
    pub fn load_for_workers(
        dir: impl AsRef<Path>,
        model: &str,
        train_batch: usize,
        workers: usize,
    ) -> Result<HloEngine> {
        let manifest = Manifest::load(&dir)?;
        let meta = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let train = compile(&client, manifest.train_artifact(model, train_batch)?)?;
        let train_stacked = if workers > 1 {
            manifest
                .stacked_train_artifact(model, workers, train_batch)
                .map(|a| compile(&client, a))
                .transpose()?
        } else {
            None
        };
        let eval = compile(&client, manifest.eval_artifact(model)?)?;
        let task = if meta.x_dtype == Dtype::I32 {
            TaskKind::LanguageModel
        } else {
            TaskKind::Classify
        };
        Ok(HloEngine {
            client,
            x_dtype: meta.x_dtype,
            meta,
            train,
            train_stacked,
            eval,
            task,
            stack_buf: Vec::new(),
        })
    }

    // NOTE: the crate's `buffer_from_host_raw_bytes` passes the
    // `ElementType` discriminant where the C API expects a
    // `PrimitiveType` (off-by-reordering: F32 becomes F16), so we use the
    // typed `buffer_from_host_buffer`, which converts correctly.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    /// Upload the flat parameter buffer as per-tensor device buffers, in
    /// manifest order (single host->device copy each, no intermediate
    /// Literal — see EXPERIMENTS.md §Perf).
    fn upload_params(&self, params: &[f32]) -> Result<Vec<xla::PjRtBuffer>> {
        anyhow::ensure!(
            params.len() == self.meta.flat_size,
            "params len {} != flat {}",
            params.len(),
            self.meta.flat_size
        );
        self.meta
            .params
            .iter()
            .map(|p| self.upload_f32(&params[p.offset..p.offset + p.size], &p.shape))
            .collect()
    }

    /// Pack the flat parameter buffer into per-tensor literals, in
    /// manifest order.
    fn pack_params(&self, params: &[f32]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            params.len() == self.meta.flat_size,
            "params len {} != flat {}",
            params.len(),
            self.meta.flat_size
        );
        self.meta
            .params
            .iter()
            .map(|p| literal_f32(&params[p.offset..p.offset + p.size], &p.shape))
            .collect()
    }

    fn pack_x(&self, x: &BatchX, batch: usize) -> Result<xla::Literal> {
        let mut dims = vec![batch];
        dims.extend_from_slice(&self.meta.data_shape);
        match (x, self.x_dtype) {
            (BatchX::F32(v), Dtype::F32) => literal_f32(v, &dims),
            (BatchX::I32(v), Dtype::I32) => literal_i32(v, &dims),
            _ => bail!("batch dtype does not match model {}", self.meta.name),
        }
    }

    fn y_dims(&self, batch: usize) -> Vec<usize> {
        if self.task == TaskKind::LanguageModel {
            vec![batch, self.meta.data_shape[0]]
        } else {
            vec![batch]
        }
    }
}

impl GradEngine for HloEngine {
    fn flat_size(&self) -> usize {
        self.meta.flat_size
    }

    fn train_batch(&self) -> usize {
        self.train.batch
    }

    fn eval_batch(&self) -> usize {
        self.eval.batch
    }

    fn loss_and_grad(
        &mut self,
        params: &[f32],
        x: BatchX,
        y: &[i32],
        seed: i32,
        grad_out: &mut [f32],
    ) -> Result<f32> {
        let b = self.train.batch;
        anyhow::ensure!(y.len() == self.y_dims(b).iter().product::<usize>(), "bad y len");
        anyhow::ensure!(grad_out.len() == self.meta.flat_size, "bad grad_out len");
        let mut inputs = self.upload_params(params)?;
        let mut xdims = vec![b];
        xdims.extend_from_slice(&self.meta.data_shape);
        inputs.push(match (&x, self.x_dtype) {
            (BatchX::F32(v), Dtype::F32) => self.upload_f32(v, &xdims)?,
            (BatchX::I32(v), Dtype::I32) => self.upload_i32(v, &xdims)?,
            _ => bail!("batch dtype does not match model {}", self.meta.name),
        });
        inputs.push(self.upload_i32(y, &self.y_dims(b))?);
        inputs.push(self.upload_i32(std::slice::from_ref(&seed), &[])?);

        let result = self
            .train
            .exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.train.art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(
            outs.len() == 1 + self.meta.params.len(),
            "expected loss + {} grads, got {}",
            self.meta.params.len(),
            outs.len()
        );
        let loss: f32 = outs[0]
            .get_first_element()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        for (spec, lit) in self.meta.params.iter().zip(&outs[1..]) {
            lit.copy_raw_to(&mut grad_out[spec.offset..spec.offset + spec.size])
                .map_err(|e| anyhow!("grad {}: {e:?}", spec.name))?;
        }
        Ok(loss)
    }

    fn loss_and_grad_all(
        &mut self,
        params: &[Vec<f32>],
        xs: &[BatchXOwned],
        ys: &[Vec<i32>],
        seeds: &[i32],
        grad_out: &mut [Vec<f32>],
    ) -> Result<Vec<f32>> {
        let w = params.len();
        let Some(stacked) = self.train_stacked.as_ref() else {
            // no stacked artifact for this (model, W, batch): per-worker path
            let mut losses = Vec::with_capacity(w);
            for i in 0..w {
                losses.push(self.loss_and_grad(
                    &params[i],
                    xs[i].as_ref(),
                    &ys[i],
                    seeds[i],
                    &mut grad_out[i],
                )?);
            }
            return Ok(losses);
        };
        anyhow::ensure!(stacked.art.workers == w, "stacked artifact is for {} workers", stacked.art.workers);
        let b = stacked.batch;

        // pack stacked params: for each tensor, concat the W workers' segments
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.meta.params.len() + 3);
        let mut stack = std::mem::take(&mut self.stack_buf);
        for p in &self.meta.params {
            stack.clear();
            for wp in params {
                stack.extend_from_slice(&wp[p.offset..p.offset + p.size]);
            }
            let mut dims = vec![w];
            dims.extend_from_slice(&p.shape);
            inputs.push(literal_f32(&stack, &dims)?);
        }
        self.stack_buf = stack;
        // x: (W, b, data...)
        let mut xdims = vec![w, b];
        xdims.extend_from_slice(&self.meta.data_shape);
        match self.x_dtype {
            Dtype::F32 => {
                let mut xs_all = Vec::new();
                for x in xs {
                    match x {
                        BatchXOwned::F32(v) => xs_all.extend_from_slice(v),
                        _ => bail!("dtype mismatch"),
                    }
                }
                inputs.push(literal_f32(&xs_all, &xdims)?);
            }
            Dtype::I32 => {
                let mut xs_all = Vec::new();
                for x in xs {
                    match x {
                        BatchXOwned::I32(v) => xs_all.extend_from_slice(v),
                        _ => bail!("dtype mismatch"),
                    }
                }
                inputs.push(literal_i32(&xs_all, &xdims)?);
            }
            Dtype::U32 => bail!("u32 features unsupported"),
        }
        // y: (W, ...) and seeds (W,)
        let y_all: Vec<i32> = ys.iter().flat_map(|v| v.iter().copied()).collect();
        let mut ydims = vec![w];
        ydims.extend_from_slice(&self.y_dims(b));
        inputs.push(literal_i32(&y_all, &ydims)?);
        inputs.push(literal_i32(seeds, &[w])?);

        let result = stacked
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", stacked.art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(outs.len() == 1 + self.meta.params.len());
        let losses: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        // scatter grads: out tensor shape (W, param shape)
        let mut scratch = std::mem::take(&mut self.stack_buf);
        for (spec, lit) in self.meta.params.iter().zip(&outs[1..]) {
            scratch.resize(w * spec.size, 0.0);
            lit.copy_raw_to(&mut scratch[..])
                .map_err(|e| anyhow!("grad {}: {e:?}", spec.name))?;
            for (i, go) in grad_out.iter_mut().enumerate() {
                go[spec.offset..spec.offset + spec.size]
                    .copy_from_slice(&scratch[i * spec.size..(i + 1) * spec.size]);
            }
        }
        self.stack_buf = scratch;
        Ok(losses)
    }

    fn eval_batch_masked(
        &mut self,
        params: &[f32],
        x: BatchX,
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)> {
        let b = self.eval.batch;
        anyhow::ensure!(mask.len() == b, "bad mask len");
        let mut inputs = self.pack_params(params)?;
        inputs.push(self.pack_x(&x, b)?);
        inputs.push(literal_i32(y, &self.y_dims(b))?);
        inputs.push(literal_f32(mask, &[b])?);
        let result = self
            .eval
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.eval.art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let (l, c) = result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        Ok((
            l.get_first_element().map_err(|e| anyhow!("{e:?}"))?,
            c.get_first_element().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    fn initial_params(&self) -> Result<Vec<f32>> {
        let path = self
            .meta
            .init_file
            .as_ref()
            .ok_or_else(|| anyhow!("model {} has no init file", self.meta.name))?;
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let p = crate::tensor::FlatParams::from_le_bytes(&bytes)?;
        anyhow::ensure!(p.len() == self.meta.flat_size, "init size mismatch");
        Ok(p.as_slice().to_vec())
    }

    fn task_kind(&self) -> TaskKind {
        self.task
    }
}

// ---------------------------------------------------------------------------
// standalone kernel executor (gossip/NAG HLO artifacts, ablation path)
// ---------------------------------------------------------------------------

/// Executes the standalone Pallas-lowered kernel artifacts
/// (`gossip_pair_nN`, `nag_nN`) — used by the kernel-parity tests and the
/// rust-vs-HLO ablation bench; the coordinator's production path is the
/// native implementation in `tensor`.
pub struct KernelEngine {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    kind: ArtifactKind,
}

impl KernelEngine {
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<KernelEngine> {
        let manifest = Manifest::load(&dir)?;
        let art = manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not found"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        let loaded = compile(&client, art)?;
        Ok(KernelEngine {
            exe: loaded.exe,
            n: art.batch,
            kind: art.kind,
        })
    }

    /// Run the elastic pair update artifact.
    pub fn gossip_pair(&self, ti: &[f32], tk: &[f32], alpha: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(self.kind == ArtifactKind::Gossip, "not a gossip artifact");
        anyhow::ensure!(ti.len() == self.n && tk.len() == self.n);
        let inputs = vec![
            literal_f32(ti, &[self.n])?,
            literal_f32(tk, &[self.n])?,
            xla::Literal::scalar(alpha),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs).map_err(|e| anyhow!("{e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (a, b) = result.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        Ok((
            a.to_vec().map_err(|e| anyhow!("{e:?}"))?,
            b.to_vec().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Run the fused NAG artifact.
    pub fn nag(
        &self,
        theta: &[f32],
        v: &[f32],
        g: &[f32],
        eta: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(self.kind == ArtifactKind::Nag, "not a nag artifact");
        let inputs = vec![
            literal_f32(theta, &[self.n])?,
            literal_f32(v, &[self.n])?,
            literal_f32(g, &[self.n])?,
            xla::Literal::scalar(eta),
            xla::Literal::scalar(mu),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs).map_err(|e| anyhow!("{e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (t, vv) = result.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        Ok((
            t.to_vec().map_err(|e| anyhow!("{e:?}"))?,
            vv.to_vec().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }
}

// ---------------------------------------------------------------------------
// synthetic engine (engine-free tests)
// ---------------------------------------------------------------------------

/// A closed-form "model" for coordinator tests: per-class targets
/// `c_y` on the parameter space; loss = mean_i 1/2 ||theta - c_{y_i}||^2,
/// so `grad = theta - mean_i(c_{y_i})` — linear in theta, which makes the
/// All-reduce ≡ large-batch equivalence exact and testable.
pub struct SyntheticEngine {
    pub n: usize,
    pub classes: usize,
    pub train_b: usize,
    pub eval_b: usize,
    targets: Vec<Vec<f32>>,
    /// precomputed ||c_y||^2 per class (keeps loss O(n), not O(batch*n))
    target_sq: Vec<f64>,
}

impl SyntheticEngine {
    pub fn new(n: usize, classes: usize, train_b: usize, eval_b: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let targets: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let target_sq: Vec<f64> = targets
            .iter()
            .map(|t| t.iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        SyntheticEngine {
            n,
            classes,
            train_b,
            eval_b,
            targets,
            target_sq,
        }
    }

    /// The class targets (tests use these to craft exact scenarios).
    pub fn targets(&self) -> &[Vec<f32>] {
        &self.targets
    }

    fn mean_target(&self, y: &[i32]) -> Vec<f32> {
        let mut m = vec![0.0f32; self.n];
        for &yi in y {
            let t = &self.targets[yi as usize % self.classes];
            for (a, &b) in m.iter_mut().zip(t) {
                *a += b;
            }
        }
        let inv = 1.0 / y.len() as f32;
        m.iter_mut().for_each(|x| *x *= inv);
        m
    }
}

impl GradEngine for SyntheticEngine {
    fn flat_size(&self) -> usize {
        self.n
    }
    fn train_batch(&self) -> usize {
        self.train_b
    }
    fn eval_batch(&self) -> usize {
        self.eval_b
    }

    fn loss_and_grad(
        &mut self,
        params: &[f32],
        _x: BatchX,
        y: &[i32],
        _seed: i32,
        grad_out: &mut [f32],
    ) -> Result<f32> {
        let m = self.mean_target(y);
        // mean_i 1/2 ||p - c_i||^2 = 1/2 (||p||^2 - 2 p.m + mean_i ||c_i||^2)
        let p_sq: f64 = params.iter().map(|&p| (p as f64) * (p as f64)).sum();
        let p_dot_m: f64 = params.iter().zip(&m).map(|(&p, &mi)| p as f64 * mi as f64).sum();
        let mean_c_sq: f64 = y
            .iter()
            .map(|&yi| self.target_sq[yi as usize % self.classes])
            .sum::<f64>()
            / y.len() as f64;
        let loss = 0.5 * (p_sq - 2.0 * p_dot_m + mean_c_sq);
        for ((g, &p), &mi) in grad_out.iter_mut().zip(params).zip(&m) {
            *g = p - mi;
        }
        Ok(loss as f32)
    }

    fn eval_batch_masked(
        &mut self,
        params: &[f32],
        _x: BatchX,
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32)> {
        // "correct" = nearest target class matches the label
        let mut sum_loss = 0.0f32;
        let mut correct = 0.0f32;
        let mut best = (f32::INFINITY, 0usize);
        for (c, t) in self.targets.iter().enumerate() {
            let d: f32 = params.iter().zip(t).map(|(&p, &ti)| (p - ti) * (p - ti)).sum();
            if d < best.0 {
                best = (d, c);
            }
        }
        for (i, &yi) in y.iter().enumerate() {
            if mask[i] == 0.0 {
                continue;
            }
            let t = &self.targets[yi as usize % self.classes];
            let d: f32 = params.iter().zip(t).map(|(&p, &ti)| (p - ti) * (p - ti)).sum();
            sum_loss += 0.5 * d;
            if best.1 == yi as usize % self.classes {
                correct += 1.0;
            }
        }
        Ok((sum_loss, correct))
    }

    fn initial_params(&self) -> Result<Vec<f32>> {
        Ok(vec![0.0; self.n])
    }

    fn task_kind(&self) -> TaskKind {
        TaskKind::Classify
    }
}

/// Factory for [`SyntheticEngine`].
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub n: usize,
    pub classes: usize,
    pub train_b: usize,
    pub eval_b: usize,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec { n: 16, classes: 4, train_b: 8, eval_b: 16, seed: 0 }
    }
}

impl SyntheticSpec {
    /// The spec `run_experiment` builds internally for a synthetic-engine
    /// config — the single seed/class/eval-batch convention.  Harnesses
    /// that need the factory *alongside* the config (the threaded
    /// runtime, the async runtime, equivalence tests) must construct it
    /// through here, so a sync reference run and its async counterpart
    /// can never drift onto different engines.
    pub fn for_cfg(cfg: &crate::config::ExperimentConfig) -> Result<SyntheticSpec> {
        let crate::config::EngineKind::Synthetic { dim } = &cfg.engine else {
            bail!("config {} does not use the synthetic engine", cfg.label);
        };
        Ok(SyntheticSpec {
            n: *dim,
            classes: 10,
            train_b: cfg.per_worker_batch(),
            eval_b: 32,
            seed: cfg.seed ^ 0x5EED,
        })
    }
}

impl EngineFactory for SyntheticSpec {
    fn build(&self) -> Result<Box<dyn GradEngine>> {
        Ok(Box::new(SyntheticEngine::new(
            self.n, self.classes, self.train_b, self.eval_b, self.seed,
        )))
    }
    fn describe(&self) -> String {
        format!("synthetic:n{}", self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grad_is_linear_in_params() {
        let mut e = SyntheticEngine::new(8, 3, 4, 8, 1);
        let y = vec![0, 1, 2, 0];
        let p1 = vec![0.5f32; 8];
        let p2 = vec![-1.0f32; 8];
        let mut g1 = vec![0.0f32; 8];
        let mut g2 = vec![0.0f32; 8];
        e.loss_and_grad(&p1, BatchX::F32(&[]), &y, 0, &mut g1).unwrap();
        e.loss_and_grad(&p2, BatchX::F32(&[]), &y, 0, &mut g2).unwrap();
        for i in 0..8 {
            assert!(((g1[i] - g2[i]) - (p1[i] - p2[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn synthetic_grad_descends() {
        let mut e = SyntheticEngine::new(8, 3, 4, 8, 1);
        let y = vec![1, 1, 1, 1];
        let mut p = vec![0.0f32; 8];
        let mut g = vec![0.0f32; 8];
        let l0 = e.loss_and_grad(&p, BatchX::F32(&[]), &y, 0, &mut g).unwrap();
        for _ in 0..50 {
            for (pi, &gi) in p.iter_mut().zip(&g) {
                *pi -= 0.2 * gi;
            }
            e.loss_and_grad(&p, BatchX::F32(&[]), &y, 0, &mut g).unwrap();
        }
        let l1 = e.loss_and_grad(&p, BatchX::F32(&[]), &y, 0, &mut g).unwrap();
        assert!(l1 < l0 * 0.1, "loss {l0} -> {l1}");
    }

    #[test]
    fn synthetic_eval_counts_mask() {
        let mut e = SyntheticEngine::new(8, 3, 4, 4, 1);
        // params exactly at target 0 -> class-0 rows are "correct"
        let p = e.targets()[0].clone();
        let y = vec![0, 0, 1, 0];
        let (_, c_all) = e
            .eval_batch_masked(&p, BatchX::F32(&[]), &y, &[1.0; 4])
            .unwrap();
        assert_eq!(c_all, 3.0);
        let (_, c_half) = e
            .eval_batch_masked(&p, BatchX::F32(&[]), &y, &[1.0, 1.0, 0.0, 0.0])
            .unwrap();
        assert_eq!(c_half, 2.0);
    }

    #[test]
    fn factory_builds() {
        let f = SyntheticSpec::default();
        let e = f.build().unwrap();
        assert_eq!(e.flat_size(), 16);
        assert_eq!(e.train_batch(), 8);
        assert!(f.describe().contains("synthetic"));
    }
}
