//! Vendored stand-in for the `xla-rs` PJRT surface.
//!
//! The crate's dependency set is intentionally empty (`anyhow` only): the
//! training path is pure rust, and the PJRT boundary is exercised only on
//! artifact-equipped boxes.  This module mirrors the exact `xla-rs` API
//! shape that [`super`] (the HLO engine) is written against, so the crate
//! **compiles and tests everywhere** — every constructor returns a
//! descriptive error, every downstream type is uninhabited (methods on
//! them are statically unreachable), and the HLO integration tests skip
//! themselves when `artifacts/` is absent.
//!
//! To run the real PJRT path, replace this module with the actual
//! dependency: delete the `#[path = "xla_stub.rs"] mod xla;` line in
//! `runtime/mod.rs` and add `xla = { git = "..." }` (the upstream
//! `xla-rs` bindings) to `Cargo.toml`.  No other code changes are needed
//! — the call sites are written against the real API.

#![allow(dead_code)]

const STUB: &str = "PJRT/XLA backend not linked: this build uses the vendored \
     stub (rust/src/runtime/xla_stub.rs). Swap in the real `xla-rs` crate \
     to execute HLO artifacts";

/// Error type formatted with `{:?}` at every call site.
pub struct XlaError(pub &'static str);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

type XlaResult<T> = Result<T, XlaError>;

/// Uninhabited marker: values of stub device types cannot exist, so their
/// methods are statically unreachable (bodies are `match self.void {}`).
enum Void {}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct PjRtClient {
    void: Void,
}

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(XlaError(STUB))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        match self.void {}
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        match self.void {}
    }
}

pub struct HloModuleProto {
    void: Void,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(XlaError(STUB))
    }
}

pub struct XlaComputation {
    void: Void,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.void {}
    }
}

pub struct PjRtLoadedExecutable {
    void: Void,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        match self.void {}
    }

    pub fn execute_b<T>(&self, _inputs: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        match self.void {}
    }
}

pub struct PjRtBuffer {
    void: Void,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        match self.void {}
    }
}

pub struct Literal {
    void: Void,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> XlaResult<Literal> {
        Err(XlaError(STUB))
    }

    pub fn scalar<T>(_v: T) -> Literal {
        unreachable!("xla stub: literals cannot be constructed")
    }

    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        match self.void {}
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        match self.void {}
    }

    pub fn to_tuple2(&self) -> XlaResult<(Literal, Literal)> {
        match self.void {}
    }

    pub fn get_first_element<T>(&self) -> XlaResult<T> {
        match self.void {}
    }

    pub fn copy_raw_to<T>(&self, _out: &mut [T]) -> XlaResult<()> {
        match self.void {}
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        match self.void {}
    }
}
