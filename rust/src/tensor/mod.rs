//! Flat parameter buffers and vectorized in-place math for the hot loop.
//!
//! Each worker's model parameters (and optimizer velocity) live in one
//! contiguous `Vec<f32>` — `FlatParams` — segmented per tensor according
//! to the manifest's `ParamSpec` layout.  All communication-related
//! updates (gossip, all-reduce, EASGD) and the NAG optimizer operate
//! directly on these flat buffers; only the PJRT boundary re-slices them
//! into per-tensor literals.

use crate::manifest::ModelMeta;

pub mod simd;

/// A worker's flat parameter (or velocity/gradient) buffer.
#[derive(Clone, Debug)]
pub struct FlatParams {
    data: Vec<f32>,
}

impl FlatParams {
    pub fn zeros(n: usize) -> Self {
        FlatParams { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        FlatParams { data }
    }

    /// Load raw little-endian f32s (the `<model>_init.bin` format
    /// emitted by aot.py).
    pub fn from_le_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(bytes.len() % 4 == 0, "init file not a multiple of 4 bytes");
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(FlatParams { data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// View one named tensor segment according to the model layout.
    pub fn segment<'a>(&'a self, meta: &ModelMeta, idx: usize) -> &'a [f32] {
        let p = &meta.params[idx];
        &self.data[p.offset..p.offset + p.size]
    }
}

// ---------------------------------------------------------------------------
// flat-vector kernels (the rust-native hot path)
// ---------------------------------------------------------------------------
// The cache-blocking (chunk sizes, accumulator layouts, per-element op
// order) lives here; the innermost bodies route through the
// runtime-dispatched SIMD layer in [`simd`] (AVX2 / NEON / scalar, with
// every vector path bit-identical to its scalar reference — see that
// module's docs for the contract).  `EG_FORCE_SCALAR=1` pins the scalar
// bodies.  An HLO (Pallas-lowered) path for the same ops exists behind
// runtime::KernelEngine for the kernel-parity ablation bench.

/// Elastic pair update (Eqs. 3.7/3.8), applied simultaneously:
/// `delta = alpha (a - b); a -= delta; b += delta`.
///
/// The same `delta` leaves `a` and enters `b` — elastic symmetry, the
/// invariant the thesis ties to EASGD's stability.
pub fn elastic_pair_update(a: &mut [f32], b: &mut [f32], alpha: f32) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        let delta = alpha * (*x - *y);
        *x -= delta;
        *y += delta;
    }
}

/// One-sided elastic pull: `a -= alpha * (a - b)` (b unmodified).
/// Used to apply a multi-peer set-K update from captured pre-round state.
pub fn elastic_pull(a: &mut [f32], b: &[f32], alpha: f32) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        *x -= alpha * (*x - y);
    }
}

/// Fused multi-peer elastic update (Eq. 3.5's sum term):
///
/// ```text
/// dst <- dst - alpha * SUM_{k} (snap_self - snaps[k])
/// ```
///
/// where `snap_self` is the worker's own pre-round snapshot (constant
/// through the call).  Instead of one full sweep over `dst` per peer —
/// the seed implementation, `|K|` round trips through memory — this
/// walks `dst` once in cache-sized chunks and applies every peer to the
/// resident chunk.  The per-element operation *order* is exactly the
/// per-peer reference loop's (peer k's term is subtracted k-th), so the
/// result is bit-identical to applying [`elastic_pull`]-style sweeps one
/// peer at a time; `rust/tests/proptests.rs` asserts this bit-for-bit.
pub fn elastic_multi_pull(dst: &mut [f32], snap_self: &[f32], snaps: &[&[f32]], alpha: f32) {
    assert_eq!(dst.len(), snap_self.len());
    for s in snaps {
        assert_eq!(s.len(), dst.len());
    }
    if snaps.is_empty() {
        return;
    }
    const CHUNK: usize = 512;
    let n = dst.len();
    let mut start = 0;
    while start < n {
        let end = (start + CHUNK).min(n);
        for s in snaps {
            simd::sub_scaled_diff(
                &mut dst[start..end],
                &snap_self[start..end],
                &s[start..end],
                alpha,
            );
        }
        start = end;
    }
}

/// Multi-peer elastic update fed through an accessor, batched into
/// GROUP-of-8 [`elastic_multi_pull`] calls — the single implementation
/// behind both the synchronous arena apply
/// ([`crate::algos::ScratchArena::elastic_apply`], peers from the
/// snapshot plane) and the asynchronous boundary apply (peers from
/// message buffers).  One shared body is what guarantees the two
/// regimes stay bit-identical in lockstep; per-element op order equals
/// the per-peer reference loop regardless of grouping (property-tested).
pub fn elastic_apply_grouped<'p>(
    dst: &mut [f32],
    snap_self: &[f32],
    n_peers: usize,
    peer: impl Fn(usize) -> &'p [f32],
    alpha: f32,
) {
    const GROUP: usize = 8;
    let mut g = 0;
    while g < n_peers {
        let take = (n_peers - g).min(GROUP);
        let mut refs: [&[f32]; GROUP] = [&[]; GROUP];
        for (slot, r) in refs.iter_mut().enumerate().take(take) {
            *r = peer(g + slot);
        }
        elastic_multi_pull(dst, snap_self, &refs[..take], alpha);
        g += take;
    }
}

/// `dst = 0.5 * (a + b)` — pull-gossip averaging from pre-round
/// snapshots (Algorithm 3 line 6).
pub fn average_into(dst: &mut [f32], a: &[f32], b: &[f32]) {
    assert!(dst.len() == a.len() && dst.len() == b.len());
    simd::average(dst, a, b);
}

/// `dst = 0.5 * (dst + other)` — the in-place form of [`average_into`]
/// used by the event-driven pull protocol, where the receiver's live
/// buffer *is* its pre-apply state.  Per-element op is the identical
/// `0.5 * (x + y)` expression, so when `dst` equals the snapshot the two
/// forms are bit-identical.
pub fn average_with(dst: &mut [f32], other: &[f32]) {
    assert_eq!(dst.len(), other.len());
    simd::average_in(dst, other);
}

/// Push-gossip receiver mean: `dst = mean({snap_self} ∪ peers)`, one
/// fused pass with a stack accumulator (no heap).  `peer(j)` yields the
/// j-th pusher's parameter snapshot; per-element accumulation order is
/// self first, then peers in index order, then one scale — both the
/// synchronous arena round ([`crate::algos::ScratchArena::push_mean_apply`])
/// and the asynchronous boundary apply route through this single
/// implementation, which is what makes them bit-identical in lockstep.
pub fn push_mean_into<'p>(
    dst: &mut [f32],
    snap_self: &[f32],
    n_peers: usize,
    peer: impl Fn(usize) -> &'p [f32],
) {
    if n_peers == 0 {
        return;
    }
    assert_eq!(dst.len(), snap_self.len());
    let inv = 1.0 / (n_peers + 1) as f32;
    const CHUNK: usize = 256;
    let n = dst.len();
    let mut acc = [0.0f32; CHUNK];
    let mut s = 0;
    while s < n {
        let e = (s + CHUNK).min(n);
        let m = e - s;
        acc[..m].copy_from_slice(&snap_self[s..e]);
        for j in 0..n_peers {
            simd::add_assign(&mut acc[..m], &peer(j)[s..e]);
        }
        simd::scale_into(&mut dst[s..e], &acc[..m], inv);
        s = e;
    }
}

/// GoSGD push-sum convex combination:
///
/// ```text
/// dst = (base * snap_self + SUM_j w_j * peer_j) / (base + SUM_j w_j)
/// ```
///
/// computed in f64 with a stack accumulator, chunk-fused; `peer(j)`
/// yields the j-th message's `(weight, params)`.  Returns the total
/// weight (the receiver's post-fold push-sum weight).  Shared by the
/// synchronous `apply_slot` and the asynchronous boundary apply — same
/// per-element op order (self term, then each message in arrival order,
/// then scale), so the two regimes are bit-identical in lockstep.
pub fn weighted_mean_into<'p>(
    dst: &mut [f32],
    snap_self: &[f32],
    base: f64,
    n_peers: usize,
    peer: impl Fn(usize) -> (f64, &'p [f32]),
) -> f64 {
    let mut total = base;
    for j in 0..n_peers {
        total += peer(j).0;
    }
    if n_peers == 0 {
        return total;
    }
    assert_eq!(dst.len(), snap_self.len());
    let inv = 1.0 / total;
    const CHUNK: usize = 128;
    let n = dst.len();
    let mut acc = [0.0f64; CHUNK];
    let mut s = 0;
    while s < n {
        let e = (s + CHUNK).min(n);
        let m = e - s;
        simd::wacc_set(&mut acc[..m], &snap_self[s..e], base);
        for j in 0..n_peers {
            let (wj, sj) = peer(j);
            simd::wacc_add(&mut acc[..m], &sj[s..e], wj);
        }
        simd::store_scaled(&mut dst[s..e], &acc[..m], inv);
        s = e;
    }
    total
}

// ---------------------------------------------------------------------------
// wire-codec kernels (quantize / dequantize / top-k select)
// ---------------------------------------------------------------------------
// The `comm::codec` subsystem compresses gossip payloads on the async
// fabric; these are its fused hot loops.  All three write into
// caller-owned buffers whose capacity persists across calls, so the
// codec path performs no heap allocation after warm-up (asserted by the
// fingerprint tests in `comm::codec`).

/// Per-chunk affine int8 quantization.
///
/// Wire layout, per `chunk`-sized block of `src` (the last block may be
/// short): `[min: f32 LE][scale: f32 LE][codes: u8 x block_len]` where
/// `scale = (max - min) / 255` and `code = round((x - min) / scale)`.
/// Total size: `src.len() + 8 * ceil(src.len() / chunk)` bytes.
///
/// Dequantized values satisfy `|x - x'| <= scale / 2` up to f32 rounding
/// — the per-chunk quantization bound the property suite asserts.  A
/// constant block (`max == min`) encodes `scale = 0` and reconstructs
/// exactly.  Behavior is unspecified for non-finite inputs.
///
/// The min/max fold runs [`simd::minmax`]'s strided-8 scheme and the
/// code loop runs [`simd::quant_codes`] — both bit-identical between
/// the scalar and vector dispatch paths.
pub fn quantize_q8_into(src: &[f32], chunk: usize, out: &mut Vec<u8>) {
    assert!(chunk > 0, "chunk must be positive");
    out.clear();
    out.reserve(src.len() + 8 * src.len().div_ceil(chunk));
    for block in src.chunks(chunk) {
        let (lo, hi) = simd::minmax(block);
        let range = hi - lo;
        // a subnormal range would overflow `inv` below; such a chunk is
        // constant to within 1e-38 and reconstructs as its minimum
        let scale = if range > f32::MIN_POSITIVE { range / 255.0 } else { 0.0 };
        out.extend_from_slice(&lo.to_le_bytes());
        out.extend_from_slice(&scale.to_le_bytes());
        let start = out.len();
        out.resize(start + block.len(), 0); // constant block stays all-zero codes
        if scale > 0.0 {
            let inv = 255.0 / range;
            simd::quant_codes(block, lo, inv, 255, &mut out[start..]);
        }
    }
}

/// Inverse of [`quantize_q8_into`]: `dst` supplies the expected element
/// count; errors if `bytes` is not exactly one q8 stream for that count.
pub fn dequantize_q8_into(bytes: &[u8], chunk: usize, dst: &mut [f32]) -> anyhow::Result<()> {
    assert!(chunk > 0, "chunk must be positive");
    let n = dst.len();
    let expect = n + 8 * n.div_ceil(chunk);
    anyhow::ensure!(
        bytes.len() == expect,
        "q8 stream is {} bytes, expected {expect} for {n} f32s (chunk {chunk})",
        bytes.len()
    );
    let mut off = 0usize;
    for block in dst.chunks_mut(chunk) {
        let lo = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        off += 8;
        simd::dequant_codes(&bytes[off..off + block.len()], lo, scale, block);
        off += block.len();
    }
    Ok(())
}

/// Exact wire size of [`quantize_q4_into`]'s stream for `n` elements:
/// an 8-byte header per chunk plus one byte per *pair* of codes, with
/// packing restarting at each chunk boundary (an odd-length chunk pads
/// its final high nibble).
pub fn q4_encoded_len(n: usize, chunk: usize) -> usize {
    assert!(chunk > 0, "chunk must be positive");
    let full = n / chunk;
    let rem = n % chunk;
    8 * n.div_ceil(chunk) + full * chunk.div_ceil(2) + rem.div_ceil(2)
}

/// Per-chunk affine 4-bit quantization — two codes per byte, breaking
/// q8's ~4x ceiling at ~8x (header-amortized; see
/// [`q4_encoded_len`]).
///
/// Wire layout, per `chunk`-sized block of `src` (the last block may be
/// short): `[min: f32 LE][scale: f32 LE][packed: u8 x ceil(len/2)]`
/// where `scale = (max - min) / 15` and `code = round((x - min) /
/// scale)`; the even-indexed element of each pair occupies the **low**
/// nibble, and an odd-length block's final high nibble is zero.
///
/// Error bound, constant-block exactness, and non-finite caveats mirror
/// [`quantize_q8_into`] with a step of `range / 15`.  The min/max fold
/// and the code computation share the q8 SIMD bodies (4-bit codes are
/// just `max_code = 15`); only the nibble pack is scalar.
pub fn quantize_q4_into(src: &[f32], chunk: usize, out: &mut Vec<u8>) {
    assert!(chunk > 0, "chunk must be positive");
    out.clear();
    out.reserve(q4_encoded_len(src.len(), chunk));
    // per-tile staging for the SIMD code loop; 256 is even, so every
    // tile starts at a fresh packed byte
    const TILE: usize = 256;
    let mut tile = [0u8; TILE];
    for block in src.chunks(chunk) {
        let (lo, hi) = simd::minmax(block);
        let range = hi - lo;
        let scale = if range > f32::MIN_POSITIVE { range / 15.0 } else { 0.0 };
        out.extend_from_slice(&lo.to_le_bytes());
        out.extend_from_slice(&scale.to_le_bytes());
        let start = out.len();
        out.resize(start + block.len().div_ceil(2), 0); // zero: pack ORs nibbles in
        if scale > 0.0 {
            let inv = 15.0 / range;
            let packed = &mut out[start..];
            for (t, sub) in block.chunks(TILE).enumerate() {
                let codes = &mut tile[..sub.len()];
                simd::quant_codes(sub, lo, inv, 15, codes);
                let pb = &mut packed[t * (TILE / 2)..];
                for (i, &c) in codes.iter().enumerate() {
                    pb[i / 2] |= c << ((i & 1) * 4);
                }
            }
        }
    }
}

/// Inverse of [`quantize_q4_into`]: `dst` supplies the expected element
/// count; errors if `bytes` is not exactly one q4 stream for that count.
pub fn dequantize_q4_into(bytes: &[u8], chunk: usize, dst: &mut [f32]) -> anyhow::Result<()> {
    assert!(chunk > 0, "chunk must be positive");
    let n = dst.len();
    let expect = q4_encoded_len(n, chunk);
    anyhow::ensure!(
        bytes.len() == expect,
        "q4 stream is {} bytes, expected {expect} for {n} f32s (chunk {chunk})",
        bytes.len()
    );
    const TILE: usize = 256;
    let mut tile = [0u8; TILE];
    let mut off = 0usize;
    for block in dst.chunks_mut(chunk) {
        let lo = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        off += 8;
        let packed = &bytes[off..off + block.len().div_ceil(2)];
        off += block.len().div_ceil(2);
        for (t, sub) in block.chunks_mut(TILE).enumerate() {
            let pb = &packed[t * (TILE / 2)..];
            let codes = &mut tile[..sub.len()];
            for (i, c) in codes.iter_mut().enumerate() {
                *c = (pb[i / 2] >> ((i & 1) * 4)) & 0x0f;
            }
            simd::dequant_codes(codes, lo, scale, sub);
        }
    }
    Ok(())
}

/// Select the `k` largest-magnitude entries of `scores`, writing their
/// indices into `idx` in ascending index order (the canonical wire
/// order, and cache-friendly for the scatter on decode).
///
/// Deterministic: ties break toward the lower index, so the selected
/// *set* is unique for any input — a requirement for reproducible
/// trajectories.  In-place partial selection over the reused `idx`
/// buffer; no allocation beyond `idx`'s high-water capacity.
pub fn top_k_select(scores: &[f32], k: usize, idx: &mut Vec<u32>) {
    let n = scores.len();
    idx.clear();
    idx.extend(0..n as u32);
    let k = k.min(n);
    if k == 0 {
        idx.clear();
        return;
    }
    if k < n {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            let (sa, sb) = (scores[a as usize].abs(), scores[b as usize].abs());
            sb.total_cmp(&sa).then_with(|| a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
}

/// `dst += src`.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d += s;
    }
}

/// `dst += c * src` (AXPY).
pub fn axpy(dst: &mut [f32], c: f32, src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d += c * s;
    }
}

/// `dst *= c`.
pub fn scale(dst: &mut [f32], c: f32) {
    for d in dst.iter_mut() {
        *d *= c;
    }
}

/// `dst = mean of rows` where `rows` are equal-length slices.
pub fn mean_of(rows: &[&[f32]], dst: &mut [f32]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f32;
    dst.copy_from_slice(rows[0]);
    for r in &rows[1..] {
        add_assign(dst, r);
    }
    scale(dst, inv);
}

/// Average two buffers into both (Gossiping-SGD line 6 with both sides —
/// the alpha=0.5 symmetric special case, computed once for bit-parity).
pub fn average_pair(a: &mut [f32], b: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        let m = 0.5 * (*x + *y);
        *x = m;
        *y = m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{ModelMeta, ParamSpec};
    use crate::manifest::Dtype;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "m".into(),
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![2, 2], size: 4, offset: 0 },
                ParamSpec { name: "b".into(), shape: vec![3], size: 3, offset: 4 },
            ],
            flat_size: 7,
            data_shape: vec![2],
            x_dtype: Dtype::F32,
            classes: 3,
            init_file: None,
        }
    }

    #[test]
    fn segments() {
        let p = FlatParams::from_vec((0..7).map(|i| i as f32).collect());
        let m = meta();
        assert_eq!(p.segment(&m, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(p.segment(&m, 1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn from_le_bytes_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = FlatParams::from_le_bytes(&bytes).unwrap();
        assert_eq!(p.as_slice(), &vals);
        assert!(FlatParams::from_le_bytes(&bytes[..5]).is_err());
    }

    #[test]
    fn elastic_pair_conserves_sum() {
        let mut a = vec![1.0, 2.0, 3.0];
        let mut b = vec![5.0, -2.0, 0.5];
        let sums: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        elastic_pair_update(&mut a, &mut b, 0.3);
        for i in 0..3 {
            assert!((a[i] + b[i] - sums[i]).abs() < 1e-6);
        }
        // alpha = 0.5 -> both become the average
        let mut a = vec![1.0f32, 3.0];
        let mut b = vec![3.0f32, 1.0];
        elastic_pair_update(&mut a, &mut b, 0.5);
        assert_eq!(a, vec![2.0, 2.0]);
        assert_eq!(b, vec![2.0, 2.0]);
    }

    #[test]
    fn elastic_extremes() {
        // Eq. 3.9: alpha=0 no-op, alpha=1 swap
        let a0 = vec![1.0f32, -4.0];
        let b0 = vec![2.5f32, 7.0];
        let (mut a, mut b) = (a0.clone(), b0.clone());
        elastic_pair_update(&mut a, &mut b, 0.0);
        assert_eq!((a.clone(), b.clone()), (a0.clone(), b0.clone()));
        elastic_pair_update(&mut a, &mut b, 1.0);
        assert_eq!(a, b0);
        assert_eq!(b, a0);
    }

    #[test]
    fn axpy_scale_mean() {
        let mut d = vec![1.0f32, 2.0];
        axpy(&mut d, 2.0, &[10.0, 20.0]);
        assert_eq!(d, vec![21.0, 42.0]);
        scale(&mut d, 0.5);
        assert_eq!(d, vec![10.5, 21.0]);
        let r1 = vec![1.0f32, 3.0];
        let r2 = vec![3.0f32, 5.0];
        let mut m = vec![0.0f32; 2];
        mean_of(&[&r1, &r2], &mut m);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn multi_pull_matches_sequential_per_peer() {
        let n = 1037; // force a ragged tail past the chunk width
        let mut rng = crate::util::rng::Rng::new(13);
        let snap_self: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
        let peers: Vec<Vec<f32>> = (0..5).map(|_| (0..n).map(|_| rng.gauss_f32()).collect()).collect();
        let refs: Vec<&[f32]> = peers.iter().map(|p| p.as_slice()).collect();
        let alpha = 0.3f32;

        let mut fused = snap_self.clone();
        elastic_multi_pull(&mut fused, &snap_self, &refs, alpha);

        let mut naive = snap_self.clone();
        for p in &peers {
            for ((t, &si), &sk) in naive.iter_mut().zip(&snap_self).zip(p) {
                *t -= alpha * (si - sk);
            }
        }
        assert_eq!(fused, naive, "fused kernel must be bit-identical");
    }

    #[test]
    fn multi_pull_no_peers_is_noop() {
        let mut dst = vec![1.0f32, 2.0];
        let snap = dst.clone();
        elastic_multi_pull(&mut dst, &snap, &[], 0.7);
        assert_eq!(dst, vec![1.0, 2.0]);
    }

    #[test]
    fn average_into_works() {
        let mut d = vec![0.0f32; 2];
        average_into(&mut d, &[0.0, 4.0], &[2.0, 0.0]);
        assert_eq!(d, vec![1.0, 2.0]);
    }

    #[test]
    fn average_pair_works() {
        let mut a = vec![0.0f32, 4.0];
        let mut b = vec![2.0f32, 0.0];
        average_pair(&mut a, &mut b);
        assert_eq!(a, vec![1.0, 2.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn average_with_matches_average_into_when_dst_is_snapshot() {
        let mut rng = crate::util::rng::Rng::new(21);
        let a: Vec<f32> = (0..301).map(|_| rng.gauss_f32()).collect();
        let b: Vec<f32> = (0..301).map(|_| rng.gauss_f32()).collect();
        let mut via_into = vec![0.0f32; a.len()];
        average_into(&mut via_into, &a, &b);
        let mut via_with = a.clone();
        average_with(&mut via_with, &b);
        assert_eq!(via_into, via_with, "must be bit-identical");
    }

    #[test]
    fn push_mean_into_matches_plain_mean() {
        let n = 517; // ragged tail past the chunk width
        let mut rng = crate::util::rng::Rng::new(7);
        let snap: Vec<f32> = (0..n).map(|_| rng.gauss_f32()).collect();
        let peers: Vec<Vec<f32>> = (0..3).map(|_| (0..n).map(|_| rng.gauss_f32()).collect()).collect();
        let mut dst = vec![0.0f32; n];
        push_mean_into(&mut dst, &snap, peers.len(), |j| peers[j].as_slice());
        for i in 0..n {
            let want = (snap[i] + peers[0][i] + peers[1][i] + peers[2][i]) / 4.0;
            assert!((dst[i] - want).abs() < 1e-5, "[{i}] {} vs {want}", dst[i]);
        }
        // zero peers is a no-op
        let orig = dst.clone();
        push_mean_into(&mut dst, &snap, 0, |_| unreachable!());
        assert_eq!(dst, orig);
    }

    #[test]
    fn q8_roundtrip_within_chunk_bound() {
        let mut rng = crate::util::rng::Rng::new(31);
        for &(n, chunk) in &[(1usize, 4usize), (7, 3), (256, 256), (1000, 64), (517, 512)] {
            let src: Vec<f32> = (0..n).map(|_| rng.gauss_f32() * 3.0).collect();
            let mut wire = Vec::new();
            quantize_q8_into(&src, chunk, &mut wire);
            assert_eq!(wire.len(), n + 8 * n.div_ceil(chunk));
            let mut back = vec![0.0f32; n];
            dequantize_q8_into(&wire, chunk, &mut back).unwrap();
            for (b0, (s, b)) in src.chunks(chunk).zip(back.chunks(chunk)).enumerate() {
                let lo = s.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let step = (hi - lo) / 255.0;
                let bound = step * 0.51 + 1e-6 * (lo.abs() + hi.abs() + 1.0);
                for (i, (&x, &y)) in s.iter().zip(b).enumerate() {
                    assert!(
                        (x - y).abs() <= bound,
                        "chunk {b0} [{i}]: {x} vs {y} exceeds bound {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn q8_constant_chunk_is_exact() {
        let src = vec![1.25f32; 10];
        let mut wire = Vec::new();
        quantize_q8_into(&src, 4, &mut wire);
        let mut back = vec![0.0f32; 10];
        dequantize_q8_into(&wire, 4, &mut back).unwrap();
        assert_eq!(src, back);
        // wrong stream length is rejected
        let mut short = vec![0.0f32; 9];
        assert!(dequantize_q8_into(&wire, 4, &mut short).is_err());
    }

    #[test]
    fn q4_roundtrip_within_chunk_bound() {
        let mut rng = crate::util::rng::Rng::new(47);
        // odd lengths, odd chunks, and chunk > n all exercise the
        // per-chunk nibble-pack restart
        for &(n, chunk) in &[(1usize, 4usize), (7, 3), (256, 256), (1000, 64), (517, 512), (9, 100)]
        {
            let src: Vec<f32> = (0..n).map(|_| rng.gauss_f32() * 3.0).collect();
            let mut wire = Vec::new();
            quantize_q4_into(&src, chunk, &mut wire);
            assert_eq!(wire.len(), q4_encoded_len(n, chunk));
            let mut back = vec![0.0f32; n];
            dequantize_q4_into(&wire, chunk, &mut back).unwrap();
            for (b0, (s, b)) in src.chunks(chunk).zip(back.chunks(chunk)).enumerate() {
                let lo = s.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let step = (hi - lo) / 15.0;
                let bound = step * 0.51 + 1e-6 * (lo.abs() + hi.abs() + 1.0);
                for (i, (&x, &y)) in s.iter().zip(b).enumerate() {
                    assert!(
                        (x - y).abs() <= bound,
                        "chunk {b0} [{i}]: {x} vs {y} exceeds bound {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn q4_constant_chunk_is_exact() {
        let src = vec![-3.75f32; 11];
        let mut wire = Vec::new();
        quantize_q4_into(&src, 4, &mut wire);
        let mut back = vec![0.0f32; 11];
        dequantize_q4_into(&wire, 4, &mut back).unwrap();
        assert_eq!(src, back);
        // wrong stream length is rejected
        let mut short = vec![0.0f32; 10];
        assert!(dequantize_q4_into(&wire, 4, &mut short).is_err());
    }

    #[test]
    fn q4_encoded_len_counts_chunk_padding() {
        // even chunk: pairs never straddle chunks, so bytes = ceil(n/2)
        assert_eq!(q4_encoded_len(10, 4), 8 * 3 + 5);
        // odd chunk: each full chunk pads its final nibble
        assert_eq!(q4_encoded_len(10, 3), 8 * 4 + 2 + 2 + 2 + 1);
        assert_eq!(q4_encoded_len(0, 7), 0);
    }

    #[test]
    fn top_k_select_picks_largest_magnitudes() {
        let scores = vec![0.1f32, -5.0, 2.0, -2.0, 0.0, 3.5];
        let mut idx = Vec::new();
        top_k_select(&scores, 3, &mut idx);
        assert_eq!(idx, vec![1, 2, 5]); // |-5|, |3.5|, |2| — ascending index order
        // ties break toward the lower index: |2.0| at 2 beats |-2.0| at 3
        top_k_select(&scores, 2, &mut idx);
        assert_eq!(idx, vec![1, 5]);
        top_k_select(&scores, 0, &mut idx);
        assert!(idx.is_empty());
        top_k_select(&scores, 99, &mut idx);
        assert_eq!(idx, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn weighted_mean_into_convex_combination() {
        let n = 139; // one ragged chunk
        let snap = vec![2.0f32; n];
        let peer = vec![6.0f32; n];
        let mut dst = vec![0.0f32; n];
        let total = weighted_mean_into(&mut dst, &snap, 0.25, 1, |_| (0.75, peer.as_slice()));
        assert!((total - 1.0).abs() < 1e-12);
        for &d in &dst {
            // 0.25*2 + 0.75*6 = 5.0
            assert!((d - 5.0).abs() < 1e-6, "{d}");
        }
        // zero peers: dst untouched, total == base
        let orig = dst.clone();
        let t = weighted_mean_into(&mut dst, &snap, 0.5, 0, |_| unreachable!());
        assert_eq!(dst, orig);
        assert_eq!(t, 0.5);
    }
}
